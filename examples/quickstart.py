"""Quickstart: the public API in ~60 lines.

Builds a small LLaMA-family model, trains it with ElasticZO (ZO body +
BP tail), then serves it (prefill + greedy decode).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import LaneConfig, ShapeConfig, get_arch, reduced
from repro.core import api
from repro.core.elastic import TrainState
from repro.data.synthetic import token_batch
from repro.sharding.rules import ShardingRules

# 1. pick an architecture (any of the 10 assigned ids) and reduce it to a
#    laptop-size config of the same family
cfg = reduced(get_arch("llama3-8b"), num_layers=4, d_model=128, d_ff=256)

# 2. the training lane: ElasticZO = ZO for the body, BP for the last layer
lane = LaneConfig(lane="elastic_zo", bp_tail_layers=1,
                  learning_rate=5e-2, zo_eps=1e-3, zo_num_probes=2)

shape = ShapeConfig("quickstart", seq_len=128, global_batch=8, kind="train")
rules = ShardingRules(None, cfg, shape)       # None mesh = single device
model = api.build(cfg, shape, lane, rules)

params = model.init(jax.random.key(0))
state = TrainState(params, jnp.int32(0),
                   jax.random.key_data(jax.random.key(1)))
step = jax.jit(model.train_step, donate_argnums=(0,))

print(f"training {cfg.name}: "
      f"{sum(x.size for x in jax.tree.leaves(params)):,} params, lane={lane.lane}")
for i in range(40):
    x, y, m = token_batch(8, 128, cfg.vocab_size, seed=0, step=i)
    batch = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y),
             "mask": jnp.asarray(m)}
    state, metrics = step(state, batch, jnp.ones((2,), jnp.float32))
    if i % 10 == 0:
        print(f"  step {i:3d}  loss {float(metrics['loss']):.4f}  "
              f"|g|={float(metrics['zo_g']):.3f}")

# 3. serve it: prefill a prompt, then decode greedily with the KV cache
pshape = ShapeConfig("qs_p", seq_len=144, global_batch=2, kind="prefill")
dshape = ShapeConfig("qs_d", seq_len=144, global_batch=2, kind="decode")
server_p = api.build(cfg, pshape, lane, ShardingRules(None, cfg, pshape))
server_d = api.build(cfg, dshape, lane, ShardingRules(None, cfg, dshape))

prompt = jnp.asarray(token_batch(2, 128, cfg.vocab_size, seed=5)[0])
next_tok, caches = jax.jit(server_p.prefill_step)(state.params,
                                                  {"tokens": prompt})
caches = jax.tree.map(
    lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, 16), (0, 0), (0, 0)])
    if a.ndim == 5 and a.shape[2] == 128 else a, caches)
decode = jax.jit(server_d.decode_step, donate_argnums=(2,))
out = [next_tok]
for t in range(8):
    next_tok, caches = decode(state.params, next_tok, caches,
                              jnp.int32(128 + t))
    out.append(next_tok)
print("decoded:", [int(t[0, 0]) for t in out])
print("quickstart OK")
