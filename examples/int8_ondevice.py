"""ElasticZO-INT8 (Alg. 2): integer-arithmetic-only on-device learning.

Trains the int8 LeNet-5 with the ternary integer loss-sign gradient
(INT8*, §4.3) and the NITI int8 BP tail — no float op touches the model
path (the fp32 numbers printed are evaluation-only).

    PYTHONPATH=src python examples/int8_ondevice.py
"""
import jax
import jax.numpy as jnp

from repro.configs import LaneConfig
from repro.core.elastic import TrainState
from repro.core.elastic_int8 import int8_eval, make_int8_elastic_step
from repro.core.int8 import quant_from_float
from repro.data.synthetic import glyphs
from repro.models import lenet


def main(steps=400, batch=64):
    lane = LaneConfig(int8_r_max=3, int8_p_zero=0.33, int8_b_zo=1,
                      int8_b_bp=5)
    # ZO-Feat-Cls1: convs+fc1+fc2 via integer ZO, fc3 via integer BP
    step = jax.jit(make_int8_elastic_step(
        lenet.lenet5_forward_int8,
        partition_fn=lambda p: lenet.partition_at(p, 4),
        tail_fcs=[("fc3", "fc3_in")], lane=lane, loss_mode="int"))

    params = lenet.init_lenet5_int8(jax.random.key(0))
    state = TrainState(params, jnp.int32(0),
                       jax.random.key_data(jax.random.key(2)))
    xs_tr, ys_tr = glyphs(2048, seed=0)
    xs_te, ys_te = glyphs(512, seed=1, start=10_000)
    qx_te, y_te = quant_from_float(jnp.asarray(xs_te)), jnp.asarray(ys_te)

    # the paper's p_zero schedule: 0.33 -> 0.5 -> 0.9
    for s in range(steps):
        i0 = (s * batch) % 2048
        bx = quant_from_float(jnp.asarray(xs_tr[i0:i0 + batch]))
        by = jnp.asarray(ys_tr[i0:i0 + batch])
        state, m = step(state, {"x": bx, "y": by}, jnp.ones((1,)))
        if s % (steps // 8) == 0:
            acc = int8_eval(lenet.lenet5_forward_int8, state.params,
                            qx_te, y_te)
            print(f"step {s:4d}  train-loss {float(m['loss']):.3f} "
                  f" test-acc {float(acc)*100:.1f}%  g={int(m['g'])}")
    acc = float(int8_eval(lenet.lenet5_forward_int8, state.params,
                          qx_te, y_te))
    print(f"final int8* test accuracy: {acc*100:.1f}%")
    assert acc > 0.5, "integer-only training should beat chance by far"
    print("int8_ondevice OK")


if __name__ == "__main__":
    main()
