"""LM-scale ElasticZO: the paper's technique on a transformer LM.

Compares the three lanes (full_zo / elastic_zo / full_bp) on a reduced
llama3-family config, demonstrating the paper's central claim at LM scale:
the hybrid recovers most of the BP convergence while the ZO part needs no
gradient memory or gradient communication (its only cross-device traffic
is a scalar per probe).

    PYTHONPATH=src python examples/lm_zo_finetune.py [--steps N]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import LaneConfig, ShapeConfig, get_arch, reduced
from repro.core import api
from repro.core.elastic import TrainState
from repro.data.synthetic import token_batch
from repro.sharding.rules import ShardingRules


def run_lane(lane_name, cfg, shape, steps, probes=4):
    # per-lane lr, as the paper tunes per experiment: ZO needs a far
    # smaller step than BP (SPSA step variance scales with dim)
    zo_lr = 2e-3 if lane_name != "full_bp" else 0.05
    lane = LaneConfig(lane=lane_name, bp_tail_layers=1, learning_rate=zo_lr,
                      tail_learning_rate=0.05, zo_eps=1e-2,
                      zo_num_probes=probes,
                      lr_decay_factor=0.8, lr_decay_every=max(steps // 10, 1))
    rules = ShardingRules(None, cfg, shape)
    model = api.build(cfg, shape, lane, rules)
    params = model.init(jax.random.key(0))
    state = TrainState(params, jnp.int32(0),
                       jax.random.key_data(jax.random.key(1)))
    step = jax.jit(model.train_step, donate_argnums=(0,))
    pm = jnp.ones((probes,), jnp.float32)
    losses = []
    for i in range(steps):
        x, y, m = token_batch(shape.global_batch, shape.seq_len,
                              cfg.vocab_size, seed=3, step=i % 4)
        batch = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y),
                 "mask": jnp.asarray(m)}
        state, metrics = step(state, batch, pm)
        losses.append(float(metrics["loss"]))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    cfg = reduced(get_arch("llama3-8b"), num_layers=4, d_model=128,
                  d_ff=256, vocab_size=512)
    shape = ShapeConfig("ft", seq_len=64, global_batch=8, kind="train")
    print(f"config: {cfg.name} L={cfg.num_layers} d={cfg.d_model}")
    results = {}
    for lane in ("full_zo", "elastic_zo", "full_bp"):
        losses = run_lane(lane, cfg, shape, args.steps)
        results[lane] = losses
        print(f"{lane:11s}: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    # paper ordering: elastic between zo and bp
    drop = {k: v[0] - min(v) for k, v in results.items()}
    print("loss drops:", {k: f"{v:.3f}" for k, v in drop.items()})
    assert drop["elastic_zo"] >= drop["full_zo"] - 0.05, \
        "elastic should converge at least as fast as pure ZO"
    print("lm_zo_finetune OK")


if __name__ == "__main__":
    main()
