"""Paper Table 2: fine-tuning under distribution shift (rotated images).

Pre-trains LeNet-5 with BP on upright glyphs, then fine-tunes on rotated
glyphs with each lane (Full ZO / ZO-Feat-Cls2 / ZO-Feat-Cls1 / Full BP),
reproducing the paper's ordering: the hybrid lanes recover most of the
Full-BP accuracy at ZO-like cost.

    PYTHONPATH=src python examples/finetune_rotated.py [--steps N]
"""
import argparse

import jax
import jax.numpy as jnp

from benchmarks.paper_tables import lenet_lanes
from repro.configs import LaneConfig
from repro.core.elastic import TrainState, make_elastic_step
from repro.data.synthetic import glyphs
from repro.models import lenet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--deg", type=float, default=45.0)
    args = ap.parse_args()

    # --- pretrain (BP, upright) ---------------------------------------- #
    params = lenet.init_lenet5(jax.random.key(7))
    lane = LaneConfig(lane="full_bp", learning_rate=0.05)
    step = jax.jit(make_elastic_step(lenet.lenet5_loss, lane))
    state = TrainState(params, jnp.int32(0),
                       jax.random.key_data(jax.random.key(1)))
    xs, ys = glyphs(2048, seed=0)
    for s in range(args.steps):
        i0 = (s * 32) % 2048
        state, _ = step(state, {"x": jnp.asarray(xs[i0:i0 + 32]),
                                "y": jnp.asarray(ys[i0:i0 + 32])},
                        jnp.ones((1,), jnp.float32))
    pre = state.params

    xs_r, ys_r = glyphs(512, seed=5, rotate_deg=args.deg, start=20_000)
    logits, _ = lenet.lenet5_forward(pre, jnp.asarray(xs_r))
    acc0 = float(jnp.mean((jnp.argmax(logits, -1) == jnp.asarray(ys_r))
                          .astype(jnp.float32)))
    print(f"w/o fine-tuning @ {args.deg}deg: {acc0*100:.1f}%")

    # --- fine-tune with every lane -------------------------------------- #
    res = lenet_lanes(steps=args.steps, rotate=args.deg, init_params=pre,
                      zo_lr=0.01)
    for k in ("full_zo", "zo_feat_cls2", "zo_feat_cls1", "full_bp"):
        print(f"{k:14s}: {res[k][0]*100:5.1f}%")
    assert res["zo_feat_cls1"][0] >= res["full_zo"][0] - 0.02, \
        "hybrid should not be worse than pure ZO"
    print("finetune_rotated OK")


if __name__ == "__main__":
    main()
