"""Paper-faithful experiment harnesses (Tables 1-2, Figs. 2-7 analogs).

Datasets are the deterministic synthetic stand-ins (docs/design.md §9); the
claims being reproduced are the *orderings and gaps between lanes*
(Full BP > ZO-Feat-Cls1 > ZO-Feat-Cls2 > Full ZO), the memory accounting
(Eqs. 2-4, 13-15 evaluated exactly), the INT8 speed/memory ratios, and the
~95% integer sign agreement.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import LaneConfig
from repro.configs.paper_models import LeNet5Config, PointNetConfig
from repro.core.elastic import TrainState, make_elastic_step
from repro.core.elastic_int8 import make_int8_elastic_step, int8_eval
from repro.core.int8 import QTensor, quant_from_float
from repro.core.int_loss import float_loss, int_loss_sign
from repro.data.synthetic import glyphs, point_clouds
from repro.models import lenet, pointnet


# ------------------------------------------------------------------ #
# Table 1 analog: accuracy by lane
# ------------------------------------------------------------------ #
def _eval_lenet(params, xs, ys):
    logits, _ = lenet.lenet5_forward(params, xs)
    return float(jnp.mean((jnp.argmax(logits, -1) == ys).astype(jnp.float32)))


def lenet_lane_configs(steps=600, lr=0.05, zo_lr=5e-3, eps=1e-2, probes=4
                       ) -> List[Tuple[str, LaneConfig, int]]:
    """The four paper lanes as (name, LaneConfig, partition point C) —
    shared by the accuracy harness and the measured-memory harness so
    the two can never drift apart."""
    dk = dict(lr_decay_factor=0.8, lr_decay_every=max(steps // 10, 1))
    return [
        ("full_zo", LaneConfig(lane="full_zo", learning_rate=zo_lr,
                               zo_eps=eps, zo_num_probes=probes, **dk), 5),
        ("zo_feat_cls2", LaneConfig(lane="elastic_zo", learning_rate=zo_lr,
                                    tail_learning_rate=lr, zo_eps=eps,
                                    zo_num_probes=probes, **dk), 3),
        ("zo_feat_cls1", LaneConfig(lane="elastic_zo", learning_rate=zo_lr,
                                    tail_learning_rate=lr, zo_eps=eps,
                                    zo_num_probes=probes, **dk), 4),
        ("full_bp", LaneConfig(lane="full_bp", learning_rate=lr, **dk), 0),
    ]


# INT8/INT8* lanes (Alg. 2): (name, partition point C, tail FCs)
INT8_LANES = [
    ("full_zo", 5, []),
    ("zo_feat_cls2", 3, [("fc2", "fc2_in"), ("fc3", "fc3_in")]),
    ("zo_feat_cls1", 4, [("fc3", "fc3_in")]),
]


def _int8_lane_cfg() -> LaneConfig:
    return LaneConfig(int8_r_max=3, int8_p_zero=0.33, int8_b_zo=1,
                      int8_b_bp=5)


def lenet_lanes(steps=600, batch=32, train_n=2048, test_n=512, seed=0,
                lr=0.05, zo_lr=5e-3, eps=1e-2, rotate=0.0, init_params=None,
                probes=4):
    """Returns {lane: (test_acc, loss_curve)} for the four paper lanes."""
    xs_tr, ys_tr = glyphs(train_n, seed=seed, rotate_deg=rotate)
    xs_te, ys_te = glyphs(test_n, seed=seed + 1, start=10_000,
                          rotate_deg=rotate)
    xs_te, ys_te = jnp.asarray(xs_te), jnp.asarray(ys_te)
    results = {}
    cfgs = lenet_lane_configs(steps=steps, lr=lr, zo_lr=zo_lr, eps=eps,
                              probes=probes)
    for name, lane, c in cfgs:
        params = init_params or lenet.init_lenet5(jax.random.key(7))
        part = (lambda p, c=c: lenet.partition_at(p, c)) \
            if lane.lane == "elastic_zo" else None
        step = jax.jit(make_elastic_step(lenet.lenet5_loss, lane,
                                         partition_fn=part))
        state = TrainState(params, jnp.int32(0),
                           jax.random.key_data(jax.random.key(11)))
        pm = jnp.ones((lane.zo_num_probes,), jnp.float32)
        curve = []
        for s in range(steps):
            i0 = (s * batch) % train_n
            bx = jnp.asarray(xs_tr[i0:i0 + batch])
            by = jnp.asarray(ys_tr[i0:i0 + batch])
            state, m = step(state, {"x": bx, "y": by}, pm)
            if s % max(steps // 20, 1) == 0:
                curve.append(float(m["loss"]))
        acc = _eval_lenet(state.params, xs_te, ys_te)
        results[name] = (acc, curve)
    return results


def lenet_int8_lanes(steps=600, batch=64, train_n=2048, test_n=512, seed=0,
                     loss_mode="int"):
    """INT8/INT8* lanes (Alg. 2)."""
    xs_tr, ys_tr = glyphs(train_n, seed=seed)
    xs_te, ys_te = glyphs(test_n, seed=seed + 1, start=10_000)
    qx_te = quant_from_float(jnp.asarray(xs_te))
    results = {}
    for name, c, tail in INT8_LANES:
        lane = _int8_lane_cfg()
        step = jax.jit(make_int8_elastic_step(
            lenet.lenet5_forward_int8,
            partition_fn=lambda p, c=c: lenet.partition_at(p, c),
            tail_fcs=tail, lane=lane, loss_mode=loss_mode))
        params = lenet.init_lenet5_int8(jax.random.key(7))
        state = TrainState(params, jnp.int32(0),
                           jax.random.key_data(jax.random.key(13)))
        for s in range(steps):
            i0 = (s * batch) % train_n
            bx = quant_from_float(jnp.asarray(xs_tr[i0:i0 + batch]))
            by = jnp.asarray(ys_tr[i0:i0 + batch])
            state, m = step(state, {"x": bx, "y": by},
                            jnp.ones((1,), jnp.float32))
        acc = float(int8_eval(lenet.lenet5_forward_int8, state.params,
                              qx_te, ys_te))
        results[name] = (acc, [])
    return results


def pointnet_lanes(steps=400, batch=32, train_n=1024, test_n=256,
                   num_points=256, classes=8):
    cfg = PointNetConfig(num_classes=classes, num_points=num_points)
    xs_tr, ys_tr = point_clouds(train_n, num_points, seed=3,
                                num_classes=classes)
    xs_te, ys_te = point_clouds(test_n, num_points, seed=4, start=50_000,
                                num_classes=classes)
    xs_te, ys_te = jnp.asarray(xs_te), jnp.asarray(ys_te)
    results = {}
    dk = dict(lr_decay_factor=0.8, lr_decay_every=max(steps // 10, 1))
    for name, lanecfg, c in [
        ("full_zo", LaneConfig(lane="full_zo", learning_rate=5e-3,
                               zo_eps=1e-2, zo_num_probes=4, **dk), 8),
        ("zo_feat_cls2", LaneConfig(lane="elastic_zo", learning_rate=5e-3,
                                    tail_learning_rate=0.05, zo_eps=1e-2,
                                    zo_num_probes=4, **dk), 6),
        ("zo_feat_cls1", LaneConfig(lane="elastic_zo", learning_rate=5e-3,
                                    tail_learning_rate=0.05, zo_eps=1e-2,
                                    zo_num_probes=4, **dk), 7),
        ("full_bp", LaneConfig(lane="full_bp", learning_rate=0.05, **dk), 0),
    ]:
        params = pointnet.init_pointnet(jax.random.key(5), cfg)
        part = (lambda p, c=c: pointnet.partition_at(p, c)) \
            if lanecfg.lane == "elastic_zo" else None
        step = jax.jit(make_elastic_step(pointnet.pointnet_loss, lanecfg,
                                         partition_fn=part))
        state = TrainState(params, jnp.int32(0),
                           jax.random.key_data(jax.random.key(17)))
        pm = jnp.ones((lanecfg.zo_num_probes,), jnp.float32)
        for s in range(steps):
            i0 = (s * batch) % train_n
            state, m = step(state, {"x": jnp.asarray(xs_tr[i0:i0 + batch]),
                                    "y": jnp.asarray(ys_tr[i0:i0 + batch])}, pm)
        logits, _ = pointnet.pointnet_forward(state.params, xs_te)
        acc = float(jnp.mean((jnp.argmax(logits, -1) == ys_te)
                             .astype(jnp.float32)))
        results[name] = (acc, [])
    return results


# ------------------------------------------------------------------ #
# Figs. 4-6 analog: memory accounting, Eqs. 2-4 / 13-15 evaluated exactly
# ------------------------------------------------------------------ #
def lenet_memory_table(batch: int) -> Dict[str, Dict[str, float]]:
    """Exact evaluation of the paper's memory model for LeNet-5."""
    cfg = LeNet5Config()
    c1, c2 = cfg.conv_channels
    # activation sizes per layer (fp32 elements, batch included)
    acts = {
        "conv1": batch * 28 * 28 * c1, "pool1": batch * 14 * 14 * c1,
        "conv2": batch * 14 * 14 * c2, "pool2": batch * 7 * 7 * c2,
        "fc1": batch * 120, "fc2": batch * 84, "fc3": batch * 10,
    }
    thetas = {
        "conv1": 5 * 5 * 1 * c1 + c1, "conv2": 5 * 5 * c1 * c2 + c2,
        "fc1": 784 * 120 + 120, "fc2": 120 * 84 + 84, "fc3": 84 * 10 + 10,
    }
    trainable = list(thetas)
    A = sum(acts.values())
    TH = sum(thetas.values())

    def mem_fp32(c):                       # Eq. 2-4, bytes (fp32 = 4B)
        tail = trainable[c:]
        g = sum(thetas[l] for l in tail)   # gradients of tail params
        e = sum(acts[l] for l in tail)     # errors of tail layers
        return 4 * (TH + A + g + e)

    def mem_int8(c, reuse_scratch: bool):
        """Eq. 13-15. ``reuse_scratch=False`` is the paper's no-lifetime
        accounting (every int32 accumulator held simultaneously);
        ``True`` models the real implementation where the int32 scratch is
        rounded to int8 immediately and one buffer is reused across layers
        (this is what reproduces the paper's measured 1.46-1.60x)."""
        tail = trainable[c:]
        g8 = sum(thetas[l] for l in tail)
        e8 = sum(acts[l] for l in tail)
        if reuse_scratch:
            a32 = max(acts[l] for l in trainable)
            g32 = max((thetas[l] for l in tail), default=0)
            e32 = max((acts[l] for l in tail), default=0)
        else:
            a32 = sum(acts[l] for l in trainable)
            g32 = sum(thetas[l] for l in tail)
            e32 = sum(acts[l] for l in tail)
        return (TH + A + g8 + e8) + 4 * (a32 + g32 + e32)

    rows = {}
    for name, c in [("full_bp", 0), ("zo_feat_cls1", 4), ("zo_feat_cls2", 3),
                    ("full_zo", 5)]:
        rows[name] = {"fp32_bytes": mem_fp32(c),
                      "int8_bytes": mem_int8(c, False),
                      "int8_reused_bytes": mem_int8(c, True)}
    return rows


def pointnet_memory_table(batch: int, num_points=1024):
    cfg = PointNetConfig()
    dims = (3,) + cfg.feat_dims
    acts = {f"feat{i}": batch * num_points * dims[i + 1] for i in range(5)}
    acts["pool"] = batch * 1024
    hd = (1024,) + cfg.head_dims + (cfg.num_classes,)
    for i, n in enumerate(("head0", "head1", "cls")):
        acts[n] = batch * hd[i + 1]
    thetas = {f"feat{i}": dims[i] * dims[i + 1] + dims[i + 1] for i in range(5)}
    for i, n in enumerate(("head0", "head1", "cls")):
        thetas[n] = hd[i] * hd[i + 1] + hd[i + 1]
    trainable = list(thetas)
    A, TH = sum(acts.values()), sum(thetas.values())

    def mem(c):
        tail = trainable[c:]
        g = sum(thetas[l] for l in tail)
        e = sum(acts[l] for l in tail)
        return 4 * (TH + A + g + e)

    return {"full_bp": {"fp32_bytes": mem(0)},
            "zo_feat_cls1": {"fp32_bytes": mem(7)},
            "zo_feat_cls2": {"fp32_bytes": mem(6)},
            "full_zo": {"fp32_bytes": mem(8)},
            "theta_bytes": 4 * TH, "act_bytes": 4 * A}


# ------------------------------------------------------------------ #
# measured memory: XLA buffer assignment per lane, next to Eqs. 2-4/13-15
# ------------------------------------------------------------------ #
def lenet_measured_memory(batch: int = 32) -> Dict[str, Dict[str, int]]:
    """MEASURED per-lane step footprint for the four fp32 paper lanes.

    Lowers and compiles (never runs) the exact production train step —
    same ``make_elastic_step`` program, same state donation as the train
    loop — and reads XLA's buffer-assignment stats
    (core/engine.step_memory_analysis). Returns
    {lane: {argument_bytes, output_bytes, temp_bytes, alias_bytes,
    peak_bytes, ...}}; benchmarks/run.py places ``peak_bytes`` next to
    ``lenet_memory_table``'s Eq. 2-4 value and reports the residual.
    """
    from repro.core.engine import step_memory_analysis
    xs, ys = glyphs(batch, seed=0)
    batch_d = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
    rows = {}
    for name, lane, c in lenet_lane_configs():
        params = lenet.init_lenet5(jax.random.key(7))
        part = (lambda p, c=c: lenet.partition_at(p, c)) \
            if lane.lane == "elastic_zo" else None
        step = make_elastic_step(lenet.lenet5_loss, lane, partition_fn=part)
        state = TrainState(params, jnp.int32(0),
                           jax.random.key_data(jax.random.key(11)))
        rows[name] = step_memory_analysis(
            step, state, batch_d, np.ones((lane.zo_num_probes,), np.float32))
    return rows


def lenet_int8_measured_memory(batch: int = 32) -> Dict[str, Dict[str, int]]:
    """MEASURED per-lane step footprint for the INT8/INT8* lanes (Alg. 2).

    Same instrument as ``lenet_measured_memory`` over the int8 step.
    Reconciliation caveat: this build *simulates* int8 in XLA (int8
    storage but int32/float32 compute upcasts throughout), so the
    measured peak lands well ABOVE Eq. 13-15 — and above the fp32 lane —
    unlike the paper's hand-managed MCU buffers. The residual reported
    in BENCH_paper.json quantifies exactly that simulation overhead;
    the analytic table remains the paper-faithful number.
    """
    from repro.core.engine import step_memory_analysis
    xs, ys = glyphs(batch, seed=0)
    batch_d = {"x": quant_from_float(jnp.asarray(xs)), "y": jnp.asarray(ys)}
    rows = {}
    for name, c, tail in INT8_LANES:
        step = make_int8_elastic_step(
            lenet.lenet5_forward_int8,
            partition_fn=lambda p, c=c: lenet.partition_at(p, c),
            tail_fcs=tail, lane=_int8_lane_cfg(), loss_mode="int")
        params = lenet.init_lenet5_int8(jax.random.key(7))
        state = TrainState(params, jnp.int32(0),
                           jax.random.key_data(jax.random.key(13)))
        rows[name] = step_memory_analysis(step, state, batch_d,
                                          np.ones((1,), np.float32))
    return rows


# ------------------------------------------------------------------ #
# Fig. 7 analog: step-time breakdown (wall clock on this host)
# ------------------------------------------------------------------ #
def steptime_breakdown(batch=64, iters=20):
    xs, ys = glyphs(batch, seed=0)
    out = {}
    # fp32 phases
    params = lenet.init_lenet5(jax.random.key(0))
    from repro.core import zo
    key = jax.random.key(1)
    fwd = jax.jit(lambda p, x: lenet.lenet5_forward(p, x)[0])
    pert = jax.jit(lambda p: zo.perturb(p, key, 1e-3))
    upd = jax.jit(lambda p: zo.zo_update(p, key, 1e-4))
    bx = jnp.asarray(xs)

    def t(f, *a):
        f(*a)                              # compile+warm
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(f(*a))
        return (time.perf_counter() - t0) / iters * 1e6

    out["fp32_forward_us"] = t(fwd, params, bx) * 2   # two passes per step
    out["fp32_perturb_us"] = t(pert, params) * 2
    out["fp32_update_us"] = t(upd, params)
    tail_loss = jax.jit(jax.grad(
        lambda bp, x, y: lenet.lenet5_loss({**params, **bp}, {"x": x, "y": y})))
    bp_part = {n: params[n] for n in ("fc3",)}
    out["fp32_bp_tail_us"] = t(tail_loss, bp_part, bx, jnp.asarray(ys))

    # int8 phases
    qparams = lenet.init_lenet5_int8(jax.random.key(0))
    qx = quant_from_float(bx)
    from repro.core.int8 import perturb_int8
    from repro.core import prng
    seed = prng.seed_from_key(key)
    qfwd = jax.jit(lambda p, x: lenet.lenet5_forward_int8(p, x)[0].data)
    qpert = jax.jit(lambda p: perturb_int8(p, seed, 1, 3, jnp.float32(0.33)))
    out["int8_forward_us"] = t(qfwd, qparams, qx) * 2
    out["int8_perturb_us"] = t(qpert, qparams) * 2
    return out


# ------------------------------------------------------------------ #
# §4.3 claim: integer sign agreement rate
# ------------------------------------------------------------------ #
def sign_agreement(trials=500, classes=10, seed=0):
    rng = np.random.default_rng(seed)
    agree, total = 0, 0
    for _ in range(trials):
        B = int(rng.choice([1, 4, 16, 32]))
        ea = int(rng.integers(-6, -2))
        eb = ea + int(rng.integers(-1, 2))
        a = QTensor(jnp.asarray(rng.integers(-110, 110, (B, classes)), jnp.int8),
                    jnp.int32(ea))
        b = QTensor(jnp.asarray(
            np.clip(np.asarray(a.data) + rng.integers(-25, 25, (B, classes)),
                    -127, 127), jnp.int8), jnp.int32(eb))
        y = jnp.asarray(rng.integers(0, classes, (B,)), jnp.int32)
        s_int = int(int_loss_sign(a, b, y))
        d = float(float_loss(a, y) - float_loss(b, y))
        if d == 0.0:
            continue
        total += 1
        agree += (s_int == np.sign(d))
    return agree / total, total
