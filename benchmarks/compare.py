"""BENCH regression gate: diff a fresh BENCH_*.json against the
committed baseline with direction-aware tolerance bands.

``python benchmarks/compare.py BENCH_paper.json``           (self-check)
``python benchmarks/compare.py BENCH_fresh_fleet.json --report diff.json``

The baseline defaults to the committed ``BENCH_<name>.json`` at the repo
root, resolved from the fresh document's own ``"name"`` field, so CI
runs the bench with ``--out BENCH_fresh_<name>.json`` and compares
against whatever is checked in.

Direction-aware means each metric only fails in the direction that is a
regression: throughput (tok/s) may rise freely but only fall so far;
measured peak bytes may fall freely but only rise so far; deterministic
byte/count accounting must match exactly.  Rules are first-match-wins on
the metric name (see RULES); anything unmatched gets the default
relative band.  Beyond metrics, the gate also checks:

  * config equality — a flag change means the two runs measure different
    things; that is exit 2 ("re-baseline"), not a pass or a regression;
  * counters — exact (they count events, and events are deterministic);
  * gauges — presence only (values are instantaneous and host-dependent);
  * histograms — observation count exact, p50/p99 banded like timings,
    raw buckets ignored;
  * memory ledger — every tag the baseline tracked must still be tracked
    (coverage guard; byte values are enforced via the ``memory_*``
    metrics, not here);
  * cross-metric invariants (CROSS_RULES) — hard inequalities checked
    inside the fresh document alone (e.g. serve's paged throughput must
    beat dense at batch 4), so they can never be re-baselined away.

Exit codes: 0 in-band, 1 regression, 2 usage / config mismatch.
"""
from __future__ import annotations

import argparse
import json
import math
import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

# (pattern, kind, param) — first match wins, applied to the metric name.
#   skip        informational; never fails (param: reason)
#   exact       fresh == base (param: abs epsilon)
#   rise_rel    higher is worse: fresh <= base * (1 + p)
#   fall_abs    lower is worse:  fresh >= base - p
#   higher      lower is worse:  fresh >= base / p     (throughput)
#   lower       higher is worse: fresh <= base * p     (latency)
#   band_abs    |fresh - base| <= max(p, |base| * frac) (param: (abs, frac))
RULES = [
    (r"memory_resid_", "skip", "XLA-version-dependent residual"),
    (r"(_err(or)?($|_)|max_abs_diff)", "lower", 2.0),
    (r"(memory_measured_.*_peak_bytes$|peak_bytes$)", "rise_rel", 0.10),
    (r"(acc($|_|uracy)|agreement)", "fall_abs", 0.08),
    (r"(ratio|overhead|share|util|saving|pct)", "band_abs", (0.25, 1.0)),
    (r"(tps$|tokens_per_s)", "higher", 8.0),
    (r"(_us$|_ms$|_s_per_step$|wall|_s$)", "lower", 8.0),
    (r"(bytes|^n_|_n_|steps$|pages$|trials|workers|probes|^b\d+_batch)",
     "exact", 0.0),
    (r"loss", "band_abs", (0.1, 0.15)),
]
DEFAULT_RULE = ("band_abs", (1e-9, 0.25))

# Cross-metric rules, keyed on the document name: (lhs, rhs) means the
# fresh document must satisfy lhs >= rhs *within itself* — no baseline
# involved, so drift can never re-baseline its way past the invariant.
# The serve rule is the paged-serving acceptance bar: continuous batching
# must beat the dense static-batch path at the CI matrix's batch 4.
CROSS_RULES = {
    "serve": [("b4_paged_tps", "b4_dense_tps")],
}


def rule_for(name: str):
    for pat, kind, param in RULES:
        if re.search(pat, name):
            return kind, param, pat
    kind, param = DEFAULT_RULE
    return kind, param, "<default>"


def check(kind, param, base: float, fresh: float):
    """-> (ok, bound_str) for one metric under one rule."""
    if kind == "skip":
        return True, param
    if kind == "exact":
        return math.isclose(fresh, base, rel_tol=0, abs_tol=param), \
            f"== {base:g}"
    if kind == "rise_rel":
        hi = base * (1 + param) if base >= 0 else base * (1 - param)
        return fresh <= hi, f"<= {hi:g}"
    if kind == "fall_abs":
        return fresh >= base - param, f">= {base - param:g}"
    if kind == "higher":
        lo = base / param
        return fresh >= lo, f">= {lo:g}"
    if kind == "lower":
        hi = base * param
        return fresh <= hi, f"<= {hi:g}"
    if kind == "band_abs":
        abs_tol, frac = param
        tol = max(abs_tol, abs(base) * frac)
        return abs(fresh - base) <= tol, f"± {tol:g}"
    raise ValueError(kind)


def load(path: Path) -> dict:
    try:
        return json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"compare: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def compare_metrics(base: dict, fresh: dict, rows: list) -> int:
    bad = 0
    for name in sorted(base):
        kind, param, pat = rule_for(name)
        if name not in fresh:
            if kind == "skip":
                continue
            rows.append({"metric": name, "status": "MISSING",
                         "baseline": base[name], "fresh": None,
                         "rule": kind, "bound": "present"})
            bad += 1
            continue
        b, f = base[name], fresh[name]
        if not (isinstance(b, (int, float)) and isinstance(f, (int, float))):
            ok, bound = b == f, "== (non-numeric)"
        else:
            ok, bound = check(kind, param, float(b), float(f))
        rows.append({"metric": name, "status": "ok" if ok else "FAIL",
                     "baseline": b, "fresh": f, "rule": kind,
                     "bound": bound})
        bad += 0 if ok else 1
    for name in sorted(set(fresh) - set(base)):
        rows.append({"metric": name, "status": "new",
                     "baseline": None, "fresh": fresh[name],
                     "rule": "-", "bound": "-"})
    return bad


def compare_cross(name: str, fresh: dict, rows: list) -> int:
    """Fresh-doc-internal invariants (CROSS_RULES): lhs >= rhs, hard."""
    bad = 0
    for lhs, rhs in CROSS_RULES.get(name, []):
        f_l, f_r = fresh.get(lhs), fresh.get(rhs)
        if not (isinstance(f_l, (int, float))
                and isinstance(f_r, (int, float))):
            rows.append({"metric": f"cross:{lhs}>={rhs}",
                         "status": "MISSING", "baseline": None,
                         "fresh": None, "rule": "cross",
                         "bound": "both present"})
            bad += 1
            continue
        ok = f_l >= f_r
        rows.append({"metric": f"cross:{lhs}>={rhs}",
                     "status": "ok" if ok else "FAIL",
                     "baseline": f_r, "fresh": f_l, "rule": "cross",
                     "bound": f">= {f_r:g}"})
        bad += 0 if ok else 1
    return bad


def compare_attribution(base: dict, fresh: dict, rows: list) -> int:
    """Counters exact, gauges presence, histograms count+percentiles."""
    bad = 0
    bc = base.get("counters", {}).get("counters", {})
    fc = fresh.get("counters", {}).get("counters", {})
    for name in sorted(bc):
        if re.search(r"(_ms|_us|_ns|time|wall)", name):
            continue                      # time-derived: informational
        f = fc.get(name)
        ok = f == bc[name]
        rows.append({"metric": f"counter:{name}",
                     "status": "ok" if ok else "FAIL",
                     "baseline": bc[name], "fresh": f,
                     "rule": "exact", "bound": f"== {bc[name]}"})
        bad += 0 if ok else 1
    bg = base.get("counters", {}).get("gauges", {})
    fg = fresh.get("counters", {}).get("gauges", {})
    for name in sorted(set(bg) - set(fg)):
        rows.append({"metric": f"gauge:{name}", "status": "MISSING",
                     "baseline": bg[name], "fresh": None,
                     "rule": "presence", "bound": "present"})
        bad += 1
    bh = base.get("timings", {}).get("histograms", {})
    fh = fresh.get("timings", {}).get("histograms", {})
    for name in sorted(bh):
        f = fh.get(name)
        if f is None:
            rows.append({"metric": f"hist:{name}", "status": "MISSING",
                         "baseline": bh[name].get("count"), "fresh": None,
                         "rule": "presence", "bound": "present"})
            bad += 1
            continue
        ok = f.get("count") == bh[name].get("count")
        rows.append({"metric": f"hist:{name}.count",
                     "status": "ok" if ok else "FAIL",
                     "baseline": bh[name].get("count"),
                     "fresh": f.get("count"), "rule": "exact",
                     "bound": f"== {bh[name].get('count')}"})
        bad += 0 if ok else 1
        for q in ("p50", "p99"):
            b_q, f_q = bh[name].get(q), f.get(q)
            if not (isinstance(b_q, (int, float))
                    and isinstance(f_q, (int, float))) or b_q <= 0:
                continue
            ok, bound = check("lower", 8.0, float(b_q), float(f_q))
            rows.append({"metric": f"hist:{name}.{q}",
                         "status": "ok" if ok else "FAIL",
                         "baseline": b_q, "fresh": f_q,
                         "rule": "lower", "bound": bound})
            bad += 0 if ok else 1
    # memory ledger coverage: every tag the baseline tracked must still be
    bt = base.get("memory", {}).get("ledger", {}).get("peak", {})
    ft = fresh.get("memory", {}).get("ledger", {}).get("peak", {})
    for tag in sorted(set(bt) - set(ft)):
        rows.append({"metric": f"memtag:{tag}", "status": "MISSING",
                     "baseline": bt[tag], "fresh": None,
                     "rule": "presence", "bound": "present"})
        bad += 1
    return bad


def print_table(rows, verbose: bool):
    shown = [r for r in rows if verbose or r["status"] in ("FAIL", "MISSING")]
    if not shown:
        return
    w = max(len(r["metric"]) for r in shown)

    def fmt(v):
        if v is None:
            return "-"
        if isinstance(v, float):
            return f"{v:.6g}"
        return str(v)

    print(f"{'metric':<{w}}  {'status':<7} {'baseline':>14} "
          f"{'fresh':>14}  rule ({'bound'})")
    for r in shown:
        print(f"{r['metric']:<{w}}  {r['status']:<7} "
              f"{fmt(r['baseline']):>14} {fmt(r['fresh']):>14}  "
              f"{r['rule']} ({r['bound']})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="freshly-produced BENCH_*.json")
    ap.add_argument("--baseline", default="",
                    help="baseline BENCH file (default: the committed "
                         "BENCH_<name>.json at the repo root, <name> "
                         "taken from the fresh document)")
    ap.add_argument("--report", default="",
                    help="also write the full row-by-row diff as JSON "
                         "(CI uploads this as an artifact)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every row, not just failures")
    args = ap.parse_args(argv)

    fresh_doc = load(Path(args.fresh))
    name = fresh_doc.get("name", "")
    base_path = Path(args.baseline) if args.baseline \
        else REPO_ROOT / f"BENCH_{name}.json"
    base_doc = load(base_path)

    rows: list = []
    report = {"baseline": str(base_path), "fresh": args.fresh,
              "name": name, "rows": rows}

    def finish(code: int, verdict: str) -> int:
        report["verdict"] = verdict
        if args.report:
            Path(args.report).write_text(json.dumps(report, indent=2) + "\n")
        return code

    if base_doc.get("name") != name:
        print(f"compare: baseline is '{base_doc.get('name')}' but fresh "
              f"is '{name}' — wrong file pairing", file=sys.stderr)
        return finish(2, "name-mismatch")
    if base_doc.get("config") != fresh_doc.get("config"):
        print("compare: config mismatch — the runs measure different "
              "things. If the flag change is intentional, re-baseline "
              f"(re-run the bench and commit the new {base_path.name}).",
              file=sys.stderr)
        print(f"  baseline: {json.dumps(base_doc.get('config'))}",
              file=sys.stderr)
        print(f"  fresh:    {json.dumps(fresh_doc.get('config'))}",
              file=sys.stderr)
        return finish(2, "config-mismatch")

    bad = compare_metrics(base_doc.get("metrics", {}),
                          fresh_doc.get("metrics", {}), rows)
    bad += compare_cross(name, fresh_doc.get("metrics", {}), rows)
    bad += compare_attribution(base_doc, fresh_doc, rows)
    print_table(rows, args.verbose)
    n = len([r for r in rows if r["status"] != "new"])
    if bad:
        print(f"compare: {name}: {bad}/{n} checks OUT OF BAND vs "
              f"{base_path.name}")
        return finish(1, "regression")
    print(f"compare: {name}: {n} checks in band vs {base_path.name}")
    return finish(0, "ok")


if __name__ == "__main__":
    sys.exit(main())
