"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs_per_device / 197 TFLOP/s
  memory term     = HLO_bytes_per_device / 819 GB/s
  collective term = collective_bytes_per_device / 50 GB/s
  MODEL_FLOPS     = analytic ideal (formula below), ratio vs HLO flops.

HLO flops/bytes use the depth-extrapolated values (scan bodies are counted
once by cost_analysis; docs/design.md §7). bytes_accessed on the CPU backend
double-counts bf16 traffic as f32 (float normalization); we report the raw
value and a /2 bf16-adjusted value, and use the adjusted one for the
bottleneck call.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Optional

from repro.configs import get_arch, get_shape, LaneConfig
from repro.core.api import tail_periods

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, n_devices: int,
                           lane: Optional[LaneConfig] = None) -> Dict[str, float]:
    """Analytic ideal FLOPs for one step, per device (formulas in §Roofline)."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    lane = lane or LaneConfig()
    N = cfg.param_count(active_only=True)
    N_tot = cfg.param_count(active_only=False)
    S, B = shape.seq_len, shape.global_batch

    # attention context flops per token (QK^T + AV = 4 * ctx * H * Dh per layer)
    attn_layers = [i for i in range(cfg.num_layers)
                   if cfg.pattern[i % len(cfg.pattern)] == "attn"]
    ctx = {"train": S / 2, "prefill": S / 2, "decode": S}[shape.kind]
    if cfg.sliding_window:
        ctx = min(ctx, cfg.sliding_window)
    attn_per_tok = 4 * ctx * cfg.num_heads * cfg.head_dim * len(attn_layers)

    fwd_per_tok = 2 * N + attn_per_tok
    if shape.kind == "train":
        k = tail_periods(cfg, lane)
        f_tail = k / cfg.num_periods
        if lane.lane == "full_bp":
            mult = 3.0
        elif lane.lane == "full_zo":
            mult = 2.0 * lane.zo_num_probes
        else:
            mult = 2.0 * lane.zo_num_probes * (1.0 + f_tail)
        tokens = B * S
        total = mult * fwd_per_tok * tokens
        formula = (f"{mult:.2f} x (2N + attn) x {tokens} tok "
                   f"(N_act={N:.3e}, f_tail={f_tail:.3f})")
    elif shape.kind == "prefill":
        tokens = B * S
        total = fwd_per_tok * tokens
        formula = f"(2N + attn) x {tokens} tok"
    else:
        tokens = B * 1
        total = fwd_per_tok * tokens
        formula = f"(2N + attn(ctx={ctx:.0f})) x {tokens} tok"
    return {"total": total, "per_device": total / n_devices,
            "formula": formula, "params_active": N, "params_total": N_tot}


def load_cell(arch: str, shape: str, mesh: str) -> Optional[dict]:
    f = RESULTS / f"{arch}__{shape}__{mesh}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def roofline_row(arch: str, shape: str, mesh: str = "single",
                 lane: Optional[LaneConfig] = None) -> Optional[dict]:
    rec = load_cell(arch, shape, mesh)
    if rec is None or rec.get("status") != "ok":
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "status": (rec or {}).get("error", "missing")}
    n_dev = 1
    for v in rec["mesh_shape"].values():
        n_dev *= v
    cost = rec.get("extrapolated") or rec["full"]
    flops = cost["flops"]
    raw_bytes = cost["bytes_accessed"]
    adj_bytes = raw_bytes / 2.0          # bf16 float-normalization artifact
    # /2: XLA:CPU float-normalization carries bf16 payloads as f32 on the
    # wire in the compiled HLO; a TPU build moves bf16 (verified in dumps)
    coll = rec["full"]["collective_bytes"] / 2.0
    t_c = flops / PEAK_FLOPS
    t_m = adj_bytes / HBM_BW
    t_x = coll / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    ideal = model_flops_per_device(arch, shape, n_dev, lane)
    util = ideal["per_device"] / max(flops, 1.0)
    # roofline fraction: ideal compute time over the achievable step time
    t_step = max(t_c, t_m, t_x)
    frac = (ideal["per_device"] / PEAK_FLOPS) / max(t_step, 1e-12)
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "status": "ok",
        "devices": n_dev,
        "flops_dev": flops, "bytes_dev_adj": adj_bytes,
        "coll_bytes_dev": coll,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "bottleneck": dom,
        "model_flops_dev": ideal["per_device"],
        "model_formula": ideal["formula"],
        "useful_flops_ratio": min(util, 1.0),
        "roofline_fraction": min(frac, 1.0),
        "temp_bytes_dev": rec["full"]["memory"].get("temp_size_in_bytes"),
        "arg_bytes_dev": rec["full"]["memory"].get("argument_size_in_bytes"),
        "collectives": rec["full"].get("collectives", {}),
        "attn_plan": rec.get("attn_plan"), "moe_plan": rec.get("moe_plan"),
    }


def full_table(mesh: str = "single"):
    from repro.configs import cell_matrix
    rows = []
    for a, s, run, why in cell_matrix():
        if not run:
            rows.append({"arch": a, "shape": s, "mesh": mesh,
                         "status": f"skipped: {why}"})
            continue
        r = roofline_row(a, s, mesh)
        if r:
            rows.append(r)
    return rows


def format_table(rows) -> str:
    out = ["| arch | shape | bottleneck | t_comp | t_mem | t_coll | "
           "MODEL/HLO | roofline |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                       f"{r['status'][:60]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | **{r['bottleneck']}** "
            f"| {r['t_compute_s']*1e3:.1f}ms | {r['t_memory_s']*1e3:.1f}ms "
            f"| {r['t_collective_s']*1e3:.1f}ms "
            f"| {r['useful_flops_ratio']*100:.0f}% "
            f"| {r['roofline_fraction']*100:.0f}% |")
    return "\n".join(out)
