"""Benchmark driver: one section per paper table/figure + the roofline.

``PYTHONPATH=src python -m benchmarks.run [--fast] [--section NAME]``

Prints ``name,us_per_call,derived`` CSV rows (plus human-readable tables
as '#'-prefixed comment lines).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def emit(name, us, derived=""):
    print(f"{name},{us},{derived}", flush=True)


def section_accuracy(fast: bool):
    from . import paper_tables as pt
    steps = 150 if fast else 800
    t0 = time.perf_counter()
    res = pt.lenet_lanes(steps=steps)
    dt = (time.perf_counter() - t0) * 1e6 / steps
    order = ["full_zo", "zo_feat_cls2", "zo_feat_cls1", "full_bp"]
    accs = {k: res[k][0] for k in order}
    print(f"# Table1(FP32 glyphs): " +
          " ".join(f"{k}={accs[k]*100:.1f}%" for k in order))
    emit("table1_fp32_lenet", f"{dt:.0f}",
         ";".join(f"{k}={accs[k]:.4f}" for k in order))

    t0 = time.perf_counter()
    res8 = pt.lenet_int8_lanes(steps=steps, loss_mode="int")
    dt8 = (time.perf_counter() - t0) * 1e6 / steps
    accs8 = {k: res8[k][0] for k in res8}
    print(f"# Table1(INT8* glyphs): " +
          " ".join(f"{k}={v*100:.1f}%" for k, v in accs8.items()))
    emit("table1_int8star_lenet", f"{dt8:.0f}",
         ";".join(f"{k}={v:.4f}" for k, v in accs8.items()))

    t0 = time.perf_counter()
    resp = pt.pointnet_lanes(steps=100 if fast else 400)
    dtp = (time.perf_counter() - t0) * 1e6 / max(100 if fast else 400, 1)
    print(f"# Table1(PointNet clouds): " +
          " ".join(f"{k}={v[0]*100:.1f}%" for k, v in resp.items()))
    emit("table1_pointnet", f"{dtp:.0f}",
         ";".join(f"{k}={v[0]:.4f}" for k, v in resp.items()))


def section_finetune(fast: bool):
    from . import paper_tables as pt
    import jax
    import jax.numpy as jnp
    from repro.models import lenet
    from repro.configs import LaneConfig
    from repro.core.elastic import TrainState, make_elastic_step
    from repro.data.synthetic import glyphs
    steps = 100 if fast else 400
    # pretrain with BP on upright glyphs (paper: 1-100 epochs of BP)
    params = lenet.init_lenet5(jax.random.key(7))
    lane = LaneConfig(lane="full_bp", learning_rate=0.05)
    step = jax.jit(make_elastic_step(lenet.lenet5_loss, lane))
    state = TrainState(params, jnp.int32(0),
                       jax.random.key_data(jax.random.key(1)))
    xs, ys = glyphs(2048, seed=0)
    for s in range(steps):
        i0 = (s * 32) % 2048
        state, _ = step(state, {"x": jnp.asarray(xs[i0:i0 + 32]),
                                "y": jnp.asarray(ys[i0:i0 + 32])},
                        jnp.ones((1,), jnp.float32))
    pre = state.params
    for deg in (30, 45):
        xs_r, ys_r = glyphs(512, seed=5, rotate_deg=deg, start=20_000)
        logits, _ = lenet.lenet5_forward(pre, jnp.asarray(xs_r))
        acc0 = float(jnp.mean((jnp.argmax(logits, -1) == jnp.asarray(ys_r))
                              .astype(jnp.float32)))
        t0 = time.perf_counter()
        res = pt.lenet_lanes(steps=steps, rotate=deg, init_params=pre,
                             zo_lr=5e-3)
        dt = (time.perf_counter() - t0) * 1e6 / steps
        row = ";".join(f"{k}={v[0]:.4f}" for k, v in res.items())
        print(f"# Table2(rot{deg}): before={acc0*100:.1f}% " +
              " ".join(f"{k}={v[0]*100:.1f}%" for k, v in res.items()))
        emit(f"table2_rot{deg}", f"{dt:.0f}", f"before={acc0:.4f};{row}")


def section_memory(_fast: bool):
    from . import paper_tables as pt
    for b in (32, 256):
        t = pt.lenet_memory_table(b)
        full_bp = t["full_bp"]["fp32_bytes"]
        fz = t["full_zo"]["fp32_bytes"]
        print(f"# Fig4/5 (LeNet B={b}): " + " ".join(
            f"{k}: fp32={v['fp32_bytes']/1e6:.2f}MB "
            f"int8={v['int8_bytes']/1e6:.2f}MB" for k, v in t.items()))
        derived = (f"bp_over_zo={full_bp/fz:.2f};"
                   f"cls1_overhead={(t['zo_feat_cls1']['fp32_bytes']-fz)/fz*100:.3f}%;"
                   f"int8_saving={fz/t['full_zo']['int8_bytes']:.2f}x;"
                   f"int8_saving_reused={fz/t['full_zo']['int8_reused_bytes']:.2f}x")
        emit(f"memory_lenet_b{b}", "0", derived)
    p = pt.pointnet_memory_table(32)
    print(f"# Fig6 (PointNet B=32): full_bp={p['full_bp']['fp32_bytes']/1e6:.1f}MB "
          f"full_zo={p['full_zo']['fp32_bytes']/1e6:.1f}MB "
          f"cls1={p['zo_feat_cls1']['fp32_bytes']/1e6:.1f}MB")
    emit("memory_pointnet_b32", "0",
         f"bp_over_zo={p['full_bp']['fp32_bytes']/p['full_zo']['fp32_bytes']:.3f}")


def section_steptime(fast: bool):
    from . import paper_tables as pt
    bd = pt.steptime_breakdown(iters=5 if fast else 20)
    print("# Fig7 (step-time, this host): " +
          " ".join(f"{k}={v:.0f}us" for k, v in bd.items()))
    fp32_total = bd["fp32_forward_us"] + bd["fp32_perturb_us"] \
        + bd["fp32_update_us"] + bd["fp32_bp_tail_us"]
    emit("steptime_fp32_total", f"{fp32_total:.0f}",
         f"fwd_share={bd['fp32_forward_us']/fp32_total:.2f}")
    int8_total = bd["int8_forward_us"] + bd["int8_perturb_us"]
    emit("steptime_int8_fwdperturb", f"{int8_total:.0f}",
         f"note=CPU-host-XLA;paper_ratio_on_rpi=1.38-1.42x")


def section_signagree(_fast: bool):
    from . import paper_tables as pt
    t0 = time.perf_counter()
    rate, total = pt.sign_agreement()
    dt = (time.perf_counter() - t0) * 1e6 / max(total, 1)
    print(f"# §4.3 sign agreement: {rate*100:.1f}% over {total} trials "
          f"(paper: ~95%)")
    emit("int_loss_sign_agreement", f"{dt:.0f}", f"rate={rate:.4f}")


def section_roofline(_fast: bool):
    from . import roofline as rl
    rows = rl.full_table("single")
    ok = [r for r in rows if r.get("status") == "ok"]
    print("# Roofline (single-pod 16x16, per-device):")
    print("\n".join("# " + l for l in rl.format_table(rows).splitlines()))
    for r in ok:
        emit(f"roofline_{r['arch']}_{r['shape']}",
             f"{max(r['t_compute_s'], r['t_memory_s'], r['t_collective_s'])*1e6:.0f}",
             f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.3f};"
             f"useful={r['useful_flops_ratio']:.3f}")
    out = Path(__file__).resolve().parent.parent / "results" / "roofline_single.json"
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(rows, indent=1, default=str))


SECTIONS = {
    "signagree": section_signagree,
    "memory": section_memory,
    "roofline": section_roofline,
    "steptime": section_steptime,
    "accuracy": section_accuracy,
    "finetune": section_finetune,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--section", choices=sorted(SECTIONS), action="append")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in SECTIONS.items():
        if args.section and name not in args.section:
            continue
        t0 = time.perf_counter()
        try:
            fn(args.fast)
        except Exception as e:  # noqa: BLE001
            emit(f"{name}_ERROR", "0", f"{type(e).__name__}:{e}")
        print(f"# [{name}] done in {time.perf_counter()-t0:.1f}s")


if __name__ == '__main__':
    main()
