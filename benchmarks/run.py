"""Benchmark driver: one section per paper table/figure + the roofline.

``PYTHONPATH=src python -m benchmarks.run [--fast] [--section NAME]``

Each section prints a human-readable '#'-prefixed table and returns a
flat metrics dict; the driver merges them into ``BENCH_paper.json`` on
the standardized bench_util schema ({name, config, metrics}) so the
paper-reproduction trajectory is diffable across PRs like every other
benchmark (BENCH_fleet.json, BENCH_serve.json).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from .bench_util import write_bench


def section_accuracy(fast: bool):
    from . import paper_tables as pt
    metrics = {}
    steps = 150 if fast else 800
    t0 = time.perf_counter()
    res = pt.lenet_lanes(steps=steps)
    dt = (time.perf_counter() - t0) * 1e6 / steps
    order = ["full_zo", "zo_feat_cls2", "zo_feat_cls1", "full_bp"]
    accs = {k: res[k][0] for k in order}
    print("# Table1(FP32 glyphs): " +
          " ".join(f"{k}={accs[k]*100:.1f}%" for k in order))
    metrics["table1_fp32_lenet_us_per_step"] = dt
    metrics.update({f"table1_fp32_lenet_acc_{k}": accs[k] for k in order})

    t0 = time.perf_counter()
    res8 = pt.lenet_int8_lanes(steps=steps, loss_mode="int")
    dt8 = (time.perf_counter() - t0) * 1e6 / steps
    accs8 = {k: res8[k][0] for k in res8}
    print("# Table1(INT8* glyphs): " +
          " ".join(f"{k}={v*100:.1f}%" for k, v in accs8.items()))
    metrics["table1_int8star_lenet_us_per_step"] = dt8
    metrics.update({f"table1_int8star_lenet_acc_{k}": v
                    for k, v in accs8.items()})

    psteps = 100 if fast else 400
    t0 = time.perf_counter()
    resp = pt.pointnet_lanes(steps=psteps)
    dtp = (time.perf_counter() - t0) * 1e6 / psteps
    print("# Table1(PointNet clouds): " +
          " ".join(f"{k}={v[0]*100:.1f}%" for k, v in resp.items()))
    metrics["table1_pointnet_us_per_step"] = dtp
    metrics.update({f"table1_pointnet_acc_{k}": v[0]
                    for k, v in resp.items()})
    return metrics


def section_finetune(fast: bool):
    from . import paper_tables as pt
    import jax
    import jax.numpy as jnp
    from repro.models import lenet
    from repro.configs import LaneConfig
    from repro.core.elastic import TrainState, make_elastic_step
    from repro.data.synthetic import glyphs
    metrics = {}
    steps = 100 if fast else 400
    # pretrain with BP on upright glyphs (paper: 1-100 epochs of BP)
    params = lenet.init_lenet5(jax.random.key(7))
    lane = LaneConfig(lane="full_bp", learning_rate=0.05)
    step = jax.jit(make_elastic_step(lenet.lenet5_loss, lane))
    state = TrainState(params, jnp.int32(0),
                       jax.random.key_data(jax.random.key(1)))
    xs, ys = glyphs(2048, seed=0)
    for s in range(steps):
        i0 = (s * 32) % 2048
        state, _ = step(state, {"x": jnp.asarray(xs[i0:i0 + 32]),
                                "y": jnp.asarray(ys[i0:i0 + 32])},
                        jnp.ones((1,), jnp.float32))
    pre = state.params
    for deg in (30, 45):
        xs_r, ys_r = glyphs(512, seed=5, rotate_deg=deg, start=20_000)
        logits, _ = lenet.lenet5_forward(pre, jnp.asarray(xs_r))
        acc0 = float(jnp.mean((jnp.argmax(logits, -1) == jnp.asarray(ys_r))
                              .astype(jnp.float32)))
        t0 = time.perf_counter()
        res = pt.lenet_lanes(steps=steps, rotate=deg, init_params=pre,
                             zo_lr=5e-3)
        dt = (time.perf_counter() - t0) * 1e6 / steps
        print(f"# Table2(rot{deg}): before={acc0*100:.1f}% " +
              " ".join(f"{k}={v[0]*100:.1f}%" for k, v in res.items()))
        metrics[f"table2_rot{deg}_us_per_step"] = dt
        metrics[f"table2_rot{deg}_acc_before"] = acc0
        metrics.update({f"table2_rot{deg}_acc_{k}": v[0]
                        for k, v in res.items()})
    return metrics


# The structured reconciliation table section_memory builds for
# BENCH_paper.json's "memory" section (write_bench merges it with the
# recorder's tagged-ledger snapshot). Module-level because sections
# return flat scalar metrics only.
MEMORY_DOC: dict = {}


def section_memory(_fast: bool):
    from . import paper_tables as pt
    metrics = {}
    for b in (32, 256):
        t = pt.lenet_memory_table(b)
        full_bp = t["full_bp"]["fp32_bytes"]
        fz = t["full_zo"]["fp32_bytes"]
        print(f"# Fig4/5 (LeNet B={b}): " + " ".join(
            f"{k}: fp32={v['fp32_bytes']/1e6:.2f}MB "
            f"int8={v['int8_bytes']/1e6:.2f}MB" for k, v in t.items()))
        metrics[f"memory_lenet_b{b}_bp_over_zo"] = full_bp / fz
        metrics[f"memory_lenet_b{b}_cls1_overhead_pct"] = \
            (t["zo_feat_cls1"]["fp32_bytes"] - fz) / fz * 100
        metrics[f"memory_lenet_b{b}_int8_saving"] = \
            fz / t["full_zo"]["int8_bytes"]
        metrics[f"memory_lenet_b{b}_int8_saving_reused"] = \
            fz / t["full_zo"]["int8_reused_bytes"]
    p = pt.pointnet_memory_table(32)
    print(f"# Fig6 (PointNet B=32): full_bp={p['full_bp']['fp32_bytes']/1e6:.1f}MB "
          f"full_zo={p['full_zo']['fp32_bytes']/1e6:.1f}MB "
          f"cls1={p['zo_feat_cls1']['fp32_bytes']/1e6:.1f}MB")
    metrics["memory_pointnet_b32_bp_over_zo"] = \
        p["full_bp"]["fp32_bytes"] / p["full_zo"]["fp32_bytes"]

    # ---- MEASURED: XLA buffer assignment per lane, reconciled -------- #
    mb = 32
    analytic = pt.lenet_memory_table(mb)
    meas = pt.lenet_measured_memory(mb)
    MEMORY_DOC.clear()
    MEMORY_DOC.update({"model": "lenet5", "batch": mb,
                       "instrument": "xla_buffer_assignment "
                                     "(Compiled.memory_analysis)",
                       "lanes": {}, "int8_lanes": {}})
    for k, fp in meas.items():
        a = analytic[k]["fp32_bytes"]
        resid = fp["peak_bytes"] - a
        metrics[f"memory_measured_lenet_b{mb}_{k}_peak_bytes"] = \
            fp["peak_bytes"]
        metrics[f"memory_resid_lenet_b{mb}_{k}_bytes"] = resid
        MEMORY_DOC["lanes"][k] = {**fp, "analytic_bytes": a,
                                  "residual_bytes": resid}
    metrics[f"memory_measured_lenet_b{mb}_bp_over_zo"] = \
        meas["full_bp"]["peak_bytes"] / meas["full_zo"]["peak_bytes"]
    metrics[f"memory_measured_lenet_b{mb}_cls1_overhead_pct"] = \
        (meas["zo_feat_cls1"]["peak_bytes"] - meas["full_zo"]["peak_bytes"]) \
        / meas["full_zo"]["peak_bytes"] * 100
    print(f"# Fig4/5 measured (LeNet B={mb}, XLA): " + " ".join(
        f"{k}={v['peak_bytes']/1e6:.2f}MB" for k, v in meas.items())
        + f"  bp_over_zo={metrics[f'memory_measured_lenet_b{mb}_bp_over_zo']:.2f}")

    meas8 = pt.lenet_int8_measured_memory(mb)
    for k, fp in meas8.items():
        a = analytic[k]["int8_reused_bytes"]
        resid = fp["peak_bytes"] - a
        metrics[f"memory_measured_int8_lenet_b{mb}_{k}_peak_bytes"] = \
            fp["peak_bytes"]
        metrics[f"memory_resid_int8_lenet_b{mb}_{k}_bytes"] = resid
        MEMORY_DOC["int8_lanes"][k] = {
            **fp, "analytic_bytes": a,
            "analytic_noreuse_bytes": analytic[k]["int8_bytes"],
            "residual_bytes": resid}
    # measured fp32/int8 ratio — honest: the int8 *simulation* upcasts
    # to int32 in XLA, so this sits below 1.0 (the paper's MCU 1.46-1.60x
    # lives in memory_lenet_b*_int8_saving_reused above)
    metrics[f"memory_measured_lenet_b{mb}_int8_ratio"] = \
        meas["full_zo"]["peak_bytes"] / meas8["full_zo"]["peak_bytes"]
    print(f"# Fig4/5 measured (LeNet B={mb}, INT8 sim): " + " ".join(
        f"{k}={v['peak_bytes']/1e6:.2f}MB" for k, v in meas8.items()))
    return metrics


def section_steptime(fast: bool):
    from . import paper_tables as pt
    bd = pt.steptime_breakdown(iters=5 if fast else 20)
    print("# Fig7 (step-time, this host): " +
          " ".join(f"{k}={v:.0f}us" for k, v in bd.items()))
    metrics = dict(bd)
    fp32_total = bd["fp32_forward_us"] + bd["fp32_perturb_us"] \
        + bd["fp32_update_us"] + bd["fp32_bp_tail_us"]
    metrics["steptime_fp32_total_us"] = fp32_total
    metrics["steptime_fp32_fwd_share"] = bd["fp32_forward_us"] / fp32_total
    metrics["steptime_int8_fwdperturb_us"] = \
        bd["int8_forward_us"] + bd["int8_perturb_us"]
    return metrics


def section_signagree(_fast: bool):
    from . import paper_tables as pt
    t0 = time.perf_counter()
    rate, total = pt.sign_agreement()
    dt = (time.perf_counter() - t0) * 1e6 / max(total, 1)
    print(f"# §4.3 sign agreement: {rate*100:.1f}% over {total} trials "
          "(paper: ~95%)")
    return {"int_loss_sign_agreement": rate,
            "int_loss_sign_trials": total,
            "int_loss_sign_us_per_trial": dt}


def section_roofline(_fast: bool):
    from . import roofline as rl
    rows = rl.full_table("single")
    ok = [r for r in rows if r.get("status") == "ok"]
    print("# Roofline (single-pod 16x16, per-device):")
    print("\n".join("# " + l for l in rl.format_table(rows).splitlines()))
    metrics = {}
    for r in ok:
        key = f"roofline_{r['arch']}_{r['shape']}"
        metrics[f"{key}_us"] = max(r["t_compute_s"], r["t_memory_s"],
                                   r["t_collective_s"]) * 1e6
        metrics[f"{key}_fraction"] = r["roofline_fraction"]
        metrics[f"{key}_useful_flops_ratio"] = r["useful_flops_ratio"]
    out = Path(__file__).resolve().parent.parent / "results" / "roofline_single.json"
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(rows, indent=1, default=str))
    return metrics


SECTIONS = {
    "signagree": section_signagree,
    "memory": section_memory,
    "roofline": section_roofline,
    "steptime": section_steptime,
    "accuracy": section_accuracy,
    "finetune": section_finetune,
}


def main() -> None:
    from repro import obs
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--section", choices=sorted(SECTIONS), action="append")
    ap.add_argument("--out", default="")
    obs.add_observability_args(ap)
    args = ap.parse_args()
    obs.configure_from_args(args)
    if not obs.get().enabled:
        obs.install()      # BENCH_paper.json always carries timings
    ran = []
    metrics = {}
    rec = obs.get()
    for name, fn in SECTIONS.items():
        if args.section and name not in args.section:
            continue
        t0 = time.perf_counter()
        try:
            with rec.span(f"bench/{name}", track="main"):
                metrics.update(fn(args.fast))
            ran.append(name)
        except Exception as e:  # noqa: BLE001
            print(f"# [{name}] ERROR {type(e).__name__}: {e}")
            metrics[f"{name}_error"] = f"{type(e).__name__}:{e}"
        print(f"# [{name}] done in {time.perf_counter()-t0:.1f}s")
    obs.memory.sample()        # final tagged-vs-jax reconciliation
    write_bench("paper", {"fast": args.fast, "sections": ",".join(ran)},
                metrics, out=args.out or None,
                memory=MEMORY_DOC or None)
    obs.write_outputs(args)


if __name__ == '__main__':
    main()
