"""Shared benchmark output contract.

Every benchmark writes ``BENCH_<name>.json`` at the repo root with the
schema ``{"name": ..., "config": {...}, "metrics": {...}}`` so the perf
trajectory is diffable across PRs (one file per benchmark, committed
runs optional, schema stable). Keep metrics flat: scalar leaves only.

When the process has an armed flight recorder (repro.obs), the document
additionally carries two attribution sections straight off the recorder
snapshot — ``"timings"`` (span totals + latency histograms) and
``"counters"`` (counters + gauges) — so every committed BENCH file also
says *where* its headline numbers came from.
"""
from __future__ import annotations

import json
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def write_bench(name: str, config: dict, metrics: dict,
                out: str | None = None) -> Path:
    doc = {"name": name, "config": config, "metrics": metrics}
    try:
        from repro import obs
        rec = obs.get()
    except ImportError:                    # benchmarks run without src?
        rec = None
    if rec is not None and rec.enabled:
        snap = rec.snapshot()
        doc["timings"] = {"spans": snap["spans"],
                          "histograms": snap["histograms"]}
        doc["counters"] = {"counters": snap["counters"],
                           "gauges": snap["gauges"]}
    path = Path(out) if out else REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"# wrote {path}")
    return path
