"""Shared benchmark output contract.

Every benchmark writes ``BENCH_<name>.json`` at the repo root with the
schema ``{"name": ..., "config": {...}, "metrics": {...}}`` so the perf
trajectory is diffable across PRs (one file per benchmark, committed
runs optional, schema stable). Keep metrics flat: scalar leaves only.
"""
from __future__ import annotations

import json
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def write_bench(name: str, config: dict, metrics: dict,
                out: str | None = None) -> Path:
    doc = {"name": name, "config": config, "metrics": metrics}
    path = Path(out) if out else REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"# wrote {path}")
    return path
