"""Shared benchmark output contract.

Every benchmark writes ``BENCH_<name>.json`` at the repo root with the
schema ``{"name": ..., "config": {...}, "metrics": {...}}`` so the perf
trajectory is diffable across PRs (one file per benchmark, committed
runs optional, schema stable). Keep metrics flat: scalar leaves only.

When the process has an armed flight recorder (repro.obs), the document
additionally carries attribution sections straight off the recorder
snapshot — ``"timings"`` (span totals + latency histograms),
``"counters"`` (counters + gauges), and ``"memory"`` (the tagged
live-bytes ledger, merged with any benchmark-supplied reconciliation
dict such as run.py's measured-vs-analytic lane table) — so every
committed BENCH file also says *where* its headline numbers came from.

``benchmarks/compare.py`` is the enforcement half: it diffs a fresh
BENCH file against the committed baseline with direction-aware
tolerance bands and fails CI on out-of-band regressions.
"""
from __future__ import annotations

import json
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def write_bench(name: str, config: dict, metrics: dict,
                out: str | None = None, memory: dict | None = None) -> Path:
    doc = {"name": name, "config": config, "metrics": metrics}
    try:
        from repro import obs
        rec = obs.get()
    except ImportError:                    # benchmarks run without src?
        rec = None
    if rec is not None and rec.enabled:
        snap = rec.snapshot()
        doc["timings"] = {"spans": snap["spans"],
                          "histograms": snap["histograms"]}
        doc["counters"] = {"counters": snap["counters"],
                           "gauges": snap["gauges"]}
        doc["memory"] = dict(memory or {})
        doc["memory"]["ledger"] = snap.get("memory", {})
    elif memory:
        doc["memory"] = dict(memory)
    path = Path(out) if out else REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"# wrote {path}")
    return path
