"""Serving throughput: dense static-batch vs paged continuous batching.

``PYTHONPATH=src python -m benchmarks.bench_serve --arch qwen3-4b --smoke \
      --batches 2,4,8``

For each batch size, generates the same greedy workload through both
paths — each a warmed, long-lived server object timed over ``REPS``
repetitions with the best run reported (single-shot timings flap on
shared CI cores, and the paged >= dense gate must not flake on noise) —
and reports tokens/sec plus paged-pool utilization and SWA
reclamation counts, written as BENCH_serve.json at the repo root
({name, config, metrics} — the shared benchmark schema,
benchmarks/bench_util.py; metrics are flattened per batch size as
``b<N>_dense_tps`` etc.). ``--scale-batches`` additionally sweeps the
paged engine alone up the batch axis (default 2 -> 256) for the
continuous-batching scaling curve (``scale_b<N>_tps``); the dense
baseline stops at the CI matrix sizes where its static cache is still a
serving configuration rather than an allocator stress test.

benchmarks/compare.py enforces ``b4_paged_tps >= b4_dense_tps`` as a
hard fresh-document invariant (CROSS_RULES) on top of the banded
baseline diff.

On CPU this measures engine overhead, not kernel speed (the Pallas paged
kernel only engages on TPU); the point of the JSON is tracking the
dense/paged ratio and page accounting across PRs.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import obs
from repro.configs import ServeConfig, get_arch, reduced
from repro.serve import DenseServer, Engine, SamplingParams

from .bench_util import write_bench

REPS = 3          # timed repetitions per path; best-of is reported


def bench_one(cfg, batch: int, prompt_len: int, new_tokens: int,
              page_size: int, seed: int = 0):
    total = cfg.num_image_tokens + prompt_len + new_tokens
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (batch, prompt_len)).astype(np.int32)
    serve = ServeConfig(
        page_size=page_size,
        num_pages=1 + batch * (-(-(total + 1) // page_size)),
        max_batch_slots=batch, max_seq_len=total,
        max_new_tokens=new_tokens)
    eng = Engine(cfg, serve)
    srv = DenseServer(cfg, eng.params, batch, prompt_len, new_tokens)

    # warm both compile caches out of the timed region — with the
    # recorder disarmed, so compile spans never pollute the latency
    # attribution. The region's wall clock IS recorded (serve.compile_ms
    # gauge + b<N>_compile_ms metric): compile time is attributed, not
    # discarded.
    warm = [list(p) for p in prompts]
    rec = obs.get()
    if rec.enabled:
        obs.uninstall()
    t0 = time.perf_counter()
    try:
        eng.generate(warm, SamplingParams(), new_tokens)
        srv.generate(prompts)
    finally:
        compile_ms = (time.perf_counter() - t0) * 1e3
        if rec.enabled:
            obs.install(rec)
            rec.gauge("serve.compile_ms").set(compile_ms)
            rec.histogram("serve.compile_warm_ms").observe(compile_ms)

    # both paths timed the same way: warmed long-lived server object,
    # best of REPS runs (single-shot timings flap on shared CI cores, and
    # the b4 paged>=dense CROSS_RULES gate must not flake on noise)
    dense, dense_dt = None, float("inf")
    paged, paged_dt = None, float("inf")
    steps0, reclaim0 = eng.steps_run, eng.sched.reclaimed_pages
    for _ in range(REPS):
        t0 = time.perf_counter()
        dense = srv.generate(prompts)
        dense_dt = min(dense_dt, time.perf_counter() - t0)
        t0 = time.perf_counter()
        paged = eng.generate(warm, SamplingParams(), new_tokens)
        paged_dt = min(paged_dt, time.perf_counter() - t0)
    engine_steps = (eng.steps_run - steps0) // REPS
    reclaimed = (eng.sched.reclaimed_pages - reclaim0) // REPS

    n_tok = batch * new_tokens
    assert [list(d) for d in dense] == paged, "dense/paged diverged"
    util = eng.page_utilization()
    eng.release_memory_tags()      # retired below; keep live bytes honest
    return {
        "batch": batch,
        "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "dense_tps": n_tok / dense_dt,
        "paged_tps": n_tok / paged_dt,
        "compile_ms": compile_ms,
        "engine_steps": engine_steps,
        "total_pages": util["total_pages"],
        "page_util_peak": util["peak_util"],
        "page_util_mean": util["mean_util"],
        "reclaimed_pages": reclaimed,
    }


def bench_scaling(cfg, batch: int, prompt_len: int, new_tokens: int,
                  page_size: int, seed: int = 0):
    """Paged-only throughput at one batch size for the scaling curve.

    The dense baseline is a static [batch, total] cache — past the CI
    matrix sizes it measures allocator behaviour, not serving — so the
    curve tracks how continuous batching alone scales 2 -> 256."""
    total = cfg.num_image_tokens + prompt_len + new_tokens
    rng = np.random.default_rng(seed)
    prompts = [list(p) for p in rng.integers(
        0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32)]
    serve = ServeConfig(
        page_size=page_size,
        num_pages=1 + batch * (-(-(total + 1) // page_size)),
        max_batch_slots=batch, max_seq_len=total,
        max_new_tokens=new_tokens)
    eng = Engine(cfg, serve)
    eng.generate(prompts, SamplingParams(), new_tokens)     # warm compile
    reclaim0 = eng.sched.reclaimed_pages
    dt = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        eng.generate(prompts, SamplingParams(), new_tokens)
        dt = min(dt, time.perf_counter() - t0)
    reclaimed = (eng.sched.reclaimed_pages - reclaim0) // REPS
    util = eng.page_utilization()
    eng.release_memory_tags()
    return {"tps": batch * new_tokens / dt,
            "page_util_peak": util["peak_util"],
            "reclaimed_pages": reclaimed}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batches", default="2,4,8")
    ap.add_argument("--scale-batches", default="2,8,32,64,128,256",
                    help="paged-only scaling-curve batch sizes "
                         "(empty string disables the sweep)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--out", default="")
    obs.add_observability_args(ap)
    args = ap.parse_args(argv)
    obs.configure_from_args(args)
    if not obs.get().enabled:
        obs.install()      # BENCH_serve.json always carries timings

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    metrics = {}
    for b in [int(x) for x in args.batches.split(",")]:
        r = bench_one(cfg, b, args.prompt_len, args.tokens, args.page_size)
        print(f"# batch={b}: dense {r['dense_tps']:.1f} tok/s, paged "
              f"{r['paged_tps']:.1f} tok/s, peak pages "
              f"{100 * r['page_util_peak']:.0f}%", flush=True)
        for k in ("dense_tps", "paged_tps", "compile_ms", "engine_steps",
                  "total_pages", "page_util_peak", "page_util_mean",
                  "reclaimed_pages"):
            metrics[f"b{b}_{k}"] = r[k]
    if args.scale_batches:
        for b in [int(x) for x in args.scale_batches.split(",")]:
            r = bench_scaling(cfg, b, args.prompt_len, args.tokens,
                              args.page_size)
            print(f"# scale batch={b}: paged {r['tps']:.1f} tok/s, peak "
                  f"pages {100 * r['page_util_peak']:.0f}%", flush=True)
            metrics[f"scale_b{b}_tps"] = r["tps"]
            metrics[f"scale_b{b}_page_util_peak"] = r["page_util_peak"]
            metrics[f"scale_b{b}_reclaimed_pages"] = r["reclaimed_pages"]
    obs.memory.sample()        # reconcile serve.kv_pages/params tags
    write_bench("serve", {
        "arch": cfg.name, "batches": args.batches,
        "scale_batches": args.scale_batches,
        "prompt_len": args.prompt_len, "new_tokens": args.tokens,
        "page_size": args.page_size,
    }, metrics, out=args.out or None)
    obs.write_outputs(args)


if __name__ == "__main__":
    main()
