"""Serving throughput: dense static-batch vs paged continuous batching.

``PYTHONPATH=src python -m benchmarks.bench_serve --arch qwen3-4b --smoke \
      --batches 2,4,8``

For each batch size, generates the same greedy workload through both
paths and reports tokens/sec plus paged-pool utilization, written as
BENCH_serve.json at the repo root ({name, config, metrics} — the shared
benchmark schema, benchmarks/bench_util.py; metrics are flattened per
batch size as ``b<N>_dense_tps`` etc.).

On CPU this measures engine overhead, not kernel speed (the Pallas paged
kernel only engages on TPU); the point of the JSON is tracking the
dense/paged ratio and page accounting across PRs.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import obs
from repro.configs import ServeConfig, get_arch, reduced
from repro.serve import DenseServer, Engine, SamplingParams

from .bench_util import write_bench


def bench_one(cfg, batch: int, prompt_len: int, new_tokens: int,
              page_size: int, seed: int = 0):
    total = cfg.num_image_tokens + prompt_len + new_tokens
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (batch, prompt_len)).astype(np.int32)
    serve = ServeConfig(
        page_size=page_size,
        num_pages=1 + batch * (-(-(total + 1) // page_size)),
        max_batch_slots=batch, max_seq_len=total,
        max_new_tokens=new_tokens)
    eng = Engine(cfg, serve)
    srv = DenseServer(cfg, eng.params, batch, prompt_len, new_tokens)

    # warm both compile caches out of the timed region — with the
    # recorder disarmed, so compile spans never pollute the latency
    # attribution. The region's wall clock IS recorded (serve.compile_ms
    # gauge + b<N>_compile_ms metric): compile time is attributed, not
    # discarded.
    warm = [list(p) for p in prompts]
    rec = obs.get()
    if rec.enabled:
        obs.uninstall()
    t0 = time.perf_counter()
    try:
        eng.generate(warm, SamplingParams(), new_tokens)
        srv.generate(prompts)
    finally:
        compile_ms = (time.perf_counter() - t0) * 1e3
        if rec.enabled:
            obs.install(rec)
            rec.gauge("serve.compile_ms").set(compile_ms)
            rec.histogram("serve.compile_warm_ms").observe(compile_ms)

    t0 = time.perf_counter()
    dense = srv.generate(prompts)
    dense_dt = time.perf_counter() - t0

    eng2 = Engine(cfg, serve, params=eng.params)
    eng2._decode = eng._decode            # reuse compiled decode
    eng2._prefill_cache = eng._prefill_cache
    t0 = time.perf_counter()
    paged = eng2.generate(warm, SamplingParams(), new_tokens)
    paged_dt = time.perf_counter() - t0

    n_tok = batch * new_tokens
    assert [list(d) for d in dense] == paged, "dense/paged diverged"
    util = eng2.page_utilization()
    eng.release_memory_tags()      # retired below; keep live bytes honest
    eng2.release_memory_tags()
    return {
        "batch": batch,
        "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "dense_tps": n_tok / dense_dt,
        "paged_tps": n_tok / paged_dt,
        "compile_ms": compile_ms,
        "engine_steps": eng2.steps_run,
        "total_pages": util["total_pages"],
        "page_util_peak": util["peak_util"],
        "page_util_mean": util["mean_util"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batches", default="2,4,8")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--out", default="")
    obs.add_observability_args(ap)
    args = ap.parse_args(argv)
    obs.configure_from_args(args)
    if not obs.get().enabled:
        obs.install()      # BENCH_serve.json always carries timings

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    metrics = {}
    for b in [int(x) for x in args.batches.split(",")]:
        r = bench_one(cfg, b, args.prompt_len, args.tokens, args.page_size)
        print(f"# batch={b}: dense {r['dense_tps']:.1f} tok/s, paged "
              f"{r['paged_tps']:.1f} tok/s, peak pages "
              f"{100 * r['page_util_peak']:.0f}%", flush=True)
        for k in ("dense_tps", "paged_tps", "compile_ms", "engine_steps",
                  "total_pages", "page_util_peak", "page_util_mean"):
            metrics[f"b{b}_{k}"] = r[k]
    obs.memory.sample()        # reconcile serve.kv_pages/params tags
    write_bench("serve", {
        "arch": cfg.name, "batches": args.batches,
        "prompt_len": args.prompt_len, "new_tokens": args.tokens,
        "page_size": args.page_size,
    }, metrics, out=args.out or None)
    obs.write_outputs(args)


if __name__ == "__main__":
    main()
