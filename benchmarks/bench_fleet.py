"""Fleet training: wall-clock and bytes-on-wire vs single-worker.

``PYTHONPATH=src python -m benchmarks.bench_fleet --arch llama3-8b \
      --smoke --workers 8 --steps 10 --dropout 0.1``

Runs the same workload twice — a W-worker chaos fleet (repro.fleet) and
a single-worker fleet (the degenerate W=1 deployment, no chaos) — and
reports wall-clock, per-step bytes on the wire split into the ZO scalar
part and the int8 BP-tail part, and the ZO bytes/worker/step against the
protocol floor of ``probes_per_worker * (8 + 4)`` bytes (one u64 seed +
one f32 loss-diff per probe; acceptance bar: within 2x, the header is
the only overhead). Writes BENCH_fleet.json ({name, config, metrics}).

On CPU wall-clock measures protocol + engine overhead, not kernel speed;
the bytes accounting is exact on any backend.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import FleetConfig, LaneConfig, ShapeConfig, get_arch, reduced
from repro.core import api
from repro.data.synthetic import token_batch
from repro.fleet import run_fleet
from repro.sharding.rules import ShardingRules

from .bench_util import write_bench


def bench_one(model, lane, fleet_cfg, batch_fn, steps, base_seed):
    res = run_fleet(model.loss_fn, model.init(jax.random.key(0)), lane,
                    fleet_cfg, batch_fn, steps=steps, base_seed=base_seed)
    s = res.stats
    n_records = sum(len(t) for t in res.ledger.records.values())
    return {
        "wall_s_per_step": s["wall_s"] / steps,
        "zo_bytes_per_step": s["ledger_bytes_zo"] / steps,
        "zo_bytes_per_worker_step": s["ledger_bytes_zo"] / max(n_records, 1),
        "tail_bytes_per_step": s["ledger_bytes_tail"] / steps,
        "uplink_bytes_per_step": s["bytes_uplink"] / steps,
        "n_dropped": s["n_dropped"],
        "n_straggled": s["n_straggled"],
        "final_loss": res.coordinator.loss_history[-1][1],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--probes-per-worker", type=int, default=1)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--dropout", type=float, default=0.1)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    lane = LaneConfig(lane="elastic_zo", bp_tail_layers=1,
                      zo_num_probes=args.probes_per_worker,
                      learning_rate=1e-2, zo_eps=1e-3)
    shape = ShapeConfig("bench_fleet", seq_len=args.seq,
                        global_batch=args.batch, kind="train")
    model = api.build(cfg, shape, lane, ShardingRules(None, cfg, shape))
    base_seed = jax.random.key_data(jax.random.key(1))

    def batch_fn(step):
        x, y, m = token_batch(args.batch, args.seq, cfg.vocab_size,
                              seed=1, step=step)
        return {"tokens": jnp.asarray(x), "labels": jnp.asarray(y),
                "mask": jnp.asarray(m)}

    fleet = bench_one(
        model, lane,
        FleetConfig(num_workers=args.workers,
                    probes_per_worker=args.probes_per_worker,
                    dropout=args.dropout, max_delay=2, deadline=1,
                    chaos_seed=0),
        batch_fn, args.steps, base_seed)
    single = bench_one(
        model, lane,
        FleetConfig(num_workers=1,
                    probes_per_worker=args.probes_per_worker),
        batch_fn, args.steps, base_seed)

    floor = args.probes_per_worker * (8 + 4)
    metrics = {
        **{f"fleet_{k}": v for k, v in fleet.items()},
        **{f"single_{k}": v for k, v in single.items()},
        "zo_bytes_floor_per_worker_step": floor,
        "zo_bytes_overhead_ratio":
            fleet["zo_bytes_per_worker_step"] / floor,
    }
    print(f"# fleet {args.workers}w: {fleet['wall_s_per_step']:.3f}s/step, "
          f"ZO {fleet['zo_bytes_per_worker_step']:.1f}B/worker/step "
          f"(floor {floor}B, x{metrics['zo_bytes_overhead_ratio']:.2f}), "
          f"tail {fleet['tail_bytes_per_step']:.0f}B/step")
    print(f"# single 1w: {single['wall_s_per_step']:.3f}s/step")
    write_bench("fleet", {
        "arch": cfg.name, "workers": args.workers,
        "probes_per_worker": args.probes_per_worker, "steps": args.steps,
        "batch": args.batch, "seq": args.seq, "dropout": args.dropout,
    }, metrics, out=args.out or None)


if __name__ == "__main__":
    main()
