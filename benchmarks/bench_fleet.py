"""Fleet training: wall-clock and bytes-on-wire, fp32 vs int8 lanes.

``PYTHONPATH=src python -m benchmarks.bench_fleet --workers 8 --steps 10``

Runs the seed-ledger fleet (repro.fleet) in both numerics lanes and a
single-worker fp32 control:

  * fp32 (``--arch`` LM, elastic_zo): 12 B/probe ZO records (u64 seed +
    f32 loss-diff) + error-feedback int8 tail payloads;
  * int8 (LeNet-5, ElasticZO-INT8 / Alg. 2): **9 B/probe** ZO records
    (u64 seed + ternary sign byte, record v2) + exact int8 NITI tail
    payloads;
  * single (1 worker, no chaos): the degenerate deployment baseline.

Reports per-step wall-clock and the wire split (ZO scalars vs tail
payload), the ZO bytes/worker/step against each lane's protocol floor
(probes x 12 B fp32, probes x 9 B int8; acceptance: within 2x — the
record header is the only overhead), and the fp32/int8 ratios. Writes
BENCH_fleet.json ({name, config, metrics}).

``--byzantine 'w:attack[:amp],...'`` additionally measures training
under attack (fleet/adversary.py) in each selected lane: final loss of
the attack-free run vs the attacked run without and with the robust
commit filter (fleet/robust.py), plus the filter's wall-clock overhead
— the cost of Byzantine tolerance is a handful of host-side scalar
medians per step.

``--topology gossip`` additionally runs the same chaos fleet
leaderlessly (fleet/gossip.py) and reports the wire trade: the star
uplink+broadcast vs the gossip uplink+epidemic-copy bytes per step.
The commit streams are identical (the commit rule is one pure
function); only who carries the bytes changes.

On CPU wall-clock measures protocol + engine overhead, not kernel speed;
the bytes accounting is exact on any backend. ``--fast`` shrinks steps
for the CI bench-smoke job.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs import (FleetConfig, GossipConfig, LaneConfig,
                           RobustConfig, ShapeConfig, get_arch, reduced)
from repro.core import api
from repro.data.synthetic import token_batch
from repro.fleet import parse_byzantine, run_fleet
from repro.sharding.rules import ShardingRules

from .bench_util import write_bench


def summarize(res, steps):
    s = res.stats
    n_records = sum(len(t) for t in res.ledger.records.values())
    # step 0 always holds >= 1 record: the coordinator force-accepts the
    # earliest arrival when everything misses the deadline
    some_rec = next(iter(res.ledger.records[0].values()))
    return {
        "wall_s_per_step": s["wall_s"] / steps,
        "zo_bytes_per_step": s["ledger_bytes_zo"] / steps,
        "zo_bytes_per_worker_step": s["ledger_bytes_zo"] / max(n_records, 1),
        "zo_bytes_per_probe": some_rec.zo_probe_nbytes,
        "tail_bytes_per_step": s["ledger_bytes_tail"] / steps,
        "uplink_bytes_per_step": s["bytes_uplink"] / steps,
        "broadcast_bytes_per_step": s["bytes_broadcast"] / steps,
        "gossip_bytes_per_step": s["bytes_gossip"] / steps,
        "n_dropped": s["n_dropped"],
        "n_straggled": s["n_straggled"],
        "n_rejected": s["n_rejected"],
        "n_filtered_probes": s["n_filtered_probes"],
        "n_quarantines": s["n_quarantines"],
        "final_loss": res.coordinator.loss_history[-1][1],
        **tail_wire_table(res),
    }


def tail_wire_table(res):
    """Per-worker BP-tail bytes the wire carried (accepted ledger records
    only) — the ``wire.tail_bytes.w<NN>`` rows. Uneven rows localize a
    worker whose tail payloads are dropped (chaos) or rejected
    (Byzantine filter) without eyeballing the trace."""
    tot: dict = {}
    for recs in res.ledger.records.values():
        for w, r in recs.items():
            tot[w] = tot.get(w, 0) + r.tail_nbytes
    return {f"wire.tail_bytes.w{w:02d}": float(v)
            for w, v in sorted(tot.items())}


def pop_tail_table(summary: dict, prefix: str = "") -> dict:
    """Extract the per-worker wire table from a summarize() dict. With a
    prefix, returns it as ``<prefix>.wire.tail_bytes.w<NN>`` metric rows;
    without, the table is dropped (control runs)."""
    keys = [k for k in summary if k.startswith("wire.tail_bytes.")]
    table = {k: summary.pop(k) for k in keys}
    if not prefix:
        return {}
    return {f"{prefix}.{k}": v for k, v in sorted(table.items())}


def make_fp32_setup(args):
    """(model, lane, batch_fn) built once and shared by the chaos fleet
    and the single-worker control run."""
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    lane = LaneConfig(lane="elastic_zo", bp_tail_layers=1,
                      zo_num_probes=args.probes_per_worker,
                      learning_rate=1e-2, zo_eps=1e-3)
    shape = ShapeConfig("bench_fleet", seq_len=args.seq,
                        global_batch=args.batch, kind="train")
    model = api.build(cfg, shape, lane, ShardingRules(None, cfg, shape))

    def batch_fn(step):
        x, y, m = token_batch(args.batch, args.seq, cfg.vocab_size,
                              seed=1, step=step)
        return {"tokens": jnp.asarray(x), "labels": jnp.asarray(y),
                "mask": jnp.asarray(m)}

    return model, lane, batch_fn


def bench_fp32(setup, fleet_cfg, steps):
    model, lane, batch_fn = setup
    base_seed = jax.random.key_data(jax.random.key(1))
    res = run_fleet(model.loss_fn, model.init(jax.random.key(0)), lane,
                    fleet_cfg, batch_fn, steps=steps, base_seed=base_seed)
    return summarize(res, steps)


def bench_int8(args, fleet_cfg, steps):
    # the one int8 deployment assembly, shared with the fleet CLI
    from repro.launch.fleet import lenet_int8_fleet_setup
    params, lane, partition_fn, probe_fn, batch_fn = \
        lenet_int8_fleet_setup(bp_tail_layers=1,
                               probes=args.probes_per_worker,
                               batch=args.batch, seed=0)
    base_seed = jax.random.key_data(jax.random.key(1))
    res = run_fleet(None, params, lane, fleet_cfg, batch_fn, steps=steps,
                    base_seed=base_seed, partition_fn=partition_fn,
                    probe_fn=probe_fn)
    return summarize(res, steps)


def bench_gossip(args, chaos, steps, star_metrics, runner, tag):
    """Leaderless wire trade for one lane: run the same chaos fleet with
    --topology gossip and compare bytes-on-wire per step against the
    star run (`star_metrics`, already measured by the main pass)."""
    gossip = dataclasses.replace(
        chaos, topology="gossip",
        gossip=GossipConfig(fanout=args.gossip_fanout,
                            rounds=args.gossip_rounds))
    g = {k: v for k, v in runner(gossip).items()
         if not k.startswith("wire.tail_bytes.")}   # table: chaos run only
    star_wire = star_metrics["uplink_bytes_per_step"] \
        + star_metrics["broadcast_bytes_per_step"]
    gossip_wire = g["uplink_bytes_per_step"] + g["gossip_bytes_per_step"]
    out = {f"gossip_{k}": v for k, v in g.items()}
    out["gossip_vs_star_wire_ratio"] = gossip_wire / max(star_wire, 1e-9)
    print(f"# {tag} gossip {args.workers}w: "
          f"{g['wall_s_per_step']:.3f}s/step, uplink "
          f"{g['uplink_bytes_per_step']:.0f}B/step + epidemic "
          f"{g['gossip_bytes_per_step']:.0f}B/step vs star "
          f"{star_metrics['uplink_bytes_per_step']:.0f}B uplink + "
          f"{star_metrics['broadcast_bytes_per_step']:.0f}B broadcast "
          f"(wire x{out['gossip_vs_star_wire_ratio']:.2f}, "
          "no coordinator to lose)")
    return out


def bench_byzantine(args, chaos, steps, free_metrics, runner, tag):
    """Accuracy-under-attack + filter overhead for one lane.

    runner(fleet_cfg) -> summarize() dict; `free_metrics` is the lane's
    attack-free chaos-fleet summary (already measured by the main pass).
    """
    specs = parse_byzantine(args.byzantine)
    attacked = dataclasses.replace(chaos, byzantine=specs)
    robust = dataclasses.replace(attacked, robust=RobustConfig())
    unfilt = runner(attacked)
    filt = runner(robust)
    overhead = filt["wall_s_per_step"] / max(unfilt["wall_s_per_step"],
                                             1e-9)
    out = {
        "byz_final_loss_attack_free": free_metrics["final_loss"],
        "byz_final_loss_unfiltered": unfilt["final_loss"],
        "byz_final_loss_filtered": filt["final_loss"],
        "byz_filter_wall_overhead": overhead,
    }
    print(f"# {tag} byzantine [{args.byzantine}]: final loss "
          f"free {out['byz_final_loss_attack_free']:.4f} / "
          f"attacked {out['byz_final_loss_unfiltered']:.4f} / "
          f"filtered {out['byz_final_loss_filtered']:.4f}; "
          f"filter wall x{overhead:.2f}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--lane", default="both",
                    choices=["both", "fp32", "int8"])
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--probes-per-worker", type=int, default=1)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--dropout", type=float, default=0.1)
    ap.add_argument("--byzantine", default="",
                    help="worker:attack[:amp] specs: also benchmark "
                         "accuracy-under-attack and robust-filter "
                         "overhead (fleet/adversary.py, fleet/robust.py)")
    ap.add_argument("--topology", default="star",
                    choices=["star", "gossip"],
                    help="gossip: also run the leaderless fleet "
                         "(fleet/gossip.py) and record uplink/broadcast "
                         "vs epidemic bytes against the star run")
    ap.add_argument("--gossip-fanout", type=int, default=2)
    ap.add_argument("--gossip-rounds", type=int, default=2)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke scale (fewer steps, reduced arch)")
    ap.add_argument("--out", default="")
    obs.add_observability_args(ap)
    args = ap.parse_args(argv)
    obs.configure_from_args(args)
    if not obs.get().enabled:
        obs.install()      # BENCH_fleet.json always carries timings
    if args.fast:
        args.smoke = True
        args.steps = min(args.steps, 4)

    chaos = FleetConfig(num_workers=args.workers,
                        probes_per_worker=args.probes_per_worker,
                        dropout=args.dropout, max_delay=2, deadline=1,
                        chaos_seed=0)
    calm = FleetConfig(num_workers=1,
                       probes_per_worker=args.probes_per_worker)

    metrics, arch_name = {}, "-"
    if args.lane in ("both", "fp32"):
        setup = make_fp32_setup(args)
        arch_name = setup[0].cfg.name
        fleet = bench_fp32(setup, chaos, args.steps)
        single = bench_fp32(setup, calm, args.steps)
        if args.byzantine:
            byz = bench_byzantine(
                args, chaos, args.steps, fleet,
                lambda cfg: bench_fp32(setup, cfg, args.steps), "fp32")
            metrics.update({f"fleet_{k}": v for k, v in byz.items()})
        if args.topology == "gossip":
            gos = bench_gossip(
                args, chaos, args.steps, fleet,
                lambda cfg: bench_fp32(setup, cfg, args.steps), "fp32")
            metrics.update({f"fleet_{k}": v for k, v in gos.items()})
        floor = args.probes_per_worker * 12
        metrics.update(pop_tail_table(fleet, "fleet"))
        pop_tail_table(single)             # 1-worker control: no table
        metrics.update({f"fleet_{k}": v for k, v in fleet.items()})
        metrics.update({f"single_{k}": v for k, v in single.items()})
        metrics["zo_bytes_floor_per_worker_step"] = floor
        metrics["zo_bytes_overhead_ratio"] = \
            fleet["zo_bytes_per_worker_step"] / floor
        print(f"# fp32 fleet {args.workers}w: "
              f"{fleet['wall_s_per_step']:.3f}s/step, "
              f"ZO {fleet['zo_bytes_per_worker_step']:.1f}B/worker/step "
              f"(floor {floor}B, x{metrics['zo_bytes_overhead_ratio']:.2f}),"
              f" tail {fleet['tail_bytes_per_step']:.0f}B/step")
        print(f"# fp32 single 1w: {single['wall_s_per_step']:.3f}s/step")
    if args.lane in ("both", "int8"):
        i8 = bench_int8(args, chaos, args.steps)
        if args.byzantine:
            byz8 = bench_byzantine(
                args, chaos, args.steps, i8,
                lambda cfg: bench_int8(args, cfg, args.steps), "int8")
            metrics.update({f"int8_fleet_{k}": v for k, v in byz8.items()})
        if args.topology == "gossip":
            gos8 = bench_gossip(
                args, chaos, args.steps, i8,
                lambda cfg: bench_int8(args, cfg, args.steps), "int8")
            metrics.update({f"int8_fleet_{k}": v for k, v in gos8.items()})
        floor8 = args.probes_per_worker * 9
        metrics.update(pop_tail_table(i8, "int8_fleet"))
        metrics.update({f"int8_fleet_{k}": v for k, v in i8.items()})
        metrics["int8_zo_bytes_floor_per_worker_step"] = floor8
        metrics["int8_zo_bytes_overhead_ratio"] = \
            i8["zo_bytes_per_worker_step"] / floor8
        print(f"# int8 fleet {args.workers}w: "
              f"{i8['wall_s_per_step']:.3f}s/step, "
              f"ZO {i8['zo_bytes_per_worker_step']:.1f}B/worker/step "
              f"({i8['zo_bytes_per_probe']}B/probe, floor {floor8}B), "
              f"tail {i8['tail_bytes_per_step']:.0f}B/step")
    if args.lane == "both":
        metrics["int8_over_fp32_zo_bytes"] = \
            metrics["int8_fleet_zo_bytes_per_step"] \
            / metrics["fleet_zo_bytes_per_step"]
        metrics["int8_over_fp32_wall"] = \
            metrics["int8_fleet_wall_s_per_step"] \
            / metrics["fleet_wall_s_per_step"]
        print("# int8/fp32: ZO bytes x"
              f"{metrics['int8_over_fp32_zo_bytes']:.2f}, "
              f"step-time x{metrics['int8_over_fp32_wall']:.2f} "
              "(different models — the bytes ratio is the protocol "
              "claim, 9/12 per probe)")

    obs.memory.sample()    # reconcile fleet ledger/param tags vs jax live
    write_bench("fleet", {
        "arch": arch_name, "lane": args.lane, "workers": args.workers,
        "probes_per_worker": args.probes_per_worker, "steps": args.steps,
        "batch": args.batch, "seq": args.seq, "dropout": args.dropout,
        "byzantine": args.byzantine, "topology": args.topology,
    }, metrics, out=args.out or None)
    obs.write_outputs(args)


if __name__ == "__main__":
    main()
