"""Byzantine chaos matrix: 8 workers x {fp32, int8} x every adversary.

For each attack model in fleet/adversary.py, in both numerics lanes,
under transport chaos (dropout + stragglers):

  (a) the robust-filtered fleet's canonical parameter stream is
      bit-exact vs the filtered single-process reference, which
      re-derives every validation/quarantine/filter verdict itself from
      the realized arrival masks — including the Commit v2 stream;
  (b) the filtered run's final loss stays within tolerance of the
      attack-free run (the attack is *neutralized*, not just survived);
  (c) the unfiltered attacked run demonstrably diverges from the
      attack-free canon — and, for the statistical attacks, from the
      filtered run too — proving the filter does real work.

Marked ``chaos``: CI runs this matrix in a dedicated job (once also
under PYTHONOPTIMIZE=1 — the gate must be assert-free).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import (ByzantineSpec, FleetConfig, LaneConfig,
                           RobustConfig, ShapeConfig, get_arch, reduced)
from repro.core import api
from repro.core.int8 import quant_from_float
from repro.data.synthetic import glyphs, token_batch
from repro.fleet import (make_int8_probe_fn, make_probe_fn,
                         make_reference_step, reference_state, run_fleet)
from repro.fleet.adversary import ATTACKS
from repro.models import lenet
from repro.sharding.rules import ShardingRules
from repro.train.train_loop import LoopConfig, run

pytestmark = pytest.mark.chaos

WORKERS = 8
STEPS = 5
ROBUST = RobustConfig(window=3, quarantine_after=2, quarantine_steps=2)
# statistical attacks are caught by the scalar/loss filter; protocol
# attacks are caught by validation (which is on even without robust)
STATISTICAL = ("inflate", "sign_flip", "freeload", "collude")
PROTOCOL = ("seed_lie", "stale_replay")
# workers 2 and 4 are on time every step under the chaos params below
# (the attack must actually land for the divergence assertions to bite)
ATTACKER = 4
CLIQUE = (2, 4)


def specs_for(attack):
    if attack == "collude":
        return tuple(ByzantineSpec(w, "collude") for w in CLIQUE)
    return (ByzantineSpec(ATTACKER, attack),)


def test_matrix_covers_every_adversary():
    """The matrix below must enumerate fleet/adversary.py exactly."""
    assert set(STATISTICAL) | set(PROTOCOL) == set(ATTACKS)


def fleet_cfg(byzantine=(), robust=None):
    # chaos params chosen so every step keeps an honest MAJORITY on time
    # (>= 5 of 8 under chaos_seed=3) while still exercising drops and
    # stragglers — with <= 2 sound records the filter has no majority to
    # lean on, by design (docs/fleet.md, residual risks)
    return FleetConfig(num_workers=WORKERS, probes_per_worker=1,
                       dropout=0.1, max_delay=3, deadline=2,
                       chaos_seed=3, snapshot_every=4,
                       byzantine=byzantine, robust=robust)


def _bitwise_equal(a, b):
    return all(jnp.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------------------------ #
# lane environments (one jitted probe_fn each, shared by every run)
# ------------------------------------------------------------------ #


@pytest.fixture(scope="module")
def fp32env():
    cfg = reduced(get_arch("llama3-8b"), num_layers=2, d_model=32,
                  num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=128)
    lane = LaneConfig(lane="elastic_zo", bp_tail_layers=1,
                      learning_rate=5e-2, zo_eps=1e-3)
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    model = api.build(cfg, shape, lane, ShardingRules(None, cfg, shape))
    params = model.init(jax.random.key(0))

    def batch_fn(step):
        x, y, m = token_batch(2, 16, cfg.vocab_size, seed=1, step=step)
        return {"tokens": jnp.asarray(x), "labels": jnp.asarray(y),
                "mask": jnp.asarray(m)}

    # tolerance calibration: removing the attacker's probes changes the
    # (5-step) trajectory by a few percent — a real attack landing
    # unfiltered moves the loss by far more than 12%
    env = dict(lane=lane, params=params, batch_fn=batch_fn,
               partition_fn=None,
               probe_fn=make_probe_fn(model.loss_fn, lane),
               base_seed=jax.random.key_data(jax.random.key(1)),
               loss_tol=0.12)
    env["free"] = _run(env, (), None)
    return env


@pytest.fixture(scope="module")
def int8env():
    lane = LaneConfig(lane="elastic_zo_int8", zo_num_probes=1)
    part = lambda p: lenet.partition_at(p, 4)  # noqa: E731

    def batch_fn(step):
        xs, ys = glyphs(8, seed=1, start=step * 8)
        return {"x": quant_from_float(jnp.asarray(xs)),
                "y": jnp.asarray(ys)}

    env = dict(lane=lane, params=lenet.init_lenet5_int8(jax.random.key(0)),
               batch_fn=batch_fn, partition_fn=part,
               probe_fn=make_int8_probe_fn(lenet.lenet5_forward_int8, lane,
                                           part, [("fc3", "fc3_in")]),
               base_seed=jax.random.key_data(jax.random.key(1)),
               loss_tol=0.25)
    env["free"] = _run(env, (), None)
    return env


def _run(env, byzantine, robust):
    return run_fleet(None, env["params"], env["lane"],
                     fleet_cfg(byzantine, robust), env["batch_fn"],
                     steps=STEPS, base_seed=env["base_seed"],
                     partition_fn=env["partition_fn"],
                     probe_fn=env["probe_fn"], trace=True)


def _reference_trace(env, res):
    """Drive the single-process reference with the realized arrival
    masks; it re-derives every gate verdict itself."""
    step_fn = make_reference_step(None, res.schema,
                                  probe_fn=env["probe_fn"])
    state = reference_state(env["params"], res.schema, env["base_seed"])
    trace = []

    def recording_step(s, batch, mask):
        s2, metrics = step_fn(s, batch, mask)
        trace.append(jax.tree.map(np.asarray, s2.params["model"]))
        return s2, metrics

    loop = LoopConfig(total_steps=STEPS, log_every=0,
                      n_probes=res.schema.n_probes,
                      mask_fn=lambda t: res.arrival_masks[t], jit=False)
    run(recording_step, state, env["batch_fn"], loop)
    return trace, step_fn.commits


def _assert_matrix_case(env, attack):
    specs = specs_for(attack)
    filt = _run(env, specs, ROBUST)
    unfilt = _run(env, specs, None)
    free = env["free"]

    # (a) bit-exact vs the filtered single-process reference, at every
    # step, including the derived Commit v2 stream
    trace, commits = _reference_trace(env, filt)
    assert len(trace) == STEPS == len(filt.param_trace)
    for t, (a, b) in enumerate(zip(filt.param_trace, trace)):
        assert _bitwise_equal(a, b), f"{attack}: diverged at step {t}"
    for t in range(STEPS):
        ca, cb = filt.ledger.commits[t], commits[t]
        assert (ca.step, ca.accepted, ca.quarantined, ca.filtered) == \
            (cb.step, cb.accepted, cb.quarantined, cb.filtered), \
            f"{attack}: commit diverged at step {t}"

    # (b) the filtered run's final loss is within tolerance of the
    # attack-free run: the attack is neutralized
    l_free = free.coordinator.loss_history[-1][1]
    l_filt = filt.coordinator.loss_history[-1][1]
    tol = max(env["loss_tol"] * abs(l_free), env["loss_tol"])
    assert abs(l_filt - l_free) <= tol, \
        f"{attack}: filtered loss {l_filt:.4f} vs free {l_free:.4f}"

    # (c) the unfiltered run demonstrably diverges from the attack-free
    # canon — the attack has teeth...
    assert not _bitwise_equal(unfilt.params, free.params), \
        f"{attack}: unfiltered attacked run == attack-free run"
    if attack in STATISTICAL:
        # ...and the filter did real work: it masked probes, and either
        # the filtered canon differs from the unfiltered one (the attack
        # had a parameter channel) or the loss metric was protected.
        # The int8 freeloader is the parameter-neutral case: a masked
        # int8 probe with g=0 is the same exact no-op as an unmasked
        # one, so only the fabricated loss needs filtering.
        assert filt.stats["n_filtered_probes"] > 0, attack
        params_changed = not _bitwise_equal(filt.params, unfilt.params)
        l_unfilt = unfilt.coordinator.loss_history[-1][1]
        metric_protected = abs(l_unfilt - l_free) > tol \
            and abs(l_filt - l_free) <= tol
        assert params_changed or metric_protected, \
            f"{attack}: filter changed neither params nor the metric"
    else:
        # protocol attacks: validation rejects in BOTH runs — the liar
        # never lands a record after its honest step-0 stash
        ok_from = 1 if attack == "stale_replay" else 0
        for res in (filt, unfilt):
            for t in range(ok_from, STEPS):
                assert not res.ledger.commits[t].accepted >> ATTACKER & 1, \
                    f"{attack}: liar committed at step {t}"
        assert unfilt.stats["n_rejected"] > 0
    return filt


@pytest.mark.parametrize("attack", ATTACKS)
def test_fp32_chaos_matrix(fp32env, attack):
    _assert_matrix_case(fp32env, attack)


@pytest.mark.parametrize("attack", ATTACKS)
def test_int8_chaos_matrix(int8env, attack):
    _assert_matrix_case(int8env, attack)


def test_fp32_no_false_positives(fp32env):
    """Attack-free + robust filter on: no honest probe is ever filtered
    and the canon is bit-identical to the filter-free run (the filter
    pays for itself only when someone lies)."""
    res = _run(fp32env, (), ROBUST)
    assert res.stats["n_filtered_probes"] == 0
    assert res.stats["n_quarantines"] == 0
    assert _bitwise_equal(res.params, fp32env["free"].params)
    # wire form: commits are v2 with all-ones bits
    for c in res.ledger.commits.values():
        assert c.version == 2 and c.inband(res.schema.n_probes).all()


def test_int8_no_false_positives(int8env):
    res = _run(int8env, (), ROBUST)
    assert res.stats["n_filtered_probes"] == 0
    assert res.stats["n_quarantines"] == 0
    assert _bitwise_equal(res.params, int8env["free"].params)


def test_quarantine_fires_in_matrix(fp32env):
    """A persistent inflate attacker lands in quarantine (commit v2
    carries the set) and the fleet keeps training without it."""
    res = _run(fp32env, (ByzantineSpec(ATTACKER, "inflate"),), ROBUST)
    assert res.stats["n_quarantines"] >= 1
    quar = [t for t, c in res.ledger.commits.items()
            if c.quarantined >> ATTACKER & 1]
    assert quar, "quarantine never recorded in a commit"
    for t in quar:
        assert not res.ledger.commits[t].accepted >> ATTACKER & 1
