"""Sampler: greedy parity, seed determinism, top-k/top-p filtering."""
import numpy as np

import jax.numpy as jnp

from repro.serve.sampler import sample_tokens


def _call(logits, temperature=1.0, top_k=0, top_p=1.0, seed=0, step=0):
    B = logits.shape[0]
    full = lambda v, dt: jnp.full((B,), v, dt)  # noqa: E731
    return np.asarray(sample_tokens(
        jnp.asarray(logits, jnp.float32), full(temperature, jnp.float32),
        full(top_k, jnp.int32), full(top_p, jnp.float32),
        full(np.uint32(seed), jnp.uint32), full(step, jnp.int32)))


def test_greedy_at_zero_temperature():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(5, 64)).astype(np.float32)
    out = _call(logits, temperature=0.0, seed=7)
    assert (out == logits.argmax(-1)).all()


def test_seed_determinism_and_divergence():
    rng = np.random.default_rng(1)
    logits = rng.normal(size=(4, 256)).astype(np.float32)
    a = _call(logits, seed=11, step=3)
    b = _call(logits, seed=11, step=3)
    assert (a == b).all()                       # replayable
    streams = [_call(logits, seed=s, step=3) for s in range(40)]
    assert any((s != a).any() for s in streams)  # seeds actually matter
    steps = [_call(logits, seed=11, step=t) for t in range(40)]
    assert any((s != a).any() for s in steps)    # steps actually matter


def test_top_k_restricts_support():
    rng = np.random.default_rng(2)
    logits = rng.normal(size=(2, 128)).astype(np.float32)
    top3 = set(np.argsort(-logits[0])[:3].tolist()) | \
        set(np.argsort(-logits[1])[:3].tolist())
    for seed in range(50):
        out = _call(logits, temperature=2.0, top_k=3, seed=seed)
        assert all(int(t) in top3 for t in out)


def test_top_k_one_is_greedy():
    rng = np.random.default_rng(3)
    logits = rng.normal(size=(3, 64)).astype(np.float32)
    out = _call(logits, temperature=5.0, top_k=1, seed=9)
    assert (out == logits.argmax(-1)).all()


def test_top_p_restricts_support():
    # one dominant token + uniform tail: even after temperature flattening
    # (filters see logits/t) the nucleus at p=0.5 is just that token
    logits = np.zeros((1, 32), np.float32)
    logits[0, 17] = 30.0
    for seed in range(50):
        out = _call(logits, temperature=3.0, top_p=0.5, seed=seed)
        assert out[0] == 17


def test_padded_vocab_never_sampled():
    """Columns >= vocab_size are huge but masked: sampling stays in-vocab."""
    logits = np.zeros((2, 64), np.float32)
    logits[:, 48:] = 50.0                           # 'padded' columns
    for seed in range(30):
        out = np.asarray(sample_tokens(
            jnp.asarray(logits), jnp.full((2,), 2.0, jnp.float32),
            jnp.zeros((2,), jnp.int32), jnp.ones((2,), jnp.float32),
            jnp.full((2,), np.uint32(seed), jnp.uint32),
            jnp.zeros((2,), jnp.int32), vocab_size=48))
        assert (out < 48).all()


def test_mixed_rows_independent():
    """Greedy and sampled rows coexist in one batch."""
    rng = np.random.default_rng(4)
    logits = rng.normal(size=(2, 64)).astype(np.float32)
    B = 2
    out = np.asarray(sample_tokens(
        jnp.asarray(logits, jnp.float32),
        jnp.asarray([0.0, 1.5], jnp.float32),
        jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.float32),
        jnp.asarray([5, 5], jnp.uint32),
        jnp.zeros((B,), jnp.int32)))
    assert out[0] == logits[0].argmax()
