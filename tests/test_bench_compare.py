"""BENCH regression gate (benchmarks/compare.py).

Pins the contract CI relies on: a byte-identical rerun is in band
(exit 0), each rule only fails in its regression direction (exit 1),
and a config change is neither — it demands a re-baseline (exit 2).
"""
import copy
import json

import pytest

from benchmarks.compare import main as compare

BASE = {
    "name": "t",
    "config": {"arch": "tiny", "steps": 4},
    "metrics": {
        "b2_dense_tps": 100.0,
        "fleet_wall_s_per_step": 0.5,
        "fleet_zo_bytes_per_step": 96.0,
        "table1_fp32_lenet_acc_full_zo": 0.8,
        "memory_measured_lenet_b32_full_zo_peak_bytes": 3_000_000,
        "memory_resid_lenet_b32_full_zo_bytes": 1_300_000,
        "memory_lenet_b32_bp_over_zo": 1.85,
        "final_loss": 2.0,
    },
    "counters": {"counters": {"fleet.wire.tail_bytes": 4096},
                 "gauges": {"serve.compile_ms": 812.0}},
    "timings": {"histograms": {"fleet.step_ms": {
        "count": 4, "p50": 10.0, "p99": 12.0}}},
    "memory": {"ledger": {"peak": {"fleet.ledger.zo": 96}}},
}


@pytest.fixture
def files(tmp_path):
    """-> (write_fresh, base_path): dump a doc, get its path."""
    base_path = tmp_path / "BENCH_t.json"
    base_path.write_text(json.dumps(BASE))

    def write_fresh(doc):
        p = tmp_path / "fresh.json"
        p.write_text(json.dumps(doc))
        return [str(p), "--baseline", str(base_path)]

    return write_fresh, base_path


def perturbed(**metric_updates):
    doc = copy.deepcopy(BASE)
    doc["metrics"].update(metric_updates)
    return doc


def test_identical_rerun_is_in_band(files):
    write_fresh, _ = files
    assert compare(write_fresh(copy.deepcopy(BASE))) == 0


def test_throughput_only_fails_downward(files):
    write_fresh, _ = files
    assert compare(write_fresh(perturbed(b2_dense_tps=5.0))) == 1
    assert compare(write_fresh(perturbed(b2_dense_tps=900.0))) == 0


def test_latency_only_fails_upward(files):
    write_fresh, _ = files
    assert compare(write_fresh(perturbed(fleet_wall_s_per_step=10.0))) == 1
    assert compare(write_fresh(perturbed(fleet_wall_s_per_step=0.01))) == 0


def test_measured_peak_bytes_only_fail_upward(files):
    write_fresh, _ = files
    key = "memory_measured_lenet_b32_full_zo_peak_bytes"
    assert compare(write_fresh(perturbed(**{key: 4_000_000}))) == 1
    assert compare(write_fresh(perturbed(**{key: 2_000_000}))) == 0


def test_accuracy_only_fails_downward(files):
    write_fresh, _ = files
    key = "table1_fp32_lenet_acc_full_zo"
    assert compare(write_fresh(perturbed(**{key: 0.6}))) == 1
    assert compare(write_fresh(perturbed(**{key: 0.95}))) == 0


def test_deterministic_bytes_must_match_exactly(files):
    write_fresh, _ = files
    assert compare(write_fresh(perturbed(fleet_zo_bytes_per_step=97.0))) == 1


def test_residuals_are_informational(files):
    write_fresh, _ = files
    key = "memory_resid_lenet_b32_full_zo_bytes"
    assert compare(write_fresh(perturbed(**{key: -9_000_000}))) == 0


def test_missing_metric_is_a_regression_but_new_is_not(files):
    write_fresh, _ = files
    doc = copy.deepcopy(BASE)
    del doc["metrics"]["final_loss"]
    assert compare(write_fresh(doc)) == 1
    assert compare(write_fresh(perturbed(brand_new_metric=1.0))) == 0


def test_counter_drift_and_missing_gauge_fail(files):
    write_fresh, _ = files
    doc = copy.deepcopy(BASE)
    doc["counters"]["counters"]["fleet.wire.tail_bytes"] = 4097
    assert compare(write_fresh(doc)) == 1
    doc = copy.deepcopy(BASE)
    del doc["counters"]["gauges"]["serve.compile_ms"]
    assert compare(write_fresh(doc)) == 1


def test_histogram_count_exact_percentiles_banded(files):
    write_fresh, _ = files
    doc = copy.deepcopy(BASE)
    doc["timings"]["histograms"]["fleet.step_ms"]["count"] = 5
    assert compare(write_fresh(doc)) == 1
    doc = copy.deepcopy(BASE)
    doc["timings"]["histograms"]["fleet.step_ms"]["p99"] = 200.0  # > 8x
    assert compare(write_fresh(doc)) == 1
    doc = copy.deepcopy(BASE)
    doc["timings"]["histograms"]["fleet.step_ms"]["p99"] = 20.0   # in band
    assert compare(write_fresh(doc)) == 0


def test_dropped_memory_tag_fails_coverage(files):
    write_fresh, _ = files
    doc = copy.deepcopy(BASE)
    doc["memory"]["ledger"]["peak"] = {}
    assert compare(write_fresh(doc)) == 1


def test_config_change_demands_rebaseline(files):
    write_fresh, _ = files
    doc = copy.deepcopy(BASE)
    doc["config"]["steps"] = 8
    assert compare(write_fresh(doc)) == 2


def test_name_mismatch_is_usage_error(files):
    write_fresh, _ = files
    doc = copy.deepcopy(BASE)
    doc["name"] = "other"
    assert compare(write_fresh(doc)) == 2


def test_report_artifact_written(files, tmp_path):
    write_fresh, _ = files
    out = tmp_path / "diff.json"
    argv = write_fresh(perturbed(b2_dense_tps=5.0)) + ["--report", str(out)]
    assert compare(argv) == 1
    rep = json.loads(out.read_text())
    assert rep["verdict"] == "regression"
    fails = [r for r in rep["rows"] if r["status"] == "FAIL"]
    assert fails and fails[0]["metric"] == "b2_dense_tps"


def test_committed_baselines_self_compare(tmp_path):
    """The acceptance gate itself: every committed BENCH file must pass
    its own compare — otherwise CI is red on an untouched tree."""
    from benchmarks.compare import REPO_ROOT

    for p in sorted(REPO_ROOT.glob("BENCH_*.json")):
        assert compare([str(p)]) == 0, f"{p.name} fails its own baseline"
