"""Mathematical invariants of the model components."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import ssm
from repro.models.layers import rope
from repro.models.moe import capacity, moe_ffn, init_moe
from repro.sharding.rules import ShardingRules


# ------------------------------------------------------------------ #
# chunked recurrences vs sequential reference
# ------------------------------------------------------------------ #
def _wkv_sequential(r, k, v, logw, u, init=None):
    B, S, H, D = r.shape
    S_state = (jnp.zeros((B, H, D, D)) if init is None else init)
    outs = []
    for t in range(S):
        rt, kt, vt = r[:, t], k[:, t], v[:, t]
        cur = S_state + (u[None] * kt)[..., None] * vt[:, :, None, :]
        outs.append(jnp.einsum("bhk,bhkv->bhv", rt, cur))
        S_state = jnp.exp(logw[:, t])[..., None] * S_state \
            + kt[..., None] * vt[:, :, None, :]
    return jnp.stack(outs, 1), S_state


@pytest.mark.parametrize("S,init", [(32, False), (64, True)])
def test_wkv_chunked_vs_sequential(S, init):
    rng = np.random.default_rng(0)
    B, H, D = 2, 3, 8
    r, k, v = (jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
               for _ in range(3))
    logw = -jnp.asarray(rng.uniform(0.01, 3.0, (B, S, H, D)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, D)), jnp.float32)
    s0 = (jnp.asarray(rng.normal(size=(B, H, D, D)), jnp.float32)
          if init else None)
    out_c, fin_c = ssm._wkv_chunked(r, k, v, logw, u, init=s0)
    out_s, fin_s = _wkv_sequential(r, k, v, logw, u, init=s0)
    np.testing.assert_allclose(out_c, out_s, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(fin_c, fin_s, rtol=2e-4, atol=2e-4)


def _mamba_sequential(xdt, dt, A, Bc, Cc, carry):
    B, S, di = xdt.shape
    h = carry
    ys = []
    for t in range(S):
        la = jnp.maximum(dt[:, t, :, None] * A[None], -ssm.DECAY_CLAMP)
        h = jnp.exp(la) * h + xdt[:, t, :, None] * Bc[:, t, None, :]
        ys.append(jnp.einsum("bdn,bn->bd", h, Cc[:, t]))
    return jnp.stack(ys, 1), h


@pytest.mark.parametrize("S", [32, 64])
def test_mamba_chunked_vs_sequential(S):
    rng = np.random.default_rng(1)
    B, di, N = 2, 16, 4
    xdt = jnp.asarray(rng.normal(size=(B, S, di)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, S, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 3.0, (di, N)), jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cc = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    carry = jnp.asarray(rng.normal(size=(B, di, N)), jnp.float32)
    y_c, f_c = ssm._mamba_chunked(xdt, dt, A, Bc, Cc, init=carry)
    y_s, f_s = _mamba_sequential(xdt, dt, A, Bc, Cc, carry)
    np.testing.assert_allclose(y_c, y_s, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(f_c, f_s, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ #
# attention / rope
# ------------------------------------------------------------------ #
def test_rope_preserves_norm_and_relativity():
    rng = np.random.default_rng(2)
    B, S, H, D = 1, 16, 2, 32
    x = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    y = rope(x, pos, 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.asarray(rng.normal(size=(1, 1, 1, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, D)), jnp.float32)
    def dot_at(i, j):
        qi = rope(q, jnp.asarray([[i]], jnp.int32), 10000.0)
        kj = rope(k, jnp.asarray([[j]], jnp.int32), 10000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4


def test_swa_equals_full_when_window_covers():
    """Sliding-window attention == full attention when window >= seq."""
    from repro.models.layers import attention, init_attention
    cfg = reduced(ARCHS["mixtral-8x7b"], sliding_window=128)
    cfg_full = dataclasses.replace(cfg, sliding_window=0)
    rules = ShardingRules(None, cfg, None)
    p = init_attention(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)) * 0.1, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32), (2, 64))
    y_swa, _ = attention(p, x, cfg, rules, pos, causal=True, window=128)
    y_full, _ = attention(p, x, cfg_full, rules, pos, causal=True, window=0)
    np.testing.assert_allclose(y_swa, y_full, rtol=1e-4, atol=1e-5)


def test_swa_locality():
    """With window w, output at position t is independent of tokens < t-w."""
    from repro.models.layers import attention, init_attention
    cfg = reduced(ARCHS["mixtral-8x7b"], sliding_window=8)
    rules = ShardingRules(None, cfg, None)
    p = init_attention(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(1, 32, cfg.d_model)) * 0.1, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(32, dtype=jnp.int32), (1, 32))
    y1, _ = attention(p, x, cfg, rules, pos, causal=True, window=8)
    x2 = x.at[0, 0].set(99.0)           # perturb a token far outside window
    y2, _ = attention(p, x2, cfg, rules, pos, causal=True, window=8)
    np.testing.assert_allclose(y1[0, -1], y2[0, -1], rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------------ #
# MoE
# ------------------------------------------------------------------ #
def test_moe_matches_dense_dispatch():
    """Sort-based dispatch == brute-force per-token expert mixing (when no
    token overflows capacity)."""
    cfg = reduced(ARCHS["mixtral-8x7b"])
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    rules = ShardingRules(None, cfg, None)
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)) * 0.3, jnp.float32)
    y = moe_ffn(p, x, cfg, rules)

    # brute force
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    gates = jax.nn.softmax(logits, -1)
    tg, ti = jax.lax.top_k(gates, cfg.experts_per_token)
    tg = tg / tg.sum(-1, keepdims=True)
    def expert(e, v):
        h = jax.nn.silu(v @ p["w_gate"][e]) * (v @ p["w_up"][e])
        return h @ p["w_down"][e]
    ref = jnp.zeros_like(x)
    for b in range(2):
        for s in range(16):
            acc = jnp.zeros((cfg.d_model,))
            for k in range(cfg.experts_per_token):
                acc += tg[b, s, k] * expert(int(ti[b, s, k]), x[b, s])
            ref = ref.at[b, s].set(acc)
    np.testing.assert_allclose(y, ref, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_are_bounded():
    cfg = reduced(ARCHS["phi3.5-moe-42b-a6.6b"])
    assert capacity(cfg, 128) >= 128 * cfg.experts_per_token \
        * cfg.capacity_factor / cfg.num_experts - 1
