"""Fused antithetic-pair forward == unfused two-pass ElasticZO (§Perf)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, LaneConfig, ShapeConfig, reduced
from repro.core import api, prng
from repro.core.elastic import TrainState
from repro.sharding.rules import ShardingRules


@pytest.mark.parametrize("arch", ["llama3-8b", "mixtral-8x7b"])
def test_fused_equals_unfused(arch):
    cfg = reduced(ARCHS[arch])
    shape = ShapeConfig("s", seq_len=64, global_batch=2, kind="train")
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (2, 64), 0,
                                     cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(jax.random.key(2), (2, 64), 0,
                                     cfg.vocab_size, jnp.int32),
        "mask": jnp.ones((2, 64), jnp.float32),
    }
    outs = {}
    for fused in (False, True):
        lane = LaneConfig(lane="elastic_zo", bp_tail_layers=1,
                          fused_probes=fused, learning_rate=1e-2,
                          zo_eps=1e-3)
        rules = ShardingRules(None, cfg, shape)
        m = api.build(cfg, shape, lane, rules)
        params = m.init(jax.random.key(0))
        state = TrainState(params, jnp.int32(0),
                           jax.random.key_data(jax.random.key(7)))
        st2, metrics = jax.jit(m.train_step)(state, batch,
                                             jnp.ones((1,), jnp.float32))
        outs[fused] = (float(metrics["loss"]), st2.params)
    assert abs(outs[False][0] - outs[True][0]) < 1e-3
    for a, b in zip(jax.tree.leaves(outs[False][1]),
                    jax.tree.leaves(outs[True][1])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-4)


def test_offset_noise_matches_stacked_slice():
    """The flat-offset property the fused pair relies on: noise of a
    stacked leaf's slice l == offset generation at l*slice_size."""
    seed = jnp.uint32(99)
    full = prng.normal(seed, 13, (6, 4, 8))
    for l in range(6):
        sl = prng.normal(seed, 13, (4, 8), offset=l * 32)
        assert jnp.array_equal(full[l], sl)
