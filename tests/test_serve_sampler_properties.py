"""Property suite pinning the sort-free sampler to the full-sort oracle.

The sort-free selector (kernels/ref.py topk_topp_mask_ref, Pallas twin
kernels/topk_mask.py) must reproduce the full-sort reference pipeline
(`sampler._top_k_mask` + `_top_p_mask`) keep-set for keep-set — the one
documented exception is the nucleus tie-run boundary under float
rounding, so the tied cases here use power-of-two vocab sizes where every
partial mass sum is an exact binary fraction and agreement is provably
bitwise. Randomized trials are seeded numpy (hypothesis is not in the
container image); each seed is a fixed regression case.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.topk_mask import topk_topp_mask as pallas_topk_topp_mask
from repro.serve import sampler


def _fullsort_mask(x, k, p):
    return np.asarray(sampler._top_p_mask(
        sampler._top_k_mask(jnp.asarray(x), jnp.asarray(k)),
        jnp.asarray(p)))


def _sortfree_mask(x, k, p):
    return np.asarray(ref.topk_topp_mask_ref(
        jnp.asarray(x), jnp.asarray(k, jnp.int32),
        jnp.asarray(p, jnp.float32)))


def _rand_case(seed, B, V, tie_grid=None):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(B, V)).astype(np.float32)
    if tie_grid:
        x = np.round(x * tie_grid) / tie_grid   # heavy value collisions
    k = rng.integers(0, V + 2, size=B).astype(np.int32)
    p = rng.choice([0.05, 0.3, 0.7, 0.95, 0.999, 1.0], size=B) \
        .astype(np.float32)
    return x, k, p


# --------------------------------------------------------------- #
# keep-set equivalence vs the full-sort reference
# --------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("tie_grid", [None, 4])
def test_sortfree_keepsets_match_fullsort(seed, tie_grid):
    x, k, p = _rand_case(seed, B=4, V=301, tie_grid=tie_grid)
    np.testing.assert_array_equal(_sortfree_mask(x, k, p),
                                  _fullsort_mask(x, k, p))


def test_sortfree_keepsets_match_fullsort_64k_vocab():
    """The motivating size: >= 64k vocab, where the full sorts dominate."""
    x, k, p = _rand_case(7, B=2, V=65536)
    k = np.asarray([50, 63000], np.int32)
    np.testing.assert_array_equal(_sortfree_mask(x, k, p),
                                  _fullsort_mask(x, k, p))


@pytest.mark.parametrize("k,p", [(0, 1.0), (5, 0.5), (256, 0.999),
                                 (300, 1.0), (1, 0.05)])
def test_all_tied_rows_power_of_two_vocab(k, p):
    """Fully tied logits at power-of-two V: every nucleus partial sum is
    an exact binary fraction, so the histogram-order and sorted-order
    accumulations agree bitwise even on the tie-run boundary."""
    V = 256
    x = np.zeros((3, V), np.float32)
    x[1] = 1.5                                   # tied at a non-zero value
    x[2] = -2.0
    ks = np.full(3, k, np.int32)
    ps = np.full(3, p, np.float32)
    np.testing.assert_array_equal(_sortfree_mask(x, ks, ps),
                                  _fullsort_mask(x, ks, ps))


def test_degenerate_knobs_disable_filters():
    """k <= 0 and p >= 1 must be exact no-ops, k >= V keeps everything."""
    x, _, _ = _rand_case(11, B=3, V=97)
    for k, p in [(0, 1.0), (-3, 1.0), (97, 1.0), (200, 1.0)]:
        ks = np.full(3, k, np.int32)
        ps = np.full(3, p, np.float32)
        got = _sortfree_mask(x, ks, ps)
        np.testing.assert_array_equal(got, x)


def test_topk_is_exact_on_distinct_values():
    """With all-distinct values, exactly k entries survive and every kept
    value beats every dropped one — the partial selection is not
    approximate."""
    x, _, _ = _rand_case(13, B=4, V=413)
    k = np.asarray([1, 7, 100, 412], np.int32)
    p = np.ones(4, np.float32)
    got = _sortfree_mask(x, k, p)
    for b in range(4):
        kept = got[b] > ref.NEG_INF / 2
        assert kept.sum() == k[b]
        assert x[b][kept].min() > x[b][~kept].max()


def test_topp_keeps_minimal_nucleus():
    """Kept mass reaches p, and removing the lightest kept entry drops
    below p (the reference's minimal-prefix semantics)."""
    x, _, _ = _rand_case(17, B=4, V=211)
    k = np.zeros(4, np.int32)
    p = np.asarray([0.1, 0.5, 0.9, 0.999], np.float32)
    got = _sortfree_mask(x, k, p)
    probs = np.exp(x - x.max(1, keepdims=True))
    probs /= probs.sum(1, keepdims=True)
    for b in range(4):
        kept = got[b] > ref.NEG_INF / 2
        mass = probs[b][kept].sum()
        assert mass >= p[b] - 1e-5
        if kept.sum() > 1:
            assert mass - probs[b][kept].min() < p[b] + 1e-5


# --------------------------------------------------------------- #
# token-stream equivalence of the two jitted samplers
# --------------------------------------------------------------- #
def _streams(fn, logits, temps, ks, ps, seeds, n_steps, vocab_size):
    out = []
    for step in range(n_steps):
        out.append(np.asarray(fn(
            jnp.asarray(logits), jnp.asarray(temps), jnp.asarray(ks),
            jnp.asarray(ps), jnp.asarray(seeds),
            jnp.full(len(seeds), step, jnp.int32),
            vocab_size=vocab_size)))
    return np.stack(out)


@pytest.mark.parametrize("seed", [0, 5])
def test_sample_tokens_streams_match_reference(seed):
    """Fixed seeds, mixed per-row knobs, several steps: the sort-free
    sampler and the full-sort oracle emit identical token streams."""
    rng = np.random.default_rng(seed)
    B, V = 5, 128
    logits = rng.normal(size=(B, V)).astype(np.float32) * 3
    temps = np.asarray([0.0, 0.7, 1.0, 1.3, 0.2], np.float32)
    ks = np.asarray([0, 5, V, 40, 1], np.int32)
    ps = np.asarray([1.0, 0.9, 0.5, 1.0, 0.3], np.float32)
    seeds = rng.integers(0, 2**32, size=B, dtype=np.uint32)
    a = _streams(sampler.sample_tokens, logits, temps, ks, ps, seeds,
                 n_steps=6, vocab_size=100)
    b = _streams(sampler.sample_tokens_reference, logits, temps, ks, ps,
                 seeds, n_steps=6, vocab_size=100)
    np.testing.assert_array_equal(a, b)


def test_temperature_zero_is_greedy_argmax():
    rng = np.random.default_rng(3)
    logits = rng.normal(size=(4, 64)).astype(np.float32)
    toks = np.asarray(sampler.sample_tokens(
        jnp.asarray(logits), jnp.zeros(4, jnp.float32),
        jnp.zeros(4, jnp.int32), jnp.ones(4, jnp.float32),
        jnp.zeros(4, jnp.uint32), jnp.zeros(4, jnp.int32)))
    np.testing.assert_array_equal(toks, logits.argmax(1))
    np.testing.assert_array_equal(
        toks, np.asarray(sampler.greedy_tokens(jnp.asarray(logits))))


# --------------------------------------------------------------- #
# Pallas kernel (interpret) is bitwise the jnp radix ref
# --------------------------------------------------------------- #
@pytest.mark.parametrize("seed,V", [(0, 300), (1, 97), (2, 1024)])
def test_pallas_topk_mask_matches_ref(seed, V):
    x, k, p = _rand_case(seed, B=3, V=V, tie_grid=4 if seed == 1 else None)
    want = _sortfree_mask(x, k, p)
    got = np.asarray(pallas_topk_topp_mask(
        jnp.asarray(x), jnp.asarray(k), jnp.asarray(p), interpret=True))
    np.testing.assert_array_equal(got, want)


def test_ops_dispatch_routes_to_ref_off_tpu():
    x, k, p = _rand_case(23, B=2, V=130)
    got = np.asarray(ops.topk_topp_mask(jnp.asarray(x), k, p))
    np.testing.assert_array_equal(got, _sortfree_mask(x, k, p))
