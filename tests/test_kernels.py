"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Contract: integer kernels are bitwise-exact; the fp perturb kernel has a
bitwise-identical z stream and an AXPY within 1 ulp (FMA contraction
differences between the interpreter and jit).
"""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: suite must collect without it
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SEED = jnp.uint32(12345)


@pytest.mark.parametrize("M,K,N", [
    (128, 128, 128), (256, 384, 128), (64, 100, 72), (512, 256, 384),
    (8, 128, 128), (128, 8, 8),
])
def test_int8_matmul_shapes(M, K, N):
    rng = np.random.default_rng(M + K + N)
    a = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
    o1, m1 = ops.int8_matmul(a, w, force_pallas=True, interpret=True)
    o2, m2 = ref.int8_matmul_ref(a, w)
    assert jnp.array_equal(o1, o2)
    assert int(m1) == int(m2)


@settings(deadline=None, max_examples=15)
@given(st.integers(1, 300), st.integers(1, 200), st.integers(1, 150))
def test_int8_matmul_property(M, K, N):
    rng = np.random.default_rng(M * 7 + K * 3 + N)
    a = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
    o1, m1 = ops.int8_matmul(a, w, force_pallas=True, interpret=True)
    o2, m2 = ref.int8_matmul_ref(a, w)
    assert jnp.array_equal(o1, o2) and int(m1) == int(m2)


@pytest.mark.parametrize("shape,dtype", [
    ((1000,), jnp.float32), ((64, 129), jnp.float32),
    ((3, 5, 7), jnp.bfloat16), ((8192,), jnp.bfloat16),
])
def test_zo_perturb_kernel(shape, dtype):
    rng = np.random.default_rng(sum(shape))
    # z-stream bitwise (theta = 0)
    z1 = ops.zo_perturb(jnp.zeros(shape, dtype), SEED, 7, jnp.float32(1.0),
                        force_pallas=True, interpret=True)
    z2 = ref.zo_perturb_ref(jnp.zeros(shape, dtype), SEED, 7, jnp.float32(1.0))
    assert jnp.array_equal(z1, z2)
    # full op within 1 ulp
    t = jnp.asarray(rng.normal(size=shape), dtype)
    p1 = ops.zo_perturb(t, SEED, 7, jnp.float32(1e-3),
                        force_pallas=True, interpret=True)
    p2 = ref.zo_perturb_ref(t, SEED, 7, jnp.float32(1e-3))
    np.testing.assert_allclose(np.asarray(p1, np.float32),
                               np.asarray(p2, np.float32),
                               rtol=2e-7, atol=1e-8)


@pytest.mark.parametrize("shape", [(1000,), (127, 3), (129, 130)])
def test_int8_perturb_kernel(shape):
    rng = np.random.default_rng(shape[0])
    t = jnp.asarray(rng.integers(-127, 128, shape), jnp.int8)
    p1 = ops.int8_perturb(t, SEED, 3, 1, 3, jnp.float32(0.33),
                          force_pallas=True, interpret=True)
    p2 = ref.int8_perturb_ref(t, SEED, 3, 1, 3, jnp.float32(0.33))
    assert jnp.array_equal(p1, p2)


def test_perturb_then_inverse_restores():
    """perturb(+eps) then perturb(-eps) with the same seed is the identity
    (up to fp addition rounding) — Alg. 1's +1/-2/+1 replay contract."""
    t = jnp.asarray(np.random.default_rng(5).normal(size=(4096,)), jnp.float32)
    p = ops.zo_perturb(t, SEED, 11, jnp.float32(1e-3))
    back = ops.zo_perturb(p, SEED, 11, jnp.float32(-1e-3))
    np.testing.assert_allclose(back, t, rtol=1e-5, atol=1e-7)
