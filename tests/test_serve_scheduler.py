"""Scheduler invariants under random admit/decode/finish traces.

The scheduler is pure host-side numpy, so these drive it without any
model: random prompt lengths, budgets and submission times, with page
conservation + slot consistency checked after every event and global
termination (no starvation) at the end. A hypothesis-driven variant runs
when hypothesis is installed; the seeded-numpy sweep always runs.
"""
import numpy as np
import pytest

from repro.configs import ServeConfig
from repro.serve.sampler import SamplingParams
from repro.serve.scheduler import FINISHED, Scheduler

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


def drive(seed: int, num_pages: int, slots: int, n_req: int) -> Scheduler:
    rng = np.random.default_rng(seed)
    serve = ServeConfig(page_size=4, num_pages=num_pages,
                        max_batch_slots=slots, max_seq_len=40,
                        max_new_tokens=8, eos_id=0)
    sched = Scheduler(serve)
    pending = [(list(rng.integers(1, 100, rng.integers(1, 12))),
                int(rng.integers(1, 9))) for _ in range(n_req)]
    steps = 0
    while pending or sched.has_work():
        steps += 1
        assert steps < 10_000, "starvation: trace did not drain"
        # staggered submissions exercise mid-flight admission
        while pending and rng.uniform() < 0.5:
            prompt, budget = pending.pop()
            sched.submit(prompt, SamplingParams(), budget)
        for seq in sched.poll_admissions():
            # ~10% of first tokens are EOS -> immediate finish path
            tok = 0 if rng.uniform() < 0.1 else int(rng.integers(1, 100))
            sched.record_first_token(seq, tok)
            sched.check_invariants()
        plan = sched.prepare_step()
        sched.check_invariants()
        if plan is None:
            continue
        sampled = rng.integers(1, 100, serve.max_batch_slots)
        sampled[rng.uniform(size=serve.max_batch_slots) < 0.05] = 0  # EOS
        sched.commit_step(sampled.astype(np.int32))
        sched.check_invariants()
    return sched


@pytest.mark.parametrize("seed", range(12))
def test_random_traces_conserve_pages_and_terminate(seed):
    rng = np.random.default_rng(seed + 1000)
    sched = drive(seed,
                  num_pages=int(rng.integers(8, 40)),
                  slots=int(rng.integers(1, 6)),
                  n_req=int(rng.integers(1, 12)))
    assert sched.pool.used_pages == 0              # every page returned
    assert not sched.waiting and not sched.running
    for s in sched.finished:
        assert s.state == FINISHED
        assert 1 <= len(s.generated) <= s.req.max_new_tokens
        assert not s.pages and s.slot == -1


@pytest.mark.parametrize("seed", range(6))
def test_steady_horizon_predicts_epoch_stability(seed):
    """steady_horizon's contract, checked against the scheduler itself:
    committing h-1 tokens and re-running prepare_step must not bump the
    plan epoch (no growth/finish/admission fires mid-horizon), every
    intermediate plan must be exactly the steady advance of the first,
    and no sequence may finish before the horizon's final tick."""
    rng = np.random.default_rng(seed)
    serve = ServeConfig(page_size=4, num_pages=int(rng.integers(12, 40)),
                        max_batch_slots=int(rng.integers(1, 5)),
                        max_seq_len=40, max_new_tokens=8, eos_id=-1,
                        megastep=16)
    sched = Scheduler(serve)
    for _ in range(int(rng.integers(2, 8))):
        try:
            sched.submit(list(rng.integers(1, 100, rng.integers(1, 12))),
                         SamplingParams(), int(rng.integers(1, 9)))
        except ValueError:
            pass                                   # pool too small: skip
    guard = 0
    while sched.has_work():
        guard += 1
        assert guard < 2_000
        for seq in sched.poll_admissions():
            sched.record_first_token(seq, int(rng.integers(1, 100)))
        plan = sched.prepare_step()
        if plan is None:
            continue
        h = sched.steady_horizon()
        assert 1 <= h <= serve.megastep
        epoch = sched.plan_epoch
        for t in range(h):
            done = sched.commit_step(
                rng.integers(1, 100, serve.max_batch_slots).astype(np.int32))
            if t < h - 1:
                assert not done, "sequence finished mid-horizon"
                mid = sched.prepare_step()
                assert sched.plan_epoch == epoch, "epoch bumped mid-horizon"
                adv = plan.seq_lens + (t + 1) * plan.active
                assert np.array_equal(mid.seq_lens, adv)
                assert np.array_equal(mid.page_table, plan.page_table)
                assert np.array_equal(mid.active, plan.active)
        sched.check_invariants()


def test_submit_rejects_impossible_requests():
    serve = ServeConfig(page_size=4, num_pages=5, max_batch_slots=2,
                        max_seq_len=16, max_new_tokens=4)
    sched = Scheduler(serve)
    with pytest.raises(ValueError):
        sched.submit(list(range(20)), SamplingParams(), 4)   # > max_seq_len
    with pytest.raises(ValueError):
        # 12 + 4 + 1 cache slots -> 5 pages > 4 usable: would deadlock
        sched.submit(list(range(12)), SamplingParams(), 4)
    with pytest.raises(ValueError):
        sched.submit([], SamplingParams(), 4)                # empty prompt
    with pytest.raises(ValueError):
        sched.submit([1, 2], SamplingParams(), 0)            # zero budget


def test_lifo_preemption_never_evicts_oldest():
    serve = ServeConfig(page_size=2, num_pages=9, max_batch_slots=3,
                        max_seq_len=14, max_new_tokens=6)
    sched = Scheduler(serve)
    first = sched.submit([1, 2, 3, 4], SamplingParams(), 6)
    sched.submit([5, 6, 7, 8], SamplingParams(), 6)
    sched.submit([9, 10, 11, 12], SamplingParams(), 6)
    order = []
    for _ in range(200):
        if not sched.has_work():
            break
        for seq in sched.poll_admissions():
            # a re-admitted sequence may finish here (last budgeted token
            # sampled straight from the re-prefill logits)
            if sched.record_first_token(seq, 1):
                order.append(seq.req.rid)
        plan = sched.prepare_step()
        if plan is None:
            continue
        for s in sched.commit_step(np.ones(3, np.int32)):
            order.append(s.req.rid)
        sched.check_invariants()
    assert sorted(order) == [0, 1, 2]
    oldest = next(s for s in sched.finished if s.req.rid == first)
    assert oldest.preemptions == 0                 # FIFO head is protected
    assert sum(s.preemptions for s in sched.finished) > 0


if HAVE_HYP:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           num_pages=st.integers(8, 64),
           slots=st.integers(1, 6),
           n_req=st.integers(1, 16))
    def test_hypothesis_traces(seed, num_pages, slots, n_req):
        sched = drive(seed, num_pages, slots, n_req)
        assert sched.pool.used_pages == 0
        assert not sched.waiting and not sched.running
