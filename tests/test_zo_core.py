"""ZO/SPSA core: estimator statistics, seed replay, ElasticZO equivalences."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LaneConfig
from repro.core import prng, zo
from repro.core.elastic import TrainState, make_elastic_step


def quad_loss(params, batch):
    # simple strongly-convex quadratic: ||Wx - y||^2
    pred = batch["x"] @ params["w"]["w"] + params["v"]["w"]
    return jnp.mean(jnp.square(pred - batch["y"]))


def make_quad(key, d=8):
    kw, kv, kx = jax.random.split(key, 3)
    params = {"w": {"w": jax.random.normal(kw, (d, d)) * 0.3},
              "v": {"w": jnp.zeros((d,))}}
    x = jax.random.normal(kx, (32, d))
    wstar = jax.random.normal(kv, (d, d)) * 0.3
    y = x @ wstar
    return params, {"x": x, "y": y}


def test_seed_replay_identical():
    params, _ = make_quad(jax.random.key(0))
    key = jax.random.key(42)
    p1 = zo.perturb(params, key, 1e-3)
    p2 = zo.perturb(params, key, 1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert jnp.array_equal(a, b)
    # perturb(+) then the replayed update reconstructs theta - eta*g*z
    g = jnp.float32(0.5)
    upd = zo.zo_update(params, key, 0.1 * g)
    z_w = (jax.tree.leaves(p1)[0] - jax.tree.leaves(params)[0]) / 1e-3
    expect = jax.tree.leaves(params)[0] - 0.1 * g * z_w
    np.testing.assert_allclose(jax.tree.leaves(upd)[0], expect,
                               rtol=2e-4, atol=2e-6)


def test_spsa_unbiased_direction():
    """E[g z] ~ grad: the SPSA estimate correlates with the true gradient."""
    params, batch = make_quad(jax.random.key(1))
    loss = lambda p: quad_loss(p, batch)  # noqa: E731
    true_grad = jax.grad(loss)(params)["w"]["w"]
    acc = jnp.zeros_like(true_grad)
    n = 300
    for i in range(n):
        key = jax.random.key(i)
        g, _, _ = zo.spsa_gradient_estimate(loss, params, key, eps=1e-3)
        z = (zo.perturb(params, key, 1.0)["w"]["w"] - params["w"]["w"])
        acc = acc + g * z
    est = acc / n
    cos = jnp.sum(est * true_grad) / (jnp.linalg.norm(est)
                                      * jnp.linalg.norm(true_grad))
    assert float(cos) > 0.6, float(cos)


def test_zo_descends_quadratic():
    params, batch = make_quad(jax.random.key(2))
    lane = LaneConfig(lane="full_zo", learning_rate=0.02, zo_eps=1e-3,
                      zo_num_probes=4)
    step = jax.jit(make_elastic_step(quad_loss, lane,
                                     partition_fn=lambda p: (dict(p), {})))
    state = TrainState(params, jnp.int32(0),
                       jax.random.key_data(jax.random.key(7)))
    l0 = float(quad_loss(params, batch))
    for _ in range(200):
        state, m = step(state, batch, jnp.ones((4,), jnp.float32))
    l1 = float(quad_loss(state.params, batch))
    assert l1 < 0.5 * l0, (l0, l1)


def test_elastic_bp_part_matches_sgd():
    """With zero-size ZO effect (eps tiny, lr 0 on ZO? -> use full_bp lane):
    full_bp lane must equal plain SGD."""
    params, batch = make_quad(jax.random.key(3))
    lane = LaneConfig(lane="full_bp", learning_rate=0.05)
    step = jax.jit(make_elastic_step(quad_loss, lane))
    state = TrainState(params, jnp.int32(0),
                       jax.random.key_data(jax.random.key(9)))
    state, _ = step(state, batch, jnp.ones((1,), jnp.float32))
    grads = jax.grad(quad_loss)(params, batch)
    manual = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(manual)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_probe_mask_renormalizes():
    """A dropped probe (straggler) must not change the expected update
    scale: masking probe i == running with the surviving probes only."""
    params, batch = make_quad(jax.random.key(4))
    lane = LaneConfig(lane="full_zo", learning_rate=0.01, zo_num_probes=2)
    step = jax.jit(make_elastic_step(quad_loss, lane,
                                     partition_fn=lambda p: (dict(p), {})))
    st = TrainState(params, jnp.int32(0),
                    jax.random.key_data(jax.random.key(11)))
    # run with probe 1 masked
    s_masked, _ = step(st, batch, jnp.asarray([1.0, 0.0]))
    # single-probe lane sees the same first probe key (fold_in(key, 0))
    lane1 = LaneConfig(lane="full_zo", learning_rate=0.01, zo_num_probes=1)
    step1 = jax.jit(make_elastic_step(quad_loss, lane1,
                                      partition_fn=lambda p: (dict(p), {})))
    s_single, _ = step1(st, batch, jnp.ones((1,), jnp.float32))
    for a, b in zip(jax.tree.leaves(s_masked.params),
                    jax.tree.leaves(s_single.params)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)


def test_hash_noise_stats():
    z = prng.normal(jnp.uint32(123), 5, (100_000,))
    assert abs(float(z.mean())) < 0.02
    assert abs(float(z.std()) - 1.0) < 0.02
    # independence across salts
    z2 = prng.normal(jnp.uint32(123), 6, (100_000,))
    corr = float(jnp.corrcoef(z, z2)[0, 1])
    assert abs(corr) < 0.02


def test_hash_noise_mesh_independent():
    """Same (seed, shape) -> same z regardless of how the computation is
    laid out (this is the elastic-restart guarantee)."""
    a = prng.normal(jnp.uint32(7), 1, (64, 32))
    b = prng.normal(jnp.uint32(7), 1, (2048,)).reshape(64, 32)
    assert jnp.array_equal(a, b)
