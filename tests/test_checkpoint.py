"""Checkpoint/restore, elastic resharding, and restart determinism."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LaneConfig
from repro.core.elastic import TrainState, make_elastic_step
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adam, apply_updates, cosine, sgd, step_decay


def _params(key=0):
    k = jax.random.key(key)
    return {"a": {"w": jax.random.normal(k, (16, 8)),
                  "b": jnp.zeros((8,))},
            "c": jax.random.normal(jax.random.fold_in(k, 1), (4, 4, 2))}


def test_save_restore_roundtrip(tmp_path):
    p = _params()
    ckpt.save(tmp_path, 7, p)
    q, step = ckpt.restore(tmp_path, p)
    assert step == 7
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(q)):
        assert jnp.array_equal(a, b)


def test_commit_protocol_ignores_partial(tmp_path):
    p = _params()
    ckpt.save(tmp_path, 5, p)
    # simulate a crash mid-save at step 9: directory without COMMIT
    d = tmp_path / "step_00000009"
    d.mkdir()
    (d / "manifest.json").write_text("{}")
    assert ckpt.latest_step(tmp_path) == 5


def test_commit_protocol_ignores_tmp_leftover(tmp_path):
    """A crash between writing COMMIT and the rename leaves step_<N>.tmp
    *containing* COMMIT; it must be invisible, not a parse crash."""
    p = _params()
    ckpt.save(tmp_path, 5, p)
    tmp = tmp_path / "step_00000009.tmp"
    tmp.mkdir()
    (tmp / "COMMIT").write_text("ok")
    assert ckpt.latest_step(tmp_path) == 5
    q, step = ckpt.restore(tmp_path, p)
    assert step == 5
    saver = ckpt.AsyncCheckpointer(tmp_path, keep=1)
    saver.save(6, p)
    saver.wait()                        # _gc must also skip the .tmp dir
    assert ckpt.latest_step(tmp_path) == 6


def test_async_checkpointer(tmp_path):
    p = _params()
    saver = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for s in (1, 2, 3):
        saver.save(s, p)
    saver.wait()
    assert ckpt.latest_step(tmp_path) == 3
    # GC keeps the last 2
    steps = sorted(x.name for x in tmp_path.glob("step_*"))
    assert len(steps) == 2


def test_elastic_reshard_restore(tmp_path):
    """Save from a (2,2) mesh layout, restore onto (4,1): the elastic
    re-scaling path (docs/design.md §8). Uses 4 fake CPU devices via shardings
    only when multiple devices exist; otherwise exercises the same code
    path with None shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    p = _params()
    ckpt.save(tmp_path, 3, p)
    devs = jax.devices()
    if len(devs) >= 4:
        mesh_a = jax.make_mesh((2, 2), ("data", "model"),
                               axis_types=(jax.sharding.AxisType.Auto,) * 2)
        shard = jax.tree.map(
            lambda _: NamedSharding(mesh_a, P()), p)
        q, _ = ckpt.restore(tmp_path, p, shardings=shard)
    else:
        q, _ = ckpt.restore(tmp_path, p, shardings=None)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(q)):
        assert jnp.array_equal(a, b)


def test_restart_determinism():
    """Running 10 steps == running 5, checkpointing (params, step), and
    running 5 more: the ZO noise stream depends only on (seed, step)."""
    def loss(params, batch):
        return jnp.mean(jnp.square(batch["x"] @ params["w"]["w"] - batch["y"]))
    lane = LaneConfig(lane="full_zo", learning_rate=0.05, zo_eps=1e-3)
    step = jax.jit(make_elastic_step(loss, lane,
                                     partition_fn=lambda p: (dict(p), {})))
    k = jax.random.key(0)
    params = {"w": {"w": jax.random.normal(k, (6, 6)) * 0.3}}
    batch = {"x": jax.random.normal(jax.random.fold_in(k, 1), (16, 6)),
             "y": jax.random.normal(jax.random.fold_in(k, 2), (16, 6))}
    pm = jnp.ones((1,), jnp.float32)
    seed = jax.random.key_data(jax.random.key(9))

    sA = TrainState(params, jnp.int32(0), seed)
    for _ in range(10):
        sA, _ = step(sA, batch, pm)

    sB = TrainState(params, jnp.int32(0), seed)
    for _ in range(5):
        sB, _ = step(sB, batch, pm)
    # "restart": rebuild state from (params, step) as a checkpoint would
    sB = TrainState(jax.tree.map(jnp.copy, sB.params), sB.step, seed)
    for _ in range(5):
        sB, _ = step(sB, batch, pm)

    for a, b in zip(jax.tree.leaves(sA.params), jax.tree.leaves(sB.params)):
        np.testing.assert_allclose(a, b, rtol=0, atol=0)


def test_optimizers_descend():
    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 3.0))
    p = {"w": jnp.zeros((4,))}
    for opt in (sgd(0.1), sgd(0.1, momentum=0.9), adam(0.2)):
        params = p
        state = opt.init(params)
        for s in range(50):
            g = jax.grad(loss)(params)
            upd, state = opt.update(g, state, jnp.int32(s))
            params = apply_updates(params, upd)
        assert float(loss(params)) < 0.1


def test_schedules():
    assert float(step_decay(1.0, 0.8, 10)(jnp.int32(0))) == 1.0
    assert abs(float(step_decay(1.0, 0.8, 10)(jnp.int32(25))) - 0.64) < 1e-6
    c = cosine(1.0, 100, warmup=10)
    assert float(c(jnp.int32(0))) == 0.0
    assert abs(float(c(jnp.int32(10))) - 1.0) < 1e-6
    assert float(c(jnp.int32(100))) < 1e-6


def test_compressed_psum_error_feedback():
    """int8 compression with error feedback: the *accumulated* update over
    many steps converges to the true sum (residual re-injection)."""
    from repro.train.compress import int8_compress, int8_decompress
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)) * 1e-3, jnp.float32)
    residual = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        q, s, residual = int8_compress(g, residual)
        acc = acc + int8_decompress(q, s)
    np.testing.assert_allclose(acc / 50, g, rtol=0.02, atol=1e-6)
