"""reprolint (src/repro/analysis): fixtures, engine mechanics, meta-gate.

Three layers:

* every rule is demonstrated by a red/green fixture mini-tree under
  tests/analysis_fixtures/<rule-id>/ — red must yield at least one
  finding of that rule, green must be completely clean;
* engine mechanics: suppression grammar (reason mandatory, trailing vs
  own-line coverage), allowlist loading errors, stale-entry detection;
* the meta-gate: reprolint over THIS repository must be clean — zero
  findings, zero stale suppressions — and the CLI must agree.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (ALL_RULES, AllowEntry, load_allowlist,
                            rules_by_id, run_analysis)
from repro.analysis.core import BAD_SUPPRESSION, STALE_SUPPRESSION
from repro.analysis.project import build_project

REPO_ROOT = Path(__file__).resolve().parents[1]
FIXTURES = REPO_ROOT / "tests" / "analysis_fixtures"

RULE_DIRS = sorted(p.name for p in FIXTURES.iterdir() if p.is_dir())


def _run(root: Path, allowlist=()):
    return run_analysis(root, ALL_RULES, allowlist=list(allowlist))


# ------------------------------------------------------------------ #
# red/green fixtures: every rule demonstrably fires and passes
# ------------------------------------------------------------------ #
def test_every_rule_has_a_fixture():
    meta_ids = {BAD_SUPPRESSION, STALE_SUPPRESSION}
    assert set(RULE_DIRS) == {r.id for r in ALL_RULES} | meta_ids


@pytest.mark.parametrize("rule_id", RULE_DIRS)
def test_red_fixture_fires(rule_id):
    report = _run(FIXTURES / rule_id / "red")
    fired = {f.rule for f in report.findings}
    assert rule_id in fired, (
        f"red fixture for {rule_id} produced {sorted(fired)}")


@pytest.mark.parametrize("rule_id", RULE_DIRS)
def test_green_fixture_clean(rule_id):
    report = _run(FIXTURES / rule_id / "green")
    assert report.clean, [f"{f.location()}: [{f.rule}] {f.message}"
                          for f in report.findings]


# ------------------------------------------------------------------ #
# engine mechanics
# ------------------------------------------------------------------ #
def _mini_tree(tmp_path: Path, source: str) -> Path:
    mod = tmp_path / "src" / "repro" / "example.py"
    mod.parent.mkdir(parents=True)
    mod.write_text(source)
    return tmp_path


def test_trailing_suppression_covers_its_own_line(tmp_path):
    root = _mini_tree(tmp_path, (
        "import time\n"
        "t = time.time()  # reprolint: allow(monotonic-clock) -- stamp\n"))
    report = _run(root)
    assert report.clean
    assert [f.rule for f in report.suppressed] == ["monotonic-clock"]


def test_own_line_suppression_covers_next_line_only(tmp_path):
    root = _mini_tree(tmp_path, (
        "import time\n"
        "# reprolint: allow(monotonic-clock) -- stamp\n"
        "a = time.time()\n"
        "b = time.time()\n"))
    report = _run(root)
    rules = [f.rule for f in report.findings]
    assert rules == ["monotonic-clock"]          # line 4 is NOT covered
    assert [f.line for f in report.findings] == [4]


def test_reasonless_suppression_suppresses_nothing(tmp_path):
    root = _mini_tree(tmp_path, (
        "import time\n"
        "# reprolint: allow(monotonic-clock)\n"
        "t = time.time()\n"))
    report = _run(root)
    rules = sorted(f.rule for f in report.findings)
    assert rules == [BAD_SUPPRESSION, "monotonic-clock"]


def test_suppression_for_wrong_rule_is_stale(tmp_path):
    root = _mini_tree(tmp_path, (
        "import time\n"
        "# reprolint: allow(no-builtin-hash) -- wrong rule id\n"
        "t = time.time()\n"))
    report = _run(root)
    rules = sorted(f.rule for f in report.findings)
    assert rules == ["monotonic-clock", STALE_SUPPRESSION]


def test_allowlist_discharges_and_goes_stale(tmp_path):
    root = _mini_tree(tmp_path, "import time\nt = time.time()\n")
    entry = AllowEntry(rule="monotonic-clock", path="src/repro/example.py",
                       reason="fixture")
    report = _run(root, allowlist=[entry])
    assert report.clean and len(report.suppressed) == 1

    stale = AllowEntry(rule="no-builtin-hash", path="src/repro/example.py",
                       reason="matches nothing")
    report = _run(root, allowlist=[entry, stale])
    assert [f.rule for f in report.findings] == [STALE_SUPPRESSION]
    assert ".reprolint.json" in report.findings[0].path


def test_allowlist_loader_rejects_missing_reason(tmp_path):
    (tmp_path / ".reprolint.json").write_text(json.dumps(
        {"allow": [{"rule": "no-builtin-hash", "path": "x.py"}]}))
    with pytest.raises(ValueError, match="reason"):
        load_allowlist(tmp_path)


def test_allowlist_loader_rejects_empty_reason(tmp_path):
    (tmp_path / ".reprolint.json").write_text(json.dumps(
        {"allow": [{"rule": "r", "path": "p", "reason": "  "}]}))
    with pytest.raises(ValueError, match="empty reason"):
        load_allowlist(tmp_path)


def test_parse_error_is_a_finding(tmp_path):
    root = _mini_tree(tmp_path, "def broken(:\n")
    report = _run(root)
    assert [f.rule for f in report.findings] == ["parse-error"]


def test_project_excludes_fixture_trees():
    project = build_project(REPO_ROOT)
    assert not [sf.path for sf in project.iter_files()
                if sf.path.startswith("tests/analysis_fixtures")]


def test_rules_by_id_covers_all_rules():
    by_id = rules_by_id()
    for rule in ALL_RULES:
        assert by_id[rule.id] is rule


# ------------------------------------------------------------------ #
# the meta-gate: this repository is clean under its own linter
# ------------------------------------------------------------------ #
def test_repo_is_reprolint_clean():
    report = run_analysis(REPO_ROOT, ALL_RULES)
    assert report.clean, "\n".join(
        f"{f.location()}: [{f.rule}] {f.message}" for f in report.findings)
    # the committed suppressions are exercised, not decorative
    assert report.suppressed, "expected grandfathered suppressions in use"


def test_cli_clean_on_repo_and_writes_report(tmp_path):
    out = tmp_path / "reprolint.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--root", str(REPO_ROOT),
         "--report", str(out)],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO_ROOT / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(out.read_text())
    assert doc["tool"] == "reprolint" and doc["clean"] is True
    assert len(doc["rules"]) >= 8


def test_cli_exits_nonzero_on_findings():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--root",
         str(FIXTURES / "no-invariant-assert" / "red")],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO_ROOT / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 1
    assert "no-invariant-assert" in proc.stdout
