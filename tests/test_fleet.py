"""repro.fleet acceptance: chaos fleet == single process, bit-exactly.

One 8-worker run with transport dropout, stragglers, and a mid-run
worker crash/rejoin is shared by the tests below (module fixture). The
bar everywhere is array_equal, not allclose — the protocol's whole point
(docs/fleet.md).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import FleetConfig, LaneConfig, ShapeConfig, get_arch, reduced
from repro.core import api
from repro.data.synthetic import token_batch
from repro.fleet import (Ledger, make_reference_step, make_replay_fn,
                         reference_state, run_fleet)
from repro.sharding.rules import ShardingRules
from repro.train import checkpoint as ckpt
from repro.train.train_loop import LoopConfig, run

# minutes-scale integration fixture: full chaos fleet + reference re-run
pytestmark = pytest.mark.slow

WORKERS = 8
STEPS = 8
CRASH = (5, 3, 3)        # worker 5 dies at step 3, rejoins at step 6


def _bitwise_equal(a, b):
    return all(jnp.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.fixture(scope="module")
def fleet_run():
    cfg = reduced(get_arch("llama3-8b"), num_layers=2, d_model=32,
                  num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=128)
    lane = LaneConfig(lane="elastic_zo", bp_tail_layers=1,
                      learning_rate=5e-2, zo_eps=1e-3)
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    model = api.build(cfg, shape, lane, ShardingRules(None, cfg, shape))
    params = model.init(jax.random.key(0))
    base_seed = jax.random.key_data(jax.random.key(1))

    def batch_fn(step):
        x, y, m = token_batch(2, 16, cfg.vocab_size, seed=1, step=step)
        return {"tokens": jnp.asarray(x), "labels": jnp.asarray(y),
                "mask": jnp.asarray(m)}

    fleet_cfg = FleetConfig(num_workers=WORKERS, probes_per_worker=1,
                            dropout=0.25, max_delay=2, deadline=1,
                            chaos_seed=3, snapshot_every=4,
                            crashes=(CRASH,))
    res = run_fleet(model.loss_fn, params, lane, fleet_cfg, batch_fn,
                    steps=STEPS, base_seed=base_seed, trace=True)
    return dict(res=res, model=model, params=params, lane=lane,
                batch_fn=batch_fn, base_seed=base_seed)


def test_chaos_run_exercised_the_failure_paths(fleet_run):
    res = fleet_run["res"]
    assert res.stats["n_dropped"] > 0, "dropout chaos never fired"
    assert res.stats["n_straggled"] > 0, "latency chaos never fired"
    assert res.stats["n_catchups"] == 1
    assert res.stats["bytes_catchup"] > 0, \
        "rejoin should have replayed a ledger slice"
    # crashed worker's probes masked while down, live again after rejoin
    w, cs, down = CRASH
    for t in range(cs, cs + down):
        assert res.masks[t][w] == 0.0
    # after rejoin the worker publishes again (its records can still hit
    # transport chaos, so "accepted at least once", not "immediately")
    assert any(res.masks[t][w] == 1.0 for t in range(cs + down, STEPS))
    # some step had a partial (but never empty) commit
    accepted = np.array([m.sum() for m in res.masks])
    assert accepted.min() >= 1 and accepted.max() <= WORKERS
    assert (accepted < WORKERS).any()


def test_workers_bitwise_in_sync_with_coordinator(fleet_run):
    """Every worker — including the crashed-and-replayed one — holds the
    canonical parameters, bit for bit."""
    res = fleet_run["res"]
    for w in res.workers:
        assert w.alive and w.step == STEPS
        assert _bitwise_equal(w.params, res.params), f"worker {w.id}"


def test_fleet_reproduces_single_process_reference(fleet_run):
    """The acceptance bar: the 8-worker chaos run's canonical parameter
    stream == train_loop.run over the single-process reference step with
    the realized probe masks, bit-exactly at every step."""
    res, model = fleet_run["res"], fleet_run["model"]
    step_fn = make_reference_step(model.loss_fn, res.schema)
    state = reference_state(fleet_run["params"], res.schema,
                            fleet_run["base_seed"])
    trace = []

    def recording_step(s, batch, mask):
        s2, metrics = step_fn(s, batch, mask)
        trace.append(jax.tree.map(np.asarray, s2.params["model"]))
        return s2, metrics

    loop = LoopConfig(total_steps=STEPS, log_every=0,
                      n_probes=res.schema.n_probes,
                      mask_fn=lambda t: res.masks[t], jit=False)
    state, _ = run(recording_step, state, fleet_run["batch_fn"], loop)
    assert len(trace) == STEPS == len(res.param_trace)
    for t, (a, b) in enumerate(zip(res.param_trace, trace)):
        assert _bitwise_equal(a, b), f"param stream diverged at step {t}"


def test_delta_checkpoint_restore(fleet_run, tmp_path):
    """save_delta(base_step, ledger slice) + restore(replay_fn) lands on
    the canonical params bit-exactly."""
    res = fleet_run["res"]
    base_step, base = res.coordinator.nearest_snapshot(STEPS - 1)
    assert base_step < STEPS, "want a real replay, not a trivial one"
    ckpt.save(tmp_path, base_step, base)
    ckpt.save_delta(tmp_path, STEPS, base_step,
                    res.ledger.slice_bytes(base_step, STEPS))
    assert ckpt.latest_step(tmp_path) == STEPS
    restored, at = ckpt.restore(tmp_path, fleet_run["params"],
                                replay_fn=make_replay_fn(res.schema))
    assert at == STEPS
    assert _bitwise_equal(restored, res.params)
    # a delta checkpoint without replay_fn must refuse, not mis-restore
    with pytest.raises(ValueError, match="ledger delta"):
        ckpt.restore(tmp_path, fleet_run["params"])


def test_ledger_roundtrip_and_wire_budget(fleet_run):
    res = fleet_run["res"]
    led = res.ledger
    led2 = Ledger.from_bytes(led.to_bytes())
    assert led2.commits.keys() == led.commits.keys()
    for t, recs in led.records.items():
        for w, r in recs.items():
            r2 = led2.records[t][w]
            assert (r2.step, r2.worker) == (r.step, r.worker)
            assert np.array_equal(r2.seeds, r.seeds)
            assert np.array_equal(r2.deltas, r.deltas)
            assert r2.loss == r.loss
            assert np.array_equal(r2.tail_scales, r.tail_scales)
            assert all(np.array_equal(a, b)
                       for a, b in zip(r2.tail_q, r.tail_q))
    assert led2.bytes_zo == led.bytes_zo
    # ZO wire bytes per worker-record within 2x of the protocol floor
    # n_probes * (8 + 4): u64 seed + f32 loss-diff per probe
    n_records = sum(len(t) for t in led.records.values())
    floor = res.schema.fleet.probes_per_worker * (8 + 4)
    assert led.bytes_zo / n_records <= 2 * floor


def test_multi_probe_fleet_matches_reference(tmp_path):
    """Smaller, denser variant: 3 workers x 2 probes, full_zo lane (no
    tail payloads on the wire), ledger replay from a fresh joiner."""
    cfg = reduced(get_arch("llama3-8b"), num_layers=2, d_model=32,
                  num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=128)
    lane = LaneConfig(lane="full_zo", zo_num_probes=2,
                      learning_rate=5e-2, zo_eps=1e-3)
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    model = api.build(cfg, shape, lane, ShardingRules(None, cfg, shape))
    params = model.init(jax.random.key(2))
    base_seed = jax.random.key_data(jax.random.key(3))

    def batch_fn(step):
        x, y, m = token_batch(2, 16, cfg.vocab_size, seed=2, step=step)
        return {"tokens": jnp.asarray(x), "labels": jnp.asarray(y),
                "mask": jnp.asarray(m)}

    fleet_cfg = FleetConfig(num_workers=3, probes_per_worker=2,
                            dropout=0.3, chaos_seed=11, snapshot_every=10)
    res = run_fleet(model.loss_fn, params, lane, fleet_cfg, batch_fn,
                    steps=4, base_seed=base_seed, trace=True)
    # records carry no tail payload in full_zo
    rec = next(iter(res.ledger.records[0].values()))
    assert rec.tail_q == [] and rec.zo_nbytes == 11 + 2 * 12

    step_fn = make_reference_step(model.loss_fn, res.schema)
    state = reference_state(params, res.schema, base_seed)
    loop = LoopConfig(total_steps=4, log_every=0, n_probes=6,
                      mask_fn=lambda t: res.masks[t], jit=False)
    state, _ = run(step_fn, state, batch_fn, loop)
    assert _bitwise_equal(state.params["model"], res.params)

    # a brand-new joiner replays the whole ledger from step 0
    joined = make_replay_fn(res.schema)(params, res.ledger.to_bytes(), 0, 4)
    assert _bitwise_equal(joined, res.params)
