"""Memory ledger: tagged live-bytes accounting (repro.obs.memory).

The ledger is the measurement half of the paper's memory story — it
turns "ElasticZO needs ~half of BP's memory" from an analytic formula
into numbers read off the running process. These tests pin the
accounting contract: alloc/free/peak arithmetic, keyed double-alloc /
double-free / leak detection, rebind deltas, region high-water marks,
snapshot JSON round-trips, reconciliation against jax.live_arrays(),
and the compiled-footprint instrument used by BENCH_paper.json.
"""
import json

import pytest

import jax.numpy as jnp

from repro import obs
from repro.obs.memory import (MemoryLedger, NullMemoryLedger,
                              compiled_footprint, tree_nbytes)


@pytest.fixture(autouse=True)
def _pristine_obs():
    obs.uninstall()
    yield
    obs.uninstall()


# ------------------------------------------------------------------ #
# tagged registry arithmetic
# ------------------------------------------------------------------ #


def test_alloc_free_peak_accounting():
    led = MemoryLedger()
    led.alloc("a", 100)
    led.alloc("a", 50)
    led.alloc("b", 30)
    assert led.live == {"a": 150, "b": 30}
    assert led.total_live == 180
    led.free("a", 120)
    assert led.live["a"] == 30
    assert led.peak == {"a": 150, "b": 30}        # peaks never fall
    assert led.total_peak == 180
    led.alloc("a", 10)
    assert led.total_live == 70


def test_unkeyed_frees_validate_against_live():
    led = MemoryLedger()
    led.alloc("t", 10)
    with pytest.raises(ValueError):
        led.free("t", 20)                          # free more than live
    with pytest.raises(ValueError):
        led.free("ghost", 1)                       # tag never allocated


def test_keyed_double_alloc_and_double_free_raise():
    led = MemoryLedger()
    led.alloc("t", 10, key="x")
    with pytest.raises(KeyError):
        led.alloc("t", 5, key="x")
    led.free("t", key="x")
    with pytest.raises(KeyError):
        led.free("t", key="x")
    assert led.total_live == 0


def test_keyed_free_size_is_looked_up():
    led = MemoryLedger()
    led.alloc("t", 64, key="buf")
    led.free("t", key="buf")                       # size comes from the key
    assert led.live.get("t", 0) == 0
    with pytest.raises(ValueError):
        led.alloc("u", 8, key="k")
        led.free("u", 99, key="k")                 # declared size mismatch


def test_leaks_lists_outstanding_keyed_allocs():
    led = MemoryLedger()
    led.alloc("t", 10, key="a")
    led.alloc("t", 20, key="b")
    led.free("t", key="a")
    assert led.leaks() == {"t:b": 20}
    assert led.snapshot()["n_outstanding"] == 1


def test_rebind_is_idempotent_delta_adjust():
    led = MemoryLedger()
    led.rebind("params", 1000, key="m")
    led.rebind("params", 1000, key="m")            # same size: no-op
    assert led.live["params"] == 1000
    led.rebind("params", 400, key="m")             # shrink by delta
    assert led.live["params"] == 400
    assert led.peak["params"] == 1000
    led.rebind("params", 0, key="m")               # release
    assert led.live["params"] == 0


def test_region_high_water_marks_and_max_merge():
    led = MemoryLedger()
    led.alloc("base", 100)
    with led.region("step"):
        led.alloc("tmp", 80)
        led.free("tmp", 80)
    with led.region("step"):                       # second entry: max-merge
        led.alloc("tmp", 30)
        led.free("tmp", 30)
    r = led.regions["step"]
    assert r["count"] == 2
    assert r["peak_bytes"] == 180                  # 100 base + 80 transient
    assert r["hwm_delta_bytes"] == 80              # above the entry floor


def test_snapshot_json_round_trip():
    led = MemoryLedger()
    led.alloc("a", 100)
    led.alloc("b", 50, key="k")
    with led.region("r"):
        led.alloc("a", 10)
    snap = led.snapshot()
    back = json.loads(json.dumps(snap, sort_keys=True))
    assert back == json.loads(json.dumps(snap, sort_keys=True))
    assert back["live"] == {"a": 110, "b": 50}
    assert back["total_peak_bytes"] == 160
    assert back["n_allocs"] == 3 and back["n_frees"] == 0
    led.reset()
    assert led.snapshot()["live"] == {}


def test_null_ledger_is_inert():
    led = NullMemoryLedger()
    assert not led.armed
    led.alloc("a", 100)
    led.free("a", 999)                             # never raises
    led.free("ghost", key="nope")
    led.rebind("p", 10, key="k")
    with led.region("r"):
        pass
    assert led.snapshot() == {}
    assert led.leaks() == {}
    assert led.sample() is None


# ------------------------------------------------------------------ #
# reconciliation against the runtime
# ------------------------------------------------------------------ #


def test_tree_nbytes_sums_leaves_and_tolerates_none():
    tree = {"w": jnp.zeros((4, 4), jnp.float32),
            "b": {"x": jnp.zeros((8,), jnp.int8), "none": None}}
    assert tree_nbytes(tree) == 4 * 4 * 4 + 8
    assert tree_nbytes(None) == 0
    assert tree_nbytes({}) == 0


def test_sample_reconciles_tagged_vs_jax_live():
    x = jnp.arange(1024, dtype=jnp.float32)        # keep a device array live
    led = MemoryLedger()
    led.rebind("t", tree_nbytes(x), key="x")
    s = led.sample()
    assert s["jax_live_bytes"] >= x.nbytes
    assert s["tagged_bytes"] == x.nbytes
    # untagged = jax live minus tagged; host-side tags (wire bytes) can
    # push this negative, but here the tag is a real device buffer
    assert s["untagged_bytes"] == s["jax_live_bytes"] - x.nbytes
    assert led.last_sample is s
    assert led.snapshot()["sample"] == s


def test_module_sample_sets_reconciliation_gauges():
    rec = obs.install()
    try:
        rec.memory.alloc("host.tag", 123)
        s = obs.memory.sample()
        snap = rec.snapshot()
    finally:
        obs.uninstall()
    assert s["tagged_bytes"] == 123
    assert snap["gauges"]["memory.tagged_bytes"] == 123
    assert snap["gauges"]["memory.jax_live_bytes"] == s["jax_live_bytes"]
    assert snap["gauges"]["memory.untagged_bytes"] == s["untagged_bytes"]


def test_module_sample_is_noop_when_disarmed():
    assert obs.memory.sample() is None             # NullRecorder installed


def test_recorder_snapshot_carries_ledger_and_reset_clears():
    rec = obs.install()
    try:
        rec.memory.alloc("a", 7)
        assert rec.snapshot()["memory"]["live"] == {"a": 7}
        rec.reset()
        assert rec.snapshot()["memory"]["live"] == {}
    finally:
        obs.uninstall()


# ------------------------------------------------------------------ #
# compiled footprint (the measured half of Eqs. 2-4 / 13-15)
# ------------------------------------------------------------------ #


def test_compiled_footprint_reports_xla_buffer_assignment():
    def f(x):
        return (x * 2.0).sum()

    x = jnp.zeros((256,), jnp.float32)
    fp = compiled_footprint(f, x)
    if fp is None:                                 # backend without analysis
        pytest.skip("memory_analysis unavailable on this backend")
    for k in ("argument_bytes", "output_bytes", "temp_bytes",
              "alias_bytes", "peak_bytes"):
        assert k in fp and fp[k] >= 0
    assert fp["argument_bytes"] >= x.nbytes
    assert fp["peak_bytes"] == (fp["argument_bytes"] + fp["output_bytes"]
                                + fp["temp_bytes"] - fp["alias_bytes"])


def test_compiled_footprint_donation_shrinks_or_matches():
    def g(x):
        return x + 1.0

    x = jnp.zeros((1024,), jnp.float32)
    plain = compiled_footprint(g, x)
    donated = compiled_footprint(g, x, donate_argnums=(0,))
    if plain is None or donated is None:
        pytest.skip("memory_analysis unavailable on this backend")
    assert donated["peak_bytes"] <= plain["peak_bytes"]


def test_step_memory_analysis_orders_lanes_like_the_paper():
    """The measured twin of the paper's Table: full-BP's XLA peak must
    exceed full-ZO's on the same LeNet step (the headline claim)."""
    from benchmarks.paper_tables import lenet_measured_memory

    lanes = lenet_measured_memory(batch=32)
    if not lanes:
        pytest.skip("memory_analysis unavailable on this backend")
    assert lanes["full_bp"]["peak_bytes"] > lanes["full_zo"]["peak_bytes"]
    assert lanes["zo_feat_cls2"]["peak_bytes"] >= \
        lanes["full_zo"]["peak_bytes"]
