"""Data pipeline determinism + elastic runtime resume."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LaneConfig, ShapeConfig, get_arch, reduced
from repro.data.pipeline import Prefetcher, lm_batch_fn, device_put_batch
from repro.train import checkpoint as ckpt
from repro.train.elastic_runtime import resume_on_mesh


def test_batch_fn_pure_function_of_step():
    cfg = reduced(get_arch("llama3-8b"))
    shape = ShapeConfig("t", seq_len=32, global_batch=2, kind="train")
    fn = lm_batch_fn(cfg, shape, seed=3)
    a = fn(17)
    b = fn(17)
    for k in a:
        assert np.array_equal(a[k], b[k])
    c = fn(18)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_prefetcher_ordered_and_restartable():
    cfg = reduced(get_arch("llama3-8b"))
    shape = ShapeConfig("t", seq_len=32, global_batch=2, kind="train")
    fn = lm_batch_fn(cfg, shape, seed=0)
    pf = Prefetcher(fn, start_step=5)
    steps, batches = [], []
    for _ in range(3):
        s, b = pf.get()
        steps.append(s)
        batches.append(b)
    pf.close()
    assert steps == [5, 6, 7]
    # a "restarted" prefetcher at step 6 replays batch 6 exactly
    pf2 = Prefetcher(fn, start_step=6)
    s2, b2 = pf2.get()
    pf2.close()
    assert s2 == 6
    assert jnp.array_equal(batches[1]["tokens"], b2["tokens"])


def test_elastic_resume_roundtrip(tmp_path):
    """Train 3 steps, checkpoint, resume via the elastic runtime (same
    single-device 'mesh' = None) and continue identically to an
    uninterrupted run."""
    cfg = reduced(get_arch("llama3-8b"))
    shape = ShapeConfig("t", seq_len=32, global_batch=2, kind="train")
    lane = LaneConfig(lane="elastic_zo", bp_tail_layers=1)
    fn = lm_batch_fn(cfg, shape, seed=1)

    def batch(step):
        return device_put_batch(fn(step))

    state, model, step = resume_on_mesh(None, cfg, shape, lane, mesh=None)
    pm = jnp.ones((1,), jnp.float32)
    # uninterrupted 6 steps
    sA = state
    for t in range(6):
        sA, _ = step(sA, batch(t), pm)

    # interrupted: 3 steps, checkpoint, resume, 3 more
    sB, model2, step2 = resume_on_mesh(None, cfg, shape, lane, mesh=None)
    for t in range(3):
        sB, _ = step2(sB, batch(t), pm)
    ckpt.save(tmp_path, 3, sB.params)
    sC, model3, step3 = resume_on_mesh(tmp_path, cfg, shape, lane, mesh=None)
    assert int(sC.step) == 3
    for t in range(3, 6):
        sC, _ = step3(sC, batch(t), pm)

    for a, b in zip(jax.tree.leaves(sA.params), jax.tree.leaves(sC.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
