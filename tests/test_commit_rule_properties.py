"""Hypothesis property tests for the extracted commit rule.

The invariants that make leaderless closing sound (fleet/commit_rule.py,
docs/fleet.md "Leaderless commits"):

  * **arrival-order invariance** — ``close_step`` sees an arrival
    multiset, not an order: permuting the arrivals list changes nothing
    about the Commit, the candidate bits, or the on-time/late split;
  * **topology invariance** — star and fully-connected gossip on a
    loss-free link produce identical Commit streams and parameters (the
    coordinator was never semantically special);
  * **partition-heal determinism** — a partition schedule is a
    deterministic fixture: rerunning it reproduces the commit stream
    and canon bit-for-bit, and every healed peer lands on them.

tests/test_commit_rule.py pins hand-picked cases of the same invariants
and runs without hypothesis.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: suite must collect without it
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import GossipConfig, RobustConfig  # noqa: E402
from repro.fleet import RobustGate, close_step  # noqa: E402
from repro.fleet.transport import Fate  # noqa: E402

from test_fleet_robust import (W, run_toy_fleet, toy_fleet_cfg,  # noqa: E402
                               toy_records, toy_schema)

finite32 = st.floats(allow_nan=False, allow_infinity=False, width=32)
delta_st = st.lists(finite32, min_size=W, max_size=W)
loss_st = st.lists(st.floats(0.0, 100.0, width=32), min_size=W, max_size=W)
fate_st = st.tuples(st.booleans(), st.integers(0, 4))
fates_st = st.lists(fate_st, min_size=1, max_size=W)
perm_st = st.permutations(list(range(W)))


def _bitwise_equal(a, b):
    return all(jnp.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _outcome_key(o):
    return (o.commit.to_bytes(), o.ontime_bits, o.late_admit_bits,
            tuple(sorted(o.records)), o.outliers,
            None if o.retried is None else o.retried.worker)


@settings(deadline=None, max_examples=60)
@given(delta_st, loss_st, fates_st, perm_st, st.booleans())
def test_close_step_invariant_to_arrival_order(deltas, losses, fates,
                                               perm, robust):
    """Shuffling the arrivals list is a no-op: the pipeline sorts by
    (delay, highest-id) internally, so every peer — whatever order the
    mesh delivered records in — closes the identical step."""
    cfg = toy_fleet_cfg(deadline=1,
                        robust=RobustConfig() if robust else None)
    _, _, schema = toy_schema(cfg)
    recs = toy_records(schema, 0, np.asarray(deltas, np.float32),
                       np.asarray(losses, np.float32))
    arrivals = [(recs[w], Fate(d, delay))
                for w, (d, delay) in enumerate(fates)]
    a = close_step(RobustGate(schema), 0, arrivals)
    shuffled = [arrivals[i] for i in perm if i < len(arrivals)]
    b = close_step(RobustGate(schema), 0, shuffled)
    assert _outcome_key(a) == _outcome_key(b)


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 2 ** 31 - 1), st.booleans(),
       st.integers(1, W - 1), st.integers(1, 3))
def test_star_and_gossip_identical_on_loss_free_link(seed, robust,
                                                     fanout, rounds):
    """Topology invariance: with no drops and no delays, a star run and
    a fully-connected-enough gossip run produce the identical Commit
    stream and canon — the commit rule is the same pure function."""
    rob = RobustConfig() if robust else None
    _, rs = run_toy_fleet(toy_fleet_cfg(chaos_seed=seed, robust=rob),
                          steps=4)
    _, rg = run_toy_fleet(
        toy_fleet_cfg(chaos_seed=seed, robust=rob, topology="gossip",
                      gossip=GossipConfig(fanout=fanout, rounds=rounds)),
        steps=4)
    assert [c.to_bytes() for c in rs.ledger.commits.values()] == \
        [c.to_bytes() for c in rg.ledger.commits.values()]
    assert _bitwise_equal(rs.params, rg.params)


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 2 ** 31 - 1),
       st.integers(1, 3), st.integers(2, 4),
       st.sets(st.integers(0, W - 1), min_size=1, max_size=W // 2 - 1))
def test_partition_heal_is_deterministic(seed, lo, width, minority):
    """Same partition schedule, same chaos seed -> bit-identical commit
    stream and canon, twice over; every surviving peer agrees."""
    group = sum(1 << w for w in minority)
    cfg = toy_fleet_cfg(
        chaos_seed=seed, dropout=0.2, max_delay=2, deadline=1,
        topology="gossip",
        gossip=GossipConfig(partitions=((lo, lo + width, group),)))
    _, r1 = run_toy_fleet(cfg, steps=lo + width + 2)
    _, r2 = run_toy_fleet(cfg, steps=lo + width + 2)
    assert [c.to_bytes() for c in r1.ledger.commits.values()] == \
        [c.to_bytes() for c in r2.ledger.commits.values()]
    assert _bitwise_equal(r1.params, r2.params)
    for p in r1.peers:
        assert p.alive and _bitwise_equal(p.params, r1.params), p.id
    # minority probes masked for the whole window
    for t in range(lo, lo + width):
        for w in minority:
            assert r1.masks[t][w] == 0.0
