"""repro.fleet int8 lane acceptance: chaos fleet == single process, bitwise.

The int8 twin of tests/test_fleet.py: an 8-worker ElasticZO-INT8 (Alg. 2)
chaos run — transport dropout, stragglers, a mid-run crash/rejoin via
ledger replay — must hold every worker and the single-process reference
bit-exact, with record-v2 ledger probes at 9 bytes each. Plus the "one
update engine" proof: a degenerate 1-worker fleet reproduces the
engine-built single-process elastic_int8 train step exactly.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import FleetConfig, LaneConfig
from repro.core.elastic import TrainState
from repro.core.elastic_int8 import make_int8_elastic_step
from repro.core.int8 import quant_from_float
from repro.data.synthetic import glyphs
from repro.fleet import (make_int8_probe_fn, make_reference_step,
                         make_replay_fn, reference_state, run_fleet)
from repro.models import lenet
from repro.train import checkpoint as ckpt
from repro.train.train_loop import LoopConfig, run

# minutes-scale integration fixture: full chaos fleet + reference re-run
pytestmark = pytest.mark.slow

WORKERS = 8
STEPS = 8
CRASH = (5, 3, 3)        # worker 5 dies at step 3, rejoins at step 6
BATCH = 8
TAIL_FCS = [("fc3", "fc3_in")]


def _bitwise_equal(a, b):
    return all(jnp.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _partition(p):
    return lenet.partition_at(p, 4)


def _batch_fn(step):
    xs, ys = glyphs(BATCH, seed=1, start=step * BATCH)
    return {"x": quant_from_float(jnp.asarray(xs)), "y": jnp.asarray(ys)}


@pytest.fixture(scope="module")
def int8_fleet_run():
    lane = LaneConfig(lane="elastic_zo_int8", zo_num_probes=1)
    probe_fn = make_int8_probe_fn(lenet.lenet5_forward_int8, lane,
                                  _partition, TAIL_FCS)
    params = lenet.init_lenet5_int8(jax.random.key(0))
    base_seed = jax.random.key_data(jax.random.key(1))
    fleet_cfg = FleetConfig(num_workers=WORKERS, probes_per_worker=1,
                            dropout=0.25, max_delay=2, deadline=1,
                            chaos_seed=3, snapshot_every=4,
                            crashes=(CRASH,))
    res = run_fleet(None, params, lane, fleet_cfg, _batch_fn, steps=STEPS,
                    base_seed=base_seed, partition_fn=_partition,
                    probe_fn=probe_fn, trace=True)
    return dict(res=res, params=params, lane=lane, probe_fn=probe_fn,
                base_seed=base_seed)


def test_chaos_exercised_and_nine_byte_probes(int8_fleet_run):
    res = int8_fleet_run["res"]
    assert res.stats["n_dropped"] > 0, "dropout chaos never fired"
    assert res.stats["n_straggled"] > 0, "latency chaos never fired"
    assert res.stats["n_catchups"] == 1
    assert res.stats["bytes_catchup"] > 0
    w, cs, down = CRASH
    for t in range(cs, cs + down):
        assert res.masks[t][w] == 0.0
    # ROADMAP claim, asserted: the int8 lane's ZO part costs <= 9
    # bytes/probe on the wire (u64 seed + ternary-sign byte, record v2)
    for step_recs in res.ledger.records.values():
        for rec in step_recs.values():
            assert rec.numerics == "int8"
            assert rec.zo_probe_nbytes <= 9
            assert len(rec.to_bytes()) == rec.nbytes
    n_records = sum(len(t) for t in res.ledger.records.values())
    hdr = 11
    assert res.ledger.bytes_zo == n_records * (hdr + 9)


def test_workers_bitwise_in_sync_with_coordinator(int8_fleet_run):
    res = int8_fleet_run["res"]
    for w in res.workers:
        assert w.alive and w.step == STEPS
        assert _bitwise_equal(w.params, res.params), f"worker {w.id}"


def test_int8_fleet_reproduces_single_process_reference(int8_fleet_run):
    """The acceptance bar: the 8-worker int8 chaos run's canonical
    parameter stream == train_loop.run over the single-process reference
    with the realized probe masks, bit-exactly at every step."""
    res = int8_fleet_run["res"]
    step_fn = make_reference_step(None, res.schema,
                                  probe_fn=int8_fleet_run["probe_fn"])
    state = reference_state(int8_fleet_run["params"], res.schema,
                            int8_fleet_run["base_seed"])
    trace = []

    def recording_step(s, batch, mask):
        s2, metrics = step_fn(s, batch, mask)
        trace.append(jax.tree.map(np.asarray, s2.params["model"]))
        return s2, metrics

    loop = LoopConfig(total_steps=STEPS, log_every=0,
                      n_probes=res.schema.n_probes,
                      mask_fn=lambda t: res.masks[t], jit=False)
    run(recording_step, state, _batch_fn, loop)
    assert len(trace) == STEPS == len(res.param_trace)
    for t, (a, b) in enumerate(zip(res.param_trace, trace)):
        assert _bitwise_equal(a, b), f"param stream diverged at step {t}"


def test_delta_checkpoint_restore_int8(int8_fleet_run, tmp_path):
    """Delta checkpoints hold int8 records: save_delta(base, slice) +
    restore(replay_fn) lands on the canonical int8 params bit-exactly."""
    res = int8_fleet_run["res"]
    base_step, base = res.coordinator.nearest_snapshot(STEPS - 1)
    assert base_step < STEPS, "want a real replay, not a trivial one"
    ckpt.save(tmp_path, base_step, base)
    ckpt.save_delta(tmp_path, STEPS, base_step,
                    res.ledger.slice_bytes(base_step, STEPS))
    restored, at = ckpt.restore(tmp_path, int8_fleet_run["params"],
                                replay_fn=make_replay_fn(res.schema))
    assert at == STEPS
    assert _bitwise_equal(restored, res.params)


def test_one_engine_fleet_equals_single_process_step():
    """The tentpole contract: a 1-worker no-chaos int8 fleet and the
    engine-built elastic_int8 train step produce the same parameter
    stream bit for bit — ledger apply and live step are one engine."""
    lane = LaneConfig(lane="elastic_zo_int8", zo_num_probes=1)
    probe_fn = make_int8_probe_fn(lenet.lenet5_forward_int8, lane,
                                  _partition, TAIL_FCS)
    params = lenet.init_lenet5_int8(jax.random.key(4))
    base_seed = jax.random.key_data(jax.random.key(5))
    res = run_fleet(None, params, lane,
                    FleetConfig(num_workers=1, probes_per_worker=1),
                    _batch_fn, steps=4, base_seed=base_seed,
                    partition_fn=_partition, probe_fn=probe_fn)

    step = jax.jit(make_int8_elastic_step(
        lenet.lenet5_forward_int8, partition_fn=_partition,
        tail_fcs=TAIL_FCS, lane=lane))
    state = TrainState(params, jnp.int32(0), jnp.asarray(base_seed))
    for t in range(4):
        state, _ = step(state, _batch_fn(t), jnp.ones((1,), jnp.float32))
    assert _bitwise_equal(state.params, res.params)


def test_multi_probe_int8_fleet_matches_reference():
    """3 workers x 2 probes, full-ZO int8 (no tail payload on the wire),
    fresh-joiner ledger replay."""
    lane = LaneConfig(lane="elastic_zo_int8", zo_num_probes=2)
    part = lambda p: lenet.partition_at(p, 5)  # noqa: E731
    probe_fn = make_int8_probe_fn(lenet.lenet5_forward_int8, lane,
                                  part, [])
    params = lenet.init_lenet5_int8(jax.random.key(2))
    base_seed = jax.random.key_data(jax.random.key(3))
    fleet_cfg = FleetConfig(num_workers=3, probes_per_worker=2,
                            dropout=0.3, chaos_seed=11, snapshot_every=10)
    res = run_fleet(None, params, lane, fleet_cfg, _batch_fn, steps=4,
                    base_seed=base_seed, partition_fn=part,
                    probe_fn=probe_fn)
    rec = next(iter(res.ledger.records[0].values()))
    assert rec.tail_q == [] and rec.zo_nbytes == 11 + 2 * 9

    step_fn = make_reference_step(None, res.schema, probe_fn=probe_fn)
    state = reference_state(params, res.schema, base_seed)
    loop = LoopConfig(total_steps=4, log_every=0, n_probes=6,
                      mask_fn=lambda t: res.masks[t], jit=False)
    state, _ = run(step_fn, state, _batch_fn, loop)
    assert _bitwise_equal(state.params["model"], res.params)

    # a brand-new joiner replays the whole int8 ledger from step 0
    joined = make_replay_fn(res.schema)(params, res.ledger.to_bytes(), 0, 4)
    assert _bitwise_equal(joined, res.params)


def test_int8_replay_kernel_parity():
    """Pallas int8 fused-replay kernel (interpret mode) == eager ref,
    bitwise, and a fused multi-step pass == live stepping."""
    from repro.kernels import ops, ref
    rng = np.random.default_rng(7)
    theta = jnp.asarray(rng.integers(-127, 128, (1000,)), jnp.int8)
    seeds = jnp.asarray(rng.integers(0, 2**32, (3, 2)), jnp.uint32)
    gs = jnp.asarray(rng.integers(-1, 2, (3, 2)), jnp.int32)
    r = ref.zo_fused_replay_int8_ref(theta, seeds, gs, 13, 3, 0.33, 1)
    k = ops.zo_fused_replay_int8(theta, seeds, gs, 13, 3, 0.33, 1,
                                 force_pallas=True, interpret=True)
    assert jnp.array_equal(r, k)
    live = theta
    for s in range(3):
        live = ops.zo_fused_replay_int8(live, seeds[s:s + 1], gs[s:s + 1],
                                        13, 3, 0.33, 1)
    assert jnp.array_equal(r, live)
    # masked probes (g = 0) are an exact no-op
    out = ops.zo_fused_replay_int8(theta, seeds, jnp.zeros_like(gs),
                                   13, 3, 0.33, 1)
    assert jnp.array_equal(out, theta)
