"""Paged serving: kernel-vs-ref, paged-vs-dense parity, preemption
robustness, dense cache-growth regression, CLI smoke."""
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, LaneConfig, ShapeConfig, ServeConfig, reduced
from repro.core import api
from repro.kernels import ref
from repro.kernels.paged_attn import paged_attention_step
from repro.serve import Engine, SamplingParams, dense_generate
from repro.sharding.rules import ShardingRules

# minutes-scale integration suite: dense-vs-paged parity + CLI smoke
pytestmark = pytest.mark.slow


# ------------------------------------------------------------------ #
# kernel vs oracle (interpret mode)
# ------------------------------------------------------------------ #
def _fused_case(seed=0):
    rng = np.random.default_rng(seed)
    B, KVd, G, Dh, N, ps, P = 3, 2, 4, 16, 16, 8, 4
    q = jnp.asarray(rng.normal(size=(B, KVd, G, Dh)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(B, KVd, Dh)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(B, KVd, Dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(N, ps, KVd, Dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, ps, KVd, Dh)), jnp.float32)
    pt = np.zeros((B, P), np.int32)
    pt[0, :2] = [3, 7]
    pt[1, :4] = [1, 2, 4, 5]
    pt[2, :1] = [9]
    sl = jnp.asarray([11, 30, 3], jnp.int32)
    return q, kn, vn, kp, vp, pt, sl


@pytest.mark.parametrize("window", [0, 6])
@pytest.mark.parametrize("pages_per_block", [1, 2, 4])
def test_paged_kernel_matches_ref(window, pages_per_block):
    q, kn, vn, kp, vp, pt, sl = _fused_case()
    o_ref, kr, vr = ref.paged_attn_step_ref(
        q, kn, vn, kp, vp, jnp.asarray(pt), sl, scale=0.25, window=window)
    o_pal, kpal, vpal = paged_attention_step(
        q, kn, vn, kp, vp, jnp.asarray(pt), sl, scale=0.25, window=window,
        pages_per_block=pages_per_block, interpret=True)
    assert float(jnp.max(jnp.abs(o_ref - o_pal))) < 1e-5
    # the fused KV write must land identically on both paths
    assert bool(jnp.array_equal(kr, kpal))
    assert bool(jnp.array_equal(vr, vpal))
    # and actually hold the incoming token at (page_of(pos), pos % ps)
    ps_ = kp.shape[1]
    for b, pos in enumerate(np.asarray(sl)):
        page = pt[b, pos // ps_]
        assert bool(jnp.array_equal(kpal[page, pos % ps_], kn[b]))


def test_paged_kernel_skips_reclaimed_null_pages():
    """SWA reclamation re-nulls fully windowed-out table entries after
    freeing their pages. The kernel must skip them (no read), and the
    output must equal the un-reclaimed run because the window mask
    already excluded those positions."""
    window = 6
    q, kn, vn, kp, vp, pt, sl = _fused_case()
    o_full, _, _ = paged_attention_step(
        q, kn, vn, kp, vp, jnp.asarray(pt), sl, scale=0.25, window=window,
        interpret=True)
    # row 1 sits at pos 30: window (24, 30] lives entirely in logical
    # page 3, so pages 0..2 are fully out of window -> reclaimed
    rec = pt.copy()
    rec[1, :3] = 0
    o_rec, krec, vrec = paged_attention_step(
        q, kn, vn, kp, vp, jnp.asarray(rec), sl, scale=0.25, window=window,
        interpret=True)
    assert float(jnp.max(jnp.abs(o_full[1] - o_rec[1]))) < 1e-6
    r_ref, kr, vr = ref.paged_attn_step_ref(
        q, kn, vn, kp, vp, jnp.asarray(rec), sl, scale=0.25, window=window)
    assert float(jnp.max(jnp.abs(r_ref - o_rec))) < 1e-5
    assert bool(jnp.array_equal(kr, krec))
    assert bool(jnp.array_equal(vr, vrec))


# ------------------------------------------------------------------ #
# paged engine vs dense static-batch path: identical greedy streams
# ------------------------------------------------------------------ #
# mixtral covers the SWA path: full_kv prefill, paged window mask, and
# window-capped dense growth (bitwise parity holds while the dense ring
# hasn't wrapped — cached 16 tokens == reduced window here)
@pytest.mark.parametrize("arch",
                         ["qwen3-4b", "jamba-v0.1-52b", "mixtral-8x7b"])
def test_paged_matches_dense(arch):
    cfg = reduced(ARCHS[arch])
    serve = ServeConfig(page_size=8, num_pages=64, max_batch_slots=3,
                        max_seq_len=64, max_new_tokens=6)
    eng = Engine(cfg, serve)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (3, 10)).astype(np.int32)
    paged = eng.generate([list(p) for p in prompts], SamplingParams(), 6)
    dense = dense_generate(cfg, eng.params, prompts, 6)
    assert [list(d) for d in dense] == paged
    eng.sched.check_invariants()
    assert eng.sched.pool.used_pages == 0          # all pages returned


def test_megastep_equals_tick_by_tick():
    """The multi-tick fused megastep (ServeConfig.megastep > 1) must be
    invisible in the token streams: same engine, same requests — mixed
    prompt lengths (exercising grouped wave admission) and mixed sampling
    knobs (greedy + temperature/top-k/top-p rows inside one scan) — run
    once with fusion disabled and once with a big horizon cap. SWA arch,
    so reclamation postponement to horizon boundaries is in play too."""
    cfg = reduced(ARCHS["mixtral-8x7b"])           # sliding_window = 16
    base = dict(page_size=4, num_pages=64, max_batch_slots=3,
                max_seq_len=48, max_new_tokens=12)
    rng = np.random.default_rng(7)
    prompts = [list(rng.integers(0, cfg.vocab_size, n).astype(int))
               for n in (5, 9, 14)]
    sampling = [SamplingParams(),
                SamplingParams(temperature=0.8, top_k=7, seed=11),
                SamplingParams(temperature=1.1, top_p=0.9, seed=23)]

    def run(serve):
        eng = Engine(cfg, serve, params=run.params)
        if run.params is None:
            run.params = eng.params
        rids = [eng.submit(p, sp, 12) for p, sp in zip(prompts, sampling)]
        out = eng.run()
        return [out[r] for r in rids], eng.steps_run
    run.params = None

    tick_by_tick, steps1 = run(ServeConfig(**base, megastep=1))
    fused, stepsN = run(ServeConfig(**base, megastep=32))
    assert fused == tick_by_tick
    assert stepsN < steps1, "megastep fusion never engaged"


def test_swa_bounded_pool_long_decode():
    """SWA reclamation: a pool sized to the *window* must complete a
    decode longer than the window (pages return to the pool as they
    slide out), token-identical to an uncontended big-pool run. The
    same request in a non-reclaiming scheduler (window 0) is rejected
    at submit — the pre-reclamation behavior."""
    cfg = reduced(ARCHS["mixtral-8x7b"])           # sliding_window = 16
    w, ps = cfg.sliding_window, 4
    assert w == 16
    new_tok = 24                                   # decode well past window
    total = 8 + new_tok
    # worst case with reclamation: pages_for(window) + 1 = 5 usable pages
    bounded = ServeConfig(page_size=ps, num_pages=1 + (w // ps + 1),
                          max_batch_slots=1, max_seq_len=total,
                          max_new_tokens=new_tok)
    big = Engine(cfg, ServeConfig(page_size=ps, num_pages=32,
                                  max_batch_slots=1, max_seq_len=total,
                                  max_new_tokens=new_tok))
    rng = np.random.default_rng(2)
    prompt = list(rng.integers(0, cfg.vocab_size, 8).astype(np.int32))
    want = big.generate([prompt], SamplingParams(), new_tok)
    small = Engine(cfg, bounded, params=big.params)
    got = small.generate([prompt], SamplingParams(), new_tok)
    assert got == want
    assert small.sched.reclaimed_pages > 0
    assert small.page_utilization()["peak_pages"] <= w // ps + 1
    assert sum(s.preemptions for s in small.sched.finished) == 0, \
        "bounded pool should reclaim, not thrash via preemption"
    small.sched.check_invariants()
    assert small.sched.pool.used_pages == 0
    # without reclamation the same request can never fit: the submit
    # worst-case guard (pages_for(total+1) > pool) rejects it
    from repro.serve.scheduler import Scheduler
    with pytest.raises(ValueError, match="worst case"):
        Scheduler(bounded, window=0).submit(prompt, SamplingParams(),
                                            new_tok)


def test_preemption_preserves_streams():
    """A pool too small for all requests at once forces preemption +
    recompute re-admission; greedy output must equal the uncontended run."""
    cfg = reduced(ARCHS["qwen3-4b"])
    rng = np.random.default_rng(1)
    prompts = [list(t) for t in
               rng.integers(0, cfg.vocab_size, (4, 9)).astype(np.int32)]
    big = Engine(cfg, ServeConfig(page_size=4, num_pages=64,
                                  max_batch_slots=4, max_seq_len=32,
                                  max_new_tokens=8))
    want = big.generate(prompts, SamplingParams(), 8)
    # 9 usable pages; one sequence needs ceil((9+8+1)/4) = 5 -> contention
    small = Engine(cfg, ServeConfig(page_size=4, num_pages=10,
                                    max_batch_slots=4, max_seq_len=32,
                                    max_new_tokens=8),
                   params=big.params)
    got = small.generate(prompts, SamplingParams(), 8)
    assert got == want
    assert sum(s.preemptions for s in small.sched.finished) > 0, \
        "test did not actually exercise preemption"
    small.sched.check_invariants()


def test_sampled_serving_runs_and_is_seeded():
    cfg = reduced(ARCHS["llama3-8b"])
    serve = ServeConfig(page_size=8, num_pages=32, max_batch_slots=2,
                        max_seq_len=48, max_new_tokens=5)
    eng = Engine(cfg, serve)
    prompts = [[1, 2, 3, 4], [9, 8, 7, 6]]
    sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=123)
    a = eng.generate(prompts, sp, 5)
    eng2 = Engine(cfg, serve, params=eng.params)
    b = eng2.generate(prompts, sp, 5)
    assert a == b                                   # seed-replay property
    assert all(len(x) == 5 for x in a)
    # sampled tokens must stay inside the REAL vocab (padded unembed
    # columns carry arbitrary weights and are masked out of sampling)
    assert all(0 <= t < cfg.vocab_size for x in a for t in x)


# ------------------------------------------------------------------ #
# dense-path cache growth regression (the old shape heuristic)
# ------------------------------------------------------------------ #
def test_grow_dense_caches_ignores_lookalike_dims():
    """whisper smoke: encoder_seq == prompt length. The old grow() padded
    any dim-2 == prompt-length leaf, corrupting cross-attn KV; the
    path-aware growth must leave everything but self-attn k/v alone."""
    from repro.serve import grow_dense_caches
    cfg = reduced(ARCHS["whisper-small"])          # encoder_seq = 16
    Lp = cfg.encoder_seq                           # collide on purpose
    lane = LaneConfig()
    ps_ = ShapeConfig("p", seq_len=Lp, global_batch=2, kind="prefill")
    mp = api.build(cfg, ps_, lane, ShardingRules(None, cfg, ps_))
    params = mp.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, Lp)), jnp.int32),
        "frames": jnp.zeros((2, cfg.encoder_seq, cfg.d_model),
                            jnp.dtype(cfg.dtype))}
    _, caches = jax.jit(mp.prefill_step)(params, batch)
    total = Lp + 8
    grown = grow_dense_caches(caches, cfg, total)
    for part in ("zo", "bp"):
        for old, new in zip(caches[part], grown[part]):
            assert new["k"].shape[2] == total
            assert new["v"].shape[2] == total
            assert new["ck"].shape == old["ck"].shape      # untouched
            assert new["cv"].shape == old["cv"].shape
            assert bool(jnp.array_equal(new["ck"], old["ck"]))


def test_dense_generate_whisper_lookalike_end_to_end():
    """Full dense serve at the collision length must decode fine."""
    cfg = reduced(ARCHS["whisper-small"])
    lane = LaneConfig()
    shape = ShapeConfig("i", seq_len=32, global_batch=1, kind="prefill")
    m = api.build(cfg, shape, lane, ShardingRules(None, cfg, shape))
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (2, cfg.encoder_seq)).astype(np.int32)
    out = dense_generate(cfg, params, prompts, 4)
    assert out.shape == (2, 4)
    assert (out >= 0).all()


# ------------------------------------------------------------------ #
# CLI smoke (acceptance: --smoke --paged completes)
# ------------------------------------------------------------------ #
def test_serve_cli_paged_smoke():
    from pathlib import Path
    src = str(Path(__file__).resolve().parents[1] / "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-4b",
         "--smoke", "--paged", "--batch", "2", "--prompt-len", "8",
         "--tokens", "4", "--page-size", "4"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": src, "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[serve] paged:" in r.stdout
    assert "pages: peak" in r.stdout
