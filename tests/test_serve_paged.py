"""Paged serving: kernel-vs-ref, paged-vs-dense parity, preemption
robustness, dense cache-growth regression, CLI smoke."""
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, LaneConfig, ShapeConfig, ServeConfig, reduced
from repro.core import api
from repro.kernels import ref
from repro.kernels.paged_attn import paged_attention
from repro.serve import Engine, SamplingParams, dense_generate
from repro.sharding.rules import ShardingRules

# minutes-scale integration suite: dense-vs-paged parity + CLI smoke
pytestmark = pytest.mark.slow


# ------------------------------------------------------------------ #
# kernel vs oracle (interpret mode)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("window", [0, 6])
def test_paged_kernel_matches_ref(window):
    rng = np.random.default_rng(0)
    B, KVd, G, Dh, N, ps, P = 3, 2, 4, 16, 16, 8, 4
    q = jnp.asarray(rng.normal(size=(B, KVd, G, Dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(N, ps, KVd, Dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, ps, KVd, Dh)), jnp.float32)
    pt = np.zeros((B, P), np.int32)
    pt[0, :2] = [3, 7]
    pt[1, :4] = [1, 2, 4, 5]
    pt[2, :1] = [9]
    sl = jnp.asarray([11, 30, 3], jnp.int32)
    o_ref = ref.paged_attn_ref(q, kp, vp, jnp.asarray(pt), sl,
                               scale=0.25, window=window)
    o_pal = paged_attention(q, kp, vp, jnp.asarray(pt), sl,
                            scale=0.25, window=window, interpret=True)
    assert float(jnp.max(jnp.abs(o_ref - o_pal))) < 1e-5


# ------------------------------------------------------------------ #
# paged engine vs dense static-batch path: identical greedy streams
# ------------------------------------------------------------------ #
# mixtral covers the SWA path: full_kv prefill, paged window mask, and
# window-capped dense growth (bitwise parity holds while the dense ring
# hasn't wrapped — cached 16 tokens == reduced window here)
@pytest.mark.parametrize("arch",
                         ["qwen3-4b", "jamba-v0.1-52b", "mixtral-8x7b"])
def test_paged_matches_dense(arch):
    cfg = reduced(ARCHS[arch])
    serve = ServeConfig(page_size=8, num_pages=64, max_batch_slots=3,
                        max_seq_len=64, max_new_tokens=6)
    eng = Engine(cfg, serve)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (3, 10)).astype(np.int32)
    paged = eng.generate([list(p) for p in prompts], SamplingParams(), 6)
    dense = dense_generate(cfg, eng.params, prompts, 6)
    assert [list(d) for d in dense] == paged
    eng.sched.check_invariants()
    assert eng.sched.pool.used_pages == 0          # all pages returned


def test_preemption_preserves_streams():
    """A pool too small for all requests at once forces preemption +
    recompute re-admission; greedy output must equal the uncontended run."""
    cfg = reduced(ARCHS["qwen3-4b"])
    rng = np.random.default_rng(1)
    prompts = [list(t) for t in
               rng.integers(0, cfg.vocab_size, (4, 9)).astype(np.int32)]
    big = Engine(cfg, ServeConfig(page_size=4, num_pages=64,
                                  max_batch_slots=4, max_seq_len=32,
                                  max_new_tokens=8))
    want = big.generate(prompts, SamplingParams(), 8)
    # 9 usable pages; one sequence needs ceil((9+8+1)/4) = 5 -> contention
    small = Engine(cfg, ServeConfig(page_size=4, num_pages=10,
                                    max_batch_slots=4, max_seq_len=32,
                                    max_new_tokens=8),
                   params=big.params)
    got = small.generate(prompts, SamplingParams(), 8)
    assert got == want
    assert sum(s.preemptions for s in small.sched.finished) > 0, \
        "test did not actually exercise preemption"
    small.sched.check_invariants()


def test_sampled_serving_runs_and_is_seeded():
    cfg = reduced(ARCHS["llama3-8b"])
    serve = ServeConfig(page_size=8, num_pages=32, max_batch_slots=2,
                        max_seq_len=48, max_new_tokens=5)
    eng = Engine(cfg, serve)
    prompts = [[1, 2, 3, 4], [9, 8, 7, 6]]
    sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=123)
    a = eng.generate(prompts, sp, 5)
    eng2 = Engine(cfg, serve, params=eng.params)
    b = eng2.generate(prompts, sp, 5)
    assert a == b                                   # seed-replay property
    assert all(len(x) == 5 for x in a)
    # sampled tokens must stay inside the REAL vocab (padded unembed
    # columns carry arbitrary weights and are masked out of sampling)
    assert all(0 <= t < cfg.vocab_size for x in a for t in x)


# ------------------------------------------------------------------ #
# dense-path cache growth regression (the old shape heuristic)
# ------------------------------------------------------------------ #
def test_grow_dense_caches_ignores_lookalike_dims():
    """whisper smoke: encoder_seq == prompt length. The old grow() padded
    any dim-2 == prompt-length leaf, corrupting cross-attn KV; the
    path-aware growth must leave everything but self-attn k/v alone."""
    from repro.serve import grow_dense_caches
    cfg = reduced(ARCHS["whisper-small"])          # encoder_seq = 16
    Lp = cfg.encoder_seq                           # collide on purpose
    lane = LaneConfig()
    ps_ = ShapeConfig("p", seq_len=Lp, global_batch=2, kind="prefill")
    mp = api.build(cfg, ps_, lane, ShardingRules(None, cfg, ps_))
    params = mp.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, Lp)), jnp.int32),
        "frames": jnp.zeros((2, cfg.encoder_seq, cfg.d_model),
                            jnp.dtype(cfg.dtype))}
    _, caches = jax.jit(mp.prefill_step)(params, batch)
    total = Lp + 8
    grown = grow_dense_caches(caches, cfg, total)
    for part in ("zo", "bp"):
        for old, new in zip(caches[part], grown[part]):
            assert new["k"].shape[2] == total
            assert new["v"].shape[2] == total
            assert new["ck"].shape == old["ck"].shape      # untouched
            assert new["cv"].shape == old["cv"].shape
            assert bool(jnp.array_equal(new["ck"], old["ck"]))


def test_dense_generate_whisper_lookalike_end_to_end():
    """Full dense serve at the collision length must decode fine."""
    cfg = reduced(ARCHS["whisper-small"])
    lane = LaneConfig()
    shape = ShapeConfig("i", seq_len=32, global_batch=1, kind="prefill")
    m = api.build(cfg, shape, lane, ShardingRules(None, cfg, shape))
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (2, cfg.encoder_seq)).astype(np.int32)
    out = dense_generate(cfg, params, prompts, 4)
    assert out.shape == (2, 4)
    assert (out >= 0).all()


# ------------------------------------------------------------------ #
# CLI smoke (acceptance: --smoke --paged completes)
# ------------------------------------------------------------------ #
def test_serve_cli_paged_smoke():
    from pathlib import Path
    src = str(Path(__file__).resolve().parents[1] / "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-4b",
         "--smoke", "--paged", "--batch", "2", "--prompt-len", "8",
         "--tokens", "4", "--page-size", "4"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": src, "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[serve] paged:" in r.stdout
    assert "pages: peak" in r.stdout
