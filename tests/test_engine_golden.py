"""Update-engine refactor contract: golden digests (tests/golden_cases.py).

``preserved``: the engine-built fp32 elastic_zo/full_zo/full_bp steps
must reproduce the *pre-refactor* implementation bit for bit (digests
captured before core/engine.py existed). ``canonical``: multi-probe
fp32 (accumulate-then-cast probe fold) and the int8 lane (per-probe key
schedule + accumulate-then-clamp) pin the engine's canonical semantics
against future refactors.

Float digests are platform-pinned; the fixture's ``canary`` (a step-free
init+forward digest) detects an environment whose baseline numerics
differ, in which case the float cases skip instead of false-failing.
Integer (int8) cases assert unconditionally on every platform.
"""
import json
from pathlib import Path

import pytest

import golden_cases as gc  # tests/ is on sys.path in pytest rootdir mode

FIXTURE = json.loads(
    (Path(__file__).parent / "golden" / "engine_steps.json").read_text())


def _check(section, name):
    fn = getattr(gc, section.upper())[name]
    want = FIXTURE[section][name]
    if not name.startswith("int8") and gc.run_canary() != FIXTURE["canary"]:
        pytest.skip("platform float numerics differ from the fixture's "
                    "(canary mismatch) — regenerate via golden_cases.py")
    got = fn()
    assert got == want, (
        f"{section}/{name}: engine output diverged from the golden digest"
        f"\n got  {got}\n want {want}")


@pytest.mark.parametrize("name", sorted(gc.PRESERVED))
def test_preserved_bitwise(name):
    """fp32 behavior is preserved bitwise through the engine refactor."""
    _check("preserved", name)


@pytest.mark.parametrize("name", sorted(gc.CANONICAL))
def test_canonical_pinned(name):
    """The engine's canonical semantics are pinned for future PRs."""
    _check("canonical", name)
