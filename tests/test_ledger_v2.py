"""Record-v2 wire format: fp32/int8 numerics tags, round-trips, rejection.

Random records of both lanes go through serialize -> parse with
field-exact recovery asserted, plus the negative space: truncated
buffers and corrupt tags must raise ValueError, never mis-parse
(docs/fleet.md wire format). With hypothesis installed the checks run
as property tests; without it (optional dep) they degrade to seeded
parametrized sweeps so the contract is still exercised.
"""
import numpy as np
import pytest

from repro.fleet import Commit, Ledger, Record

try:  # optional dep (tier1-minimal CI lane runs without it)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _fp32_record(rng, step, worker, m, n_leaves):
    return Record(
        step=step, worker=worker,
        seeds=rng.integers(0, 2**63, (m,)).astype(np.uint64),
        deltas=rng.normal(size=(m,)).astype(np.float32),
        loss=float(np.float32(rng.normal())),
        tail_q=[rng.integers(-127, 128, (int(s),)).astype(np.int8)
                for s in rng.integers(0, 17, (n_leaves,))],
        tail_scales=np.abs(rng.normal(size=(n_leaves,))).astype(np.float32))


def _int8_record(rng, step, worker, m, n_leaves):
    return Record(
        step=step, worker=worker,
        seeds=rng.integers(0, 2**63, (m,)).astype(np.uint64),
        deltas=rng.integers(-1, 2, (m,)).astype(np.int8),
        loss=float(np.float32(rng.normal())),
        tail_q=[rng.integers(-127, 128, (int(s),)).astype(np.int8)
                for s in rng.integers(0, 17, (n_leaves,))],
        numerics="int8")


def _make(numerics):
    return _int8_record if numerics == "int8" else _fp32_record


def _assert_same(a: Record, b: Record):
    assert (a.step, a.worker, a.numerics) == (b.step, b.worker, b.numerics)
    assert np.array_equal(a.seeds, b.seeds)
    assert a.deltas.dtype == b.deltas.dtype
    assert np.array_equal(a.deltas, b.deltas)
    assert a.loss == b.loss
    assert len(a.tail_q) == len(b.tail_q)
    assert all(np.array_equal(x, y) for x, y in zip(a.tail_q, b.tail_q))
    assert np.array_equal(a.tail_scales, b.tail_scales)


# ---- the three properties (plain functions) ------------------------- #
def check_roundtrip(seed, step, numerics, m, n_leaves):
    rng = np.random.default_rng(seed)
    rec = _make(numerics)(rng, step, seed % 32, m, n_leaves)
    led = Ledger()
    led.append_record(rec)
    led.append_commit(Commit(step, 1 << (seed % 32)))
    led2 = Ledger.from_bytes(led.to_bytes())
    _assert_same(led2.records[step][seed % 32], rec)
    assert led2.commits[step].accepted == 1 << (seed % 32)
    assert led2.bytes_zo == led.bytes_zo
    assert led2.bytes_tail == led.bytes_tail


def check_truncated(seed, numerics, cut):
    rng = np.random.default_rng(seed)
    rec = _make(numerics)(rng, 3, 1, 2, 2)
    led = Ledger()
    led.append_record(rec)
    led.append_commit(Commit(3, 0b10))
    buf = led.to_bytes()
    cut = cut % (len(buf) - 1) + 1      # strictly shorter, non-empty
    truncated = buf[:len(buf) - cut]
    try:
        led2 = Ledger.from_bytes(truncated)
    except ValueError:
        return                           # rejected: good
    # a prefix that happens to end on a record boundary parses cleanly
    # but must never invent bytes
    assert led2.nbytes <= led.nbytes


def check_corrupt_tag(seed, bad_tag):
    if bad_tag in (0x52, 0x43, 0x49):   # valid tags
        bad_tag = 0x00
    rng = np.random.default_rng(seed)
    led = Ledger()
    led.append_record(_fp32_record(rng, 0, 0, 1, 1))
    led.append_commit(Commit(0, 1))
    buf = bytearray(led.to_bytes())
    buf[0] = bad_tag
    with pytest.raises(ValueError):
        Ledger.from_bytes(bytes(buf))


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=40)
    @given(st.integers(0, 10**6), st.integers(0, 2**31 - 1),
           st.sampled_from(["fp32", "int8"]), st.integers(1, 8),
           st.integers(0, 4))
    def test_record_roundtrip(seed, step, numerics, m, n_leaves):
        check_roundtrip(seed, step, numerics, m, n_leaves)

    @settings(deadline=None, max_examples=40)
    @given(st.integers(0, 10**6), st.sampled_from(["fp32", "int8"]),
           st.integers(1, 200))
    def test_truncated_buffer_rejected(seed, numerics, cut):
        check_truncated(seed, numerics, cut)

    @settings(deadline=None, max_examples=30)
    @given(st.integers(0, 10**6), st.integers(0, 255))
    def test_corrupt_tag_rejected(seed, bad_tag):
        check_corrupt_tag(seed, bad_tag)
else:
    @pytest.mark.parametrize("seed", range(10))
    @pytest.mark.parametrize("numerics", ["fp32", "int8"])
    def test_record_roundtrip(seed, numerics):
        check_roundtrip(seed * 7919, seed * 13 + 1, numerics,
                        seed % 8 + 1, seed % 5)

    @pytest.mark.parametrize("seed", range(10))
    @pytest.mark.parametrize("numerics", ["fp32", "int8"])
    def test_truncated_buffer_rejected(seed, numerics):
        for cut in (1, 2, 5, 13, 40, 97):
            check_truncated(seed, numerics, cut)

    @pytest.mark.parametrize("bad_tag", [0x00, 0x01, 0x51, 0x44, 0xFF])
    def test_corrupt_tag_rejected(bad_tag):
        check_corrupt_tag(3, bad_tag)


# ---- deterministic contract tests (no hypothesis needed) ------------ #
def test_probe_entry_sizes():
    """The paper's wire claim, literally: 12 B/probe fp32, 9 B/probe int8,
    atop the common 11 B record header."""
    rng = np.random.default_rng(0)
    r32 = _fp32_record(rng, 0, 0, 3, 0)
    r8 = _int8_record(rng, 0, 0, 3, 0)
    assert r32.zo_probe_nbytes == 12 and r32.zo_nbytes == 11 + 3 * 12
    assert r8.zo_probe_nbytes == 9 and r8.zo_nbytes == 11 + 3 * 9
    assert len(r32.to_bytes()) == r32.nbytes
    assert len(r8.to_bytes()) == r8.nbytes


def test_mixed_lane_ledger_roundtrip():
    """fp32 and int8 records interleave in one buffer (tag-dispatched)."""
    rng = np.random.default_rng(1)
    led = Ledger()
    led.append_record(_fp32_record(rng, 0, 0, 2, 1))
    led.append_record(_int8_record(rng, 0, 1, 2, 1))
    led.append_commit(Commit(0, 0b11))
    led2 = Ledger.from_bytes(led.to_bytes())
    assert led2.records[0][0].numerics == "fp32"
    assert led2.records[0][1].numerics == "int8"
    _assert_same(led2.records[0][0], led.records[0][0])
    _assert_same(led2.records[0][1], led.records[0][1])


def test_empty_and_garbage():
    assert Ledger.from_bytes(b"").commits == {}
    with pytest.raises(ValueError):
        Ledger.from_bytes(b"\x00\x01\x02")
    # a lone commit truncated mid-struct
    commit = Commit(5, 0b1).to_bytes()
    with pytest.raises(ValueError):
        Ledger.from_bytes(commit[:-2])
