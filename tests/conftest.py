import os
import sys

# Tests see the real single CPU device (the 512-device override is
# dryrun.py-only by design).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Per-test wall-clock budget: the tier-1 suite is minutes-scale on
# modest hardware, so a single hung test must fail loudly instead of
# eating the whole CI job. Applied only when pytest-timeout is
# installed (CI installs it; a bare local `pip install pytest` run
# stays green without it). `slow`-marked tests get triple budget; an
# explicit @pytest.mark.timeout or --timeout always wins.
_DEFAULT_TIMEOUT = 300
_SLOW_TIMEOUT = 900


def pytest_configure(config):
    if not config.pluginmanager.hasplugin("timeout"):
        return
    # None = not passed; 0 = the plugin's documented "explicitly
    # disabled" (e.g. stepping through a hang under pdb) — honor it
    if getattr(config.option, "timeout", None) is None:
        config.option.timeout = _DEFAULT_TIMEOUT


def pytest_collection_modifyitems(config, items):
    if not config.pluginmanager.hasplugin("timeout"):
        return
    import pytest
    for item in items:
        if item.get_closest_marker("slow") is not None \
                and item.get_closest_marker("timeout") is None:
            item.add_marker(pytest.mark.timeout(_SLOW_TIMEOUT))
