import os
import sys

# Tests see the real single CPU device (the 512-device override is
# dryrun.py-only by design).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
