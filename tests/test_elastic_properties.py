"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: suite must collect without it
from hypothesis import given, settings, strategies as st

from repro.core import prng, zo
from repro.core.int8 import psr_shift, bitwidth
from repro.core.int_loss import int_loss_sign
from repro.core.int8 import QTensor


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 2**31 - 1), st.integers(1, 64),
       st.integers(1, 2000))
def test_prng_layout_invariance(seed, salt, n):
    """z depends only on the flat index: any reshape of the same count is
    bitwise identical (the elastic-remesh determinism guarantee)."""
    s = jnp.uint32(seed)
    a = prng.normal(s, salt, (n,))
    if n % 2 == 0:
        b = prng.normal(s, salt, (2, n // 2)).reshape(n)
        assert jnp.array_equal(a, b)


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 2**31 - 1), st.floats(1e-5, 1e-1))
def test_perturb_antithetic_symmetry(seed, eps):
    """(theta+eps z) + (theta-eps z) == 2 theta exactly in fp32 pairs."""
    params = {"w": jnp.ones((64,), jnp.float32) * 0.5}
    key = jax.random.key(seed % 2**31)
    p = zo.perturb(params, key, eps)["w"]
    m = zo.perturb(params, key, -eps)["w"]
    np.testing.assert_allclose(p + m, 2 * params["w"], rtol=1e-6, atol=1e-6)


@settings(deadline=None, max_examples=40)
@given(st.integers(-(2**24), 2**24), st.integers(0, 10))
def test_psr_bounded_error(x, s):
    """|psr(x, s) - x/2^s| < 1 always (rounding moves at most one step)."""
    out = int(psr_shift(jnp.int32(x), jnp.int32(s)))
    assert abs(out - x / (2 ** s)) < 1.0 + 1e-9


@settings(deadline=None, max_examples=30)
@given(st.integers(1, 2**30))
def test_bitwidth_matches_python(n):
    assert int(bitwidth(jnp.int32(n))) == n.bit_length()


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 10**6))
def test_int_loss_sign_is_antisymmetric(seed):
    """sgn(L(a)-L(b)) == -sgn(L(b)-L(a)) for the integer path."""
    rng = np.random.default_rng(seed)
    a = QTensor(jnp.asarray(rng.integers(-100, 100, (4, 10)), jnp.int8),
                jnp.int32(int(rng.integers(-6, -2))))
    b = QTensor(jnp.asarray(rng.integers(-100, 100, (4, 10)), jnp.int8),
                jnp.int32(int(rng.integers(-6, -2))))
    y = jnp.asarray(rng.integers(0, 10, (4,)), jnp.int32)
    assert int(int_loss_sign(a, b, y)) == -int(int_loss_sign(b, a, y))


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 10**6))
def test_int_loss_sign_zero_on_equal(seed):
    rng = np.random.default_rng(seed)
    a = QTensor(jnp.asarray(rng.integers(-100, 100, (2, 10)), jnp.int8),
                jnp.int32(-4))
    y = jnp.asarray(rng.integers(0, 10, (2,)), jnp.int32)
    assert int(int_loss_sign(a, a, y)) == 0
