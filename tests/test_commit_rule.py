"""fleet/commit_rule.py deterministic battery: the extracted close
pipeline and the PR 5 satellite bugfixes.

  * gate-empty steps: on-time vs late-admitted bits are SPLIT (the old
    ``arrival_history`` conflated them under an "on-time" docstring);
  * the never-empty fallback's retry of a transport-dropped record is
    accounted as a redelivery (no phantom commits that the transport
    never saw);
  * the fallback/admit order tiebreak is deterministic: earliest delay,
    then HIGHEST worker id (the leaderless tiebreak);
  * tail eligibility follows the loss-consistency channel: a worker
    with a band-rejected ZO probe keeps its BP-tail contribution, a
    worker with a lying loss does not.

tests/test_commit_rule_properties.py turns hypothesis loose on the
order/topology invariances; this module runs without hypothesis.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import FleetConfig, GossipConfig, RobustConfig
from repro.fleet import (ChaosTransport, Coordinator, RobustGate,
                         close_candidates, close_step, committed_arrays,
                         quorum_side)
from repro.fleet.transport import Fate

from test_fleet_robust import (W, run_toy_fleet, toy_fleet_cfg,
                               toy_records, toy_schema)


def _liar(rec):
    rec.seeds = np.asarray(rec.seeds, np.uint64) + np.uint64(1)
    return rec


# ------------------------------------------------------------------ #
# satellite 1: arrival-mask conflation
# ------------------------------------------------------------------ #


def test_gate_empty_step_splits_ontime_from_late_admitted():
    """A gate-empty step admits a late record: the late admission must
    land in late_admit_history, NOT be mislabeled as on-time — and the
    candidate mask (their union) must re-derive the same commit, which
    is exactly what the reference / launch self-verification does."""
    cfg = toy_fleet_cfg(deadline=1, max_delay=3)
    params, _, schema = toy_schema(cfg)
    coord = Coordinator(params, schema)
    recs = toy_records(schema, 0, 0.01 * np.arange(1, W + 1,
                                                   dtype=np.float32),
                       np.full(W, 2.0))
    # worker 0 on time but lying (validation rejects it -> gate empty);
    # worker 3 honest but past the deadline -> pulled in late
    arrivals = [(_liar(recs[0]), Fate(True, 0)),
                (recs[3], Fate(True, 3))]
    commit, _ = coord.close_step(0, arrivals)
    assert commit.accepted == 0b001000
    assert coord.ontime_history == [0b000001]
    assert coord.late_admit_history == [0b001000]
    assert coord.candidate_history == [0b001001]
    assert any("gate empty, admitted late worker 3" in e
               for e in coord.events)
    # the reference path re-derives the identical commit from the
    # candidate set alone (validation re-rejects the liar)
    cand = {0: _liar(toy_records(schema, 0, 0.01 * np.arange(
        1, W + 1, dtype=np.float32), np.full(W, 2.0))[0]), 3: recs[3]}
    outcome = close_candidates(RobustGate(schema), 0, cand)
    assert outcome.commit.to_bytes() == commit.to_bytes()


# ------------------------------------------------------------------ #
# satellite 2: phantom commits bypass transport accounting
# ------------------------------------------------------------------ #


def test_dropped_record_retry_is_accounted():
    """When the transport drops EVERYTHING, the never-empty fallback
    retries the earliest record — that retry must pass through the
    transport's books (bytes + redelivery count), not materialize out
    of thin air."""
    cfg = toy_fleet_cfg(deadline=0)
    params, _, schema = toy_schema(cfg)
    transport = ChaosTransport(cfg)
    coord = Coordinator(params, schema, transport=transport)
    recs = toy_records(schema, 0, 0.01 * np.arange(1, W + 1,
                                                   dtype=np.float32),
                       np.full(W, 2.0))
    arrivals = [(recs[w], Fate(False, w + 1)) for w in range(3)]
    assert transport.bytes_sent == 0
    commit, records = coord.close_step(0, arrivals)
    assert commit.accepted == 0b000001        # earliest retry: worker 0
    assert transport.n_redelivered == 1
    assert transport.bytes_sent == recs[0].nbytes
    assert any("redelivery" in e for e in coord.events)


def test_drop_everything_chaos_run_accounts_every_committed_byte():
    """Chaos pin: under near-total dropout, every committed record's
    bytes appear in the transport accounting — the steps where the
    network is worst are exactly the ones that used to be wrong."""
    cfg = toy_fleet_cfg(dropout=0.9, chaos_seed=13)
    params, res = run_toy_fleet(cfg, steps=6)
    transport_check = ChaosTransport(cfg)
    n_phantom = 0
    expected_bytes = 0
    for t, commit in res.ledger.commits.items():
        for w in commit.workers(W):
            rec = res.ledger.records[t][w]
            expected_bytes += rec.nbytes
            if not transport_check.fate(t, w).delivered:
                n_phantom += 1
    assert n_phantom > 0, "chaos never forced a retry; raise dropout"
    assert res.stats["n_redelivered"] == n_phantom
    # uplink covers every committed record (delivered or redelivered),
    # plus delivered-but-uncommitted ones — never less than the commits
    assert res.stats["bytes_uplink"] >= expected_bytes
    # topology must not change the books: the same chaos seed closes the
    # same steps leaderlessly, retrying (and accounting) the same records
    _, resg = run_toy_fleet(
        toy_fleet_cfg(dropout=0.9, chaos_seed=13, topology="gossip",
                      gossip=GossipConfig()), steps=6)
    assert resg.stats["n_redelivered"] == res.stats["n_redelivered"]
    assert resg.stats["bytes_uplink"] == res.stats["bytes_uplink"]


def test_fallback_tiebreak_highest_worker_id():
    """Equal delays break toward the HIGHEST worker id — the leaderless
    tiebreak every peer derives without a coordinator to ask."""
    cfg = toy_fleet_cfg(deadline=0)
    params, _, schema = toy_schema(cfg)
    recs = toy_records(schema, 0, 0.01 * np.arange(1, W + 1,
                                                   dtype=np.float32),
                       np.full(W, 2.0))
    arrivals = [(recs[1], Fate(True, 2)), (recs[4], Fate(True, 2))]
    outcome = close_step(RobustGate(schema), 0, arrivals)
    assert outcome.commit.accepted == 0b010000
    assert outcome.late_admit_bits >> 4 & 1


def test_quorum_side_majority_and_tiebreak():
    assert quorum_side(0b00000011, 8) == 0b11111100     # majority wins
    assert quorum_side(0b11111100, 8) == 0b11111100
    # 4-4 tie: the side holding worker 7 wins
    assert quorum_side(0b11110000, 8) == 0b11110000
    assert quorum_side(0b00001111, 8) == 0b11110000


# ------------------------------------------------------------------ #
# satellite 3: rejected probe no longer drops the whole tail
# ------------------------------------------------------------------ #


def test_band_rejected_probe_keeps_tail_loss_reject_drops_it():
    """A worker whose ZO probe is band-rejected but whose loss passed
    consistency keeps its BP-tail contribution (the sound first-order
    signal); a worker with an out-of-band loss loses everything."""
    _, _, schema = toy_schema(toy_fleet_cfg(robust=RobustConfig()))
    deltas = np.asarray([0.01, -0.02, 0.015, 5000.0, 0.02, 0.0],
                        np.float32)
    losses = np.asarray([2.0, 2.01, 1.99, 2.0, 50.0, 2.0], np.float32)
    recs = toy_records(schema, 0, deltas, losses)
    result = RobustGate(schema).evaluate(0, {w: recs[w] for w in range(W)})
    cs = committed_arrays(result.commit, result.records, schema)
    assert not cs.commit.inband(W)[3], "band outlier not caught"
    assert cs.mask[3] == 0.0, "band-rejected probe must stay masked"
    assert 3 in cs.tail_ws, "band-rejected probe dropped the whole tail"
    assert 4 not in cs.tail_ws, "a lying loss must poison the tail too"
    assert cs.mask[4] == 0.0
    # filter-free commits keep the all-or-nothing rule: tail == accepted
    _, _, bare = toy_schema(toy_fleet_cfg(robust=None))
    recs2 = toy_records(bare, 0, deltas, np.full(W, 2.0))
    result2 = RobustGate(bare).evaluate(0, {w: recs2[w] for w in range(W)})
    cs2 = committed_arrays(result2.commit, result2.records, bare)
    assert cs2.tail_ws == tuple(range(W))


# ------------------------------------------------------------------ #
# leaderless basics (toy fleet; the full matrix is chaos-marked)
# ------------------------------------------------------------------ #


def test_toy_gossip_matches_star_loss_free():
    """Star and gossip on a loss-free link produce the identical commit
    stream and parameters — topology is a deployment choice, not a
    semantic one."""
    params, rs = run_toy_fleet(toy_fleet_cfg(), steps=6)
    _, rg = run_toy_fleet(
        toy_fleet_cfg(topology="gossip",
                      gossip=GossipConfig(fanout=2, rounds=1)), steps=6)
    assert [c.to_bytes() for c in rs.ledger.commits.values()] == \
        [c.to_bytes() for c in rg.ledger.commits.values()]
    assert all(jnp.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(rs.params), jax.tree.leaves(rg.params)))
    assert rg.stats["bytes_broadcast"] == 0


def test_partition_equals_equivalent_crashes_on_the_quorum():
    """A temporary partition of a minority M over [lo, hi) produces the
    same commit stream and canon as crashing M for the window: either
    way the quorum never sees M's records, and both recoveries land on
    the canon by ledger replay."""
    lo, hi, minority = 2, 5, (0, 1)
    group = sum(1 << w for w in minority)
    part_cfg = toy_fleet_cfg(
        topology="gossip",
        gossip=GossipConfig(partitions=((lo, hi, group),)))
    crash_cfg = toy_fleet_cfg(
        topology="gossip", gossip=GossipConfig(),
        crashes=tuple((w, lo, hi - lo) for w in minority))
    _, rp = run_toy_fleet(part_cfg, steps=8)
    _, rc = run_toy_fleet(crash_cfg, steps=8)
    assert [c.to_bytes() for c in rp.ledger.commits.values()] == \
        [c.to_bytes() for c in rc.ledger.commits.values()]
    assert all(jnp.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(rp.params), jax.tree.leaves(rc.params)))
    assert rp.stats["n_reconciles"] == len(minority)
    assert rc.stats["n_catchups"] == len(minority)


def test_partition_config_validation():
    with pytest.raises(ValueError, match="overlap"):
        GossipConfig(partitions=((0, 4, 1), (2, 6, 2)))
    with pytest.raises(ValueError, match="empty"):
        GossipConfig(partitions=((4, 4, 1),))
    with pytest.raises(ValueError, match="proper nonempty subset"):
        FleetConfig(num_workers=4, topology="gossip",
                    gossip=GossipConfig(partitions=((0, 2, 0b1111),)))
    with pytest.raises(ValueError, match="topology"):
        FleetConfig(num_workers=4, gossip=GossipConfig())
    with pytest.raises(ValueError, match="star|gossip"):
        FleetConfig(num_workers=4, topology="ring")
