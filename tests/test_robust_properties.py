"""Hypothesis property tests for the Byzantine-robust filter.

The invariant under test (docs/design.md §11): ``filter_decision`` is a
pure function of (records, accepted mask) — permutation-invariant in
worker order, idempotent under its own application, and identical
whether derived by the coordinator gate, the replay recompute, or a
wire-roundtripped commit. tests/test_fleet_robust.py pins the same
assertions on a deterministic battery (and runs without hypothesis);
this module turns property-based search loose on them.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: suite must collect without it
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import RobustConfig  # noqa: E402
from repro.fleet import filter_decision  # noqa: E402
from repro.fleet.robust import apply_decision  # noqa: E402

from test_fleet_robust import W, _expand_mask, _run_cross_path  # noqa: E402

finite32 = st.floats(allow_nan=False, allow_infinity=False, width=32)
delta_st = st.lists(finite32, min_size=W, max_size=W)
loss_st = st.lists(st.floats(0.0, 100.0, width=32), min_size=W, max_size=W)
mask_st = st.integers(1, 2 ** W - 1)
mode_st = st.sampled_from(["mask", "clip"])
tern_st = st.lists(st.integers(-127, 127), min_size=W, max_size=W)
perm_st = st.permutations(list(range(W)))


@settings(deadline=None, max_examples=60)
@given(delta_st, loss_st, mask_st, mode_st, perm_st)
def test_filter_pure_and_permutation_invariant_fp32(deltas, losses, bits,
                                                    mode, perm):
    """Same inputs -> same verdict; relabeling the workers permutes the
    verdict with them (the filter sees a value multiset, not an order)."""
    cfg = RobustConfig(mode=mode)
    d = np.asarray(deltas, np.float32)
    l = np.asarray(losses, np.float32)
    mask = _expand_mask(bits)
    a = filter_decision(d, l, mask, 1, cfg, "fp32")
    b = filter_decision(d.copy(), l.copy(), mask.copy(), 1, cfg, "fp32")
    assert np.array_equal(a.inband, b.inband)       # pure
    assert (a.outliers, a.loss_reject) == (b.outliers, b.loss_reject)
    perm = np.asarray(perm)
    p = filter_decision(d[perm], l[perm], mask[perm], 1, cfg, "fp32")
    assert np.array_equal(p.inband, a.inband[perm])  # equivariant
    for w in range(W):
        assert (p.loss_reject >> w & 1) == (a.loss_reject >> perm[w] & 1)


@settings(deadline=None, max_examples=60)
@given(tern_st, loss_st, mask_st, perm_st)
def test_filter_pure_and_permutation_invariant_int8(deltas, losses, bits,
                                                    perm):
    cfg = RobustConfig()
    d = np.asarray(deltas, np.int8)
    l = np.asarray(losses, np.float32)
    mask = _expand_mask(bits)
    a = filter_decision(d, l, mask, 1, cfg, "int8")
    perm = np.asarray(perm)
    p = filter_decision(d[perm], l[perm], mask[perm], 1, cfg, "int8")
    assert np.array_equal(p.inband, a.inband[perm])
    # sign-consistency: every accepted non-ternary scalar is rejected
    for i in range(W):
        if mask[i] > 0 and abs(int(np.asarray(deltas)[i])) > 1:
            assert not a.inband[i]


@settings(deadline=None, max_examples=60)
@given(delta_st, loss_st, mask_st)
def test_filter_idempotent_mask_mode(deltas, losses, bits):
    """Filtering filtered arrays is a no-op: the verdict is a joint
    fixpoint of the loss and scalar channels."""
    cfg = RobustConfig()
    d = np.asarray(deltas, np.float32)
    l = np.asarray(losses, np.float32)
    mask = _expand_mask(bits)
    dec = filter_decision(d, l, mask, 1, cfg, "fp32")
    seeds = np.arange(W, dtype=np.uint64)
    _, d2, m2 = apply_decision(seeds, d, mask, dec, cfg, 1)
    dec2 = filter_decision(d2, l, m2, 1, cfg, "fp32")
    _, d3, m3 = apply_decision(seeds, d2, m2, dec2, cfg, 1)
    assert np.array_equal(d2, d3) and np.array_equal(m2, m3)


@settings(deadline=None, max_examples=30)
@given(delta_st, loss_st, mask_st)
def test_filter_identical_across_gate_replay_and_wire_fp32(deltas, losses,
                                                           bits):
    """Coordinator gate, replay recompute (step_arrays), and the
    wire-roundtripped commit all derive the same post-filter arrays."""
    _run_cross_path(np.asarray(deltas, np.float32),
                    np.asarray(losses, np.float32), bits, "fp32")


@settings(deadline=None, max_examples=30)
@given(tern_st, loss_st, mask_st)
def test_filter_identical_across_gate_replay_and_wire_int8(deltas, losses,
                                                           bits):
    _run_cross_path(np.asarray(deltas, np.int8),
                    np.asarray(losses, np.float32), bits, "int8")
