"""kernels/zo_fused_replay vs its jnp oracle, across every arch's params.

Contract (same as kernels/zo_perturb.py): the regenerated z stream is
bitwise identical; the accumulated AXPY matches within FMA-contraction
rounding. Plus the replay law the fleet depends on: an S-step fused
replay equals S live single-step applications bitwise on the ref
backend (the dispatch path everywhere off-TPU).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, LaneConfig, ShapeConfig, reduced
from repro.core import api, elastic, zo
from repro.kernels import ops, ref
from repro.sharding.rules import ShardingRules

SEEDS = jnp.asarray([[112, 913], [77, 41], [5, 2**31 + 9]], jnp.uint32)
COEFFS = jnp.asarray([[3e-3, -1e-3], [0.0, 2e-3], [-5e-4, 1e-4]],
                     jnp.float32)


def test_replay_equals_live_stepping_bitwise():
    t = jnp.asarray(np.random.default_rng(0).normal(size=(4096,)),
                    jnp.float32)
    fused = ops.zo_fused_replay(t, SEEDS, COEFFS, 13)
    live = t
    for s in range(SEEDS.shape[0]):
        live = ops.zo_fused_replay(live, SEEDS[s:s + 1], COEFFS[s:s + 1], 13)
    assert jnp.array_equal(fused, live)


def test_zero_coeff_is_identity():
    """Masked probes (coeff exactly 0) must not move the parameters."""
    t = jnp.asarray(np.random.default_rng(1).normal(size=(513,)), jnp.float32)
    out = ops.zo_fused_replay(t, SEEDS, jnp.zeros_like(COEFFS), 3)
    assert jnp.array_equal(out, t)


def test_kernel_z_stream_bitwise():
    z_ref = ref.zo_fused_replay_ref(jnp.zeros((1000,), jnp.float32),
                                    SEEDS[:1, :1],
                                    jnp.ones((1, 1), jnp.float32), 7)
    z_ker = ops.zo_fused_replay(jnp.zeros((1000,), jnp.float32),
                                SEEDS[:1, :1],
                                jnp.ones((1, 1), jnp.float32), 7,
                                force_pallas=True, interpret=True)
    assert jnp.array_equal(z_ref, z_ker)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_fused_replay_matches_ref_all_archs(arch):
    """Kernel vs oracle on real parameter leaves of every architecture
    (period-stacked, embed, norm — all shapes/dtypes the fleet replays)."""
    cfg = reduced(ARCHS[arch])
    lane = LaneConfig(lane="elastic_zo", bp_tail_layers=1)
    shape = ShapeConfig("t", seq_len=32, global_batch=2, kind="train")
    m = api.build(cfg, shape, lane, ShardingRules(None, cfg, shape))
    params = m.init(jax.random.key(0))
    zo_part, _ = elastic.partition(params, lane)
    flat = jax.tree_util.tree_flatten_with_path(zo_part)[0]
    # largest leaves stress padding/grid; keep runtime bounded
    flat = sorted(flat, key=lambda kv: -kv[1].size)[:3]
    for path, leaf in flat:
        salt = zo.path_salt(path)
        r = ref.zo_fused_replay_ref(leaf, SEEDS, COEFFS, salt)
        k = ops.zo_fused_replay(leaf, SEEDS, COEFFS, salt,
                                force_pallas=True, interpret=True)
        np.testing.assert_allclose(
            np.asarray(r, np.float32), np.asarray(k, np.float32),
            rtol=3e-7, atol=1e-7,
            err_msg=f"{arch}{jax.tree_util.keystr(path)}")
