"""Dry-run infrastructure: HLO collective parser + roofline accounting.

The SPMD pieces run in a subprocess (they need a multi-device CPU platform
flag that must not leak into the other tests' jax runtime).
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str) -> str:
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
    ).stdout


def test_collective_parser_counts_scan_trips():
    out = run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import collective_bytes, summarize
        kw = {}
        if hasattr(jax.sharding, "AxisType"):      # jax >= 0.5 only
            kw["axis_types"] = (jax.sharding.AxisType.Auto,) * 2
        mesh = jax.make_mesh((2, 4), ("data", "model"), **kw)
        def step(ws, x):
            def body(c, w):
                # row-sharded matmul -> all-reduce inside the scan body
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return jnp.sum(y)
        wspec = jax.ShapeDtypeStruct((6, 256, 256), jnp.float32)
        xspec = jax.ShapeDtypeStruct((16, 256), jnp.float32)
        c = jax.jit(step, in_shardings=(
            NamedSharding(mesh, P(None, "model", None)),
            NamedSharding(mesh, P("data", "model")),
        )).lower(wspec, xspec).compile()
        total, ops = collective_bytes(c.as_text())
        inside = [o for o in ops if o.trips > 1]
        print("TOTAL", total)
        print("TRIPS", max((o.trips for o in ops), default=0))
    """)
    lines = dict(l.split(" ", 1) for l in out.strip().splitlines() if " " in l)
    assert float(lines["TOTAL"]) > 0
    # the scan has 6 iterations; the body collective must be multiplied
    assert int(lines["TRIPS"]) >= 6


def test_roofline_model_flops_sane():
    from benchmarks.roofline import model_flops_per_device
    r = model_flops_per_device("llama3-8b", "train_4k", 256)
    # llama3-8b: ~8B params -> 2N ~ 16 GF/token; elastic x(2*(1+f_tail))
    per_tok_global = r["total"] / (256 * 4096)
    assert 2e10 < per_tok_global < 2e11, per_tok_global
    d = model_flops_per_device("llama3-8b", "decode_32k", 256)
    assert d["total"] < r["total"] / 1000


def test_cell_matrix_covers_assignment():
    from repro.configs import cell_matrix, ARCHS, SHAPES
    cells = cell_matrix()
    assert len(cells) == len(ARCHS) * len(SHAPES) == 40
    run = [c for c in cells if c[2]]
    skip = [c for c in cells if not c[2]]
    # long_500k runs only for the sub-quadratic archs
    assert {(a, s) for a, s, r, _ in cells if s == "long_500k" and r} == {
        ("mixtral-8x7b", "long_500k"), ("rwkv6-1.6b", "long_500k"),
        ("jamba-v0.1-52b", "long_500k")}
    assert len(run) == 33 and len(skip) == 7


@pytest.mark.skipif(
    not (Path(__file__).resolve().parents[1] / "results" / "dryrun").exists(),
    reason="dry-run artifacts not generated yet")
def test_dryrun_artifacts_complete_and_ok():
    res = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    from repro.configs import cell_matrix
    missing, failed = [], []
    for a, s, run, _ in cell_matrix():
        if not run:
            continue
        for mesh in ("single", "multi"):
            f = res / f"{a}__{s}__{mesh}.json"
            if not f.exists():
                missing.append(f.name)
                continue
            rec = json.loads(f.read_text())
            if rec.get("status") != "ok":
                failed.append(f.name)
    assert not missing, missing
    assert not failed, failed
