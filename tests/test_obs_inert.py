"""Observability is numerics-inert — the flight recorder's hard bar.

The recorder only wraps host-side control flow (spans around jitted
callables, counters off transport bookkeeping); it must never change a
single bit of the training stream. Pinned here the strongest way we
can: the full 8-worker chaos fleet (dropout + stragglers + crash/rejoin)
runs twice, instrumented and uninstrumented, and the canonical parameter
stream must match bit-for-bit at every step — on both lanes (fp32
tiny-llama elastic_zo, int8 LeNet Alg. 2).

Also pins the serve acceptance criterion: a traced paged-serving run
emits a Chrome-trace whose tick spans cover >= 90% of the engine's wall
time, and the document passes the schema validator CI uses.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs import (ARCHS, FleetConfig, LaneConfig, ServeConfig,
                           ShapeConfig, get_arch, reduced)
from repro.core import api
from repro.core.int8 import quant_from_float
from repro.data.synthetic import glyphs, token_batch
from repro.fleet import make_int8_probe_fn, run_fleet
from repro.models import lenet
from repro.obs.export import chrome_trace, validate_chrome_trace
from repro.serve import Engine, SamplingParams
from repro.sharding.rules import ShardingRules

# minutes-scale integration: two full chaos fleets per lane
pytestmark = pytest.mark.slow

WORKERS = 8
STEPS = 6
CRASH = (5, 2, 2)        # worker 5 dies at step 2, rejoins at step 4


@pytest.fixture(autouse=True)
def _pristine_obs():
    obs.uninstall()
    obs.set_verbosity("quiet")       # chaos runs x2: keep stdout calm
    yield
    obs.uninstall()
    obs.set_verbosity("verbose")


def _bitwise_equal(a, b):
    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(leaves_a) == len(leaves_b) and all(
        jnp.array_equal(x, y) for x, y in zip(leaves_a, leaves_b))


def _chaos_cfg():
    return FleetConfig(num_workers=WORKERS, probes_per_worker=1,
                       dropout=0.25, max_delay=2, deadline=1,
                       chaos_seed=3, snapshot_every=4, crashes=(CRASH,))


def _assert_streams_identical(ref, ins):
    assert len(ref.param_trace) == len(ins.param_trace) == STEPS
    for t, (a, b) in enumerate(zip(ref.param_trace, ins.param_trace)):
        assert _bitwise_equal(a, b), \
            f"instrumentation changed the param stream at step {t}"
    assert _bitwise_equal(ref.params, ins.params)
    for t, (ma, mb) in enumerate(zip(ref.masks, ins.masks)):
        assert np.array_equal(ma, mb), f"probe masks diverged at step {t}"


def _assert_recorder_saw_the_fleet(rec):
    tot = rec.span_totals()
    assert tot["fleet/step"]["count"] == STEPS
    assert tot["fleet/probe"]["count"] == STEPS
    assert tot["fleet/commit"]["count"] == STEPS
    snap = rec.snapshot()
    assert snap["counters"]["fleet.wire.uplink_bytes"] > 0
    assert snap["counters"]["fleet.wire.broadcast_bytes"] > 0
    assert snap["counters"]["fleet.wire.n_dropped"] > 0, \
        "chaos never fired — the inertness claim wasn't stressed"
    names = {e["name"] for e in rec.events}
    assert "worker_crash" in names and "worker_rejoin" in names
    # and the trace it exports is a loadable Chrome document
    validate_chrome_trace(chrome_trace(rec))
    # the memory ledger rode along (armed whenever the recorder is):
    # fleet tags carry bytes, the per-step region bracketed every step,
    # and a reconciliation sample against jax.live_arrays() landed
    mem = snap["memory"]
    for tag in ("fleet.ledger.zo", "fleet.ledger.tail",
                "fleet.ledger.commit", "fleet.worker.params",
                "fleet.canon.params"):
        assert mem["peak"].get(tag, 0) > 0, f"no bytes tagged under {tag}"
    assert mem["regions"]["fleet/step"]["count"] == STEPS
    assert mem["sample"]["jax_live_bytes"] > 0
    assert mem["sample"]["tagged_bytes"] > 0


def test_fp32_fleet_chaos_is_bit_exact_under_instrumentation():
    cfg = reduced(get_arch("llama3-8b"), num_layers=2, d_model=32,
                  num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=128)
    lane = LaneConfig(lane="elastic_zo", bp_tail_layers=1,
                      learning_rate=5e-2, zo_eps=1e-3)
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    model = api.build(cfg, shape, lane, ShardingRules(None, cfg, shape))
    params = model.init(jax.random.key(0))
    base_seed = jax.random.key_data(jax.random.key(1))

    def batch_fn(step):
        x, y, m = token_batch(2, 16, cfg.vocab_size, seed=1, step=step)
        return {"tokens": jnp.asarray(x), "labels": jnp.asarray(y),
                "mask": jnp.asarray(m)}

    ref = run_fleet(model.loss_fn, params, lane, _chaos_cfg(), batch_fn,
                    steps=STEPS, base_seed=base_seed, trace=True)
    rec = obs.install()
    try:
        ins = run_fleet(model.loss_fn, params, lane, _chaos_cfg(),
                        batch_fn, steps=STEPS, base_seed=base_seed,
                        trace=True)
    finally:
        obs.uninstall()
    _assert_streams_identical(ref, ins)
    _assert_recorder_saw_the_fleet(rec)


def test_int8_fleet_chaos_is_bit_exact_under_instrumentation():
    lane = LaneConfig(lane="elastic_zo_int8", zo_num_probes=1)
    partition = lambda p: lenet.partition_at(p, 4)          # noqa: E731
    probe_fn = make_int8_probe_fn(lenet.lenet5_forward_int8, lane,
                                  partition, [("fc3", "fc3_in")])
    params = lenet.init_lenet5_int8(jax.random.key(0))
    base_seed = jax.random.key_data(jax.random.key(1))

    def batch_fn(step):
        xs, ys = glyphs(8, seed=1, start=step * 8)
        return {"x": quant_from_float(jnp.asarray(xs)),
                "y": jnp.asarray(ys)}

    ref = run_fleet(None, params, lane, _chaos_cfg(), batch_fn,
                    steps=STEPS, base_seed=base_seed,
                    partition_fn=partition, probe_fn=probe_fn, trace=True)
    rec = obs.install()
    try:
        ins = run_fleet(None, params, lane, _chaos_cfg(), batch_fn,
                        steps=STEPS, base_seed=base_seed,
                        partition_fn=partition, probe_fn=probe_fn,
                        trace=True)
    finally:
        obs.uninstall()
    _assert_streams_identical(ref, ins)
    _assert_recorder_saw_the_fleet(rec)


def test_serve_trace_covers_wall_time_and_validates(tmp_path):
    """launch/serve acceptance, pinned at the library level: a traced
    paged run's tick spans account for >= 90% of engine wall time."""
    cfg = reduced(ARCHS["qwen3-4b"])
    serve = ServeConfig(page_size=8, num_pages=32, max_batch_slots=2,
                        max_seq_len=48, max_new_tokens=6)
    rng = np.random.default_rng(0)
    prompts = [list(p) for p in
               rng.integers(0, cfg.vocab_size, (2, 10)).astype(np.int32)]

    rec = obs.install()
    try:
        eng = Engine(cfg, serve)
        ref = eng.generate(prompts, SamplingParams(), 6)
    finally:
        obs.uninstall()

    spans = rec.spans
    (run_span,) = [s for s in spans if s["name"] == "serve/run"]
    ticks = sum(s["dur"] for s in spans if s["name"] == "serve/tick")
    coverage = ticks / run_span["dur"]
    assert coverage >= 0.90, f"spans cover only {coverage:.1%} of wall time"

    doc = chrome_trace(rec)
    evs = validate_chrome_trace(doc)
    assert any(e["ph"] == "X" and e["name"] == "serve/decode" for e in evs)
    # sampling is its own span (split out of serve/decode): every fused
    # megastep carries exactly one sample phase (the token download)
    n_decode = sum(1 for s in spans if s["name"] == "serve/decode")
    n_sample = sum(1 for s in spans if s["name"] == "serve/sample")
    assert n_decode > 0 and n_sample == n_decode
    hist = rec.snapshot()["histograms"]
    assert hist["serve.ttft_ms"]["count"] == 2           # one TTFT per req
    assert hist["serve.decode_token_ms"]["count"] > 0
    mem = rec.snapshot()["memory"]
    assert mem["peak"].get("serve.kv_pages", 0) > 0
    assert mem["peak"].get("serve.params", 0) > 0
    assert "serve.kv_pages_used_bytes" in rec.snapshot()["gauges"]

    # instrumentation is inert here too: same greedy stream either way
    eng2 = Engine(cfg, serve, params=eng.params)
    assert eng2.generate(prompts, SamplingParams(), 6) == ref
