"""Per-architecture smoke tests: reduced same-family config, one elastic
train step + prefill + decode on CPU; asserts shapes and finiteness.
(The full configs are exercised compile-only by launch/dryrun.py.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, LaneConfig, ShapeConfig, reduced
from repro.core import api
from repro.core.elastic import TrainState
from repro.sharding.rules import ShardingRules


def _batch(cfg, specs, key=0):
    rng = np.random.default_rng(key)
    batch = {}
    for k, v in specs.items():
        if k in ("tokens", "labels"):
            batch[k] = jnp.asarray(rng.integers(0, cfg.vocab_size, v.shape),
                                   jnp.int32)
        elif k == "mask":
            batch[k] = jnp.ones(v.shape, v.dtype)
        elif k in ("frames", "img"):
            batch[k] = jnp.asarray(rng.normal(size=v.shape) * 0.1, v.dtype)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_train_step(arch):
    cfg = reduced(ARCHS[arch])
    shape = ShapeConfig("t", seq_len=64, global_batch=2, kind="train")
    lane = LaneConfig(lane="elastic_zo", bp_tail_layers=1)
    rules = ShardingRules(None, cfg, shape)
    m = api.build(cfg, shape, lane, rules)
    params = m.init(jax.random.key(0))
    batch = _batch(cfg, m.input_specs())
    batch.pop("probe_mask", None)
    state = TrainState(params, jnp.int32(0),
                       jax.random.key_data(jax.random.key(1)))
    state, metrics = jax.jit(m.train_step)(state, batch,
                                           jnp.ones((1,), jnp.float32))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0 < loss < 20, loss
    # params changed and stayed finite
    changed = False
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state.params)):
        assert bool(jnp.all(jnp.isfinite(b.astype(jnp.float32))))
        changed |= not jnp.array_equal(a, b)
    assert changed


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_prefill_decode(arch):
    cfg = reduced(ARCHS[arch])
    lane = LaneConfig()
    ps = ShapeConfig("p", seq_len=64, global_batch=2, kind="prefill")
    ds = ShapeConfig("d", seq_len=64, global_batch=2, kind="decode")
    mp = api.build(cfg, ps, lane, ShardingRules(None, cfg, ps))
    md = api.build(cfg, ds, lane, ShardingRules(None, cfg, ds))
    params = mp.init(jax.random.key(0))
    batch = _batch(cfg, mp.input_specs())
    nt, caches = jax.jit(mp.prefill_step)(params, batch)
    assert nt.shape == (2, 1) and nt.dtype == jnp.int32
    assert int(nt.min()) >= 0
    nt2, caches2 = jax.jit(md.decode_step)(params, nt, caches, jnp.int32(63))
    assert nt2.shape == (2, 1)
    # cache structure is stable across decode steps
    assert (jax.tree_util.tree_structure(caches)
            == jax.tree_util.tree_structure(caches2))


def test_decode_matches_prefill_rwkv():
    """Recurrent arch invariant: decoding token t with the prefill-produced
    state must equal prefilling t+1 tokens (exact O(1) step vs chunked)."""
    cfg = reduced(ARCHS["rwkv6-1.6b"])
    lane = LaneConfig()
    S = 32
    ps = ShapeConfig("p", seq_len=S, global_batch=1, kind="prefill")
    ps2 = ShapeConfig("p2", seq_len=S + 1, global_batch=1, kind="prefill")
    ds = ShapeConfig("d", seq_len=S + 1, global_batch=1, kind="decode")
    mp = api.build(cfg, ps, lane, ShardingRules(None, cfg, ps))
    mp2 = api.build(cfg, ps2, lane, ShardingRules(None, cfg, ps2))
    md = api.build(cfg, ds, lane, ShardingRules(None, cfg, ds))
    params = mp.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S + 1)), jnp.int32)
    # path A: prefill S, then decode token S
    ntA, caches = jax.jit(mp.prefill_step)(params, {"tokens": toks[:, :S]})
    ntA2, _ = jax.jit(md.decode_step)(params, toks[:, S:S + 1], caches,
                                      jnp.int32(S))
    # path B: prefill S+1 directly
    ntB, _ = jax.jit(mp2.prefill_step)(params, {"tokens": toks})
    assert int(ntA2[0, 0]) == int(ntB[0, 0])
