"""Leaderless fleet acceptance: coordinator-free commits, bit-exactly.

The PR 5 bar (ISSUE 5): an 8-worker gossip fleet with NO coordinator,
under the full PR-4 chaos matrix — transport dropout + stragglers +
crash-rejoin + each of the 6 adversaries, in both numerics lanes — must
produce a Commit v2 stream and final parameters **bit-identical on
every surviving peer** and bit-exact vs the filtered single-process
reference; killing the would-be "leader" (worker 0, the star
topology's coordinator-adjacent node) mid-training must complete
without loss degradation vs the star baseline; and a temporary network
partition must heal-and-reconcile deterministically.

Marked ``chaos``: CI runs this matrix in the fleet-chaos job.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import (ByzantineSpec, FleetConfig, GossipConfig,
                           LaneConfig, RobustConfig, ShapeConfig, get_arch,
                           reduced)
from repro.core import api
from repro.core.int8 import quant_from_float
from repro.data.synthetic import glyphs, token_batch
from repro.fleet import (make_int8_probe_fn, make_probe_fn,
                         make_reference_step, reference_state, run_fleet)
from repro.fleet.adversary import ATTACKS
from repro.models import lenet
from repro.sharding.rules import ShardingRules
from repro.train.train_loop import LoopConfig, run

pytestmark = [pytest.mark.chaos, pytest.mark.slow]

WORKERS = 8
STEPS = 5
ROBUST = RobustConfig(window=3, quarantine_after=2, quarantine_steps=2)
ATTACKER = 4
CLIQUE = (2, 4)
GOSSIP = GossipConfig(fanout=2, rounds=2)


def specs_for(attack):
    if attack == "collude":
        return tuple(ByzantineSpec(w, "collude") for w in CLIQUE)
    return (ByzantineSpec(ATTACKER, attack),)


def fleet_cfg(byzantine=(), robust=None, topology="gossip", gossip=GOSSIP,
              crashes=(), chaos_seed=3):
    # same chaos point as tests/test_fleet_byzantine.py: every step keeps
    # an honest majority on time while drops/stragglers still fire
    return FleetConfig(num_workers=WORKERS, probes_per_worker=1,
                       dropout=0.1, max_delay=3, deadline=2,
                       chaos_seed=chaos_seed, snapshot_every=4,
                       byzantine=byzantine, robust=robust,
                       crashes=crashes, topology=topology,
                       gossip=gossip if topology == "gossip" else None)


def _bitwise_equal(a, b):
    return all(jnp.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------------------------ #
# lane environments (one jitted probe_fn each, shared by every run)
# ------------------------------------------------------------------ #


@pytest.fixture(scope="module")
def fp32env():
    cfg = reduced(get_arch("llama3-8b"), num_layers=2, d_model=32,
                  num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=128)
    lane = LaneConfig(lane="elastic_zo", bp_tail_layers=1,
                      learning_rate=5e-2, zo_eps=1e-3)
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    model = api.build(cfg, shape, lane, ShardingRules(None, cfg, shape))
    params = model.init(jax.random.key(0))

    def batch_fn(step):
        x, y, m = token_batch(2, 16, cfg.vocab_size, seed=1, step=step)
        return {"tokens": jnp.asarray(x), "labels": jnp.asarray(y),
                "mask": jnp.asarray(m)}

    return dict(lane=lane, params=params, batch_fn=batch_fn,
                partition_fn=None,
                probe_fn=make_probe_fn(model.loss_fn, lane),
                base_seed=jax.random.key_data(jax.random.key(1)),
                loss_tol=0.12)


@pytest.fixture(scope="module")
def int8env():
    lane = LaneConfig(lane="elastic_zo_int8", zo_num_probes=1)
    part = lambda p: lenet.partition_at(p, 4)  # noqa: E731

    def batch_fn(step):
        xs, ys = glyphs(8, seed=1, start=step * 8)
        return {"x": quant_from_float(jnp.asarray(xs)),
                "y": jnp.asarray(ys)}

    return dict(lane=lane, params=lenet.init_lenet5_int8(jax.random.key(0)),
                batch_fn=batch_fn, partition_fn=part,
                probe_fn=make_int8_probe_fn(lenet.lenet5_forward_int8, lane,
                                            part, [("fc3", "fc3_in")]),
                base_seed=jax.random.key_data(jax.random.key(1)),
                loss_tol=0.25)


def _run(env, cfg, steps=STEPS):
    return run_fleet(None, env["params"], env["lane"], cfg,
                     env["batch_fn"], steps=steps,
                     base_seed=env["base_seed"],
                     partition_fn=env["partition_fn"],
                     probe_fn=env["probe_fn"], trace=True)


def _reference_trace(env, res, steps=STEPS):
    """Drive the single-process reference with the realized candidate
    masks; it re-derives every gate verdict itself via the same commit
    rule every gossip peer ran."""
    step_fn = make_reference_step(None, res.schema,
                                  probe_fn=env["probe_fn"])
    state = reference_state(env["params"], res.schema, env["base_seed"])
    trace = []

    def recording_step(s, batch, mask):
        s2, metrics = step_fn(s, batch, mask)
        trace.append(jax.tree.map(np.asarray, s2.params["model"]))
        return s2, metrics

    loop = LoopConfig(total_steps=steps, log_every=0,
                      n_probes=res.schema.n_probes,
                      mask_fn=lambda t: res.arrival_masks[t], jit=False)
    run(recording_step, state, env["batch_fn"], loop)
    return trace, step_fn.commits


def _assert_leaderless_case(env, attack):
    """One cell of the matrix: gossip fleet with an adversary + robust
    filter — every surviving peer bit-identical, commit stream v2 and
    bit-exact vs the filtered single-process reference."""
    res = _run(env, fleet_cfg(specs_for(attack), ROBUST))
    # (a) every surviving peer holds the identical canon
    for p in res.peers:
        assert p.alive and p.step == STEPS
        assert _bitwise_equal(p.params, res.params), \
            f"{attack}: peer {p.id} diverged"
        # and derived the byte-identical Commit v2 stream
        for t in range(STEPS):
            assert p.closer.ledger.commits[t].to_bytes() == \
                res.ledger.commits[t].to_bytes(), \
                f"{attack}: peer {p.id} commit diverged at step {t}"
    # (b) bit-exact vs the filtered single-process reference — params
    # and the derived Commit v2 stream, at every step
    trace, commits = _reference_trace(env, res)
    assert len(trace) == STEPS == len(res.param_trace)
    for t, (a, b) in enumerate(zip(res.param_trace, trace)):
        assert _bitwise_equal(a, b), f"{attack}: diverged at step {t}"
    for t in range(STEPS):
        ca, cb = res.ledger.commits[t], commits[t]
        assert (ca.step, ca.accepted, ca.quarantined, ca.filtered) == \
            (cb.step, cb.accepted, cb.quarantined, cb.filtered), \
            f"{attack}: commit diverged at step {t}"
    return res


@pytest.mark.parametrize("attack", ATTACKS)
def test_fp32_gossip_chaos_matrix(fp32env, attack):
    _assert_leaderless_case(fp32env, attack)


@pytest.mark.parametrize("attack", ATTACKS)
def test_int8_gossip_chaos_matrix(int8env, attack):
    _assert_leaderless_case(int8env, attack)


# ------------------------------------------------------------------ #
# leader death: the fleet survives losing the step-0 closer
# ------------------------------------------------------------------ #


def test_leader_death_mid_run_no_loss_degradation(fp32env):
    """Kill worker 0 (the node that would have been the star
    coordinator) mid-training: the leaderless fleet completes, worker 0
    rejoins by ledger replay from a surviving peer, and the final loss
    is within tolerance of the star baseline under the same chaos."""
    steps = 6
    dead = fleet_cfg(crashes=((0, 2, 3),))
    res = _run(fp32env, dead, steps=steps)
    assert res.stats["n_catchups"] == 1
    for p in res.peers:
        assert p.alive and p.step == steps
        assert _bitwise_equal(p.params, res.params), f"peer {p.id}"
    # reference cross-check still holds with the leader dead
    trace, _ = _reference_trace(fp32env, res, steps=steps)
    for t, (a, b) in enumerate(zip(res.param_trace, trace)):
        assert _bitwise_equal(a, b), f"leader-death: diverged at step {t}"
    # no loss degradation vs the star baseline (same chaos, no crash —
    # the leaderless fleet merely lost one worker's probes for 3 steps)
    star = _run(fp32env, fleet_cfg(topology="star", gossip=None),
                steps=steps)
    l_gossip = res.coordinator.loss_history[-1][1]
    l_star = star.coordinator.loss_history[-1][1]
    tol = max(fp32env["loss_tol"] * abs(l_star), fp32env["loss_tol"])
    assert abs(l_gossip - l_star) <= tol, (l_gossip, l_star)


def test_int8_leader_death_and_partition(int8env):
    """int8 lane: leader death + a temporary partition in one run; every
    surviving peer lands bit-identical and the reference re-derives the
    stream from the realized candidate masks."""
    steps = 8
    cfg = fleet_cfg(crashes=((0, 2, 3),),
                    gossip=GossipConfig(fanout=2, rounds=2,
                                        partitions=((4, 6, 0b00000110),)))
    res = _run(int8env, cfg, steps=steps)
    for p in res.peers:
        assert p.alive and p.step == steps
        assert _bitwise_equal(p.params, res.params), f"peer {p.id}"
    # minority (workers 1, 2) masked during the partition window
    for t in range(4, 6):
        assert res.masks[t][1] == 0.0 and res.masks[t][2] == 0.0
    assert res.stats["n_reconciles"] >= 2
    trace, _ = _reference_trace(int8env, res, steps=steps)
    for t, (a, b) in enumerate(zip(res.param_trace, trace)):
        assert _bitwise_equal(a, b), f"partition: diverged at step {t}"


# ------------------------------------------------------------------ #
# wire accounting: gossip pays record copies, saves the broadcast
# ------------------------------------------------------------------ #


def test_gossip_wire_accounting(int8env):
    res = _run(int8env, fleet_cfg())
    s = res.stats
    assert s["topology"] == "gossip"
    assert s["bytes_broadcast"] == 0, "nobody broadcasts in gossip"
    assert s["bytes_gossip"] > 0, "epidemic exchange never accounted"
    # every delivered record reaches every other peer exactly once in
    # the digest-coordinated model: spread bytes <= (W-1) x uplink bytes
    assert s["bytes_gossip"] <= (WORKERS - 1) * s["bytes_uplink"]
