"""repro.obs unit acceptance: recorder primitives, exporters, CLI glue.

Fast host-only tests (no jax): span nesting and the Chrome-trace
round-trip, the typed metrics registry, the no-op singleton's
zero-allocation contract and overhead bound, the quiet/verbose switch,
and the trace validator's duty to *reject* malformed documents. The
numerics-inert integration bar lives in tests/test_obs_inert.py.
"""
import argparse
import json
import time

import pytest

from repro import obs
from repro.obs import NullRecorder, Recorder
from repro.obs.export import (chrome_trace, load_chrome_trace,
                              validate_chrome_trace, write_chrome_trace)


@pytest.fixture(autouse=True)
def _pristine_obs():
    """Every test starts and ends on the no-op singleton, verbose."""
    obs.uninstall()
    obs.set_verbosity("verbose")
    yield
    obs.uninstall()
    obs.set_verbosity("verbose")


# ------------------------------------------------------------------ #
# spans
# ------------------------------------------------------------------ #


def test_span_nesting_depth_and_order():
    rec = Recorder()
    with rec.span("outer", track="fleet", step=3):
        with rec.span("mid", track="fleet"):
            with rec.span("inner", track="fleet"):
                pass
        with rec.span("mid2", track="fleet"):
            pass
    # completion order: innermost first, outer last
    names = [s["name"] for s in rec.spans]
    assert names == ["inner", "mid", "mid2", "outer"]
    depth = {s["name"]: s["depth"] for s in rec.spans}
    assert depth == {"outer": 0, "mid": 1, "mid2": 1, "inner": 2}
    outer = rec.spans[-1]
    assert outer["args"] == {"step": 3}
    # children are contained in the parent interval
    for s in rec.spans[:-1]:
        assert s["ts"] >= outer["ts"]
        assert s["ts"] + s["dur"] <= outer["ts"] + outer["dur"]


def test_span_dur_readable_after_exit():
    rec = Recorder()
    with rec.span("t") as sp:
        time.sleep(0.01)
    assert sp.dur_ns >= 5_000_000     # slept 10ms, allow scheduler slop
    assert rec.spans[0]["dur"] == sp.dur_ns


def test_span_totals_aggregates_by_name():
    rec = Recorder()
    for _ in range(3):
        with rec.span("a"):
            pass
    with rec.span("b"):
        pass
    tot = rec.span_totals()
    assert tot["a"]["count"] == 3 and tot["b"]["count"] == 1
    assert tot["a"]["mean_ms"] == pytest.approx(tot["a"]["total_ms"] / 3)


def test_span_closes_on_exception():
    rec = Recorder()
    with pytest.raises(RuntimeError):
        with rec.span("boom"):
            raise RuntimeError("x")
    assert [s["name"] for s in rec.spans] == ["boom"]
    # stack unwound: a fresh span starts at depth 0 again
    with rec.span("after"):
        pass
    assert rec.spans[-1]["depth"] == 0


# ------------------------------------------------------------------ #
# metrics
# ------------------------------------------------------------------ #


def test_metrics_registry_identity_and_values():
    rec = Recorder()
    c = rec.counter("n")
    assert rec.counter("n") is c      # registry, not a factory
    c.inc()
    c.inc(41)
    rec.gauge("g").set(2)
    rec.gauge("g").set(7.5)           # last value wins
    snap = rec.snapshot()
    assert snap["counters"] == {"n": 42}
    assert snap["gauges"] == {"g": 7.5}


def test_histogram_summary_and_quantiles():
    rec = Recorder()
    h = rec.histogram("lat")
    for v in [1.0, 2.0, 4.0, 8.0, 1000.0]:
        h.observe(v)
    s = h.summary()
    assert s["count"] == 5
    assert s["sum"] == pytest.approx(1015.0)
    assert s["min"] == 1.0 and s["max"] == 1000.0
    # power-of-two buckets: quantile returns the bucket's upper bound
    assert s["p50"] in (2.0, 4.0)
    assert s["p99"] == 1024.0
    # the summary is self-contained: raw buckets ride along (keyed by
    # stringified exponent, upper bound 2**k) so a snapshot JSON is
    # diffable without re-deriving the layout
    assert sum(s["buckets"].values()) == 5
    assert all(isinstance(k, str) for k in s["buckets"])
    assert s["buckets"]["10"] == 1          # 1000.0 lands in (512, 1024]
    # zero/negative land in the underflow bin, quantile reports 0
    h2 = rec.histogram("z")
    h2.observe(0.0)
    assert h2.summary()["p50"] == 0.0


def test_histogram_empty_summary_is_zeroes():
    s = Recorder().histogram("e").summary()
    assert s["count"] == 0 and s["p99"] == 0.0


def test_reset_clears_but_keeps_recording():
    rec = Recorder()
    with rec.span("a"):
        pass
    rec.counter("c").inc()
    rec.event("e")
    rec.reset()
    assert not rec.spans and not rec.events
    assert rec.snapshot()["counters"] == {}
    with rec.span("b"):
        pass
    assert [s["name"] for s in rec.spans] == ["b"]


# ------------------------------------------------------------------ #
# the no-op singleton
# ------------------------------------------------------------------ #


def test_null_recorder_returns_cached_singletons():
    nrec = NullRecorder()
    assert not nrec.enabled
    # every disabled call site shares the same null objects: zero
    # allocations on the hot path
    assert nrec.counter("a") is nrec.counter("b")
    assert nrec.counter("a") is nrec.gauge("g") is nrec.histogram("h")
    assert nrec.span("x") is nrec.span("y", track="fleet", step=1)
    with nrec.span("x") as sp:
        assert sp.dur_ns == 0
    nrec.counter("a").inc(5)
    nrec.event("nothing", step=1)
    assert nrec.snapshot() == {} and nrec.span_totals() == {}
    assert not nrec.spans and not nrec.events


def test_null_recorder_overhead_bound():
    """The disabled path must stay within ~10x of a bare loop — i.e.
    a couple of method calls, no allocation, no locking."""
    nrec = NullRecorder()
    n = 50_000

    def bare():
        t0 = time.perf_counter_ns()
        x = 0
        for i in range(n):
            x += i
        return time.perf_counter_ns() - t0, x

    def instrumented():
        t0 = time.perf_counter_ns()
        x = 0
        for i in range(n):
            with nrec.span("s"):
                x += i
            nrec.counter("c").inc()
        return time.perf_counter_ns() - t0, x

    bare()
    instrumented()                       # warm both
    t_bare = min(bare()[0] for _ in range(3))
    t_inst = min(instrumented()[0] for _ in range(3))
    assert t_inst < 10 * t_bare + 50_000_000, \
        f"null recorder overhead {t_inst / max(t_bare, 1):.1f}x"


def test_install_uninstall_cycle():
    assert isinstance(obs.get(), NullRecorder)
    rec = obs.install()
    assert obs.get() is rec and rec.enabled
    obs.uninstall()
    assert isinstance(obs.get(), NullRecorder)
    # re-arming a carried recorder (the bench warm-disarm pattern)
    obs.install(rec)
    assert obs.get() is rec


# ------------------------------------------------------------------ #
# structured log + quiet switch
# ------------------------------------------------------------------ #


def test_log_echoes_and_records(capsys):
    rec = obs.install()
    obs.log("fleet", "step 3 loss 1.0", step=3, loss=1.0)
    assert "[fleet] step 3 loss 1.0" in capsys.readouterr().out
    (ev,) = rec.events
    assert ev["name"] == "step 3 loss 1.0" and ev["track"] == "fleet"
    assert ev["fields"] == {"step": 3, "loss": 1.0}


def test_quiet_silences_stdout_but_not_event_log(capsys):
    rec = obs.install()
    obs.set_verbosity("quiet")
    obs.log("gossip", "round done", step=1)
    assert capsys.readouterr().out == ""
    assert len(rec.events) == 1          # the log itself is unaffected


def test_log_without_recorder_still_prints(capsys):
    obs.log("train", "hello")
    assert "[train] hello" in capsys.readouterr().out


def test_set_verbosity_rejects_unknown():
    with pytest.raises(ValueError):
        obs.set_verbosity("loud")


# ------------------------------------------------------------------ #
# CLI glue
# ------------------------------------------------------------------ #


def _args(argv):
    ap = argparse.ArgumentParser()
    obs.add_observability_args(ap)
    return ap.parse_args(argv)


def test_configure_from_args_noop_without_flags():
    rec = obs.configure_from_args(_args([]))
    assert isinstance(rec, NullRecorder)


def test_configure_write_round_trip(tmp_path, capsys):
    trace = tmp_path / "t.json"
    metrics = tmp_path / "m.json"
    args = _args(["--trace", str(trace), "--metrics", str(metrics),
                  "--quiet"])
    rec = obs.configure_from_args(args)
    assert rec.enabled and obs.get_verbosity() == "quiet"
    with rec.span("work", track="train"):
        rec.counter("n").inc(3)
    obs.log("train", "suppressed")
    assert capsys.readouterr().out == ""
    obs.write_outputs(args)
    evs = load_chrome_trace(trace)
    assert any(e["ph"] == "X" and e["name"] == "work" for e in evs)
    snap = json.loads(metrics.read_text())
    assert snap["counters"] == {"n": 3}


# ------------------------------------------------------------------ #
# Chrome-trace export + validation
# ------------------------------------------------------------------ #


def test_chrome_trace_round_trip(tmp_path):
    rec = Recorder()
    with rec.span("fleet/step", track="fleet", step=0):
        with rec.span("fleet/probe", track="fleet"):
            pass
    with rec.span("serve/tick", track="serve"):
        pass
    rec.event("preempt", track="serve", rid=2)
    path = tmp_path / "trace.json"
    write_chrome_trace(rec, path)
    evs = load_chrome_trace(path)

    meta = [e for e in evs if e["ph"] == "M"]
    names = {e["args"]["name"] for e in meta if e["name"] == "thread_name"}
    assert {"fleet", "serve"} <= names
    # stable tid order: fleet before serve (export._TRACK_ORDER)
    tid = {e["args"]["name"]: e["tid"] for e in meta
           if e["name"] == "thread_name"}
    assert tid["fleet"] < tid["serve"]

    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert set(xs) == {"fleet/step", "fleet/probe", "serve/tick"}
    # nesting survives the µs conversion: child within parent interval
    p, c = xs["fleet/step"], xs["fleet/probe"]
    assert p["ts"] <= c["ts"]
    assert c["ts"] + c["dur"] <= p["ts"] + p["dur"] + 1e-3
    assert p["args"] == {"step": 0}

    (inst,) = [e for e in evs if e["ph"] == "i"]
    assert inst["name"] == "preempt"
    assert inst["args"] == {"rid": 2, "level": "info"}


def test_validate_rejects_garbage():
    with pytest.raises(ValueError, match="envelope"):
        validate_chrome_trace([])
    with pytest.raises(ValueError, match="must be a list"):
        validate_chrome_trace({"traceEvents": {}})
    with pytest.raises(ValueError, match="not an object"):
        validate_chrome_trace({"traceEvents": ["nope"]})
    with pytest.raises(ValueError, match="missing 'pid'"):
        validate_chrome_trace(
            {"traceEvents": [{"ph": "X", "name": "a", "tid": 1}]})
    with pytest.raises(ValueError, match="unknown phase"):
        validate_chrome_trace(
            {"traceEvents": [{"ph": "Q", "name": "a", "pid": 1, "tid": 1}]})
    with pytest.raises(ValueError, match="bad ts"):
        validate_chrome_trace(
            {"traceEvents": [{"ph": "i", "name": "a", "pid": 1, "tid": 1,
                              "ts": -1}]})
    with pytest.raises(ValueError, match="bad dur"):
        validate_chrome_trace(
            {"traceEvents": [{"ph": "X", "name": "a", "pid": 1, "tid": 1,
                              "ts": 0.0}]})


def test_validate_accepts_real_export():
    rec = Recorder()
    with rec.span("a"):
        pass
    rec.event("e")
    doc = chrome_trace(rec)
    assert len(validate_chrome_trace(doc)) == len(doc["traceEvents"])
