"""Red: registers a counter the catalog does not list."""


def tick(rec, nbytes):
    rec.counter("fleet.wire.mystery_bytes").inc(nbytes)
