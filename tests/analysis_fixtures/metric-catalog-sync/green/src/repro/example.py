"""Green: every registered metric has a catalog row and vice versa."""


def tick(rec, nbytes):
    rec.counter("fleet.wire.uplink_bytes").inc(nbytes)
