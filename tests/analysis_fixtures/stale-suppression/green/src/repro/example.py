"""Green: the allow discharges a real finding on the covered line."""


def bucket_of(key, n):
    # reprolint: allow(no-builtin-hash) -- per-process scratch, never serialized
    return hash(key) % n
