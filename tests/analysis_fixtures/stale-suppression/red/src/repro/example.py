"""Red: the allow matches no finding — stale suppressions are findings."""


def f():
    # reprolint: allow(no-builtin-hash) -- nothing here hashes anymore
    return 1
