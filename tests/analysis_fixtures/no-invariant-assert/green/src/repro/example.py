"""Green: the invariant raises, so PYTHONOPTIMIZE=1 cannot strip it."""


def commit(step, last_step):
    if step <= last_step:
        raise RuntimeError(f"commit out of order: {step} <= {last_step}")
    return step
