"""Red: a library invariant stated as `assert` (stripped under -O)."""


def commit(step, last_step):
    assert step > last_step, "commit out of order"
    return step
