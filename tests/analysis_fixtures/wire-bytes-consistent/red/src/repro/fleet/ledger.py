"""Seed-ledger wire structs (fixture)."""
import struct

_REC_HDR = struct.Struct("<BIBBf")   # tag, step, worker, m, loss -> 11 B
_PROBE = struct.Struct("<Qf")        # seed u64, loss-diff f32    -> 12 B
_PROBE8 = struct.Struct("<Qb")       # seed u64, ternary g i8     ->  9 B
