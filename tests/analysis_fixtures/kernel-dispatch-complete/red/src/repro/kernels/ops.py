"""Dispatch seam — missing the scale_rows entry."""
from . import ref  # noqa: F401
