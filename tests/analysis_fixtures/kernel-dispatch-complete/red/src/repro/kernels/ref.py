"""Reference oracles — missing scale_rows_ref."""
