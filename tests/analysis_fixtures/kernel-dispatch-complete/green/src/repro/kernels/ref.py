"""Pure-jnp oracle matching the kernel's positional signature."""


def scale_rows_ref(x, s):
    return x * s
