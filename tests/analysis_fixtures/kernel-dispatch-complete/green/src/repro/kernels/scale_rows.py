"""Green: the kernel side of a complete dispatch triangle."""
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref):
    o_ref[...] = x_ref[...] * s_ref[0]


def scale_rows(x, s, *, interpret=False):
    return pl.pallas_call(_kernel, out_shape=x, interpret=interpret)(x, s)
