"""Dispatch seam: one public entry per kernel, ref fallback off-TPU."""
from . import ref
from .scale_rows import scale_rows as _pallas_scale_rows


def _on_tpu():
    return False


def scale_rows(x, s, *, force_pallas=False, interpret=False):
    if _on_tpu() or force_pallas:
        return _pallas_scale_rows(x, s, interpret=interpret)
    return ref.scale_rows_ref(x, s)
