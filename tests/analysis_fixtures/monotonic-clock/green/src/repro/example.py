"""Green: monotonic clock for durations."""
import time


def timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
