"""Red: time.time() delta — goes negative under NTP steps."""
import time


def timed(fn):
    t0 = time.time()
    fn()
    return time.time() - t0
