"""Red: cites a design section that does not exist (docs/design.md §9)."""


def f():
    return 1
