"""Green: cites a section that exists (docs/design.md §1)."""


def f():
    return 1
