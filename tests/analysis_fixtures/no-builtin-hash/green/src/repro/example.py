"""Green: a keyed stable digest instead of the salted builtin."""
import hashlib


def bucket_of(key, n):
    d = hashlib.blake2s(repr(key).encode(), digest_size=8).digest()
    return int.from_bytes(d, "little") % n
