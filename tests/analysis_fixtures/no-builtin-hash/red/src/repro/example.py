"""Red: builtin hash() — salted per process for str, not reproducible."""


def bucket_of(key, n):
    return hash(key) % n
