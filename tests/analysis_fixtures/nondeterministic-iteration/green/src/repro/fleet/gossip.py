"""Green: the set is sorted before the order-sensitive loop."""


def broadcast(transport, peers):
    dead = {p for p in peers if not transport.alive(p)}
    for p in sorted(dead):
        transport.send(p, b"bye")
