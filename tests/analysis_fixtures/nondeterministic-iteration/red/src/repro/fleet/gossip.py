"""Red: set iteration feeding a wire-order-sensitive path."""


def broadcast(transport, peers):
    dead = {p for p in peers if not transport.alive(p)}
    for p in dead:                       # iteration order varies per process
        transport.send(p, b"bye")
