"""Green: the suppression carries its mandatory reason."""
import time


def stamp():
    # reprolint: allow(monotonic-clock) -- calendar stamp for a manifest
    return time.time()
