"""Red: a reasonless suppression — it suppresses nothing and is flagged."""
import time


def stamp():
    # reprolint: allow(monotonic-clock)
    return time.time()
