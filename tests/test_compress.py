"""train/compress.py: error feedback, shared-scale psum exactness, edges.

The shared-scale protocol (docs/design.md §8.4) is exercised on real
multi-device psums via a subprocess that forces 4 host CPU devices
(XLA_FLAGS must be set before jax imports, so it cannot run in this
process).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.compress import (compress_tree, decompress_tree,
                                  int8_compress, int8_decompress)


def test_error_feedback_residual_contraction():
    """The EF residual never exceeds half a quantization step (plus the
    incoming residual is fully re-injected, not leaked)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    residual = jnp.zeros_like(g)
    for _ in range(20):
        q, scale, residual = int8_compress(g, residual)
        # residual is exactly the quantization error of (g + residual_in)
        assert float(jnp.max(jnp.abs(residual))) <= 0.5 * float(scale) + 1e-7
        assert q.dtype == jnp.int8 and int(jnp.max(jnp.abs(q))) <= 127


def test_error_feedback_accumulates_to_truth():
    """Over T steps of a constant gradient, sum of dequantized updates ->
    T*g: the error feedback re-injects what quantization dropped."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(256,)) * 1e-3, jnp.float32)
    residual = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    T = 60
    for _ in range(T):
        q, s, residual = int8_compress(g, residual)
        acc = acc + int8_decompress(q, s)
    np.testing.assert_allclose(np.asarray(acc / T), np.asarray(g),
                               rtol=0.02, atol=1e-6)


def test_tree_roundtrip_empty_and_scalar_leaves():
    grads = {"a": jnp.float32(3.5),                 # scalar leaf
             "b": jnp.zeros((0,), jnp.float32),     # empty leaf
             "c": {"w": jnp.asarray([1.0, -2.0, 0.5], jnp.float32)}}
    residuals = jax.tree.map(lambda x: jnp.zeros_like(x), grads)
    qs, scales, new_res = compress_tree(grads, residuals)
    assert jax.tree_util.tree_structure(qs) \
        == jax.tree_util.tree_structure(grads)
    out = decompress_tree(qs, scales)
    for g, o, r in zip(jax.tree.leaves(grads), jax.tree.leaves(out),
                       jax.tree.leaves(new_res)):
        # dequant + residual reconstructs the input exactly (EF identity)
        np.testing.assert_allclose(np.asarray(o) + np.asarray(r),
                                   np.asarray(g), rtol=1e-6, atol=1e-7)
    # fully empty tree
    q0, s0, r0 = compress_tree({}, {})
    assert q0 == {} and s0 == {} and r0 == {}


_PSUM_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.train.compress import compressed_psum

    assert jax.device_count() == 4
    mesh = Mesh(np.array(jax.devices()), ("d",))
    rng = np.random.default_rng(0)
    # heterogeneous magnitudes per shard: shared scale must come from the
    # global max, and the int8 payload sum must be exact in int32
    g = np.concatenate([rng.normal(size=(1, 64)) * 10.0 ** k
                        for k in range(4)]).astype(np.float32)
    r = np.zeros_like(g)

    def f(gs, rs):
        avg, new_r = compressed_psum({"w": gs[0]}, {"w": rs[0]}, "d")
        return avg["w"][None], new_r["w"][None]

    avg, new_r = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P("d"), P("d")),
        out_specs=(P("d"), P("d"))))(jnp.asarray(g), jnp.asarray(r))
    avg = np.asarray(avg)
    # every shard sees the identical psum result
    assert all(np.array_equal(avg[0], avg[i]) for i in range(4))
    # manual protocol: one shared scale, integer-exact payload sum
    scale = np.float32(max(np.abs(g[i]).max() for i in range(4)) / 127.0)
    qs = [np.clip(np.round(g[i] / scale), -127, 127).astype(np.int64)
          for i in range(4)]
    exact = (sum(qs)).astype(np.float32) * scale / np.float32(4.0)
    assert np.allclose(avg[0], exact, rtol=0, atol=0), \\
        np.abs(avg[0] - exact).max()
    # EF identity per shard: dequant(q) + residual == x
    for i in range(4):
        np.testing.assert_allclose(
            qs[i].astype(np.float32) * scale + np.asarray(new_r)[i],
            g[i], rtol=1e-6, atol=1e-6)
    print("PSUM_OK")
""")


def test_compressed_psum_shared_scale_exact():
    """int32 psum of int8 payloads is lossless: the sharded result equals
    the host-side integer-exact protocol bitwise, on 4 real devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _PSUM_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PSUM_OK" in out.stdout
