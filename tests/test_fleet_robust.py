"""fleet/robust.py invariants: filter purity, quarantine, gate edges.

The robust filter's load-bearing property is that it is a **pure
function of (records, accepted mask)** — permutation-invariant in
worker order, idempotent (fixpoint), and identical no matter which
participant computes it (coordinator gate, reference gate, replay
recompute, wire-roundtripped commit). A deterministic battery here pins
those invariants on hand-picked nasty cases;
tests/test_robust_properties.py turns hypothesis loose on the same
assertions. The rest of the module covers the quarantine state machine,
coordinator snapshot/pruning edges, and the seed-liar regression (a
lying worker must be *rejected*, never crash the fleet — including
under ``python -O``, where the old ``assert`` vanished).

Protocol-level tests here run on a **toy fleet**: a hand-written
probe_fn over a 1-leaf parameter tree, no model, no jit — the wire
protocol, gate, and replay machinery are exactly the production code
paths, at interactive speed.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ByzantineSpec, FleetConfig, LaneConfig, RobustConfig
from repro.fleet import (Commit, Ledger, QuarantineTracker, RobustGate,
                         filter_decision, make_replay_fn, make_schema,
                         probe_seeds, replay, run_fleet, step_arrays)
from repro.fleet.ledger import pack_bits, unpack_bits
from repro.fleet.robust import apply_decision

W = 6          # toy fleet width for the protocol tests
BASE_SEED = jax.random.key_data(jax.random.key(7))


# ------------------------------------------------------------------ #
# toy fleet: production protocol, no model
# ------------------------------------------------------------------ #


def toy_partition(p):
    return p, {}


def toy_probe_fn(params, batch, step, ids, base_seed):
    """Deterministic stand-in for the jitted probe eval: loss pairs are
    a pure function of (params, step, probe id), tail empty."""
    ids = jnp.asarray(ids, jnp.float32)
    s = jnp.sum(jnp.asarray(params["w"], jnp.float32))
    lp = 2.0 + s + 0.01 * (jnp.asarray(step, jnp.float32) + 1.0) \
        + 0.003 * ids
    lm = 2.0 + s - 0.01 * (jnp.asarray(step, jnp.float32) + 1.0) \
        + 0.002 * ids
    return lp, lm, {}


def toy_fleet_cfg(**kw):
    kw.setdefault("num_workers", W)
    kw.setdefault("probes_per_worker", 1)
    kw.setdefault("snapshot_every", 2)
    return FleetConfig(**kw)


def toy_schema(fleet_cfg=None, numerics="fp32"):
    if fleet_cfg is None:
        fleet_cfg = toy_fleet_cfg(robust=RobustConfig())
    if numerics == "int8":
        from repro.core.int8 import QTensor
        lane = LaneConfig(lane="elastic_zo_int8", zo_num_probes=1)
        params = {"w": QTensor(jnp.zeros((8,), jnp.int8), jnp.int32(0))}
    else:
        lane = LaneConfig(lane="elastic_zo", learning_rate=1e-2,
                          zo_eps=1e-3)
        params = {"w": jnp.zeros((8,), jnp.float32)}
    return params, lane, make_schema(params, lane, fleet_cfg, BASE_SEED,
                                     toy_partition)


def toy_records(schema, step, deltas, losses):
    """Well-formed wire records with correct seed schedules."""
    from repro.fleet import Record
    m = schema.fleet.probes_per_worker
    seeds = probe_seeds(schema, step)
    recs = {}
    for w in range(schema.fleet.num_workers):
        d = np.asarray(deltas[w * m:(w + 1) * m])
        d = d.astype(np.int8 if schema.numerics == "int8" else np.float32)
        recs[w] = Record(step=step, worker=w,
                         seeds=seeds[w * m:(w + 1) * m].copy(),
                         deltas=d, loss=float(losses[w]),
                         numerics=schema.numerics)
    return recs


def run_toy_fleet(fleet_cfg, steps=6, trace=False):
    params, lane, _ = toy_schema(fleet_cfg)
    return params, run_fleet(None, params, lane, fleet_cfg,
                             lambda t: {}, steps=steps,
                             base_seed=BASE_SEED,
                             partition_fn=toy_partition,
                             probe_fn=toy_probe_fn, trace=trace)


def _bitwise_equal(a, b):
    return all(jnp.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------------------------ #
# deterministic battery: the filter is a pure function of (records,
# mask) — tests/test_robust_properties.py fuzzes the same invariants
# ------------------------------------------------------------------ #

# (deltas, losses, accept-bits) — hand-picked nasty cases: clean, one
# inflated, identical values (MAD=0), clique of two, huge-but-finite
# magnitudes (f32 overflow in the group means), freeloader loss, sparse
# acceptance, all-accepted-all-weird
CASES = [
    ([0.01, -0.02, 0.015, -0.005, 0.02, 0.0], [2.0] * 6, 0b111111),
    ([0.01, -0.02, 0.015, 5000.0, 0.02, 0.0], [2.0] * 6, 0b111111),
    ([0.5] * 6, [2.0] * 6, 0b111111),
    ([0.01, -0.02, 700.0, 700.0, 0.02, 0.0], [2.0] * 6, 0b111111),
    ([3e38, -3e38, 0.01, -0.02, 0.0, 0.015], [2.0] * 6, 0b111111),
    ([0.01, -0.02, 0.015, -0.005, 0.02, 0.0],
     [2.0, 2.01, 0.0, 1.99, 2.02, 2.0], 0b111111),
    ([0.01, -0.02, 0.015, -0.005, 0.02, 9.9], [2.0] * 6, 0b000011),
    ([1e30, -1e30, 1e-30, 42.0, -7.7, 3.3],
     [0.0, 50.0, 2.0, 2.0, 93.0, 2.0], 0b101101),
]
TERN_CASES = [
    ([1, -1, 0, 1, -1, 0], [2.0] * 6, 0b111111),
    ([1, -1, 64, 1, -3, 0], [2.0] * 6, 0b111111),
    ([127, -127, 2, -2, 1, 0], [2.0, 2.0, 0.0, 2.0, 2.0, 2.0], 0b110111),
]


def _expand_mask(bits):
    return np.asarray([float(bits >> w & 1) for w in range(W)], np.float32)


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("mode", ["mask", "clip"])
def test_filter_pure_and_permutation_invariant_fp32(case, mode):
    """Same inputs -> same verdict; relabeling the workers permutes the
    verdict with them (the filter sees a value multiset, not an order)."""
    deltas, losses, bits = case
    cfg = RobustConfig(mode=mode)
    d = np.asarray(deltas, np.float32)
    l = np.asarray(losses, np.float32)
    mask = _expand_mask(bits)
    a = filter_decision(d, l, mask, 1, cfg, "fp32")
    b = filter_decision(d.copy(), l.copy(), mask.copy(), 1, cfg, "fp32")
    assert np.array_equal(a.inband, b.inband)       # pure
    assert (a.outliers, a.loss_reject) == (b.outliers, b.loss_reject)
    perm = np.roll(np.arange(W), 2)
    p = filter_decision(d[perm], l[perm], mask[perm], 1, cfg, "fp32")
    assert np.array_equal(p.inband, a.inband[perm])  # equivariant
    for w in range(W):
        assert (p.loss_reject >> w & 1) == (a.loss_reject >> perm[w] & 1)


@pytest.mark.parametrize("case", TERN_CASES)
def test_filter_pure_and_permutation_invariant_int8(case):
    deltas, losses, bits = case
    cfg = RobustConfig()
    d = np.asarray(deltas, np.int8)
    l = np.asarray(losses, np.float32)
    mask = _expand_mask(bits)
    a = filter_decision(d, l, mask, 1, cfg, "int8")
    # ternary validity is per-probe and order-free
    perm = np.roll(np.arange(W), 3)
    p = filter_decision(d[perm], l[perm], mask[perm], 1, cfg, "int8")
    assert np.array_equal(p.inband, a.inband[perm])
    # sign-consistency: every accepted non-ternary scalar is rejected
    for i in range(W):
        if mask[i] > 0 and abs(int(np.asarray(deltas)[i])) > 1:
            assert not a.inband[i]


@pytest.mark.parametrize("case", CASES)
def test_filter_idempotent_mask_mode(case):
    """Filtering filtered arrays is a no-op: the verdict is a joint
    fixpoint of the loss and scalar channels."""
    deltas, losses, bits = case
    cfg = RobustConfig()
    d = np.asarray(deltas, np.float32)
    l = np.asarray(losses, np.float32)
    mask = _expand_mask(bits)
    dec = filter_decision(d, l, mask, 1, cfg, "fp32")
    seeds = np.arange(W, dtype=np.uint64)
    _, d2, m2 = apply_decision(seeds, d, mask, dec, cfg, 1)
    dec2 = filter_decision(d2, l, m2, 1, cfg, "fp32")
    _, d3, m3 = apply_decision(seeds, d2, m2, dec2, cfg, 1)
    assert np.array_equal(d2, d3) and np.array_equal(m2, m3)


@pytest.mark.parametrize("case", CASES)
def test_filter_identical_across_gate_replay_and_wire_fp32(case):
    """Coordinator gate, replay recompute (step_arrays), and the
    wire-roundtripped commit all derive the same post-filter arrays."""
    deltas, losses, bits = case
    _run_cross_path(np.asarray(deltas, np.float32),
                    np.asarray(losses, np.float32), bits, "fp32")


@pytest.mark.parametrize("case", TERN_CASES)
def test_filter_identical_across_gate_replay_and_wire_int8(case):
    deltas, losses, bits = case
    _run_cross_path(np.asarray(deltas, np.int8),
                    np.asarray(losses, np.float32), bits, "int8")


def _run_cross_path(deltas, losses, bits, numerics):
    _, _, schema = toy_schema(
        toy_fleet_cfg(robust=RobustConfig()), numerics)
    recs = toy_records(schema, 0, deltas, losses)
    on_time = {w: recs[w] for w in range(W) if bits >> w & 1}
    gate = RobustGate(schema)
    result = gate.evaluate(0, on_time)
    # the gate's carried bits == direct recomputation from the ledger view
    s1, d1, m1, _ = step_arrays(result.commit, result.records, schema)
    led = Ledger()
    for w in sorted(result.records):
        led.append_record(result.records[w])
    led.append_commit(result.commit)
    led2 = Ledger.from_bytes(led.to_bytes())
    c2, r2 = led2.step_entries(0)
    assert c2.filtered == result.commit.filtered
    s2, d2, m2, _ = step_arrays(c2, r2, schema)
    assert np.array_equal(m1, m2) and np.array_equal(d1, d2)
    assert np.array_equal(s1, s2)
    # evaluate is pure: a second gate derives the same commit
    again = RobustGate(schema).evaluate(0, on_time)
    assert (again.commit.accepted, again.commit.filtered) == \
        (result.commit.accepted, result.commit.filtered)


def test_mom_center_breakdown_semantics():
    """mom_groups=0 is the plain median (50% breakdown); a g-group MoM
    is corrupted once a clique owns >= g/2 sorted chunks — documented
    trade-off, pinned here so nobody re-defaults to a small g."""
    from repro.fleet.robust import mom_center
    vals = np.asarray([0.01, 0.012, 0.009, 0.011, 700.0, 700.0],
                      np.float32)
    assert mom_center(vals, 0) == np.float32(np.median(vals))
    assert mom_center(vals, 0) < 1.0          # median holds vs 2/6 clique
    # 4 sorted chunks over 6 values isolate the two 700s into their own
    # chunks: half the group means are corrupted and the center is
    # dragged between the honest and clique clusters
    assert mom_center(vals, 4) > 1.0
    # permutation-invariant either way
    rng = np.random.default_rng(0)
    perm = rng.permutation(6)
    assert mom_center(vals[perm], 4) == mom_center(vals, 4)


# ------------------------------------------------------------------ #
# commit v2 wire format
# ------------------------------------------------------------------ #


def test_commit_v2_wire_roundtrip_and_v1_compat():
    bits = pack_bits(np.asarray([1, 0, 1, 1, 0, 1], bool))
    v2 = Commit(5, 0b101101, quarantined=0b010000, filtered=bits)
    v1 = Commit(6, 0b111)
    assert v1.version == 1 and len(v1.to_bytes()) == 9 == v1.nbytes
    assert v2.version == 2 and len(v2.to_bytes()) == v2.nbytes
    led = Ledger()
    led.append_commit(v2)
    led.append_commit(v1)
    led2 = Ledger.from_bytes(led.to_bytes())
    r2, r1 = led2.commits[5], led2.commits[6]
    assert (r2.accepted, r2.quarantined, r2.filtered) == \
        (v2.accepted, v2.quarantined, bits)
    assert np.array_equal(r2.inband(6), [1, 0, 1, 1, 0, 1])
    # old commits decode as filter-free
    assert r1.filtered is None and r1.quarantined == 0
    assert r1.inband(6).all()
    # append-only invariant raises (not asserts) on duplicate steps
    with pytest.raises(ValueError, match="append-only"):
        led2.append_commit(Commit(5, 1))
    # truncated filter bitmask is rejected, never mis-parsed
    buf = v2.to_bytes()
    with pytest.raises(ValueError):
        Ledger.from_bytes(buf[:-1])
    assert np.array_equal(unpack_bits(pack_bits(np.ones(9, bool)), 9),
                          np.ones(9, bool))


def test_robust_probe_count_validated_at_construction():
    """The commit-v2 filter bitmask length is a u8 byte count: a config
    that could not serialize must fail at construction, not mid-run."""
    FleetConfig(num_workers=32, probes_per_worker=128)     # fine w/o robust
    with pytest.raises(ValueError, match="at most 2040 probes"):
        FleetConfig(num_workers=32, probes_per_worker=128,
                    robust=RobustConfig())


def test_v2_ledger_without_robust_config_refuses_to_replay():
    """Wire bits alone cannot distinguish mask from clip semantics: a
    replayer missing the RobustConfig must raise, not silently guess."""
    _, _, schema = toy_schema()
    deltas = np.asarray([0.01, -0.02, 0.015, 500.0, 0.0, 0.02], np.float32)
    recs = toy_records(schema, 0, deltas, np.full(W, 2.0))
    result = RobustGate(schema).evaluate(0, {w: recs[w] for w in range(W)})
    _, _, bare = toy_schema(toy_fleet_cfg(robust=None))
    with pytest.raises(ValueError, match="no RobustConfig"):
        step_arrays(result.commit, result.records, bare)


def test_forged_filter_mask_rejected_on_replay():
    """A v2 commit whose carried bits contradict the deterministic
    recomputation is a corrupt/forged ledger -> ValueError."""
    _, _, schema = toy_schema()
    deltas = np.asarray([0.01, -0.02, 0.015, 500.0, 0.0, 0.02], np.float32)
    recs = toy_records(schema, 0, deltas, np.full(W, 2.0))
    gate = RobustGate(schema)
    result = gate.evaluate(0, {w: recs[w] for w in range(W)})
    assert not result.commit.inband(W)[3]         # the outlier is caught
    forged = Commit(0, result.commit.accepted,
                    quarantined=result.commit.quarantined,
                    filtered=pack_bits(np.ones(W, bool)))
    with pytest.raises(ValueError, match="does not match"):
        step_arrays(forged, result.records, schema)


# ------------------------------------------------------------------ #
# quarantine state machine
# ------------------------------------------------------------------ #


def test_quarantine_enter_exit_and_window():
    cfg = RobustConfig(window=3, quarantine_after=2, quarantine_steps=2)
    t = QuarantineTracker(cfg, 4)
    t.observe(0, 0b0010)
    assert t.active_bits(1) == 0
    t.observe(1, 0b0010)                 # 2 verdicts in window -> enter
    assert t.active_bits(2) == 0b0010 and t.active_bits(3) == 0b0010
    t.observe(2, 0)
    t.observe(3, 0)
    assert t.active_bits(4) == 0         # released after quarantine_steps
    assert (2, 1, "enter") in t.events
    # verdicts outside the sliding window don't accumulate
    t2 = QuarantineTracker(cfg, 4)
    t2.observe(0, 0b1)
    t2.observe(1, 0)
    t2.observe(2, 0)
    t2.observe(3, 0b1)                   # step-0 verdict aged out
    assert t2.active_bits(4) == 0


def test_quarantine_never_empties_the_fleet():
    cfg = RobustConfig(window=1, quarantine_after=1, quarantine_steps=0)
    t = QuarantineTracker(cfg, 2)
    t.observe(0, 0b11)                   # everyone looks like an outlier
    assert bin(t.active_bits(1)).count("1") == 1
    # permanent quarantine (quarantine_steps=0) never exits
    t.observe(1, 0)
    t.observe(2, 0)
    assert bin(t.active_bits(3)).count("1") == 1


def test_quarantined_worker_excluded_then_readmitted():
    """Fleet-level: a persistent outlier is quarantined (commit v2
    carries the set), sits out, and is readmitted after the timer."""
    cfg = toy_fleet_cfg(
        byzantine=(ByzantineSpec(2, "inflate"),),
        robust=RobustConfig(window=2, quarantine_after=2,
                            quarantine_steps=2))
    _, res = run_toy_fleet(cfg, steps=8)
    quar_steps = [t for t, c in res.ledger.commits.items()
                  if c.quarantined >> 2 & 1]
    assert quar_steps, "attacker never quarantined"
    for t in quar_steps:
        assert not res.ledger.commits[t].accepted >> 2 & 1
    # readmitted (as accepted-but-filtered) after the quarantine lapses
    assert any(c.accepted >> 2 & 1 for c in res.ledger.commits.values())
    assert res.stats["n_quarantines"] >= 1


# ------------------------------------------------------------------ #
# seed-schedule liars: reject, don't crash (the PR 4 regression)
# ------------------------------------------------------------------ #


def test_seed_liar_rejected_not_fatal():
    """A worker publishing a diverged seed schedule is rejected from
    every commit and cannot poison or crash the fleet."""
    cfg = toy_fleet_cfg(byzantine=(ByzantineSpec(1, "seed_lie"),))
    params, res = run_toy_fleet(cfg, steps=5)
    for c in res.ledger.commits.values():
        assert not c.accepted >> 1 & 1, "liar entered a commit"
    assert res.stats["n_rejected"] == 5
    assert any("seed schedule diverged" in e for e in res.coordinator.events)
    # the canon is exactly the attack-free canon minus the liar's probes:
    # replaying the ledger from scratch reproduces it
    rejoined = make_replay_fn(res.schema)(params, res.ledger.to_bytes(),
                                          0, 5)
    assert _bitwise_equal(rejoined, res.params)


def test_stale_replayer_rejected():
    cfg = toy_fleet_cfg(byzantine=(ByzantineSpec(4, "stale_replay"),))
    _, res = run_toy_fleet(cfg, steps=5)
    # step 0's record is honest (nothing to replay yet), all others stale
    assert res.ledger.commits[0].accepted >> 4 & 1
    for t in range(1, 5):
        assert not res.ledger.commits[t].accepted >> 4 & 1
    assert any("stale/foreign step" in e for e in res.coordinator.events)


def test_stale_replayer_survives_crash_gap():
    """A crash gap that swallows the replay target must not crash the
    adversary (it falls back to the newest record it actually has), and
    the fleet/reference adversary stashes stay aligned because the
    reference skips stashing on the worker's down steps."""
    cfg = toy_fleet_cfg(byzantine=(ByzantineSpec(4, "stale_replay"),),
                        crashes=((4, 2, 3),), snapshot_every=2)
    _, res = run_toy_fleet(cfg, steps=8)
    # rejoined at 5; its step-5 wire record replays stash[3] -> but 3
    # fell in the gap, so the newest held is step 1 -> stale, rejected
    assert res.workers[4].alive
    assert not res.ledger.commits[5].accepted >> 4 & 1
    # and a worker crashed from step 0 has nothing at all to replay: the
    # honest fallback goes out (and is accepted)
    cfg0 = toy_fleet_cfg(byzantine=(ByzantineSpec(3, "stale_replay"),),
                         crashes=((3, 0, 2),), snapshot_every=2)
    _, res0 = run_toy_fleet(cfg0, steps=5)
    assert res0.ledger.commits[2].accepted >> 3 & 1


def test_seed_liar_rejected_under_python_O(tmp_path):
    """The old coordinator died on `assert` when a worker lied about its
    seed schedule — which also means `python -O` removed the check
    entirely. The rejection path must be assert-free."""
    script = tmp_path / "liar.py"
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    test_dir = os.path.dirname(__file__)
    script.write_text(
        "import sys\n"
        f"sys.path.insert(0, {src_dir!r})\n"
        f"sys.path.insert(0, {test_dir!r})\n"
        "assert not __debug__, 'this regression must run under -O'\n"
        "from repro.configs import ByzantineSpec\n"
        "from test_fleet_robust import run_toy_fleet, toy_fleet_cfg\n"
        "cfg = toy_fleet_cfg(byzantine=(ByzantineSpec(1, 'seed_lie'),))\n"
        "_, res = run_toy_fleet(cfg, steps=3)\n"
        "assert True  # stripped; use exceptions below\n"
        "if any(c.accepted >> 1 & 1 for c in res.ledger.commits.values()):\n"
        "    raise SystemExit('liar entered a commit under -O')\n"
        "if res.stats['n_rejected'] != 3:\n"
        "    raise SystemExit('rejections not counted under -O')\n"
        "print('OK-rejected-under-O')\n")
    env = {**os.environ, "PYTHONOPTIMIZE": "1", "JAX_PLATFORMS": "cpu"}
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "OK-rejected-under-O" in out.stdout


# ------------------------------------------------------------------ #
# coordinator snapshot pruning / nearest_snapshot edges
# ------------------------------------------------------------------ #


def test_snapshot_pruning_keep_one_and_nearest_edges():
    cfg = toy_fleet_cfg(snapshot_every=2)
    params, lane, schema = toy_schema(cfg)
    from repro.fleet import Coordinator
    from repro.fleet.transport import Fate
    coord = Coordinator(params, schema, keep_snapshots=1)
    for t in range(6):
        recs = toy_records(
            schema, t, 0.01 * np.arange(1, W + 1, dtype=np.float32),
            np.full(W, 2.0))
        arrivals = [(recs[w], Fate(True, 0)) for w in range(W)]
        coord.close_step(t, arrivals)
    # keep_snapshots=1: only the newest snapshot survives (step 0 pruned)
    assert sorted(coord.snapshots) == [6]
    base, snap = coord.nearest_snapshot(6)
    assert base == 6 and _bitwise_equal(snap, coord.params)
    # restoring exactly at a pruned base is a clear error, not max([])
    with pytest.raises(ValueError, match="no snapshot at or before"):
        coord.nearest_snapshot(5)
    # replay from the retained snapshot is the identity at its own step
    assert _bitwise_equal(
        replay(snap, coord.ledger, schema, 6, 6), coord.params)


def test_out_of_order_close_step_raises():
    params, lane, schema = toy_schema(toy_fleet_cfg())
    from repro.fleet import Coordinator
    from repro.fleet.transport import Fate
    coord = Coordinator(params, schema)
    recs = toy_records(schema, 1, np.zeros(W, np.float32),
                       np.full(W, 2.0))
    with pytest.raises(ValueError, match="out of order"):
        coord.close_step(1, [(recs[0], Fate(True, 0))])
    with pytest.raises(ValueError, match="out of order"):
        coord.close_step(0, [])


def test_quarantined_worker_rejoins_via_ledger_replay():
    """Crash a Byzantine worker mid-quarantine: it restarts from the
    coordinator snapshot + a v2-commit ledger slice and lands bit-exact
    on the canon (quarantine state rides in the commits, not in any
    worker-side state)."""
    cfg = toy_fleet_cfg(
        byzantine=(ByzantineSpec(2, "collude"),),
        robust=RobustConfig(window=2, quarantine_after=2,
                            quarantine_steps=3),
        crashes=((2, 3, 2),), snapshot_every=3)
    _, res = run_toy_fleet(cfg, steps=8)
    assert res.stats["n_catchups"] == 1
    assert res.stats["n_quarantines"] >= 1
    w2 = res.workers[2]
    assert w2.alive and w2.catchup_bytes > 0
    for w in res.workers:
        assert _bitwise_equal(w.params, res.params), f"worker {w.id}"


def test_empty_commit_is_a_noop_step():
    """If no sound record exists for a step, the commit is empty and the
    canonical update is an exact parameter no-op."""
    _, _, schema = toy_schema(toy_fleet_cfg())
    from repro.fleet import Coordinator
    from repro.fleet.transport import Fate
    coord = Coordinator(toy_schema(toy_fleet_cfg())[0], schema)
    before = jax.tree.map(np.asarray, coord.params)
    recs = toy_records(schema, 0, np.zeros(W, np.float32),
                       np.full(W, 2.0))
    bad = recs[0]
    bad.seeds = bad.seeds + np.uint64(1)         # only arrival lies
    commit, _ = coord.close_step(0, [(bad, Fate(True, 0))])
    assert commit.accepted == 0
    assert _bitwise_equal(before, coord.params)
    assert any("empty commit" in e for e in coord.events)
    # a no-op step is not an observation: no fictitious 0.0 in the curve
    assert np.isnan(coord.loss_history[0][1])
    recs2 = toy_records(schema, 1, np.zeros(W, np.float32),
                        np.full(W, 2.0))
    coord.close_step(1, [(recs2[w], Fate(True, 0)) for w in range(W)])
    bad2 = recs2[0]
    # (records are stashed per step; reuse a stale one as the sole
    # arrival for step 2 -> rejected -> empty commit carries prev loss)
    commit2, _ = coord.close_step(2, [(bad2, Fate(True, 0))])
    assert commit2.accepted == 0
    assert coord.loss_history[2][1] == coord.loss_history[1][1]
