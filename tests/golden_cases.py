"""Golden-digest harness for the update-engine refactor contract.

The engine refactor (core/engine.py) must preserve fp32 single-process
training bitwise. This module defines the pinned cases and the digest
function; the fixture ``tests/golden/engine_steps.json`` stores, per
case, the sha256 of the post-step parameter bytes and the per-step loss
floats (as exact hex) captured on the **pre-refactor** implementation.

Fixture sections:
  * ``preserved``  — cases whose behavior the refactor must not change
    at all: the three fp32 lanes at n_probes=1 (where per-probe and
    accumulate-then-cast application coincide) and the int8 lane
    (integer arithmetic, platform-exact).
  * ``canonical``  — multi-probe fp32 cases pinning the engine's
    canonical accumulate-then-cast order (docs/design.md §10). These
    digests are generated on the engine implementation itself and guard
    *future* refactors.

Float digests are platform-pinned (XLA CPU codegen varies across ISAs /
jax versions), so the fixture also stores a ``canary``: the digest of a
step-free computation (init + forward loss) that the refactor does not
touch. If the canary mismatches, the environment's baseline numerics
differ and the float cases are skipped; if the canary matches but a case
digest doesn't, the refactor changed semantics. Integer cases assert
unconditionally.

Regenerate (section-selective; run from the repo root):
    PYTHONPATH=src python tests/golden_cases.py preserved
    PYTHONPATH=src python tests/golden_cases.py canonical
"""
from __future__ import annotations

import hashlib
import json
import sys
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

FIXTURE = Path(__file__).parent / "golden" / "engine_steps.json"
STEPS = 3
BATCH = 16


def digest_tree(tree) -> str:
    h = hashlib.sha256()
    for path, leaf in sorted(jax.tree_util.tree_flatten_with_path(tree)[0],
                             key=lambda kv: jax.tree_util.keystr(kv[0])):
        h.update(jax.tree_util.keystr(path).encode())
        a = np.asarray(jax.device_get(leaf))
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def _glyph_batch():
    from repro.data.synthetic import glyphs
    xs, ys = glyphs(BATCH, seed=0)
    return jnp.asarray(xs), jnp.asarray(ys)


def run_canary() -> str:
    """Init + forward + loss only — independent of the step construction."""
    from repro.models import lenet
    params = lenet.init_lenet5(jax.random.key(7))
    bx, by = _glyph_batch()
    loss = lenet.lenet5_loss(params, {"x": bx, "y": by})
    return digest_tree({"params": params, "loss": loss})


def run_fp32_case(lane_name: str, n_probes: int, mask) -> dict:
    from repro.configs import LaneConfig
    from repro.core.elastic import TrainState, make_elastic_step
    from repro.models import lenet
    lane = LaneConfig(lane=lane_name, learning_rate=0.05,
                      tail_learning_rate=0.05 if lane_name == "elastic_zo"
                      else None,
                      zo_eps=1e-2, zo_num_probes=n_probes,
                      lr_decay_factor=0.5, lr_decay_every=2)
    part = (lambda p: lenet.partition_at(p, 4)) \
        if lane_name == "elastic_zo" else None
    step = jax.jit(make_elastic_step(lenet.lenet5_loss, lane,
                                     partition_fn=part))
    params = lenet.init_lenet5(jax.random.key(7))
    state = TrainState(params, jnp.int32(0),
                       jax.random.key_data(jax.random.key(11)))
    bx, by = _glyph_batch()
    pm = jnp.asarray(mask, jnp.float32)
    losses = []
    for _ in range(STEPS):
        state, m = step(state, {"x": bx, "y": by}, pm)
        losses.append(float(m["loss"]))
    return {"params_sha256": digest_tree(state.params),
            "loss_hex": [np.float32(v).tobytes().hex() for v in losses]}


def run_int8_case(loss_mode: str) -> dict:
    from repro.configs import LaneConfig
    from repro.core.elastic import TrainState
    from repro.core.elastic_int8 import make_int8_elastic_step
    from repro.core.int8 import quant_from_float
    from repro.models import lenet
    lane = LaneConfig(lane="elastic_zo_int8", int8_r_max=3,
                      int8_p_zero=0.33, int8_b_zo=1, int8_b_bp=5)
    step = jax.jit(make_int8_elastic_step(
        lenet.lenet5_forward_int8,
        partition_fn=lambda p: lenet.partition_at(p, 4),
        tail_fcs=[("fc3", "fc3_in")], lane=lane, loss_mode=loss_mode))
    params = lenet.init_lenet5_int8(jax.random.key(7))
    state = TrainState(params, jnp.int32(0),
                       jax.random.key_data(jax.random.key(13)))
    bx, by = _glyph_batch()
    qx = quant_from_float(bx)
    gs = []
    for _ in range(STEPS):
        state, m = step(state, {"x": qx, "y": by},
                        jnp.ones((1,), jnp.float32))
        gs.append(int(m["g"]))
    return {"params_sha256": digest_tree(state.params), "g_signs": gs}


PRESERVED = {
    "fp32_full_zo_n1": lambda: run_fp32_case("full_zo", 1, [1.0]),
    "fp32_elastic_zo_n1": lambda: run_fp32_case("elastic_zo", 1, [1.0]),
    "fp32_full_bp": lambda: run_fp32_case("full_bp", 1, [1.0]),
}
# Engine-canonical cases, generated ON the engine implementation:
#  * multi-probe fp32 with a masked (straggler) probe pins the
#    accumulate-then-cast probe fold;
#  * the int8 lane pins the per-probe key schedule
#    fold_in(fold_in(base, step), probe_id) the engine unified with the
#    fleet (the pre-engine int8 step used the bare step key) and the
#    int32 accumulate-then-clamp update. Integer arithmetic is
#    platform-exact, so these assert regardless of the canary.
CANONICAL = {
    "fp32_full_zo_n3_masked": lambda: run_fp32_case(
        "full_zo", 3, [1.0, 0.0, 1.0]),
    "fp32_elastic_zo_n3_masked": lambda: run_fp32_case(
        "elastic_zo", 3, [1.0, 0.0, 1.0]),
    "int8_elastic_intloss": lambda: run_int8_case("int"),
    "int8_elastic_floatloss": lambda: run_int8_case("float"),
}


def regenerate(sections):
    doc = json.loads(FIXTURE.read_text()) if FIXTURE.exists() else {}
    doc.setdefault("meta", {})["jax"] = jax.__version__
    doc["canary"] = run_canary()
    for name, cases in (("preserved", PRESERVED), ("canonical", CANONICAL)):
        if name not in sections:
            continue
        doc[name] = {k: fn() for k, fn in cases.items()}
        print(f"[golden] regenerated section {name!r} "
              f"({len(doc[name])} cases)")
    FIXTURE.parent.mkdir(exist_ok=True)
    FIXTURE.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"[golden] wrote {FIXTURE}")


if __name__ == "__main__":
    regenerate(set(sys.argv[1:]) or {"preserved", "canonical"})
