"""Flash-attention Pallas kernel vs dense-softmax oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention
from repro.kernels.ref import flash_attention_ref


@pytest.mark.parametrize("B,H,Sq,Sk,D,causal,win", [
    (1, 2, 128, 128, 64, True, 0),
    (2, 1, 256, 256, 128, True, 0),
    (1, 1, 128, 256, 64, False, 0),      # cross-attention shape
    (1, 2, 256, 256, 64, True, 128),     # sliding window
    (1, 1, 384, 384, 128, True, 0),
])
def test_flash_matches_ref(B, H, Sq, Sk, D, causal, win):
    rng = np.random.default_rng(Sq + Sk + D)
    q = jnp.asarray(rng.normal(size=(B, H, Sq, D)), jnp.float32) * 0.3
    k = jnp.asarray(rng.normal(size=(B, H, Sk, D)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(B, H, Sk, D)), jnp.float32)
    o1 = flash_attention(q, k, v, causal=causal, window=win, interpret=True)
    o2 = flash_attention_ref(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16_io():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    o1 = flash_attention(q, k, v, interpret=True)
    o2 = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert o1.dtype == jnp.bfloat16
