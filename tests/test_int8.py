"""NITI int8 substrate + integer CE sign trick (paper §4.2-4.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: suite must collect without it
from hypothesis import given, settings, strategies as st

from repro.core import int8 as q8
from repro.core.int8 import QTensor
from repro.core.int_loss import float_loss, int_loss_sign


def test_quant_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)) * 3,
                    jnp.float32)
    qt = q8.quant_from_float(x)
    back = q8.dequant(qt)
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.02           # 7-bit quantization error bound


@settings(deadline=None, max_examples=30)
@given(st.integers(0, 2**20), st.integers(0, 8))
def test_psr_expectation(x, s):
    """Pseudo-stochastic rounding is ~unbiased: averaging psr over many
    nearby values recovers x / 2^s within the quantization step."""
    xs = jnp.arange(x, x + 256, dtype=jnp.int32)
    out = q8.psr_shift(xs, jnp.int32(s))
    mean_out = float(out.astype(jnp.float64).mean())
    mean_in = float(xs.astype(jnp.float64).mean()) / (2 ** s)
    assert abs(mean_out - mean_in) < 1.0, (mean_out, mean_in)


def test_psr_sign_symmetry():
    xs = jnp.asarray([100, -100, 255, -255, 7, -7], jnp.int32)
    out = q8.psr_shift(xs, jnp.int32(3))
    assert jnp.array_equal(jnp.sign(out), jnp.sign(xs))
    assert jnp.array_equal(q8.psr_shift(xs, jnp.int32(0)), jnp.abs(xs) * jnp.sign(xs))


def test_bitwidth():
    for v, b in [(1, 1), (2, 2), (127, 7), (128, 8), (255, 8), (256, 9)]:
        assert int(q8.bitwidth(jnp.int32(v))) == b, v


def test_int8_matmul_matches_fp():
    rng = np.random.default_rng(1)
    a = QTensor(jnp.asarray(rng.integers(-64, 64, (32, 16)), jnp.int8),
                jnp.int32(-5))
    w = QTensor(jnp.asarray(rng.integers(-64, 64, (16, 8)), jnp.int8),
                jnp.int32(-6))
    out = q8.qdense(a, w)
    exact = q8.dequant(a) @ q8.dequant(w)
    approx = q8.dequant(out)
    denom = float(jnp.max(jnp.abs(exact))) + 1e-9
    assert float(jnp.max(jnp.abs(approx - exact))) / denom < 0.02


def test_qconv_equals_lax_conv():
    rng = np.random.default_rng(2)
    x = QTensor(jnp.asarray(rng.integers(-32, 32, (2, 12, 12, 3)), jnp.int8),
                jnp.int32(-4))
    w = QTensor(jnp.asarray(rng.integers(-32, 32, (5, 5, 3, 4)), jnp.int8),
                jnp.int32(-4))
    out = q8.qconv2d(x, w)
    ref = jax.lax.conv_general_dilated(
        x.data.astype(jnp.float32), w.data.astype(jnp.float32), (1, 1),
        "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    got = out.data.astype(jnp.float32) * 2.0 ** float(out.exp - (x.exp + w.exp))
    # integer conv then rescale: compare against exact int32 accumulation
    np.testing.assert_allclose(got, ref, atol=2.0 ** float(out.exp - (x.exp + w.exp)))


def _rand_qlogits(rng, B, C, exp_a, exp_b):
    a = QTensor(jnp.asarray(rng.integers(-100, 100, (B, C)), jnp.int8),
                jnp.int32(exp_a))
    b = QTensor(jnp.asarray(rng.integers(-100, 100, (B, C)), jnp.int8),
                jnp.int32(exp_b))
    return a, b


def test_int_loss_sign_agreement():
    """Paper §4.3 / §5.2: integer sign matches the fp32 sign ~95% of the
    time (they report ~95%; we assert >= 90% over random logit pairs)."""
    rng = np.random.default_rng(3)
    agree, total = 0, 0
    for trial in range(200):
        B = rng.choice([1, 4, 8])
        a, b = _rand_qlogits(rng, B, 10, rng.integers(-6, -2),
                             rng.integers(-6, -2))
        y = jnp.asarray(rng.integers(0, 10, (B,)), jnp.int32)
        s_int = int(int_loss_sign(a, b, y))
        s_fp = float(float_loss(a, y) - float_loss(b, y))
        if s_fp == 0.0:
            continue
        total += 1
        agree += (s_int == np.sign(s_fp))
    assert total > 150
    assert agree / total >= 0.90, agree / total


def test_int8_perturb_replay_and_sparsity():
    from repro.core.int8 import int8_noise
    seed = jnp.uint32(99)
    z1 = int8_noise(seed, 1, (10000,), 3, jnp.float32(0.9))
    z2 = int8_noise(seed, 1, (10000,), 3, jnp.float32(0.9))
    assert jnp.array_equal(z1, z2)
    frac_zero = float(jnp.mean((z1 == 0).astype(jnp.float32)))
    assert frac_zero > 0.88    # p_zero=0.9 (+ uniform zeros)
    assert int(jnp.max(z1)) <= 3 and int(jnp.min(z1)) >= -3


def test_output_error_int8_direction():
    """e_L ~ 127*(p - y): correct class entry negative, others >= 0."""
    logits = QTensor(jnp.asarray([[50, -20, -30, 10]], jnp.int8), jnp.int32(-4))
    e = q8.output_error_int8(logits, jnp.asarray([0], jnp.int32))
    assert int(e[0, 0]) < 0
    assert all(int(v) >= 0 for v in np.asarray(e[0, 1:]))
