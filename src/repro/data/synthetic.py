"""Deterministic synthetic datasets (offline stand-ins; docs/design.md §9).

* glyphs       — 28x28 grayscale 10-class "digit-like" images: each class is
                 a distinct parametric stroke pattern + noise + small affine
                 jitter. Learnable by LeNet-5; hard enough that lane
                 orderings (BP > Elastic > ZO) are visible.
* rotated glyphs — the fine-tuning distribution shift (paper Table 2).
* point clouds — 8 parametric shapes (sphere, cube, cone, torus, ...)
                 sampled to N points, unit-normalized (PointNet).
* token stream — integer LM batches with next-token labels (Zipf-ish
                 bigram process so losses are compressible).

Everything is a pure function of (seed, index): the data-pipeline state is
the step counter alone, which is what makes checkpoint-restart and elastic
rescaling exact (docs/design.md §8).
"""
from __future__ import annotations

import numpy as np

# --------------------------------------------------------------------- #
# glyph images
# --------------------------------------------------------------------- #
def _glyph_canvas(cls: int, rng: np.random.Generator) -> np.ndarray:
    img = np.zeros((28, 28), np.float32)
    xx, yy = np.meshgrid(np.arange(28), np.arange(28))
    cx, cy = 13.5 + rng.uniform(-2, 2), 13.5 + rng.uniform(-2, 2)
    r = 8 + rng.uniform(-1.5, 1.5)
    t = (cls % 10)
    if t == 0:      # ring
        img += np.exp(-((np.hypot(xx - cx, yy - cy) - r) ** 2) / 3)
    elif t == 1:    # vertical bar
        img += np.exp(-((xx - cx) ** 2) / 4) * (np.abs(yy - cy) < r)
    elif t == 2:    # diagonal
        img += np.exp(-((xx - yy + cx - cy) ** 2) / 6)
    elif t == 3:    # cross
        img += np.exp(-((xx - cx) ** 2) / 4) + np.exp(-((yy - cy) ** 2) / 4)
    elif t == 4:    # two dots
        for dx in (-5, 5):
            img += np.exp(-(((xx - cx - dx) ** 2) + (yy - cy) ** 2) / 6)
    elif t == 5:    # horizontal bar
        img += np.exp(-((yy - cy) ** 2) / 4) * (np.abs(xx - cx) < r)
    elif t == 6:    # half ring
        d = np.hypot(xx - cx, yy - cy)
        img += np.exp(-((d - r) ** 2) / 3) * (yy < cy)
    elif t == 7:    # corner
        img += (np.exp(-((xx - cx + r) ** 2) / 4) * (yy > cy - r)
                + np.exp(-((yy - cy + r) ** 2) / 4) * (xx > cx - r))
    elif t == 8:    # double ring
        d = np.hypot(xx - cx, yy - cy)
        img += np.exp(-((d - r) ** 2) / 3) + np.exp(-((d - r / 2) ** 2) / 3)
    else:           # blob + tail
        img += np.exp(-(((xx - cx) ** 2) + (yy - cy) ** 2) / 12)
        img += np.exp(-((xx - yy + cx - cy) ** 2) / 8) * (xx > cx)
    img += rng.normal(0, 0.12, img.shape).astype(np.float32)
    return np.clip(img, 0, 1.5)


def glyphs(n: int, *, seed: int = 0, rotate_deg: float = 0.0,
           start: int = 0):
    """Returns (x [n,28,28,1] fp32, y [n] int32); sample i is a pure
    function of (seed, start + i)."""
    xs = np.zeros((n, 28, 28, 1), np.float32)
    ys = np.zeros((n,), np.int32)
    for i in range(n):
        idx = start + i
        rng = np.random.default_rng(np.uint64(seed * 1_000_003 + idx))
        cls = idx % 10
        img = _glyph_canvas(cls, rng)
        if rotate_deg:
            img = _rotate(img, np.deg2rad(rotate_deg))
        xs[i, :, :, 0] = img
        ys[i] = cls
    return xs, ys


def _rotate(img: np.ndarray, theta: float) -> np.ndarray:
    h, w = img.shape
    yy, xx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    cy, cx = (h - 1) / 2, (w - 1) / 2
    ys = cy + (yy - cy) * np.cos(theta) - (xx - cx) * np.sin(theta)
    xs = cx + (yy - cy) * np.sin(theta) + (xx - cx) * np.cos(theta)
    y0 = np.clip(ys.round().astype(int), 0, h - 1)
    x0 = np.clip(xs.round().astype(int), 0, w - 1)
    return img[y0, x0]


# --------------------------------------------------------------------- #
# point clouds
# --------------------------------------------------------------------- #
def point_clouds(n: int, num_points: int = 256, *, seed: int = 0,
                 num_classes: int = 8, start: int = 0):
    xs = np.zeros((n, num_points, 3), np.float32)
    ys = np.zeros((n,), np.int32)
    for i in range(n):
        idx = start + i
        rng = np.random.default_rng(np.uint64(seed * 999_983 + idx))
        cls = idx % num_classes
        pts = _shape_points(cls, num_points, rng)
        pts -= pts.mean(0, keepdims=True)
        pts /= max(np.linalg.norm(pts, axis=1).max(), 1e-6)
        xs[i] = pts
        ys[i] = cls
    return xs, ys


def _shape_points(cls, n, rng):
    u = rng.uniform(0, 1, n)
    v = rng.uniform(0, 1, n)
    th, ph = 2 * np.pi * u, np.arccos(2 * v - 1)
    if cls == 0:      # sphere
        p = np.stack([np.sin(ph) * np.cos(th), np.sin(ph) * np.sin(th),
                      np.cos(ph)], 1)
    elif cls == 1:    # cube surface
        p = rng.uniform(-1, 1, (n, 3))
        ax = rng.integers(0, 3, n)
        sgn = rng.choice([-1.0, 1.0], n)
        p[np.arange(n), ax] = sgn
    elif cls == 2:    # cone
        h = rng.uniform(0, 1, n)
        p = np.stack([(1 - h) * np.cos(th), (1 - h) * np.sin(th), h * 2 - 1], 1)
    elif cls == 3:    # torus
        R, r = 1.0, 0.35
        p = np.stack([(R + r * np.cos(2 * np.pi * v)) * np.cos(th),
                      (R + r * np.cos(2 * np.pi * v)) * np.sin(th),
                      r * np.sin(2 * np.pi * v)], 1)
    elif cls == 4:    # cylinder
        p = np.stack([np.cos(th), np.sin(th), 2 * v - 1], 1)
    elif cls == 5:    # plane with ridge
        p = np.stack([2 * u - 1, 2 * v - 1,
                      0.3 * np.sin(4 * np.pi * u)], 1)
    elif cls == 6:    # two spheres
        p = np.stack([np.sin(ph) * np.cos(th) * 0.5,
                      np.sin(ph) * np.sin(th) * 0.5, np.cos(ph) * 0.5], 1)
        p[:, 0] += np.where(rng.uniform(size=n) > 0.5, 0.8, -0.8)
    else:             # helix
        t = 4 * np.pi * u
        p = np.stack([np.cos(t), np.sin(t), (t / (2 * np.pi)) - 1], 1)
        p += rng.normal(0, 0.05, (n, 3))
    return (p + rng.normal(0, 0.02, (n, 3))).astype(np.float32)


# --------------------------------------------------------------------- #
# token streams (LM)
# --------------------------------------------------------------------- #
def token_batch(batch: int, seq: int, vocab: int, *, seed: int = 0,
                step: int = 0):
    """Zipf-bigram token stream; labels are next tokens (last = -1/masked)."""
    rng = np.random.default_rng(np.uint64(seed * 7_368_787 + step))
    # a cheap deterministic bigram: next ~ (a*cur + noise) mod vocab_eff
    vocab_eff = min(vocab, 32768)
    a = 6364136223846793005 % vocab_eff
    toks = np.zeros((batch, seq + 1), np.int64)
    toks[:, 0] = rng.integers(0, vocab_eff, batch)
    noise = rng.integers(0, 64, (batch, seq))
    for t in range(seq):
        toks[:, t + 1] = (toks[:, t] * a + noise[:, t]) % vocab_eff
    x = toks[:, :-1].astype(np.int32)
    y = toks[:, 1:].astype(np.int32)
    mask = np.ones((batch, seq), np.float32)
    return x, y, mask
