"""Sharding-aware host data pipeline.

Deterministic-by-step batches (data/synthetic.py) placed directly onto the
mesh with the training step's input shardings, plus a one-deep host
prefetch thread so batch generation overlaps device compute. The pipeline
carries **no state other than the step index** — restart/elastic-remesh
resume is a pure function of the checkpointed step (docs/design.md §8).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import synthetic


def lm_batch_fn(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    """step -> host batch dict for the LM train step."""
    S_tok = shape.seq_len - (cfg.num_image_tokens or 0)

    def fn(step: int) -> Dict[str, np.ndarray]:
        x, y, m = synthetic.token_batch(shape.global_batch, S_tok,
                                        cfg.vocab_size, seed=seed, step=step)
        b: Dict[str, Any] = {"tokens": x, "labels": y, "mask": m}
        if cfg.encoder_layers:
            b["frames"] = np.zeros(
                (shape.global_batch, cfg.encoder_seq, cfg.d_model),
                np.float32)
        if cfg.num_image_tokens:
            b["img"] = np.zeros(
                (shape.global_batch, cfg.num_image_tokens, cfg.d_model),
                np.float32)
        return b
    return fn


def device_put_batch(batch: Dict[str, np.ndarray], shardings=None,
                     dtypes: Optional[Dict[str, Any]] = None):
    out = {}
    for k, v in batch.items():
        dt = (dtypes or {}).get(k)
        arr = v.astype(dt) if dt is not None else v
        sh = None if shardings is None else shardings.get(k)
        out[k] = jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)
    return out


class Prefetcher:
    """One-deep host prefetch: generate batch t+1 while t trains.

    Iteration order is driven by the caller's step indices, so a restart
    at step k replays the identical stream.
    """

    def __init__(self, batch_fn: Callable[[int], Dict[str, np.ndarray]],
                 start_step: int, shardings=None, dtypes=None, depth: int = 2):
        self.batch_fn = batch_fn
        self.shardings = shardings
        self.dtypes = dtypes
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next
        while not self._stop.is_set():
            host = self.batch_fn(step)
            try:
                self._q.put((step, host), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def get(self) -> Any:
        step, host = self._q.get()
        return step, device_put_batch(host, self.shardings, self.dtypes)

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
