import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (results/dryrun/<arch>__<shape>__<mesh>.json):
  - memory_analysis: per-device argument/output/temp bytes (fits-in-HBM proof)
  - cost_analysis at full depth, plus depth-2/depth-4 variants for the
    while-body cost extrapolation (docs/design.md §7)
  - per-device collective bytes parsed from the post-SPMD HLO
    (trip-count-weighted; launch/hlo_analysis.py)

The FIRST two lines of this file set XLA_FLAGS before any jax import so the
CPU platform exposes 512 placeholder devices; smoke tests and benchmarks
never import this module and keep seeing 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
"""
import argparse
import dataclasses
import json
import sys
import traceback
from pathlib import Path

import jax

from .. import obs
from ..configs import LaneConfig, cell_matrix, get_arch, get_shape
from ..core import api
from ..core.elastic import TrainState
from ..sharding.params import cache_shardings, param_shardings
from ..sharding.rules import ShardingRules
from .hlo_analysis import collective_bytes, summarize
from .mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# TPU v5e hardware model (roofline constants; see docs/design.md §7)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per chip


def depth_variant(cfg, depth_periods: int):
    plen = len(cfg.pattern)
    kw = dict(num_layers=depth_periods * plen)
    if cfg.encoder_layers:
        kw["encoder_layers"] = depth_periods
    return dataclasses.replace(cfg, **kw)


def build_cell(cfg, shape, mesh, lane, scan_unroll=False, strategy="tp"):
    rules = ShardingRules(mesh, cfg, shape, strategy=strategy)
    model = api.build(cfg, shape, lane, rules, scan_unroll=scan_unroll)
    specs = model.input_specs()
    bshard = api.batch_shardings(specs, rules)
    aparams = model.abstract_params()
    pshard = param_shardings(aparams, rules)
    return model, rules, specs, bshard, aparams, pshard


def lower_cell(cfg, shape, mesh, lane, scan_unroll=False, strategy="tp"):
    """Returns (lowered, compiled).  Never allocates device memory."""
    model, rules, specs, bshard, aparams, pshard = build_cell(
        cfg, shape, mesh, lane, scan_unroll=scan_unroll, strategy=strategy)
    scalar = None if mesh is None else jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec())

    if shape.kind == "train":
        state_spec = model.abstract_state()
        state_shard = TrainState(pshard, scalar, scalar)
        pm = specs.pop("probe_mask")
        bshard = {k: v for k, v in bshard.items() if k != "probe_mask"}
        fn = jax.jit(model.train_step,
                     in_shardings=(state_shard, bshard, scalar),
                     donate_argnums=(0,))
        lowered = fn.lower(state_spec,
                           {k: v for k, v in specs.items()}, pm)
    elif shape.kind == "prefill":
        fn = jax.jit(model.prefill_step,
                     in_shardings=(pshard, bshard))
        lowered = fn.lower(aparams, specs)
    else:  # decode
        acaches = model.abstract_caches()
        cshard = cache_shardings(acaches, model.rules)
        fn = jax.jit(model.decode_step,
                     in_shardings=(pshard, bshard["tokens"], cshard, scalar),
                     donate_argnums=(2,))
        lowered = fn.lower(aparams, specs["tokens"], acaches,
                           specs["cache_len"])
    compiled = lowered.compile()
    return lowered, compiled


def analyze(compiled):
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem[f] = getattr(ma, f, None)
    hlo = compiled.as_text()
    coll_total, ops = collective_bytes(hlo)
    return {
        "flops": ca.get("flops", 0.0),
        "bytes_accessed": ca.get("bytes accessed", 0.0),
        "transcendentals": ca.get("transcendentals", 0.0),
        "memory": mem,
        "collective_bytes": coll_total,
        "collectives": summarize(ops),
    }


def add_depth_extrapolation(rec, cfg, shape, mesh, lane, strategy="tp"):
    """Depth-2/4 *unrolled* compiles -> exact per-period cost slope.

    The full-depth module keeps lax.scan (memory/collective truth), but its
    cost_analysis counts the body once; the unrolled shallow variants give
    cost(P) = base + P * per_period exactly (docs/design.md §7).
    """
    for d in (2, 4):
        dc = depth_variant(cfg, d)
        _, comp_d = lower_cell(dc, shape, mesh, lane, scan_unroll=True,
                               strategy=strategy)
        rec[f"depth{d}"] = analyze(comp_d)
        del comp_d
    P = cfg.num_periods
    f2, f4 = rec["depth2"]["flops"], rec["depth4"]["flops"]
    b2, b4 = (rec["depth2"]["bytes_accessed"],
              rec["depth4"]["bytes_accessed"])
    rec["extrapolated"] = {
        "flops": f2 + (f4 - f2) / 2.0 * (P - 2),
        "bytes_accessed": b2 + (b4 - b2) / 2.0 * (P - 2),
        "periods": P,
        "per_period_flops": (f4 - f2) / 2.0,
    }


def update_depth(arch: str, shape_name: str, lane: LaneConfig, out_dir: Path):
    """Recompute only the depth variants of an existing cell JSON."""
    out = out_dir / f"{arch}__{shape_name}__single.json"
    rec = json.loads(out.read_text())
    if rec.get("status") != "ok":
        return rec
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=False)
    t0 = obs.monotonic()
    try:
        add_depth_extrapolation(rec, cfg, shape, mesh, lane)
        rec["depth_mode"] = "unrolled"
    except Exception as e:  # noqa: BLE001
        rec["depth_error"] = f"{type(e).__name__}: {e}"
    rec["depth_elapsed_s"] = round(obs.monotonic() - t0, 1)
    out.write_text(json.dumps(rec, indent=1))
    return rec


def run_cell(arch: str, shape_name: str, mesh_kind: str, lane: LaneConfig,
             out_dir: Path, force=False, depth_variants=True,
             strategy="tp"):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    suffix = "" if strategy == "tp" else f"+{strategy}"
    if lane.fused_probes:
        suffix += "+fused"
    out = out_dir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())
    t0 = obs.monotonic()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "strategy": strategy,
           "mesh_shape": dict(zip(mesh.axis_names,
                                  (int(s) for s in mesh.devices.shape))),
           "lane": lane.lane, "status": "ok"}
    try:
        lowered, compiled = lower_cell(cfg, shape, mesh, lane,
                                       strategy=strategy)
        rec["full"] = analyze(compiled)
        rules = ShardingRules(mesh, cfg, shape, strategy=strategy)
        rec["attn_plan"] = dataclasses.asdict(rules.attn)
        rec["moe_plan"] = rules.moe
        del lowered, compiled
        if depth_variants and mesh_kind == "single":
            add_depth_extrapolation(rec, cfg, shape, mesh, lane,
                                    strategy=strategy)
    except Exception as e:  # noqa: BLE001 - record the failure and move on
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=20)
    rec["elapsed_s"] = round(obs.monotonic() - t0, 1)
    out_dir.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--lane", default="elastic_zo")
    ap.add_argument("--no-depth-variants", action="store_true")
    ap.add_argument("--strategy", default="tp",
                    choices=["tp", "fsdp", "serve"])
    ap.add_argument("--fused", action="store_true",
                    help="fused antithetic-pair forward")
    ap.add_argument("--update-depth", action="store_true",
                    help="recompute only depth variants of existing cells")
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args(argv)

    lane = LaneConfig(lane=args.lane, fused_probes=args.fused)
    out_dir = Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    cells = []
    if args.all:
        for a, s, run, why in cell_matrix():
            if run:
                cells.append((a, s))
            else:
                print(f"SKIP {a} x {s}: {why}")
    else:
        if not (args.arch and args.shape):
            raise SystemExit("--arch/--shape or --all")
        cells = [(args.arch, args.shape)]

    # small cells first for early signal
    def cell_cost(c):
        cfg, sh = get_arch(c[0]), get_shape(c[1])
        return cfg.param_count() * (sh.seq_len if sh.kind != "decode" else 1)
    cells.sort(key=cell_cost)

    failures = 0
    if args.update_depth:
        for a, s in cells:
            rec = update_depth(a, s, lane, out_dir)
            ex = rec.get("extrapolated", {})
            err = rec.get("depth_error", "")
            print(f"DEPTH {a} x {s}: flops={ex.get('flops', 0):.3e} "
                  f"per_period={ex.get('per_period_flops', 0):.3e} "
                  f"{err} ({rec.get('depth_elapsed_s')}s)", flush=True)
            failures += bool(err)
        print(f"\ndone; failures={failures}")
        return 1 if failures else 0
    for a, s in cells:
        for mk in meshes:
            rec = run_cell(a, s, mk, lane, out_dir, force=args.force,
                           depth_variants=not args.no_depth_variants,
                           strategy=args.strategy)
            st = rec["status"]
            if st != "ok":
                failures += 1
                print(f"FAIL {a} x {s} x {mk}: {rec.get('error')}",
                      flush=True)
            else:
                f = rec.get("extrapolated", rec["full"]).get("flops", 0)
                cb = rec["full"]["collective_bytes"]
                tmp = rec["full"]["memory"].get("temp_size_in_bytes")
                print(f"OK   {a} x {s} x {mk}: flops/dev={f:.3e} "
                      f"coll/dev={cb:.3e}B temp={tmp} "
                      f"({rec['elapsed_s']}s)", flush=True)
    print(f"\ndone; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
