"""Post-SPMD HLO analysis: collective-byte accounting with while-loop
trip-count awareness.

``compiled.cost_analysis()`` counts while bodies once (docs/design.md §7), so we
parse the compiled HLO text ourselves: track which computation each
collective lives in, recover each while's trip count from its condition
computation's integer constant, and multiply.

Byte conventions (per device, ring algorithms):
  all-gather        out_bytes * (n-1)/n
  all-reduce        2 * out_bytes * (n-1)/n
  reduce-scatter    out_bytes * (n-1)
  all-to-all        out_bytes * (n-1)/n
  collective-permute out_bytes
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclass
class CollectiveOp:
    kind: str
    bytes_moved: float      # per device, trip-count-weighted
    group: int
    computation: str
    trips: int


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        # computation headers are single lines: `%name (args) -> type {`
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$", s)
        if m and not s.startswith("ROOT"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


def _while_info(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """Map body-computation name -> trip count (from condition constants)."""
    body_trips: Dict[str, int] = {}
    for lines in comps.values():
        for s in lines:
            if " while(" not in s:
                continue
            mb = re.search(r"body=%?([\w.\-]+)", s)
            mc = re.search(r"condition=%?([\w.\-]+)", s)
            if not mb or not mc:
                continue
            trips = 1
            cond = comps.get(mc.group(1), [])
            consts = []
            for cl in cond:
                for cm in re.finditer(r"constant\((\d+)\)", cl):
                    consts.append(int(cm.group(1)))
            if consts:
                trips = max(consts)
            body_trips[mb.group(1)] = max(trips, 1)
    return body_trips


def _callers_closure(comps, body_trips):
    """Propagate trip counts through nested calls/whiles (one level deep
    nesting is enough for our programs, but do a small fixpoint anyway)."""
    # map computation -> multiplier
    mult = defaultdict(lambda: 1)
    for body, t in body_trips.items():
        mult[body] = t
    # find calls from while bodies into other computations (fusions excluded:
    # collectives never live inside fusions)
    for _ in range(3):
        for name, lines in comps.items():
            for s in lines:
                m = re.search(r"(?:calls|body)=%?([\w.\-]+)", s)
                if m and m.group(1) in comps and mult[name] > 1:
                    callee = m.group(1)
                    if callee not in body_trips:
                        mult[callee] = max(mult[callee], mult[name])
    return mult


def collective_bytes(hlo: str) -> Tuple[float, List[CollectiveOp]]:
    """Total per-device collective bytes (trip-weighted) + op list."""
    comps = _split_computations(hlo)
    body_trips = _while_info(comps)
    mult = _callers_closure(comps, body_trips)
    ops: List[CollectiveOp] = []
    for cname, lines in comps.items():
        trips = mult[cname]
        for s in lines:
            for kind in _COLLECTIVES:
                token = f" {kind}("
                start_token = f" {kind}-start("
                if token not in s and start_token not in s:
                    continue
                # result type is on the left of ' = '
                body = s.split(" = ")[1] if " = " in s else s
                out_b = _shape_bytes(body.split("(")[0])
                n = _group_size(s)
                if n <= 1:
                    continue
                if kind == "all-gather":
                    b = out_b * (n - 1) / n
                elif kind == "all-reduce":
                    b = 2 * out_b * (n - 1) / n
                elif kind == "reduce-scatter":
                    b = out_b * (n - 1)
                elif kind == "all-to-all":
                    b = out_b * (n - 1) / n
                else:
                    b = out_b
                ops.append(CollectiveOp(kind, b * trips, n, cname, trips))
                break
    total = sum(o.bytes_moved for o in ops)
    return total, ops


def summarize(ops: List[CollectiveOp]) -> Dict[str, Dict[str, float]]:
    by_kind: Dict[str, Dict[str, float]] = {}
    for o in ops:
        d = by_kind.setdefault(o.kind, {"count": 0, "bytes": 0.0})
        d["count"] += o.trips
        d["bytes"] += o.bytes_moved
    return by_kind
