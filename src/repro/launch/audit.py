import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Collective audit: the §Perf microscope.

Lowers one (arch x shape x strategy) cell and prints the top collectives by
per-device bytes (trip-weighted), so each hillclimb iteration names the op
it intends to kill before changing anything.

  PYTHONPATH=src python -m repro.launch.audit --arch llama3-8b \
      --shape train_4k --strategy tp [--top 15]
"""
import argparse

from ..configs import LaneConfig, get_arch, get_shape
from .dryrun import lower_cell
from .hlo_analysis import collective_bytes
from .mesh import make_production_mesh


def audit(arch: str, shape_name: str, strategy: str = "tp", top: int = 15,
          multi_pod: bool = False, lane: str = "elastic_zo"):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    _, compiled = lower_cell(cfg, shape, mesh, LaneConfig(lane=lane),
                             strategy=strategy)
    total, ops = collective_bytes(compiled.as_text())
    ops.sort(key=lambda o: -o.bytes_moved)
    print(f"total per-device collective bytes: {total:.3e} "
          f"({total/50e9*1e3:.1f} ms @50GB/s)")
    for o in ops[:top]:
        print(f"  {o.bytes_moved:10.3e}B  {o.kind:18s} group={o.group:4d} "
              f"trips={o.trips:4d}  in {o.computation[:60]}")
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    print(f"flops/dev={ca.get('flops', 0):.3e}  "
          f"bytes/dev={ca.get('bytes accessed', 0):.3e}  "
          f"temp={ma.temp_size_in_bytes/1e9:.2f}GB  "
          f"args={ma.argument_size_in_bytes/1e9:.2f}GB")
    return total, ops


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--strategy", default="tp")
    ap.add_argument("--lane", default="elastic_zo")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--multi", action="store_true")
    args = ap.parse_args(argv)
    audit(args.arch, args.shape, args.strategy, args.top, args.multi,
          args.lane)


if __name__ == "__main__":
    main()
