"""Fleet training launcher: simulated edge swarm with chaos injection.

``python -m repro.launch.fleet --arch llama3-8b --smoke --workers 8 \
      --dropout 0.2 --steps 20``

Runs N in-process workers against the seed-ledger protocol
(repro.fleet, docs/fleet.md): per-step scalar records for the ZO half,
error-feedback int8 payloads for the BP tail, deterministic
dropout/straggler chaos, optional crash/rejoin via ledger replay
(--crash worker:step:down). Exits non-zero if any worker's parameters
diverge from the coordinator's canon — the run is its own consistency
check.

``--lane int8`` runs the ElasticZO-INT8 lane (Alg. 2) instead: the
paper's LeNet-5 on deterministic glyphs, integer-only updates, 9-byte
ledger probes (record v2, docs/fleet.md), the same chaos matrix — and
additionally self-verifies the whole run bit-exact against the
single-process int8 reference (fleet/reference.py) before exiting.

``--byzantine 3:sign_flip,5:inflate:100`` puts deterministic attackers
on the named workers (fleet/adversary.py: inflate, sign_flip, freeload,
collude, seed_lie, stale_replay); ``--robust`` arms the Byzantine-robust
commit filter + quarantine (fleet/robust.py, commit v2 on the wire).
The int8 self-verification covers the Byzantine path too: the reference
re-derives every filter verdict from the realized arrival masks.

``--topology gossip`` removes the coordinator entirely: peers exchange
records epidemically (fleet/gossip.py, ``--gossip-fanout`` /
``--gossip-rounds``) and every peer closes each step independently via
the deterministic leaderless commit rule — the run exits non-zero
unless every surviving peer is bit-identical. ``--partition lo:hi:w+w``
schedules a temporary network split (the listed workers vs the rest);
the majority side keeps committing, the minority stalls and reconciles
at heal.
"""
from __future__ import annotations

import argparse
import sys


import jax
import jax.numpy as jnp

from .. import obs
from ..configs import (FleetConfig, GossipConfig, LaneConfig, RobustConfig,
                       ShapeConfig, get_arch, reduced)
from ..core import api
from ..data.synthetic import token_batch
from ..fleet import (make_int8_probe_fn, make_reference_step,
                     parse_byzantine, reference_state, run_fleet)
from ..sharding.rules import ShardingRules
from ..train.train_loop import LoopConfig, run


def _parse_partitions(ap, args):
    """'lo:hi:w+w+w,...' -> ((lo, hi, group_bitmask), ...)."""
    parts = []
    for p in args.partition.split(","):
        if not p:
            continue
        bits = p.split(":")
        if len(bits) != 3:
            ap.error(f"--partition entry {p!r} must be lo:hi:w+w+w")
        try:
            lo, hi = int(bits[0]), int(bits[1])
            group = 0
            for w in bits[2].split("+"):
                wi = int(w)
                if not 0 <= wi < args.workers:
                    ap.error(f"--partition worker {wi} out of range for "
                             f"--workers {args.workers}")
                group |= 1 << wi
        except ValueError:
            ap.error(f"--partition entry {p!r} must be lo:hi:w+w+w")
        parts.append((lo, hi, group))
    return tuple(parts)


def _parse_crashes(ap, args):
    crashes = []
    for c in args.crash.split(","):
        if not c:
            continue
        parts = c.split(":")
        if len(parts) != 3:
            ap.error(f"--crash entry {c!r} must be worker:step:down")
        w, cs, down = (int(x) for x in parts)
        if not 0 <= w < args.workers:
            ap.error(f"--crash worker {w} out of range for "
                     f"--workers {args.workers}")
        if cs < 0 or down < 1:
            ap.error(f"--crash entry {c!r}: step must be >= 0, down >= 1")
        crashes.append((w, cs, down))
    return tuple(crashes)


def lenet_int8_fleet_setup(bp_tail_layers: int = 1, probes: int = 1,
                           batch: int = 8, seed: int = 0):
    """LeNet-5 int8 fleet pieces: (params, lane, partition_fn, probe_fn,
    batch_fn). The one assembly of the paper's int8 deployment — the CLI
    below and benchmarks/bench_fleet.py share it. ``bp_tail_layers``
    counts trailing FC layers (paper: ZO-Feat-Cls1/2 = 1/2; 0 = Full-ZO
    INT8)."""
    from ..core.int8 import quant_from_float
    from ..data.synthetic import glyphs
    from ..models import lenet
    if not 0 <= bp_tail_layers <= 2:
        raise ValueError("int8 lane supports 0..2 tail FCs, got "
                         f"{bp_tail_layers}")
    c = 5 - bp_tail_layers
    tail_fcs = [("fc2", "fc2_in"), ("fc3", "fc3_in")][2 - bp_tail_layers:]
    lane = LaneConfig(lane="elastic_zo_int8", zo_num_probes=probes)
    partition_fn = lambda p, c=c: lenet.partition_at(p, c)  # noqa: E731
    probe_fn = make_int8_probe_fn(lenet.lenet5_forward_int8, lane,
                                  partition_fn, tail_fcs)
    params = lenet.init_lenet5_int8(jax.random.key(seed))

    def batch_fn(step):
        xs, ys = glyphs(batch, seed=seed + 1, start=step * batch)
        return {"x": quant_from_float(jnp.asarray(xs)),
                "y": jnp.asarray(ys)}

    return params, lane, partition_fn, probe_fn, batch_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="LM arch (fp32 lanes; default llama3-8b)")
    ap.add_argument("--lane", default="elastic_zo",
                    choices=["elastic_zo", "full_zo", "int8"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--probes-per-worker", type=int, default=1)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--bp-tail-layers", type=int, default=1)
    ap.add_argument("--lr", type=float, default=None,
                    help="ZO learning rate (fp32 lanes; default 1e-2)")
    ap.add_argument("--eps", type=float, default=None,
                    help="SPSA eps (fp32 lanes; default 1e-3)")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-record transport loss probability")
    ap.add_argument("--max-delay", type=int, default=0,
                    help="max record delivery delay (virtual ticks)")
    ap.add_argument("--deadline", type=int, default=0,
                    help="coordinator per-step wait (virtual ticks)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--snapshot-every", type=int, default=10)
    ap.add_argument("--crash", default="",
                    help="worker:step:down triples, comma-separated, e.g. "
                         "'3:5:4' = worker 3 dies at step 5 for 4 steps")
    ap.add_argument("--byzantine", default="",
                    help="worker:attack[:amp] triples, comma-separated, "
                         "e.g. '3:sign_flip,5:inflate:100' "
                         "(fleet/adversary.py)")
    ap.add_argument("--robust", action="store_true",
                    help="arm the Byzantine-robust commit filter + "
                         "quarantine (fleet/robust.py; commit v2)")
    ap.add_argument("--robust-k-mad", type=float, default=6.0,
                    help="scalar filter band half-width, in MADs")
    ap.add_argument("--robust-mode", default="mask",
                    choices=["mask", "clip"],
                    help="reject out-of-band probes, or clip their "
                         "loss-diffs to the band")
    ap.add_argument("--topology", default="star",
                    choices=["star", "gossip"],
                    help="star: a coordinator closes every step; gossip: "
                         "leaderless — every peer closes independently "
                         "via the deterministic commit rule "
                         "(fleet/gossip.py)")
    ap.add_argument("--gossip-fanout", type=int, default=2,
                    help="peers contacted per epidemic push round")
    ap.add_argument("--gossip-rounds", type=int, default=2,
                    help="push rounds per step (anti-entropy then runs "
                         "the component to quiescence)")
    ap.add_argument("--partition", default="",
                    help="lo:hi:w+w+w windows, comma-separated: during "
                         "steps [lo,hi) the listed workers split from "
                         "the rest; the majority side keeps committing "
                         "(gossip topology only)")
    ap.add_argument("--no-verify-reference", action="store_true",
                    help="skip the single-process reference re-run "
                         "(int8 lane verifies it by default)")
    ap.add_argument("--seed", type=int, default=0)
    obs.add_observability_args(ap)
    args = ap.parse_args(argv)
    obs.configure_from_args(args)

    crashes = _parse_crashes(ap, args)
    try:
        byzantine = parse_byzantine(args.byzantine)
    except ValueError as e:
        ap.error(str(e))
    robust = RobustConfig(mode=args.robust_mode,
                          k_mad=args.robust_k_mad) if args.robust else None
    partitions = _parse_partitions(ap, args)
    if partitions and args.topology != "gossip":
        ap.error("--partition needs --topology gossip (the star "
                 "coordinator cannot survive a split)")
    try:
        gossip = GossipConfig(fanout=args.gossip_fanout,
                              rounds=args.gossip_rounds,
                              partitions=partitions) \
            if args.topology == "gossip" else None
        fleet_cfg = FleetConfig(
            num_workers=args.workers,
            probes_per_worker=args.probes_per_worker,
            dropout=args.dropout, max_delay=args.max_delay,
            deadline=args.deadline, chaos_seed=args.chaos_seed,
            snapshot_every=args.snapshot_every, crashes=crashes,
            byzantine=byzantine, robust=robust,
            topology=args.topology, gossip=gossip)
    except ValueError as e:
        ap.error(str(e))

    loss_fn = None
    probe_fn = None
    if args.lane == "int8":
        # the int8 lane is integer-only LeNet-5 — reject fp32-lane flags
        # instead of silently ignoring them
        for flag, val in (("--lr", args.lr), ("--eps", args.eps),
                          ("--arch", args.arch)):
            if val is not None:
                ap.error(f"{flag} does not apply to --lane int8 "
                         "(integer-only LeNet-5; Alg. 2 knobs live in "
                         "LaneConfig.int8_*)")
        params, lane, partition_fn, probe_fn, batch_fn = \
            lenet_int8_fleet_setup(args.bp_tail_layers,
                                   args.probes_per_worker, args.batch,
                                   args.seed)
        desc = "lenet5-int8"
    else:
        if args.lr is None:
            args.lr = 1e-2
        if args.eps is None:
            args.eps = 1e-3
        cfg = get_arch(args.arch or "llama3-8b")
        if args.smoke:
            cfg = reduced(cfg)
        lane = LaneConfig(lane=args.lane, bp_tail_layers=args.bp_tail_layers,
                          zo_num_probes=args.probes_per_worker,
                          learning_rate=args.lr, zo_eps=args.eps)
        shape = ShapeConfig("fleet_cli", seq_len=args.seq,
                            global_batch=args.batch, kind="train")
        model = api.build(cfg, shape, lane, ShardingRules(None, cfg, shape))
        params = model.init(jax.random.key(args.seed))
        loss_fn = model.loss_fn
        partition_fn = None
        desc = cfg.name

        def batch_fn(step):
            x, y, m = token_batch(args.batch, args.seq, cfg.vocab_size,
                                  seed=args.seed + 1, step=step)
            return {"tokens": jnp.asarray(x), "labels": jnp.asarray(y),
                    "mask": jnp.asarray(m)}

    base_seed = jax.random.key_data(jax.random.key(args.seed + 1))
    obs.log("fleet", f"{desc}: {args.workers} workers x "
            f"{args.probes_per_worker} probes, lane={args.lane}, "
            f"topology={args.topology}, dropout={args.dropout}, "
            f"crashes={crashes or 'none'}, "
            f"partitions={args.partition or 'none'}, "
            f"byzantine={args.byzantine or 'none'}, "
            f"robust={'on' if robust else 'off'}")
    res = run_fleet(loss_fn, params, lane, fleet_cfg, batch_fn,
                    steps=args.steps, base_seed=base_seed,
                    partition_fn=partition_fn, probe_fn=probe_fn,
                    log_every=max(args.steps // 10, 1))
    for e in res.coordinator.events:
        obs.log("fleet", f"event: {e}")
    s = res.stats
    n_records = sum(len(t) for t in res.ledger.records.values())
    per_worker_step = s["ledger_bytes_zo"] / max(n_records, 1)
    # step 0 always holds >= 1 record: the coordinator force-accepts the
    # earliest arrival when everything misses the deadline ("a step is
    # never empty", fleet/coordinator.py)
    some_rec = next(iter(res.ledger.records[0].values()))
    obs.log("fleet", f"done: {s['steps']} steps, wall {s['wall_s']:.1f}s; "
          f"ZO wire {s['ledger_bytes_zo']}B "
          f"({per_worker_step:.1f}B/record, "
          f"{some_rec.zo_probe_nbytes}B/probe), tail wire "
          f"{s['ledger_bytes_tail']}B, catch-up {s['bytes_catchup']}B; "
          f"dropped {s['n_dropped']}, straggled {s['n_straggled']}, "
          f"redelivered {s['n_redelivered']}, "
          f"rejoins {s['n_catchups']}; rejected {s['n_rejected']}, "
          f"filtered probes {s['n_filtered_probes']}, "
          f"quarantines {s['n_quarantines']}"
          + (f"; gossip wire {s['bytes_gossip']}B, "
             f"reconciles {s['n_reconciles']}"
             if s["topology"] == "gossip" else ""))

    failed = False
    if args.lane == "int8" and some_rec.zo_probe_nbytes > 9:
        obs.log("fleet", "ERROR int8 ZO probe entry is "
                f"{some_rec.zo_probe_nbytes}B on the wire (> 9B budget)",
                level="error")
        failed = True

    n_exact = 0
    n_checked = 0
    canon_leaves = jax.tree.leaves(res.params)
    canon_struct = jax.tree.structure(res.params)
    for w in res.workers:
        if not w.alive:
            # crash scheduled past the end of the run: nothing to verify
            obs.log("fleet", f"note: worker {w.id} still down at end of run")
            continue
        ok = (jax.tree.structure(w.params) == canon_struct
              and all(jnp.array_equal(a, b) for a, b in
                      zip(jax.tree.leaves(w.params), canon_leaves)))
        if not ok:
            obs.log("fleet", f"ERROR worker {w.id} diverged from the canon",
                    level="error")
            failed = True
        n_exact += ok
        n_checked += 1
    who = "the coordinator" if args.topology == "star" \
        else "every other surviving peer (leaderless canon)"
    obs.log("fleet", f"{n_exact}/{n_checked} live workers bit-exact with "
            f"{who} at step {res.coordinator.step}")

    if args.lane == "int8" and not args.no_verify_reference:
        # replay the realized masks through the single-process reference
        # — the whole chaos run must reproduce bit-exactly. Byzantine
        # runs are driven by the ARRIVAL masks; the reference re-derives
        # validation, quarantine, and the filter itself.
        byz_path = byzantine or robust is not None
        drive = res.arrival_masks if byz_path else res.masks
        step_fn = make_reference_step(None, res.schema, probe_fn=probe_fn)
        state = reference_state(params, res.schema, base_seed)
        loop = LoopConfig(total_steps=args.steps, log_every=0,
                          n_probes=res.schema.n_probes,
                          mask_fn=lambda t: drive[t], jit=False)
        state, _ = run(step_fn, state, batch_fn, loop)
        ref_leaves = jax.tree.leaves(state.params["model"])
        ok = all(jnp.array_equal(a, b)
                 for a, b in zip(ref_leaves, canon_leaves))
        if ok:
            obs.log("fleet", "single-process int8 reference: bit-exact")
        else:
            obs.log("fleet", "ERROR fleet diverged from the "
                    "single-process int8 reference", level="error")
            failed = True

    obs.write_outputs(args)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
