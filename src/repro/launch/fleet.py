"""Fleet training launcher: simulated edge swarm with chaos injection.

``python -m repro.launch.fleet --arch llama3-8b --smoke --workers 8 \
      --dropout 0.2 --steps 20``

Runs N in-process workers against the seed-ledger protocol
(repro.fleet, docs/fleet.md): per-step scalar records for the ZO half,
error-feedback int8 payloads for the BP tail, deterministic
dropout/straggler chaos, optional crash/rejoin via ledger replay
(--crash worker:step:down). Exits non-zero if any worker's parameters
diverge from the coordinator's canon — the run is its own consistency
check.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from ..configs import FleetConfig, LaneConfig, ShapeConfig, get_arch, reduced
from ..core import api
from ..data.synthetic import token_batch
from ..fleet import run_fleet
from ..sharding.rules import ShardingRules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--lane", default="elastic_zo",
                    choices=["elastic_zo", "full_zo"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--probes-per-worker", type=int, default=1)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--bp-tail-layers", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-record transport loss probability")
    ap.add_argument("--max-delay", type=int, default=0,
                    help="max record delivery delay (virtual ticks)")
    ap.add_argument("--deadline", type=int, default=0,
                    help="coordinator per-step wait (virtual ticks)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--snapshot-every", type=int, default=10)
    ap.add_argument("--crash", default="",
                    help="worker:step:down triples, comma-separated, e.g. "
                         "'3:5:4' = worker 3 dies at step 5 for 4 steps")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    lane = LaneConfig(lane=args.lane, bp_tail_layers=args.bp_tail_layers,
                      zo_num_probes=args.probes_per_worker,
                      learning_rate=args.lr, zo_eps=args.eps)
    crashes = []
    for c in args.crash.split(","):
        if not c:
            continue
        parts = c.split(":")
        if len(parts) != 3:
            ap.error(f"--crash entry {c!r} must be worker:step:down")
        w, cs, down = (int(x) for x in parts)
        if not 0 <= w < args.workers:
            ap.error(f"--crash worker {w} out of range for "
                     f"--workers {args.workers}")
        if cs < 0 or down < 1:
            ap.error(f"--crash entry {c!r}: step must be >= 0, down >= 1")
        crashes.append((w, cs, down))
    crashes = tuple(crashes)
    fleet_cfg = FleetConfig(
        num_workers=args.workers, probes_per_worker=args.probes_per_worker,
        dropout=args.dropout, max_delay=args.max_delay,
        deadline=args.deadline, chaos_seed=args.chaos_seed,
        snapshot_every=args.snapshot_every, crashes=crashes)

    shape = ShapeConfig("fleet_cli", seq_len=args.seq,
                        global_batch=args.batch, kind="train")
    model = api.build(cfg, shape, lane, ShardingRules(None, cfg, shape))
    params = model.init(jax.random.key(args.seed))
    base_seed = jax.random.key_data(jax.random.key(args.seed + 1))

    def batch_fn(step):
        x, y, m = token_batch(args.batch, args.seq, cfg.vocab_size,
                              seed=args.seed + 1, step=step)
        return {"tokens": jnp.asarray(x), "labels": jnp.asarray(y),
                "mask": jnp.asarray(m)}

    print(f"[fleet] {cfg.name}: {args.workers} workers x "
          f"{args.probes_per_worker} probes, lane={args.lane}, "
          f"dropout={args.dropout}, crashes={crashes or 'none'}")
    res = run_fleet(model.loss_fn, params, lane, fleet_cfg, batch_fn,
                    steps=args.steps, base_seed=base_seed,
                    log_every=max(args.steps // 10, 1))
    for e in res.coordinator.events:
        print(f"[fleet] event: {e}")
    s = res.stats
    n_records = sum(len(t) for t in res.ledger.records.values())
    per_worker_step = s["ledger_bytes_zo"] / max(n_records, 1)
    print(f"[fleet] done: {s['steps']} steps, wall {s['wall_s']:.1f}s; "
          f"ZO wire {s['ledger_bytes_zo']}B "
          f"({per_worker_step:.1f}B/record), tail wire "
          f"{s['ledger_bytes_tail']}B, catch-up {s['bytes_catchup']}B; "
          f"dropped {s['n_dropped']}, straggled {s['n_straggled']}, "
          f"rejoins {s['n_catchups']}")

    diverged = False
    n_checked = 0
    canon_leaves = jax.tree.leaves(res.params)
    canon_struct = jax.tree.structure(res.params)
    for w in res.workers:
        if not w.alive:
            # crash scheduled past the end of the run: nothing to verify
            print(f"[fleet] note: worker {w.id} still down at end of run")
            continue
        ok = (jax.tree.structure(w.params) == canon_struct
              and all(jnp.array_equal(a, b) for a, b in
                      zip(jax.tree.leaves(w.params), canon_leaves)))
        if not ok:
            print(f"[fleet] ERROR worker {w.id} diverged from the canon")
            diverged = True
        n_checked += 1
    if diverged:
        sys.exit(1)
    print(f"[fleet] {n_checked}/{args.workers} live workers bit-exact with "
          f"the coordinator at step {res.coordinator.step}")


if __name__ == "__main__":
    main()
