"""Production mesh factories.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices; real launches rely on the actual TPU
topology.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh helper (tests / elastic re-meshing)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
