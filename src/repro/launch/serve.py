"""Serving launcher: paged continuous-batching engine (or dense baseline).

``python -m repro.launch.serve --arch qwen3-4b --smoke --paged``

--paged drives repro.serve.Engine: paged KV pool, admission queue,
preemption, per-request sampling. Without it, the legacy dense
static-batch greedy loop runs (kept as the baseline; its cache growth now
uses the path-aware grow_dense_caches instead of a shape heuristic).
"""
from __future__ import annotations

import argparse

import numpy as np

from .. import obs
from ..configs import LaneConfig, ServeConfig, get_arch, reduced
from ..serve import Engine, SamplingParams, dense_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged continuous-batching engine")
    ap.add_argument("--batch", type=int, default=2,
                    help="number of requests")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="pool pages per layer (0 = auto-size)")
    ap.add_argument("--slots", type=int, default=0,
                    help="decode batch slots (0 = --batch)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    obs.add_observability_args(ap)
    args = ap.parse_args(argv)
    obs.configure_from_args(args)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    total = cfg.num_image_tokens + args.prompt_len + args.tokens
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)

    if not args.paged:
        if args.temperature != 0.0 or args.top_k or args.top_p != 1.0:
            ap.error("--temperature/--top-k/--top-p require --paged "
                     "(the dense baseline is greedy-only)")
        t0 = obs.monotonic()
        out = dense_generate(cfg, _init_params(cfg, total), prompts,
                             args.tokens)
        dt = obs.monotonic() - t0
        obs.log("serve", f"dense: {args.tokens} tok/seq x{args.batch} in "
                f"{dt:.2f}s ({args.batch * args.tokens / dt:.1f} tok/s)")
        obs.log("serve", f"sample: {out[0][:16]}")
        obs.write_outputs(args)
        return

    slots = args.slots or args.batch
    ps = args.page_size
    num_pages = args.num_pages or (
        1 + slots * (-(-(total + 1) // ps)))      # null + worst case/slot
    serve = ServeConfig(page_size=ps, num_pages=num_pages,
                        max_batch_slots=slots, max_seq_len=total,
                        max_new_tokens=args.tokens)
    eng = Engine(cfg, serve)
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              seed=args.seed)
    t0 = obs.monotonic()
    outs = eng.generate([list(p) for p in prompts], sampling, args.tokens)
    dt = obs.monotonic() - t0
    util = eng.page_utilization()
    n_tok = sum(len(o) for o in outs)
    obs.log("serve",
            f"paged: {n_tok} tokens across {args.batch} requests in "
            f"{dt:.2f}s ({n_tok / dt:.1f} tok/s, {eng.steps_run} engine "
            "steps)", tokens=n_tok, wall_s=dt, steps=eng.steps_run)
    obs.log("serve",
            f"pages: peak {util['peak_pages']}/{util['total_pages']} "
            f"({100 * util['peak_util']:.0f}%), mean "
            f"{100 * util['mean_util']:.0f}%")
    obs.log("serve", f"sample: {outs[0][:16]}")
    obs.write_outputs(args)


def _init_params(cfg, total):
    import jax
    from ..configs import ShapeConfig
    from ..core import api
    from ..sharding.rules import ShardingRules
    shape = ShapeConfig("cli_init", seq_len=total, global_batch=1,
                        kind="prefill")
    m = api.build(cfg, shape, LaneConfig(), ShardingRules(None, cfg, shape))
    return m.init(jax.random.key(0))


if __name__ == "__main__":
    main()
