"""Serving launcher: batched prefill + greedy decode loop.

``python -m repro.launch.serve --arch qwen3-4b --smoke --tokens 32``
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import LaneConfig, ShapeConfig, get_arch, reduced
from ..core import api
from ..sharding.rules import ShardingRules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    total = args.prompt_len + args.tokens
    lane = LaneConfig()
    pshape = ShapeConfig("cli_p", seq_len=total, global_batch=args.batch,
                         kind="prefill")
    dshape = ShapeConfig("cli_d", seq_len=total, global_batch=args.batch,
                         kind="decode")
    mp = api.build(cfg, pshape, lane, ShardingRules(None, cfg, pshape))
    md = api.build(cfg, dshape, lane, ShardingRules(None, cfg, dshape))
    params = mp.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                    (args.batch, args.prompt_len)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.encoder_layers:
        batch["frames"] = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
    if cfg.num_image_tokens:
        batch["img"] = jnp.zeros((args.batch, cfg.num_image_tokens, cfg.d_model),
                                 jnp.dtype(cfg.dtype))

    # prefill produces a cache sized for the *prompt*; decode steps then
    # extend it. For the CLI we allocate the full-length cache up front by
    # prefilling into `total`-sized shapes via right-aligned copy.
    t0 = time.time()
    nxt, caches = jax.jit(mp.prefill_step)(params, batch)
    print(f"[serve] prefill {args.prompt_len} tokens in {time.time()-t0:.2f}s")

    # grow cache buffers to `total` (prefill returns prompt-sized k/v)
    def grow(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == args.prompt_len + (
                cfg.num_image_tokens or 0):
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, total + (cfg.num_image_tokens or 0)
                      - leaf.shape[2])
            return jnp.pad(leaf, pad)
        return leaf
    caches = jax.tree.map(grow, caches)

    decode = jax.jit(md.decode_step, donate_argnums=(2,))
    out = [nxt]
    cur = args.prompt_len + (cfg.num_image_tokens or 0)
    t0 = time.time()
    for i in range(args.tokens - 1):
        nxt, caches = decode(params, nxt, caches, jnp.int32(cur))
        out.append(nxt)
        cur += 1
    toks_out = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"[serve] decoded {args.tokens} tokens/seq x{args.batch} "
          f"in {dt:.2f}s ({dt/max(args.tokens-1,1)*1000:.1f} ms/tok)")
    print("[serve] sample:", np.asarray(toks_out[0][:16]))


if __name__ == "__main__":
    main()
