"""LM training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs real steps on the available devices (CPU smoke scale by default, the
full production mesh when launched on a TPU slice). For the compile-only
512-way proof use ``repro.launch.dryrun``.
"""
from __future__ import annotations

import argparse


import jax
import jax.numpy as jnp

from .. import obs
from ..configs import LaneConfig, ShapeConfig, get_arch, reduced
from ..core import api
from ..data.synthetic import token_batch
from ..sharding.params import param_shardings
from ..sharding.rules import ShardingRules
from ..train.train_loop import LoopConfig, init_state, run
from .mesh import make_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--lane", default="elastic_zo",
                    choices=["elastic_zo", "full_zo", "full_bp"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--bp-tail-layers", type=int, default=1)
    ap.add_argument("--probes", type=int, default=1)
    ap.add_argument("--probe-drop", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--mesh", default="",
                    help="e.g. '2x2:data,model' to shard across local devices")
    ap.add_argument("--profile-phases", action="store_true",
                    help="time the engine's canonical step phases "
                         "(separately-jitted diagnostic programs with "
                         "device syncs; the production step is untouched)")
    obs.add_observability_args(ap)
    args = ap.parse_args(argv)
    obs.configure_from_args(args)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    lane = LaneConfig(lane=args.lane, bp_tail_layers=args.bp_tail_layers,
                      zo_num_probes=args.probes, learning_rate=args.lr,
                      zo_eps=args.eps)
    mesh = None
    if args.mesh:
        spec, axes = args.mesh.split(":")
        mesh = make_mesh(tuple(int(x) for x in spec.split("x")),
                         tuple(axes.split(",")))
    rules = ShardingRules(mesh, cfg, shape)
    model = api.build(cfg, shape, lane, rules)
    params = model.init(jax.random.key(0))
    pshard = param_shardings(model.abstract_params(), rules)
    if mesh is not None:
        params = jax.tree.map(jax.device_put, params, pshard)
    state = init_state(params, seed=0)

    def batch_fn(step):
        x, y, m = token_batch(args.batch, args.seq - cfg.num_image_tokens
                              if cfg.num_image_tokens else args.seq,
                              cfg.vocab_size, seed=1, step=step)
        b = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y),
             "mask": jnp.asarray(m)}
        if cfg.encoder_layers:
            b["frames"] = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
        if cfg.num_image_tokens:
            b["img"] = jnp.zeros((args.batch, cfg.num_image_tokens, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
        return b

    # n_probes is derived from the lane (LoopConfig.for_lane): the step
    # asserts the mask shape, so the two can never drift apart again
    loop = LoopConfig.for_lane(lane, total_steps=args.steps,
                               ckpt_dir=args.ckpt_dir,
                               log_every=max(args.steps // 10, 1),
                               probe_drop_rate=args.probe_drop)
    if args.profile_phases:
        from ..core import engine as eng
        phases = eng.profile_step_phases(
            eng.engine_for(lane, model.partition_fn
                           if hasattr(model, "partition_fn") else None),
            model.loss_fn, state, batch_fn(0))
        for name, us in phases.items():
            obs.log("train", f"phase {name:10s} {us:10.1f} us")

    state, history = run(model.train_step, state, batch_fn, loop,
                         param_shardings=pshard)
    obs.log("train", f"done at step {int(state.step)}; "
            f"logged {len(history)} loss points")
    obs.write_outputs(args)


if __name__ == "__main__":
    main()
