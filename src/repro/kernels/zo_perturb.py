"""Pallas TPU fused ZO perturb/update: theta' = theta + scale * z.

z is regenerated *inside* the kernel from the murmur-style counter hash
(core/prng.py) on the element's global flat index — the identical math, so
the Pallas path is bitwise-equal to the XLA path in interpret mode. HBM
traffic is exactly 1R + 1W of theta; z never exists outside VREGs. This is
the roofline-optimal form of Alg. 1's PerturbParameters/ZOUpdateParameters
(the op is purely memory-bound, so eliminating the z stream is the whole
game; the paper's NEON implementation makes the same observation for CPU).

The int8 variant fuses Alg. 2's sparse-uniform perturbation with the clamp.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import prng

LANES = 128
SUBL = 8
BLOCK_ROWS = 64          # (64, 128) fp32 tile = 32KB VMEM


def _hash_block(row0, shape, seed, salt):
    """uint32 hash bits for a (rows, LANES) block starting at flat row row0."""
    r = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    idx = (row0.astype(jnp.uint32) + r) * np.uint32(LANES) + c
    h = idx * prng._PHI + jnp.asarray(salt, jnp.uint32)
    h = prng._fmix32(h ^ seed.astype(jnp.uint32))
    return prng._fmix32(h + seed.astype(jnp.uint32) * prng._M2)


def _normal_block(row0, shape, seed, salt):
    b1 = _hash_block(row0, shape, seed, 2 * salt + np.uint32(1))
    b2 = _hash_block(row0, shape, seed, 2 * salt + np.uint32(2))
    u1 = (b1 >> np.uint32(8)).astype(jnp.float32) * np.float32(2 ** -24) \
        + np.float32(2 ** -25)
    u2 = (b2 >> np.uint32(8)).astype(jnp.float32) * np.float32(2 ** -24)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(np.float32(2.0 * np.pi) * u2)


def _int8_noise_block(row0, shape, seed, salt, r_max, p_zero):
    """Alg. 2 sparse uniform int8 noise for a (rows, LANES) block —
    bitwise core/int8.int8_noise on the same flat layout."""
    bits_u = _hash_block(row0, shape, seed, 3 * salt + np.uint32(1))
    bits_m = _hash_block(row0, shape, seed, 3 * salt + np.uint32(2))
    u = (bits_u % (2 * r_max + 1).astype(jnp.uint32)).astype(jnp.int32) \
        - r_max.astype(jnp.int32)
    keep = (bits_m.astype(jnp.float32)
            < (1.0 - p_zero) * np.float32(2 ** 32)).astype(jnp.int32)
    return u * keep


def _perturb_kernel(seed_ref, salt_ref, scale_ref, t_ref, o_ref):
    rows = t_ref.shape[0]
    row0 = pl.program_id(0) * rows
    z = _normal_block(jnp.uint32(row0), t_ref.shape, seed_ref[0], salt_ref[0])
    o_ref[...] = (t_ref[...].astype(jnp.float32)
                  + scale_ref[0] * z).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("salt", "interpret"))
def zo_perturb(theta: jax.Array, seed: jax.Array, salt: int,
               scale: jax.Array, *, interpret: bool = False):
    """theta (+) scale*z, any shape; z from the global flat index.

    Equals ref.zo_perturb_ref bitwise in interpret mode. scale may be a
    traced scalar (eta*g for the fused update, +/-eps for perturbation).
    """
    shape, dtype = theta.shape, theta.dtype
    n = theta.size
    rows = -(-n // LANES)
    rows_pad = -(-rows // BLOCK_ROWS) * BLOCK_ROWS
    flat = jnp.zeros((rows_pad * LANES,), dtype).at[:n].set(theta.reshape(-1))
    grid = rows_pad // BLOCK_ROWS
    out = pl.pallas_call(
        _perturb_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANES), dtype),
        interpret=interpret,
    )(seed.reshape(1).astype(jnp.uint32),
      jnp.asarray([salt], jnp.uint32),
      jnp.asarray(scale, jnp.float32).reshape(1),
      flat.reshape(rows_pad, LANES))
    return out.reshape(-1)[:n].reshape(shape)


# ------------------------------------------------------------------ #
# int8 (Alg. 2): theta' = clamp(theta + k * m(.)u, -127, 127)
# ------------------------------------------------------------------ #
def _int8_kernel(seed_ref, salt_ref, k_ref, rmax_ref, pz_ref, t_ref, o_ref):
    rows = t_ref.shape[0]
    row0 = pl.program_id(0) * rows
    z = _int8_noise_block(jnp.uint32(row0), t_ref.shape, seed_ref[0],
                          salt_ref[0], rmax_ref[0], pz_ref[0])
    o_ref[...] = jnp.clip(t_ref[...].astype(jnp.int32) + k_ref[0] * z,
                          -127, 127).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("salt", "interpret"))
def int8_perturb(theta: jax.Array, seed: jax.Array, salt: int, k: jax.Array,
                 r_max: jax.Array, p_zero: jax.Array, *,
                 interpret: bool = False):
    shape = theta.shape
    n = theta.size
    rows = -(-n // LANES)
    rows_pad = -(-rows // BLOCK_ROWS) * BLOCK_ROWS
    flat = jnp.zeros((rows_pad * LANES,), jnp.int8).at[:n].set(theta.reshape(-1))
    out = pl.pallas_call(
        _int8_kernel,
        grid=(rows_pad // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)] * 5
        + [pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANES), jnp.int8),
        interpret=interpret,
    )(seed.reshape(1).astype(jnp.uint32), jnp.asarray([salt], jnp.uint32),
      jnp.asarray(k, jnp.int32).reshape(1),
      jnp.asarray(r_max, jnp.int32).reshape(1),
      jnp.asarray(p_zero, jnp.float32).reshape(1),
      flat.reshape(rows_pad, LANES))
    return out.reshape(-1)[:n].reshape(shape)
