"""Backend dispatch for the Pallas kernels.

On TPU the Pallas kernels run natively; on CPU (tests, this container's
dry-run) the pure-jnp refs are used, with ``interpret=True`` Pallas
execution available for correctness work. The public entry points keep one
signature regardless of backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .flash_attn import flash_attention as _pallas_flash_attention
from .int8_matmul import int8_matmul as _pallas_int8_matmul
from .paged_attn import paged_attention_step as _pallas_paged_attention_step
from .topk_mask import topk_topp_mask as _pallas_topk_topp_mask
from .zo_fused_replay import zo_fused_replay as _pallas_zo_fused_replay
from .zo_fused_replay import \
    zo_fused_replay_int8 as _pallas_zo_fused_replay_int8
from .zo_perturb import int8_perturb as _pallas_int8_perturb
from .zo_perturb import zo_perturb as _pallas_zo_perturb


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, force_pallas: bool = False,
                    interpret: bool = False):
    """Online-softmax attention, q/k/v [B,H,S,D] head-major — Pallas on
    TPU (S must be 128-aligned there), dense-softmax ref elsewhere."""
    if _on_tpu() or force_pallas:
        return _pallas_flash_attention(q, k, v, causal=causal, window=window,
                                       scale=scale, interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   scale=scale)


def int8_matmul(a, w, *, force_pallas: bool = False, interpret: bool = False):
    """(out int32, maxabs) — Pallas on TPU, ref elsewhere."""
    if _on_tpu() or force_pallas:
        M, K = a.shape
        _, N = w.shape
        if M % 128 or K % 128 or N % 128:
            # pad to MXU alignment; zeros are exact in integer arithmetic
            Mp, Kp, Np = (-(-M // 128) * 128, -(-K // 128) * 128,
                          -(-N // 128) * 128)
            ap = jnp.zeros((Mp, Kp), a.dtype).at[:M, :K].set(a)
            wp = jnp.zeros((Kp, Np), w.dtype).at[:K, :N].set(w)
            out, mx = _pallas_int8_matmul(ap, wp, interpret=interpret)
            return out[:M, :N], mx
        return _pallas_int8_matmul(a, w, interpret=interpret)
    return ref.int8_matmul_ref(a, w)


def zo_perturb(theta, seed, salt: int, scale, *, force_pallas: bool = False,
               interpret: bool = False):
    if _on_tpu() or force_pallas:
        return _pallas_zo_perturb(theta, seed, salt, scale,
                                  interpret=interpret)
    return ref.zo_perturb_ref(theta, seed, salt, jnp.asarray(scale))


def zo_fused_replay(theta, seeds, coeffs, salt: int, *,
                    force_pallas: bool = False, interpret: bool = False):
    """Apply S ledger steps of P (seed, coeff) ZO records in one pass.

    Pallas on TPU (single 1R+1W sweep over theta for the whole catch-up),
    ref elsewhere. Both paths share the canonical per-step accumulate-then-
    cast order, so live stepping (S=1 per step) and multi-step replay agree
    bitwise within a backend — the fleet's catch-up guarantee.
    """
    if _on_tpu() or force_pallas:
        return _pallas_zo_fused_replay(theta, seeds, coeffs, salt,
                                       interpret=interpret)
    return ref.zo_fused_replay_ref(theta, jnp.asarray(seeds, jnp.uint32),
                                   jnp.asarray(coeffs, jnp.float32), salt)


def zo_fused_replay_int8(theta, seeds, gs, salt: int, r_max: int, p_zero,
                         shift: int, *, force_pallas: bool = False,
                         interpret: bool = False):
    """int8-lane fused ledger replay: S steps x P (seed, ternary g)
    records in one pass over an int8 leaf. Integer arithmetic, so the
    Pallas kernel and the eager ref agree bitwise on every backend."""
    if _on_tpu() or force_pallas:
        return _pallas_zo_fused_replay_int8(theta, seeds, gs, salt,
                                            int(r_max), p_zero, int(shift),
                                            interpret=interpret)
    return ref.zo_fused_replay_int8_ref(
        theta, jnp.asarray(seeds, jnp.uint32), jnp.asarray(gs, jnp.int32),
        salt, int(r_max), p_zero, int(shift))


def int8_perturb(theta, seed, salt: int, k, r_max, p_zero, *,
                 force_pallas: bool = False, interpret: bool = False):
    if _on_tpu() or force_pallas:
        return _pallas_int8_perturb(theta, seed, salt, k, r_max, p_zero,
                                    interpret=interpret)
    return ref.int8_perturb_ref(theta, seed, salt, int(k), int(r_max), p_zero)


def paged_attention_step(q, k_new, v_new, k_pool, v_pool, page_table,
                         seq_lens, *, scale, window: int = 0,
                         force_pallas: bool = False,
                         interpret: bool = False):
    """Fused paged decode megastep — Pallas on TPU, write+gather+dense ref
    elsewhere. Returns (o, k_pool, v_pool): the token's K/V write rides
    inside the step (in-place via input_output_aliases on TPU), so callers
    never scatter into the pool themselves.

    The ref path is bitwise the dense decode attention (see
    ref.paged_attn_step_ref) so CPU serve output is exactly comparable to
    the dense cache path.
    """
    if _on_tpu() or force_pallas:
        return _pallas_paged_attention_step(
            q, k_new, v_new, k_pool, v_pool, page_table, seq_lens,
            scale=scale, window=window, interpret=interpret)
    return ref.paged_attn_step_ref(q, k_new, v_new, k_pool, v_pool,
                                   page_table, seq_lens, scale=scale,
                                   window=window)


def topk_topp_mask(logits, k, p, *, force_pallas: bool = False,
                   interpret: bool = False):
    """Sort-free top-k/top-p logit filter (threshold-refine selection).

    logits [B, V] f32; k [B] int32 (<=0 disables); p [B] f32 (>=1
    disables). Returns logits with filtered entries at NEG_INF. Pallas on
    TPU, jnp radix ref elsewhere — both replace the sampler's two
    full-vocab argsorts with a 4-round byte-radix descent; see
    ref.topk_topp_mask_ref for the keep-set contract and the one
    boundary-rounding caveat vs. the full-sort reference.
    """
    if _on_tpu() or force_pallas:
        return _pallas_topk_topp_mask(logits, k, p, interpret=interpret)
    return ref.topk_topp_mask_ref(logits, jnp.asarray(k, jnp.int32),
                                  jnp.asarray(p, jnp.float32))
