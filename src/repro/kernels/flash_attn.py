"""Pallas TPU flash attention (causal / windowed), online softmax.

The roofline analysis (EXPERIMENTS.md §Perf) shows that after the sharding
fixes, the llama train cell's dominant term is HBM traffic, a large share
of which is the [Sq, Sk] score tensor round-trips of the XLA reference
attention. This kernel keeps scores in VMEM with the standard
online-softmax recurrence, so attention HBM traffic drops to the q/k/v/o
streams — the canonical flash win, adapted to TPU tiling:

  * blocks are (BLOCK_Q x head_dim) / (BLOCK_K x head_dim), 128-aligned
    for the MXU; running max/sum live in SMEM-scalar-free VMEM scratch;
  * the kv loop is the innermost grid dim so the accumulator tile stays
    resident (same pattern as kernels/int8_matmul.py);
  * causal masking is index-computed per tile; fully-masked tiles are
    skipped by the grid construction for the banded (SWA) case.

Shapes: q [B, H, Sq, D], k/v [B, H, Sk, D] (head-major for clean 2D tiles;
ops.py transposes from the model's [B, S, H, D]). fp32 accumulation.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, block_q, block_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window

    s = jax.lax.dot_general(
        q_ref[0, 0].astype(jnp.float32), k_ref[0, 0].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                      # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)             # rescale of old accumulator
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_ref[0, 0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, interpret: bool = False):
    """q [B,H,Sq,D], k/v [B,H,Sk,D] -> o [B,H,Sq,D].

    Sq, Sk must be multiples of 128 (ops.py pads); D in {64, 128}.
    """
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    # reprolint: allow(no-invariant-assert) -- jit-trace-time shape check
    assert Sq % BLOCK_Q == 0 and Sk % BLOCK_K == 0, (Sq, Sk)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    grid = (B, H, Sq // BLOCK_Q, Sk // BLOCK_K)
    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, block_q=BLOCK_Q, block_k=BLOCK_K)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, BLOCK_Q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, BLOCK_K, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, BLOCK_K, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, BLOCK_Q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q, D), jnp.float32),
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
