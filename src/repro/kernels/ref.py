"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from ..core import prng


def int8_matmul_ref(a: jax.Array, w: jax.Array):
    """a [M,K] int8, w [K,N] int8 -> (out int32 [M,N], maxabs int32 scalar)."""
    out = jax.lax.dot_general(a.astype(jnp.int32), w.astype(jnp.int32),
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return out, jnp.max(jnp.abs(out)).astype(jnp.int32)


def zo_perturb_ref(theta: jax.Array, seed: jax.Array, salt: int,
                   scale: jax.Array):
    """theta + scale * z with z = hash-gaussian over the *global* flat index
    (bitwise-identical to core/prng.normal on the same flat layout)."""
    flat = theta.reshape(-1)
    z = prng.normal(seed, salt, flat.shape)
    out = flat.astype(jnp.float32) + scale.astype(jnp.float32) * z
    return out.reshape(theta.shape).astype(theta.dtype)


def zo_fused_replay_ref(theta: jax.Array, seeds: jax.Array,
                        coeffs: jax.Array, salt: int):
    """Apply S ledger steps of P (seed, coeff) probe records to one leaf.

    Canonical fleet update stream (docs/fleet.md): per step, the probe
    contributions are accumulated in probe order in fp32, subtracted once,
    and cast to the parameter dtype; the next step starts from that cast
    value. This is bitwise the live path (S=1 applied per step), which is
    what makes ledger replay reproduce the canonical parameter stream
    exactly. seeds uint32 [S, P]; coeffs fp32 [S, P] (0 for masked probes).

    Deliberately a plain python loop over eagerly-dispatched ops: compiling
    the loop (fori_loop / jit) lets XLA contract the mul-add chain into
    FMAs, which shifts the stream by ~1 ulp relative to other call sites.
    Keep every caller on this eager entry point (kernels/ops.py off-TPU).
    """
    S, P = seeds.shape
    shape, dtype = theta.shape, theta.dtype
    n = theta.size
    x = theta.reshape(-1).astype(jnp.float32)
    for s in range(S):
        inner = jnp.zeros((n,), jnp.float32)
        for p in range(P):
            z = prng.normal(seeds[s, p], salt, (n,))
            inner = inner + coeffs[s, p] * z
        x = (x - inner).astype(dtype).astype(jnp.float32)
    return x.reshape(shape).astype(dtype)


def zo_fused_replay_int8_ref(theta: jax.Array, seeds: jax.Array,
                             gs: jax.Array, salt: int, r_max: int,
                             p_zero, shift: int):
    """int8-lane twin of zo_fused_replay_ref (docs/fleet.md record v2).

    Per committed step the per-probe integer updates psr(g*z, shift) are
    accumulated in int32 in probe order and clamped ONCE to [-127, 127]
    — the integer analogue of the fp32 accumulate-then-cast, stated by
    the engine (core/engine.py Int8Engine.zo_apply). Masked probes carry
    g = 0, an exact no-op. Integer ops are immune to FMA contraction, so
    this path matches the Pallas kernel and the live traced step bitwise
    on every backend.
    """
    from ..core.int8 import int8_noise, psr_shift
    S, P = seeds.shape
    n = theta.size
    x = theta.reshape(-1).astype(jnp.int32)
    pz = jnp.float32(p_zero)
    for s in range(S):
        acc = jnp.zeros((n,), jnp.int32)
        for p in range(P):
            z = int8_noise(seeds[s, p], salt, (n,), r_max, pz)
            acc = acc + psr_shift(gs[s, p].astype(jnp.int32) * z,
                                  jnp.int32(shift))
        x = jnp.clip(x - acc, -127, 127)
    return x.astype(jnp.int8).reshape(theta.shape)


def int8_perturb_ref(theta: jax.Array, seed: jax.Array, salt: int, k: int,
                     r_max: int, p_zero: jax.Array):
    """Alg. 2 perturbation on an int8 leaf (clamped +/- sparse uniform)."""
    from ..core.int8 import int8_noise
    z = int8_noise(seed, salt, theta.shape, r_max, p_zero)
    return jnp.clip(theta.astype(jnp.int32) + k * z, -127, 127).astype(jnp.int8)


def paged_attn_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                   page_table: jax.Array, seq_lens: jax.Array, *,
                   scale: float, window: int = 0):
    """Gather-then-attend oracle for kernels/paged_attn.py.

    q [B,KVd,G,Dh]; pools [N,ps,KVd,Dh]; page_table [B,P]; seq_lens [B].
    Materializes the gathered [B, P*ps, KVd, Dh] cache and reuses the model's
    dense ``_attend_block`` so the serve path is *bitwise* the dense decode
    math — the parity tests (tests/test_serve_paged.py) rely on this.
    """
    from ..models.layers import _attend_block
    B, KVd, G, Dh = q.shape
    ps = k_pool.shape[1]
    k = k_pool[page_table].reshape(B, -1, KVd, Dh)
    v = v_pool[page_table].reshape(B, -1, KVd, Dh)
    t = jnp.arange(k.shape[1], dtype=jnp.int32)
    valid = t[None, :] <= seq_lens[:, None]
    if window > 0:
        valid &= t[None, :] > seq_lens[:, None] - window
    out = _attend_block(q[:, None], k, v, valid[:, None, :], scale)
    return out[:, 0]
