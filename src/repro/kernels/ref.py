"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math


import jax
import jax.numpy as jnp

from ..core import prng


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """Dense-softmax oracle for kernels/flash_attn.py, same layout."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def int8_matmul_ref(a: jax.Array, w: jax.Array):
    """a [M,K] int8, w [K,N] int8 -> (out int32 [M,N], maxabs int32 scalar)."""
    out = jax.lax.dot_general(a.astype(jnp.int32), w.astype(jnp.int32),
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return out, jnp.max(jnp.abs(out)).astype(jnp.int32)


def zo_perturb_ref(theta: jax.Array, seed: jax.Array, salt: int,
                   scale: jax.Array):
    """theta + scale * z with z = hash-gaussian over the *global* flat index
    (bitwise-identical to core/prng.normal on the same flat layout)."""
    flat = theta.reshape(-1)
    z = prng.normal(seed, salt, flat.shape)
    out = flat.astype(jnp.float32) + scale.astype(jnp.float32) * z
    return out.reshape(theta.shape).astype(theta.dtype)


def zo_fused_replay_ref(theta: jax.Array, seeds: jax.Array,
                        coeffs: jax.Array, salt: int):
    """Apply S ledger steps of P (seed, coeff) probe records to one leaf.

    Canonical fleet update stream (docs/fleet.md): per step, the probe
    contributions are accumulated in probe order in fp32, subtracted once,
    and cast to the parameter dtype; the next step starts from that cast
    value. This is bitwise the live path (S=1 applied per step), which is
    what makes ledger replay reproduce the canonical parameter stream
    exactly. seeds uint32 [S, P]; coeffs fp32 [S, P] (0 for masked probes).

    Deliberately a plain python loop over eagerly-dispatched ops: compiling
    the loop (fori_loop / jit) lets XLA contract the mul-add chain into
    FMAs, which shifts the stream by ~1 ulp relative to other call sites.
    Keep every caller on this eager entry point (kernels/ops.py off-TPU).
    """
    S, P = seeds.shape
    shape, dtype = theta.shape, theta.dtype
    n = theta.size
    x = theta.reshape(-1).astype(jnp.float32)
    for s in range(S):
        inner = jnp.zeros((n,), jnp.float32)
        for p in range(P):
            z = prng.normal(seeds[s, p], salt, (n,))
            inner = inner + coeffs[s, p] * z
        x = (x - inner).astype(dtype).astype(jnp.float32)
    return x.reshape(shape).astype(dtype)


def zo_fused_replay_int8_ref(theta: jax.Array, seeds: jax.Array,
                             gs: jax.Array, salt: int, r_max: int,
                             p_zero, shift: int):
    """int8-lane twin of zo_fused_replay_ref (docs/fleet.md record v2).

    Per committed step the per-probe integer updates psr(g*z, shift) are
    accumulated in int32 in probe order and clamped ONCE to [-127, 127]
    — the integer analogue of the fp32 accumulate-then-cast, stated by
    the engine (core/engine.py Int8Engine.zo_apply). Masked probes carry
    g = 0, an exact no-op. Integer ops are immune to FMA contraction, so
    this path matches the Pallas kernel and the live traced step bitwise
    on every backend.
    """
    from ..core.int8 import int8_noise, psr_shift
    S, P = seeds.shape
    n = theta.size
    x = theta.reshape(-1).astype(jnp.int32)
    pz = jnp.float32(p_zero)
    for s in range(S):
        acc = jnp.zeros((n,), jnp.int32)
        for p in range(P):
            z = int8_noise(seeds[s, p], salt, (n,), r_max, pz)
            acc = acc + psr_shift(gs[s, p].astype(jnp.int32) * z,
                                  jnp.int32(shift))
        x = jnp.clip(x - acc, -127, 127)
    return x.astype(jnp.int8).reshape(theta.shape)


def int8_perturb_ref(theta: jax.Array, seed: jax.Array, salt: int, k: int,
                     r_max: int, p_zero: jax.Array):
    """Alg. 2 perturbation on an int8 leaf (clamped +/- sparse uniform)."""
    from ..core.int8 import int8_noise
    z = int8_noise(seed, salt, theta.shape, r_max, p_zero)
    return jnp.clip(theta.astype(jnp.int32) + k * z, -127, 127).astype(jnp.int8)


NEG_INF = -1e30


def _monotone_key(x: jax.Array) -> jax.Array:
    """float32 -> uint32 order-preserving key (-0.0 canonicalized to +0.0,
    so key comparisons agree with float comparisons everywhere)."""
    x = x.astype(jnp.float32) + jnp.float32(0.0)
    s = jax.lax.bitcast_convert_type(x, jnp.int32)
    u = s.astype(jnp.uint32)
    return jnp.where(s < 0, ~u, u | jnp.uint32(0x80000000))


def topk_topp_mask_ref(logits: jax.Array, k: jax.Array, p: jax.Array):
    """Sort-free top-k/top-p filter: threshold-refine partial selection.

    logits [B, V] f32; k [B] int32 (<=0 disables); p [B] f32 in (0, 1]
    (>=1 disables). Returns logits with filtered entries at NEG_INF —
    the same keep sets as the full-sort reference (serve/sampler.py
    ``_top_k_mask``/``_top_p_mask``) without materializing a sort:

    * top-k: a 4-round byte-radix descent over the monotone float key
      finds the exact k-th largest *value*; keep = (x >= kth), which is
      bit-identical to the sorted threshold (ties keep everything equal,
      possibly more than k — the reference's semantics);
    * top-p: the same radix descent over probability mass finds the
      boundary value T where the nucleus crosses p, plus G = total mass
      strictly above T. Values above T are kept outright; the tied run at
      T is split by original index order (rank r kept iff G + r*p_T < p),
      mirroring the reference's stable descending sort. Only the boundary
      comparison is float-rounding sensitive (G accumulates in histogram
      order, the reference in sorted order) — identical on exactly
      representable mass grids, and never observable unless p lands
      within one ulp of a partial sum.
    """
    B, V = logits.shape
    rows = jnp.arange(B)[:, None]

    # ---- top-k: radix-select the exact k-th largest key -------------- #
    keys = _monotone_key(logits)
    krem = jnp.clip(k, 1, V).astype(jnp.int32)
    cand = jnp.ones((B, V), jnp.int32)
    kth = jnp.zeros((B,), jnp.uint32)
    for shift in (24, 16, 8, 0):
        byte = ((keys >> shift) & jnp.uint32(0xFF)).astype(jnp.int32)
        hist = jnp.zeros((B, 256), jnp.int32).at[rows, byte].add(cand)
        cnt_ge = jnp.cumsum(hist[:, ::-1], axis=1)[:, ::-1]
        above = cnt_ge - hist                  # strictly above bucket j
        cond = (above < krem[:, None]) & (cnt_ge >= krem[:, None])
        j = jnp.argmax(cond, axis=1).astype(jnp.int32)   # unique True
        krem = krem - jnp.take_along_axis(above, j[:, None], 1)[:, 0]
        kth = kth | (j.astype(jnp.uint32) << shift)
        cand = cand * (byte == j[:, None])
    keep = (keys >= kth[:, None]) | (k <= 0)[:, None]
    x = jnp.where(keep, logits, NEG_INF)

    # ---- top-p: refine the nucleus boundary value -------------------- #
    probs = jax.nn.softmax(x, axis=-1)
    keys = _monotone_key(x)
    cand_m = jnp.ones((B, V), jnp.float32)
    above_mass = jnp.zeros((B,), jnp.float32)
    tkey = jnp.zeros((B,), jnp.uint32)
    for shift in (24, 16, 8, 0):
        byte = ((keys >> shift) & jnp.uint32(0xFF)).astype(jnp.int32)
        mh = jnp.zeros((B, 256), jnp.float32).at[rows, byte].add(
            probs * cand_m)
        above = jnp.cumsum(mh[:, ::-1], axis=1)[:, ::-1] - mh \
            + above_mass[:, None]              # mass strictly above bucket
        cond = above < p[:, None]
        j = jnp.argmax(cond, axis=1).astype(jnp.int32)   # lowest such bucket
        above_mass = jnp.take_along_axis(above, j[:, None], 1)[:, 0]
        tkey = tkey | (j.astype(jnp.uint32) << shift)
        cand_m = cand_m * (byte == j[:, None])
    eq = keys == tkey[:, None]
    p_t = jnp.max(jnp.where(eq, probs, 0.0), axis=1)
    r = jnp.cumsum(eq, axis=1) - eq            # tie rank in index order
    keep_p = (keys > tkey[:, None]) \
        | (eq & (above_mass[:, None] + r * p_t[:, None] < p[:, None])) \
        | (p >= 1.0)[:, None]
    return jnp.where(keep_p, x, NEG_INF)


def paged_attn_step_ref(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                        k_pool: jax.Array, v_pool: jax.Array,
                        page_table: jax.Array, seq_lens: jax.Array, *,
                        scale: float, window: int = 0):
    """Write-then-gather-then-attend oracle for kernels/paged_attn.py.

    q [B,KVd,G,Dh]; k_new/v_new [B,KVd,Dh]; pools [N,ps,KVd,Dh];
    page_table [B,P]; seq_lens [B]. Mirrors the fused megastep: the
    token's K/V is scattered into its pool slot first, then the gathered
    [B, P*ps, KVd, Dh] cache is attended with the model's dense
    ``_attend_block`` so the serve path is *bitwise* the dense decode
    math — the parity tests (tests/test_serve_paged.py) rely on this.
    Null table entries (page 0 — unmapped tail or SWA-reclaimed) are
    masked per position, which is a no-op for live rows: every position
    ``t <= seq_len`` inside the window is backed by a real page.
    """
    from ..models.layers import _attend_block
    from ..serve.kv_pages import NULL_PAGE
    B, KVd, G, Dh = q.shape
    ps = k_pool.shape[1]
    pos = seq_lens.astype(jnp.int32)
    pidx = jnp.take_along_axis(page_table.astype(jnp.int32),
                               (pos // ps)[:, None], axis=1)[:, 0]
    k_pool = k_pool.at[pidx, pos % ps].set(k_new.astype(k_pool.dtype))
    v_pool = v_pool.at[pidx, pos % ps].set(v_new.astype(v_pool.dtype))
    k = k_pool[page_table].reshape(B, -1, KVd, Dh)
    v = v_pool[page_table].reshape(B, -1, KVd, Dh)
    t = jnp.arange(k.shape[1], dtype=jnp.int32)
    valid = t[None, :] <= pos[:, None]
    if window > 0:
        valid &= t[None, :] > pos[:, None] - window
    valid &= jnp.repeat(page_table != NULL_PAGE, ps, axis=1)
    out = _attend_block(q[:, None], k, v, valid[:, None, :], scale)
    return out[:, 0], k_pool, v_pool
