"""Pallas TPU paged-attention decode kernel (vLLM-style block tables).

Single-token decode over a paged KV pool: each sequence's cache lives in
fixed-size pages scattered through a global pool, addressed by a per-row
page table. The kernel never materializes the gathered [B, T, KVd, Dh]
cache — pages stream HBM->VMEM one at a time via scalar-prefetched block
indexing (``PrefetchScalarGridSpec``: the page table is available before
the body runs, so the k/v ``index_map`` picks the *physical* page for each
logical block), and the online-softmax accumulator stays resident in VMEM.

Layouts:
  q          [B, KVd, G, Dh]     (G = query heads per KV head)
  k/v pool   [N_pages, page_size, KVd, Dh]
  page_table [B, P] int32        (P = max pages per sequence; 0 = null page)
  seq_lens   [B] int32           (tokens already written, incl. current)

Grid (B, KVd, P): the page loop is innermost so the [G, Dh] accumulator
tile survives across pages (same pattern as flash_attn.py). Pages whose
first position is past seq_lens[b] are skipped with ``pl.when`` — their
table entries point at the null page and are never read.

TPU efficiency notes: Dh should be 64/128 and G padded toward the 8-sublane
tile for MXU occupancy; CPU tests run ``interpret=True`` where the tiling
constraints are relaxed.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale, window, page_size):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = sl_ref[b]                         # current absolute position

    @pl.when(p * page_size <= pos)          # page holds a live position
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32)                 # [G, Dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # [ps, Dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # [ps, Dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        t = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = t <= pos
        if window > 0:
            mask &= t > pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p_ = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p_, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p_, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == pl.num_programs(2) - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "interpret"))
def paged_attention(q, k_pool, v_pool, page_table, seq_lens, *,
                    scale: float | None = None, window: int = 0,
                    interpret: bool = False):
    """q [B,KVd,G,Dh] x paged pools -> o [B,KVd,G,Dh]."""
    B, KVd, G, Dh = q.shape
    _, page_size, _, _ = k_pool.shape
    P = page_table.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    kern = functools.partial(_kernel, scale=scale, window=window,
                             page_size=page_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVd, P),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh),
                         lambda b, h, p, pt, sl: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, Dh),
                         lambda b, h, p, pt, sl: (pt[b, p], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, Dh),
                         lambda b, h, p, pt, sl: (pt[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh),
                               lambda b, h, p, pt, sl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVd, G, Dh), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      q, k_pool, v_pool)
