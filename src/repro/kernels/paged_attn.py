"""Pallas TPU fused paged-attention decode megastep (vLLM-style tables).

One kernel call per decode step does BOTH halves of the token's cache
traffic:

  1. **fused KV write** — the incoming token's K/V row is DMA'd straight
     into its pool slot (``page_table[b, pos // ps], pos % ps``) before any
     page is read, so the pool-wide ``k_pool.at[pidx, slot].set`` scatter
     that used to run in models/layers.py (forcing XLA to copy/alias-check
     the whole pool every token) disappears; the pools are
     ``input_output_aliases``-donated and updated in place;
  2. **megastep attention** — pages stream HBM->VMEM ``pages_per_block``
     at a time through double-width VMEM scratch, and every KV head is
     batched into one ``[KVd*G, Dh]`` accumulator tile per row, so the MXU
     sees one tall tile instead of KVd skinny ``[G, Dh]`` ones and the
     grid drops from (B, KVd, P) to (B, ceil(P / F)).

Layouts:
  q          [B, KVd, G, Dh]     (G = query heads per KV head)
  k/v new    [B, KVd, Dh]        current token's K/V (pool dtype)
  k/v pool   [N_pages, page_size, KVd, Dh]   (ANY/HBM; aliased outputs)
  page_table [B, P] int32        (P = max pages per sequence; 0 = null page)
  seq_lens   [B] int32           (tokens already cached == write position)

The page table and seq_lens ride as scalar-prefetch operands
(``PrefetchScalarGridSpec``) so physical page ids are known before the
body runs. A page block is skipped — no DMA, no FLOPs — when it starts
past ``seq_lens[b]`` or its table entry is the **null page** (entry 0):
that is how SWA reclamation works, the scheduler re-nulls fully
windowed-out entries after freeing their pages and the kernel never
touches them again.

TPU efficiency notes: Dh should be 64/128 and KVd*G padded toward the
8-sublane tile; CPU tests run ``interpret=True`` where tiling constraints
are relaxed.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
NULL_PAGE = 0


def _kernel(pt_ref, sl_ref, q_ref, knew_ref, vnew_ref, kpool_in, vpool_in,
            o_ref, kpool_ref, vpool_ref, k_vmem, v_vmem, acc_ref, m_ref,
            l_ref, ksem, vsem, wsem, *, scale, window, page_size, f_pages):
    b = pl.program_id(0)
    pb = pl.program_id(1)
    ps = page_size
    pos = sl_ref[b]                          # current absolute position

    @pl.when(pb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        # fused KV write: land the incoming token in its slot before any
        # page read. Inactive rows (seq_len 0, all-null table) write into
        # the reserved null page, which is never attended.
        wpage = pt_ref[b, pos // ps]
        wslot = pos % ps
        kcp = pltpu.make_async_copy(
            knew_ref.at[b], kpool_ref.at[wpage, wslot], wsem.at[0])
        vcp = pltpu.make_async_copy(
            vnew_ref.at[b], vpool_ref.at[wpage, wslot], wsem.at[1])
        kcp.start()
        vcp.start()
        kcp.wait()
        vcp.wait()

    base = pb * f_pages
    phys = [pt_ref[b, base + j] for j in range(f_pages)]
    live = [(jnp.int32((base + j) * ps) <= pos) & (phys[j] != NULL_PAGE)
            for j in range(f_pages)]
    for j in range(f_pages):
        @pl.when(live[j])
        def _copy(j=j):
            pltpu.make_async_copy(
                kpool_ref.at[phys[j]], k_vmem.at[j], ksem.at[j]).start()
            pltpu.make_async_copy(
                vpool_ref.at[phys[j]], v_vmem.at[j], vsem.at[j]).start()
    for j in range(f_pages):
        @pl.when(live[j])
        def _wait(j=j):
            pltpu.make_async_copy(
                kpool_ref.at[phys[j]], k_vmem.at[j], ksem.at[j]).wait()
            pltpu.make_async_copy(
                vpool_ref.at[phys[j]], v_vmem.at[j], vsem.at[j]).wait()

    @pl.when(jnp.int32(base * ps) <= pos)    # block holds a live position
    def _attend():
        KVd, G, Dh = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
        T = f_pages * ps
        q = q_ref[0].astype(jnp.float32)                    # [KVd, G, Dh]
        page_ok = jnp.repeat(jnp.stack(live), ps)           # [T] bool
        k = k_vmem[...].astype(jnp.float32).reshape(T, KVd, Dh)
        v = v_vmem[...].astype(jnp.float32).reshape(T, KVd, Dh)
        # dead pages inside a live block hold stale scratch; their softmax
        # weight is exactly 0, but 0 * garbage(NaN) would still poison the
        # weighted-value dot — select them to 0 before contracting.
        v = jnp.where(page_ok[:, None, None], v, 0.0)
        # head-batched scores: one [KVd*G, T] tile, head-major rows
        s = jnp.concatenate([
            jax.lax.dot_general(q[h], k[:, h, :], (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
            for h in range(KVd)], axis=0) * scale           # [KVd*G, T]
        t = jnp.int32(base * ps) + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = t <= pos
        if window > 0:
            mask &= t > pos - window
        mask &= page_ok[None, :]
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p_ = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p_, axis=1, keepdims=True)
        pv = jnp.concatenate([
            jax.lax.dot_general(p_[h * G:(h + 1) * G], v[:, h, :],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
            for h in range(KVd)], axis=0)                   # [KVd*G, Dh]
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(pb == pl.num_programs(1) - 1)
    def _done():
        KVd, G, Dh = o_ref.shape[1], o_ref.shape[2], o_ref.shape[3]
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = o.reshape(KVd, G, Dh).astype(o_ref.dtype)


def default_pages_per_block(page_size: int, table_width: int) -> int:
    """Pages streamed per grid step: aim for a >=128-position KV tile."""
    return max(1, min(table_width, -(-128 // page_size)))


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "pages_per_block", "interpret"))
def paged_attention_step(q, k_new, v_new, k_pool, v_pool, page_table,
                         seq_lens, *, scale: float | None = None,
                         window: int = 0, pages_per_block: int = 0,
                         interpret: bool = False):
    """Fused decode megastep: write the token's K/V, attend through pages.

    q [B,KVd,G,Dh], k_new/v_new [B,KVd,Dh] (pool dtype) ->
    (o [B,KVd,G,Dh], k_pool, v_pool) with the pools updated in place
    (input_output_aliases; callers should treat the inputs as donated).
    """
    B, KVd, G, Dh = q.shape
    _, page_size, _, _ = k_pool.shape
    P = page_table.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    F = pages_per_block or default_pages_per_block(page_size, P)
    F = min(F, P)
    PB = -(-P // F)
    if PB * F != P:       # pad the table with null pages (always skipped)
        page_table = jnp.pad(page_table, ((0, 0), (0, PB * F - P)),
                             constant_values=NULL_PAGE)
    kern = functools.partial(_kernel, scale=scale, window=window,
                             page_size=page_size, f_pages=F)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, PB),
        in_specs=[
            pl.BlockSpec((1, KVd, G, Dh), lambda b, p, pt, sl: (b, 0, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),      # k_new
            pl.BlockSpec(memory_space=pltpu.ANY),      # v_new
            pl.BlockSpec(memory_space=pltpu.ANY),      # k_pool
            pl.BlockSpec(memory_space=pltpu.ANY),      # v_pool
        ],
        out_specs=[
            pl.BlockSpec((1, KVd, G, Dh), lambda b, p, pt, sl: (b, 0, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        scratch_shapes=[
            pltpu.VMEM((F, page_size, KVd, Dh), k_pool.dtype),
            pltpu.VMEM((F, page_size, KVd, Dh), v_pool.dtype),
            pltpu.VMEM((KVd * G, Dh), jnp.float32),
            pltpu.VMEM((KVd * G, 1), jnp.float32),
            pltpu.VMEM((KVd * G, 1), jnp.float32),
            pltpu.SemaphoreType.DMA((F,)),
            pltpu.SemaphoreType.DMA((F,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KVd, G, Dh), q.dtype),
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
        ],
        input_output_aliases={5: 1, 6: 2},
        interpret=interpret,
    )(page_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      q, k_new.astype(k_pool.dtype), v_new.astype(v_pool.dtype),
      k_pool, v_pool)
