"""Pallas TPU sort-free top-k/top-p filter (threshold-refine selection).

One grid step per batch row: the row's logits live in VMEM and the
4-round byte-radix descent of kernels/ref.py::topk_topp_mask_ref runs
in-kernel — histograms are built by chunked bucket-compare reductions
(no scatter, which the TPU vector unit lacks), so a 128k vocab costs
4 passes of O(V) work per filter instead of two full-vocab sorts.

k and p ride as scalar-prefetch operands (per-row knobs, SMEM-resident
before the body runs). Keep semantics are identical to the jnp ref —
see its docstring for the tie-splitting and boundary-rounding contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_HIST_CHUNK = 4096


def _key(x):
    """float32 -> uint32 monotone key (-0.0 canonicalized to +0.0)."""
    x = x.astype(jnp.float32) + jnp.float32(0.0)
    s = jax.lax.bitcast_convert_type(x, jnp.int32)
    u = s.astype(jnp.uint32)
    return jnp.where(s < 0, ~u, u | jnp.uint32(0x80000000))


def _hist(byte, weights):
    """[V] int32 bucket ids x [V] weights -> [256] sums, chunked so the
    bucket-compare matrix never exceeds 256 x _HIST_CHUNK in VMEM."""
    V = byte.shape[0]
    out = jnp.zeros((256,), weights.dtype)
    for c in range(0, V, _HIST_CHUNK):
        n = min(_HIST_CHUNK, V - c)
        buckets = jax.lax.broadcasted_iota(jnp.int32, (256, n), 0)
        eq = byte[c:c + n][None, :] == buckets
        out = out + jnp.where(eq, weights[c:c + n][None, :], 0).sum(axis=1)
    return out


def _kernel(k_ref, p_ref, x_ref, o_ref):
    b = pl.program_id(0)
    x = x_ref[0]                                   # [V]
    V = x.shape[0]
    k = k_ref[b]
    p = p_ref[b]

    # ---- top-k: radix-select the exact k-th largest key ---------- #
    keys = _key(x)
    krem = jnp.clip(k, 1, V).astype(jnp.int32)
    cand = jnp.ones((V,), jnp.int32)
    kth = jnp.uint32(0)
    for shift in (24, 16, 8, 0):
        byte = ((keys >> shift) & jnp.uint32(0xFF)).astype(jnp.int32)
        hist = _hist(byte, cand)
        cnt_ge = jnp.cumsum(hist[::-1])[::-1]
        above = cnt_ge - hist
        cond = (above < krem) & (cnt_ge >= krem)
        j = jnp.argmax(cond).astype(jnp.int32)
        krem = krem - above[j]
        kth = kth | (j.astype(jnp.uint32) << shift)
        cand = cand * (byte == j)
    xk = jnp.where((keys >= kth) | (k <= 0), x, NEG_INF)

    # ---- top-p: refine the nucleus boundary value ---------------- #
    probs = jax.nn.softmax(xk)
    keys = _key(xk)
    cand_m = jnp.ones((V,), jnp.float32)
    above_mass = jnp.float32(0.0)
    tkey = jnp.uint32(0)
    for shift in (24, 16, 8, 0):
        byte = ((keys >> shift) & jnp.uint32(0xFF)).astype(jnp.int32)
        mh = _hist(byte, probs * cand_m)
        above = jnp.cumsum(mh[::-1])[::-1] - mh + above_mass
        cond = above < p
        j = jnp.argmax(cond).astype(jnp.int32)
        above_mass = above[j]
        tkey = tkey | (j.astype(jnp.uint32) << shift)
        cand_m = cand_m * (byte == j)
    eq = keys == tkey
    p_t = jnp.max(jnp.where(eq, probs, 0.0))
    r = jnp.cumsum(eq.astype(jnp.int32)) - eq      # tie rank, index order
    keep = (keys > tkey) | (eq & (above_mass + r * p_t < p)) | (p >= 1.0)
    o_ref[0] = jnp.where(keep, xk, NEG_INF)


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_topp_mask(logits, k, p, *, interpret: bool = False):
    """logits [B, V] f32, k [B] int32, p [B] f32 -> masked logits [B, V]."""
    B, V = logits.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, V), lambda b, k, p: (b, 0))],
        out_specs=pl.BlockSpec((1, V), lambda b, k, p: (b, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, V), jnp.float32),
        interpret=interpret,
    )(k.astype(jnp.int32), p.astype(jnp.float32),
      logits.astype(jnp.float32))
