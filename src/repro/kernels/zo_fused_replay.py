"""Pallas TPU fused ledger replay: K accumulated ZO records, one pass.

A crashed or late fleet worker catches up by replaying the seed ledger
(docs/fleet.md): for each missed step s it must apply

    theta <- cast(theta_f32 - sum_p coeff[s,p] * z(seed[s,p]))

where the per-step cast to the parameter dtype is part of the canonical
update (it is what the live path does one step at a time). Done naively
that is S full read-modify-write passes over the parameters; this kernel
performs all S steps in a *single* 1R + 1W pass — each block of theta is
loaded once, the S-step / P-probe accumulation runs entirely in VREGs
(z regenerated from the counter hash, exactly like kernels/zo_perturb.py),
and the block is stored once. HBM traffic for an arbitrarily long catch-up
is the same as for one training step, which is the whole point of shipping
scalars instead of checkpoints.

Replay contract: the per-step inner sum runs in probe order, and the
per-step cast is applied inside the loop, so an S-step replay equals the
live stream of per-step S=1 applications exactly on any one backend (see
ref.zo_fused_replay_ref, the dispatch oracle that carries the same
guarantee off-TPU).
"""
from __future__ import annotations

import functools


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.int8 import psr_shift
from .zo_perturb import BLOCK_ROWS, LANES, _int8_noise_block, _normal_block


def _replay_kernel(n_steps, n_probes, seeds_ref, coeffs_ref, salt_ref,
                   t_ref, o_ref):
    rows = t_ref.shape[0]
    row0 = pl.program_id(0) * rows
    x = t_ref[...].astype(jnp.float32)

    def step_body(s, x):
        inner = jnp.zeros_like(x)
        for p in range(n_probes):          # static, small (probes per step)
            z = _normal_block(jnp.uint32(row0), x.shape,
                              seeds_ref[s * n_probes + p], salt_ref[0])
            inner = inner + coeffs_ref[s * n_probes + p] * z
        # the per-step cast is part of the canonical update stream
        return (x - inner).astype(o_ref.dtype).astype(jnp.float32)

    x = jax.lax.fori_loop(0, n_steps, step_body, x)
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("salt", "interpret"))
def zo_fused_replay(theta: jax.Array, seeds: jax.Array, coeffs: jax.Array,
                    salt: int, *, interpret: bool = False):
    """Apply S ledger steps of P probes each to one parameter leaf.

    theta: any shape/dtype; seeds uint32 [S, P]; coeffs fp32 [S, P]
    (coeff = eta*g/valid per accepted probe — core/engine.py
    host_coeffs — exactly 0 for masked ones).
    The z stream is bitwise ref.zo_fused_replay_ref; the accumulated AXPY
    matches it to within FMA-contraction rounding (same 1-ulp contract as
    kernels/zo_perturb.py). Off-TPU the dispatch (kernels/ops.py) always
    uses the ref, so the fleet's bit-exact replay guarantee is backend-
    uniform.
    """
    shape, dtype = theta.shape, theta.dtype
    S, P = seeds.shape
    n = theta.size
    rows = -(-n // LANES)
    rows_pad = -(-rows // BLOCK_ROWS) * BLOCK_ROWS
    flat = jnp.zeros((rows_pad * LANES,), dtype).at[:n].set(theta.reshape(-1))
    out = pl.pallas_call(
        functools.partial(_replay_kernel, S, P),
        grid=(rows_pad // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANES), dtype),
        interpret=interpret,
    )(seeds.reshape(-1).astype(jnp.uint32),
      coeffs.reshape(-1).astype(jnp.float32),
      jnp.asarray([salt], jnp.uint32),
      flat.reshape(rows_pad, LANES))
    return out.reshape(-1)[:n].reshape(shape)


# ------------------------------------------------------------------ #
# int8 lane (Alg. 2): the ledger carries (seed, ternary g) per probe
# ------------------------------------------------------------------ #
def _replay_int8_kernel(n_steps, n_probes, shift, seeds_ref, gs_ref,
                        salt_ref, rmax_ref, pz_ref, t_ref, o_ref):
    rows = t_ref.shape[0]
    row0 = pl.program_id(0) * rows
    x = t_ref[...].astype(jnp.int32)

    def step_body(s, x):
        acc = jnp.zeros_like(x)
        for p in range(n_probes):          # static, small (probes per step)
            z = _int8_noise_block(jnp.uint32(row0), x.shape,
                                  seeds_ref[s * n_probes + p], salt_ref[0],
                                  rmax_ref[0], pz_ref[0])
            acc = acc + psr_shift(gs_ref[s * n_probes + p] * z,
                                  jnp.int32(shift))
        # int32 accumulate in probe order, ONE clamp per step — the
        # integer twin of the fp32 accumulate-then-cast (engine contract)
        return jnp.clip(x - acc, -127, 127)

    x = jax.lax.fori_loop(0, n_steps, step_body, x)
    o_ref[...] = x.astype(jnp.int8)


@functools.partial(jax.jit,
                   static_argnames=("salt", "r_max", "shift", "interpret"))
def zo_fused_replay_int8(theta: jax.Array, seeds: jax.Array, gs: jax.Array,
                         salt: int, r_max: int, p_zero, shift: int, *,
                         interpret: bool = False):
    """Apply S int8 ledger steps of P probes each to one int8 leaf.

    theta int8; seeds uint32 [S, P]; gs int32 [S, P] ternary signs
    (exactly 0 for masked probes — psr(0*z) = 0, an exact no-op, so no
    renormalization exists in the int8 lane). Integer arithmetic is
    associative, so unlike the fp32 kernel this path is bitwise equal to
    ref.zo_fused_replay_int8_ref on every backend.
    """
    shape = theta.shape
    S, P = seeds.shape
    n = theta.size
    rows = -(-n // LANES)
    rows_pad = -(-rows // BLOCK_ROWS) * BLOCK_ROWS
    flat = jnp.zeros((rows_pad * LANES,), jnp.int8).at[:n].set(
        theta.reshape(-1))
    out = pl.pallas_call(
        functools.partial(_replay_int8_kernel, S, P, shift),
        grid=(rows_pad // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)] * 5
        + [pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANES), jnp.int8),
        interpret=interpret,
    )(seeds.reshape(-1).astype(jnp.uint32),
      gs.reshape(-1).astype(jnp.int32),
      jnp.asarray([salt], jnp.uint32),
      jnp.asarray([r_max], jnp.int32),
      jnp.asarray(p_zero, jnp.float32).reshape(1),
      flat.reshape(rows_pad, LANES))
    return out.reshape(-1)[:n].reshape(shape)
