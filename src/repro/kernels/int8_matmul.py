"""Pallas TPU int8 GEMM with int32 VMEM accumulation + fused |max| reduce.

The NITI forward needs (1) the int32 accumulator and (2) max|acc| to pick
the rescale shift — computing the max inside the GEMM epilogue saves the
extra HBM round-trip over the int32 tensor (it is 4x the size of the int8
operands, so this matters on a bandwidth-limited chip).

MXU notes: int8 x int8 -> int32 is MXU-native on TPU v5+; blocks are
128-aligned on the contraction and output dims. Grid order (m, n, k) with
k innermost so each (m, n) accumulator tile stays resident in VMEM across
the K loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, w_ref, out_ref, max_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.int32), w_ref[...].astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        acc = acc_ref[...]
        out_ref[...] = acc
        max_ref[0, 0] = jnp.max(jnp.abs(acc))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul(a: jax.Array, w: jax.Array, *, bm: int = 128, bn: int = 128,
                bk: int = 128, interpret: bool = False):
    """a [M,K] int8, w [K,N] int8 -> (out [M,N] int32, maxabs int32 scalar).

    M, K, N must be multiples of the block sizes (ops.py pads).
    """
    M, K = a.shape
    K2, N = w.shape
    # reprolint: allow(no-invariant-assert) -- jit-trace-time shape check
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0, \
        (a.shape, w.shape, bm, bn, bk)
    gm, gn, gk = M // bm, N // bn, K // bk
    out, maxes = pl.pallas_call(
        _kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (i, j),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.int32),
            jax.ShapeDtypeStruct((gm, gn), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a, w)
    return out, jnp.max(maxes)
