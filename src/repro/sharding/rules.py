"""Logical-axis sharding rules (MaxText-style) with divisibility fallbacks.

A ``ShardingRules`` object binds a mesh (or ``None`` for single-device smoke
runs) to an architecture and decides, at config time:

- the attention TP plan: ``tp`` (heads sharded, KV heads duplicated to the TP
  degree, Q heads activation-padded if needed) or ``seq`` (weights replicated
  over ``model``, sequence sharded inside attention);
- the MoE plan: ``ep`` (experts sharded over ``model``) or ``tp`` (every chip
  holds a d_ff/tp slice of all experts);
- per-logical-axis mesh axes with automatic divisibility checks.

All model code asks the rules for shardings; with ``mesh=None`` every query
returns ``None`` and ``wsc`` is the identity, so the same model code runs on
one CPU device.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig, pad_to

# Max acceptable attention-flop inflation from Q-head padding before we fall
# back to sequence-sharded attention.
PAD_WASTE_LIMIT = 0.15


@dataclass(frozen=True)
class AttnPlan:
    kind: str            # "tp" | "seq"
    kv_dup: int = 1      # KV head duplication factor (tp plan)
    q_pad: int = 0       # extra padded Q heads (activation-level, tp plan)

    @property
    def padded_heads(self) -> int:
        return self.q_pad


def choose_attn_plan(cfg: ModelConfig, tp: int) -> AttnPlan:
    H, KV = cfg.num_heads, cfg.num_kv_heads
    if tp == 1:
        return AttnPlan("tp", kv_dup=1, q_pad=0)
    qh = pad_to(H, tp)
    waste = qh / H - 1.0
    if qh % tp == 0 and waste <= PAD_WASTE_LIMIT:
        if KV % tp == 0:
            return AttnPlan("tp", kv_dup=1, q_pad=qh - H)
        if tp % KV == 0:
            return AttnPlan("tp", kv_dup=tp // KV, q_pad=qh - H)
    return AttnPlan("seq")


def choose_moe_plan(cfg: ModelConfig, tp: int) -> str:
    if cfg.num_experts and tp > 1 and cfg.num_experts % tp == 0:
        return "ep"
    return "tp"          # d_ff sharded; all experts resident per chip


def _size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


class ShardingRules:
    """Binds (mesh, arch, shape, strategy) -> shardings.

    strategy:
      "tp"    — Megatron TP over `model` + FSDP storage over `data`
                (paper-faithful baseline).
      "fsdp"  — ZeRO-3: batch over BOTH axes, no tensor-parallel activation
                collectives; weights stay 2D-sharded for storage and are
                all-gathered per layer. (§Perf hillclimb lane: trades the
                2 AR/layer of activations for weight gathers.)
      "serve" — inference: weights TP over `model`, *replicated* over
                `data` (no per-token weight gathering); attention switched
                to the seq plan so the KV cache context-shards over `model`
                without KV-head duplication.
    """

    def __init__(self, mesh: Optional[Mesh], cfg: ModelConfig,
                 shape: Optional[ShapeConfig] = None,
                 strategy: str = "tp"):
        self.mesh = mesh
        self.cfg = cfg
        self.shape = shape
        self.strategy = strategy
        if mesh is not None:
            names = mesh.axis_names
            batch: Tuple[str, ...] = tuple(
                n for n in ("pod", "data") if n in names)
            self.model_axis = "model" if "model" in names else None
            tp = mesh.shape["model"] if self.model_axis else 1
            self.fsdp_axis = "data" if "data" in names else None
            if strategy == "fsdp":
                # data parallelism over every axis; no TP compute sharding
                if self.model_axis and (shape is None or
                                        shape.global_batch % (tp * max(
                                            1, _size(mesh, batch))) == 0):
                    batch = batch + (self.model_axis,)
                self.model_compute = None
            elif strategy == "serve":
                self.fsdp_axis = None          # replicate weights over data
                self.model_compute = self.model_axis
            else:
                self.model_compute = self.model_axis
            self.batch_axes = batch
        else:
            self.batch_axes = ()
            self.model_axis = None
            self.model_compute = None
            self.fsdp_axis = None
            tp = 1
        self.tp = tp if strategy != "fsdp" else 1
        self.attn = choose_attn_plan(cfg, self.tp)
        if strategy == "serve" and shape is not None and shape.kind == "decode":
            # context-parallel KV cache; no KV-head duplication
            self.attn = AttnPlan("seq")
        # MoE: expert parallelism uses the *model* axis even in the fsdp
        # lane (EP+DP: dispatch all-to-all instead of expert weight gathers)
        self.moe = choose_moe_plan(cfg, tp)
        # Long-context decode (global_batch < data size): shard cache seq over
        # the data axis (context parallelism).
        self.cache_seq_axes: Tuple[str, ...] = ()
        if (shape is not None and mesh is not None
                and shape.kind == "decode"):
            dsize = 1
            for a in self.batch_axes:
                dsize *= mesh.shape[a]
            if shape.global_batch < dsize:
                self.cache_seq_axes = self.batch_axes

    # ------------------------------------------------------------------ #
    def ns(self, *spec) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*spec))

    def wsc(self, x, *spec):
        """with_sharding_constraint if a mesh is bound, else identity."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    # -- common specs --------------------------------------------------- #
    @property
    def batch(self):                      # logical "batch"
        return tuple(self.batch_axes) if self.batch_axes else None

    @property
    def model(self):
        """Mesh axis for TP *compute* sharding (None in the fsdp lane)."""
        return self.model_compute

    @property
    def wmodel(self):
        """Mesh axis for the TP dim of weight *storage* (always set)."""
        return self.model_axis

    @property
    def fsdp(self):
        return self.fsdp_axis

    @property
    def batch_nomodel(self):
        """Batch axes minus the model axis (for EP dispatch constraints
        where the expert dim occupies `model`)."""
        axes = tuple(a for a in self.batch_axes if a != self.model_axis)
        return axes if axes else None

    # Activations [B, S, D]
    def act_btd(self, x):
        return self.wsc(x, self.batch, None, None)

    # Attention activations [B, S, H, Dh] under the tp plan
    def act_heads(self, x):
        if self.attn.kind == "tp":
            return self.wsc(x, self.batch, None, self.model, None)
        # seq plan: shard the sequence over model inside attention
        return self.wsc(x, self.batch, self.model, None, None)

    def logits(self, x):                  # [B, S, V]
        return self.wsc(x, self.batch, None, self.model)

    # -- parameter specs ------------------------------------------------- #
    # Weights are FSDP-sharded over `data` on one non-TP dim and TP-sharded
    # over `model`. `stacked` prepends the layer-stack dim (never sharded).
    def w(self, *spec, stacked: bool = False):
        full = ((None,) + tuple(spec)) if stacked else tuple(spec)
        return self.ns(*full) if self.mesh is not None else None

    def spec_embed(self):                 # [V, D]
        return (self.wmodel, self.fsdp)

    def spec_unembed(self):               # [D, V]
        return (self.fsdp, self.wmodel)

    def spec_attn_qkv(self):              # [D, H, Dh] / [D, KV, Dh]
        if self.attn.kind == "tp" and self.model is not None:
            return (self.fsdp, self.model, None)
        return (self.fsdp, self.wmodel if self.strategy == "fsdp" else None,
                None)

    def spec_attn_o(self):                # [H, Dh, D]
        if self.attn.kind == "tp" and self.model is not None:
            return (self.model, None, self.fsdp)
        return (self.wmodel if self.strategy == "fsdp" else None, None,
                self.fsdp)

    def spec_mlp_in(self):                # [D, F]
        return (self.fsdp, self.wmodel)

    def spec_mlp_out(self):               # [F, D]
        return (self.wmodel, self.fsdp)

    def spec_moe_in(self):                # [E, D, F]
        if self.moe == "ep":
            return (self.wmodel, self.fsdp, None)
        return (None, self.fsdp, self.wmodel)

    def spec_moe_out(self):               # [E, F, D]
        if self.moe == "ep":
            return (self.wmodel, None, self.fsdp)
        return (None, self.wmodel, self.fsdp)

    def spec_router(self):                # [D, E]
        return (self.fsdp, None)

    def spec_ssm_inner(self):             # mamba [D, 2*d_inner] etc.
        return (self.fsdp, self.wmodel)

    def spec_ssm_inner_t(self):           # [d_inner, D]
        return (self.wmodel, self.fsdp)

    def spec_vec(self):                   # [D]-shaped (norm scales)
        return (None,)

    def spec_vec_inner(self):             # [d_inner]
        return (self.model,)

    # -- KV-cache specs --------------------------------------------------- #
    def spec_kv_cache(self):
        # [layers, B, S, KV*dup, Dh]
        seq = self.cache_seq_axes if self.cache_seq_axes else None
        if self.attn.kind == "tp":
            return (None, self.batch, seq, self.model, None)
        return (None, self.batch, self.model if not seq else seq, None, None)

    def spec_ssm_cache(self):
        # mamba: [layers, B, d_inner, N]; rwkv: [layers, B, H, Dk, Dv]
        return (None, self.batch, self.model, None)

    def spec_rwkv_cache(self):
        return (None, self.batch, self.model, None, None)

    def spec_conv_cache(self):
        # [layers, B, conv_w-1, d_inner]
        return (None, self.batch, None, self.model)
