"""Map the LM parameter tree to NamedShardings via path-based rules.

Weights are TP-sharded over `model` on the dimension the rules pick and
FSDP-sharded over `data` on a complementary dimension; stacked period
leaves get an extra unsharded leading (layer) axis. See docs/design.md §5.
"""
from __future__ import annotations


import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..sharding.rules import ShardingRules


def _spec_for(path_names, leaf_name, rules: ShardingRules):
    r = rules
    n = leaf_name
    if n in ("embed",):
        return r.spec_embed()
    if n == "unembed":
        return r.spec_unembed()
    if n == "pos_embed":
        return (None, r.fsdp)
    if n in ("final_norm",):
        return (None,)
    # attention
    if n in ("wq", "wk", "wv"):
        return r.spec_attn_qkv()
    if n == "wo" and "attn" in path_names or n == "wo" and "cross" in path_names:
        return r.spec_attn_o()
    if n in ("q_norm", "k_norm"):
        return (None,)
    # dense mlp
    if n in ("w_gate", "w_up") and "moe" not in path_names:
        return r.spec_mlp_in()
    if n == "w_down" and "moe" not in path_names:
        return r.spec_mlp_out()
    # moe
    if n == "router":
        return r.spec_router()
    if n in ("w_gate", "w_up"):
        return r.spec_moe_in()
    if n == "w_down":
        return r.spec_moe_out()
    # rwkv
    if n in ("w_r", "w_k", "w_v", "w_g"):
        return (r.fsdp, r.wmodel)
    if n == "w_o":
        return (r.wmodel, r.fsdp)
    if n in ("maa_w1", "decay_w1"):
        return (r.fsdp, None)
    if n == "maa_w2":
        return (None, None, r.fsdp)
    if n == "decay_w2":
        return (None, r.wmodel)
    if n == "maa_base":
        return (None, None)
    if n in ("maa_x", "decay_base", "cm_mu_k", "cm_mu_r",
             "ln1", "ln2", "ln_attn", "ln_ffn", "ln_cross",
             "conv_b_dummy"):
        return (None,)
    if n in ("bonus", "gn_scale"):
        return (r.wmodel, None)
    if n == "cm_k":
        return (r.fsdp, r.wmodel)
    if n == "cm_v":
        return (r.wmodel, r.fsdp)
    if n == "cm_r":
        return (r.fsdp, None)
    # mamba
    if n == "in_proj":
        return (r.fsdp, r.wmodel)
    if n == "conv_w":
        return (None, r.wmodel)
    if n in ("conv_b", "dt_bias", "D_skip"):
        return (r.wmodel,)
    if n == "x_proj":
        return (r.wmodel, None)
    if n == "dt_proj":
        return (None, r.wmodel)
    if n == "A_log":
        return (r.wmodel, None)
    if n == "out_proj":
        return (r.wmodel, r.fsdp)
    if n in ("dt_norm", "B_norm", "C_norm", "norm"):
        return (None,)
    return None     # fall back to replicated-with-rank


def _path_names(path):
    out = []
    for e in path:
        if hasattr(e, "key"):
            out.append(str(e.key))
        elif hasattr(e, "name"):
            out.append(str(e.name))
    return out


def param_shardings(abstract_params, rules: ShardingRules):
    """Pytree of NamedShardings matching `abstract_params`."""
    if rules.mesh is None:
        return jax.tree.map(lambda _: None, abstract_params)

    def f(path, leaf):
        names = _path_names(path)
        spec = _spec_for(names, names[-1], rules)
        if spec is None:
            spec = (None,) * leaf.ndim
        stacked = any(p in ("periods_zo", "periods_bp", "periods") for p in names)
        if stacked:
            spec = (None,) + tuple(spec)
        if len(spec) != leaf.ndim:
            spec = tuple(spec) + (None,) * (leaf.ndim - len(spec))
            spec = spec[:leaf.ndim]
        # drop shardings that do not divide the dim evenly
        fixed = []
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= rules.mesh.shape[a]
            fixed.append(ax if dim % size == 0 else None)
        return NamedSharding(rules.mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(f, abstract_params)


def cache_shardings(abstract_caches, rules: ShardingRules):
    """Shardings for the (zo, bp) cache pytree by leaf rank/kind."""
    if rules.mesh is None:
        return jax.tree.map(lambda _: None, abstract_caches)

    def f(path, leaf):
        names = _path_names(path)
        n = names[-1] if names else ""
        if n in ("k", "v", "ck", "cv"):
            spec = rules.spec_kv_cache()
        elif n == "ssm":
            spec = rules.spec_ssm_cache()
        elif n == "wkv":
            spec = rules.spec_rwkv_cache()
        elif n == "conv":
            spec = rules.spec_conv_cache()
        elif n in ("tm_shift", "cm_shift"):
            spec = (None, rules.batch, None, None)
        else:
            spec = (None,) * leaf.ndim
        spec = tuple(spec)[:leaf.ndim] + (None,) * max(0, leaf.ndim - len(spec))
        fixed = []
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= rules.mesh.shape[a]
            fixed.append(ax if dim % size == 0 else None)
        return NamedSharding(rules.mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(f, abstract_caches)
