"""repro.obs — the flight recorder's public surface.

One process-wide recorder (default: the no-op ``NullRecorder``), one
structured-log front door, and the CLI plumbing every launch script
shares:

    from repro import obs

    rec = obs.get()                     # hoist in hot loops
    with rec.span("fleet/step", track="fleet", step=s):
        ...
    rec.counter("fleet.wire.uplink_bytes").inc(rec_bytes)
    obs.log("fleet", f"step {s} loss {loss:.4f}", step=s, loss=loss)

``obs.log`` is the quiet/verbose switch the fleet/gossip progress
lines route through: it always lands in the event log when a recorder
is armed, and mirrors to stdout unless verbosity is "quiet" — so
library code never calls ``print`` directly, and CLIs/users decide
what reaches the terminal.

CLI integration (launch/train.py, launch/fleet.py, launch/serve.py):

    obs.add_observability_args(parser)   # --trace/--metrics/--quiet
    obs.configure_from_args(args)        # installs a Recorder if needed
    ...run...
    obs.write_outputs(args)              # writes trace/metrics files
"""
from __future__ import annotations

from .recorder import (Counter, Gauge, Histogram, NullRecorder, Recorder,
                       monotonic, perf_ns)
from .memory import MemoryLedger, NullMemoryLedger
from . import export
from . import memory

__all__ = ["Counter", "Gauge", "Histogram", "NullRecorder", "Recorder",
           "MemoryLedger", "NullMemoryLedger",
           "monotonic", "perf_ns", "get", "install", "uninstall", "log",
           "set_verbosity", "get_verbosity", "add_observability_args",
           "configure_from_args", "write_outputs", "export", "memory"]

_NULL = NullRecorder()
_RECORDER = _NULL

# "verbose" preserves the historical CLI behavior (progress lines on
# stdout); "quiet" silences library progress output entirely. The
# event log is unaffected either way.
_VERBOSITY = "verbose"


def get():
    """The process-wide recorder (NullRecorder unless installed)."""
    return _RECORDER


def install(rec=None) -> Recorder:
    """Arm a recorder process-wide; returns it. ``install()`` makes a
    fresh one."""
    global _RECORDER
    if rec is None:
        rec = Recorder()
    _RECORDER = rec
    return rec


def uninstall():
    """Back to the no-op singleton (the numerics-inert tests flip this
    between instrumented and reference runs)."""
    global _RECORDER
    _RECORDER = _NULL


def set_verbosity(level: str):
    if level not in ("quiet", "verbose"):
        raise ValueError(f"verbosity must be quiet|verbose, got {level!r}")
    global _VERBOSITY
    _VERBOSITY = level


def get_verbosity() -> str:
    return _VERBOSITY


def log(channel: str, msg: str, level: str = "info", **fields):
    """Structured progress line: event-log record + optional stdout echo.

    The one sanctioned replacement for library ``print(f"[x] ...")``
    calls: recorded (with scalar fields) when a recorder is armed,
    printed as the familiar ``[channel] msg`` line unless quiet.
    """
    rec = _RECORDER
    if rec.enabled:
        rec.event(msg, track=channel, level=level, **fields)
    if _VERBOSITY != "quiet":
        print(f"[{channel}] {msg}", flush=True)


# ------------------------------------------------------------------ #
# CLI plumbing
# ------------------------------------------------------------------ #


def add_observability_args(parser):
    """Attach the shared --trace/--metrics/--memory/--quiet flags."""
    g = parser.add_argument_group("observability")
    g.add_argument("--trace", metavar="PATH", default=None,
                   help="write a Chrome-trace/Perfetto JSON here")
    g.add_argument("--metrics", metavar="PATH", default=None,
                   help="write the metrics snapshot JSON here")
    g.add_argument("--memory", metavar="PATH", default=None,
                   help="write the memory-ledger report JSON here "
                        "(tagged live/peak bytes + jax.live_arrays "
                        "reconciliation; arms the recorder)")
    g.add_argument("--quiet", action="store_true",
                   help="suppress library progress lines on stdout")
    return parser


def configure_from_args(args):
    """Install a Recorder iff --trace/--metrics/--memory was passed;
    apply --quiet. Returns the active recorder either way."""
    if getattr(args, "quiet", False):
        set_verbosity("quiet")
    if getattr(args, "trace", None) or getattr(args, "metrics", None) \
            or getattr(args, "memory", None):
        return install()
    return get()


def write_outputs(args):
    """Flush --trace/--metrics/--memory files (no-op when absent)."""
    rec = get()
    if not rec.enabled:
        return
    trace = getattr(args, "trace", None)
    if trace:
        export.write_chrome_trace(rec, trace)
    metrics = getattr(args, "metrics", None)
    if metrics:
        export.write_metrics(rec, metrics)
    mem = getattr(args, "memory", None)
    if mem:
        memory.sample()          # final reconciliation before the dump
        import json
        with open(mem, "w") as f:
            json.dump(rec.memory.snapshot(), f, indent=1, sort_keys=True)
        log("obs", f"wrote memory ledger to {mem}")
