"""Exporters: Chrome-trace/Perfetto JSON + metrics snapshot files.

``chrome_trace`` turns a Recorder's spans and events into the Trace
Event Format that chrome://tracing and https://ui.perfetto.dev load
directly: one fake process, one *thread per track* (named via ``M``
metadata events), ``X`` complete events for spans (``ts``/``dur`` in
microseconds), ``i`` instant events for the structured log.

``validate_chrome_trace`` is the schema gate CI runs on every emitted
trace (and tests run on round-trips): it must *reject* malformed
documents, not merely parse them — a trace that silently drops spans
would un-attribute exactly the costs this subsystem exists to pin.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

__all__ = ["chrome_trace", "write_chrome_trace", "write_metrics",
           "validate_chrome_trace", "load_chrome_trace"]

# Stable track order → stable tid assignment across runs, so diffs of
# two traces line up in the viewer. Unknown tracks append after.
_TRACK_ORDER = ("main", "engine", "train", "fleet", "serve")


def _tid_map(tracks: List[str]) -> Dict[str, int]:
    ordered = [t for t in _TRACK_ORDER if t in tracks]
    ordered += sorted(t for t in tracks if t not in _TRACK_ORDER)
    return {t: i + 1 for i, t in enumerate(ordered)}


def chrome_trace(rec) -> Dict[str, Any]:
    """Render a Recorder to a Chrome Trace Event Format document."""
    spans = list(rec.spans)
    events = list(rec.events)
    tracks = sorted({s["track"] for s in spans}
                    | {e["track"] for e in events})
    tids = _tid_map(tracks)
    out: List[Dict[str, Any]] = [
        {"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
         "args": {"name": "repro"}},
    ]
    for t, tid in tids.items():
        out.append({"ph": "M", "name": "thread_name", "pid": 1, "tid": tid,
                    "args": {"name": t}})
        out.append({"ph": "M", "name": "thread_sort_index", "pid": 1,
                    "tid": tid, "args": {"sort_index": tid}})
    for s in spans:
        ev = {"ph": "X", "name": s["name"], "cat": s["track"],
              "pid": 1, "tid": tids[s["track"]],
              "ts": s["ts"] / 1e3, "dur": s["dur"] / 1e3}
        if s.get("args"):
            ev["args"] = s["args"]
        out.append(ev)
    for e in events:
        ev = {"ph": "i", "name": e["name"], "cat": e["track"],
              "pid": 1, "tid": tids[e["track"]],
              "ts": e["ts"] / 1e3, "s": "t"}
        if e.get("fields"):
            ev["args"] = dict(e["fields"], level=e["level"])
        out.append(ev)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(rec, path) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(rec), f, indent=1)


def write_metrics(rec, path) -> None:
    with open(path, "w") as f:
        json.dump(rec.snapshot(), f, indent=2, sort_keys=True)


def validate_chrome_trace(doc: Any) -> List[Dict[str, Any]]:
    """Assert ``doc`` is a loadable Trace Event Format document.

    Returns the event list on success; raises ``ValueError`` naming the
    first offending event otherwise. Checks the subset Perfetto needs:
    the ``traceEvents`` envelope, per-event ``ph``/``name``/``pid``/
    ``tid``, numeric non-negative ``ts``, and numeric non-negative
    ``dur`` on every ``X`` event.
    """
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a Chrome trace: missing traceEvents envelope")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("traceEvents must be a list")
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        for key in ("ph", "name", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"traceEvents[{i}] missing {key!r}")
        ph = ev["ph"]
        if ph not in ("X", "M", "i", "B", "E", "C"):
            raise ValueError(f"traceEvents[{i}] unknown phase {ph!r}")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                raise ValueError(f"traceEvents[{i}] bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"traceEvents[{i}] bad dur {dur!r}")
    return evs


def load_chrome_trace(path) -> List[Dict[str, Any]]:
    """Load + validate a trace file; returns its event list."""
    with open(path) as f:
        doc = json.load(f)
    return validate_chrome_trace(doc)
