"""The flight recorder: spans, metrics, events — one process-wide canon.

The paper's whole argument is a cost ledger (forwards traded for
backward memory, int8 traded for fp32 time); this module is the
instrument that ledger is kept with. Three primitives, one recorder:

  * **spans** — nestable wall-clock intervals on named *tracks*
    (``engine``, ``train``, ``fleet``, ``serve``), timed with
    ``time.perf_counter_ns`` (monotonic — immune to NTP clock steps,
    unlike the ``time.time()`` deltas this replaced). Nesting depth is
    tracked per thread; the Chrome-trace exporter (obs/export.py) lays
    sibling spans out on their track.
  * **metrics** — a typed registry: ``Counter`` (monotone accumulate),
    ``Gauge`` (last value wins), ``Histogram`` (count/sum/min/max plus
    power-of-two buckets for percentile estimates). Scalar,
    allocation-free on the observe path.
  * **events** — a structured log: instant records with a name, a
    track, and scalar fields. Library progress lines route through
    ``obs.log`` (obs/__init__.py) so stdout is a *view* of the event
    log, not the log itself.

The default recorder is ``NullRecorder`` — a no-op singleton whose
``span``/``counter``/``gauge``/``histogram`` return cached null objects,
so an uninstrumented process pays one attribute check per call site and
allocates nothing. Hot loops hoist ``rec = obs.get()`` and guard
device syncs with ``rec.enabled``.

The design constraint, pinned by tests/test_obs_inert.py: recording is
**numerics-inert**. The recorder only ever wraps host-side control flow
and never reaches inside a jitted program — an instrumented fleet chaos
run is bit-exact against the uninstrumented reference.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Any, Dict, List

from .memory import MemoryLedger, NullMemoryLedger

__all__ = ["Counter", "Gauge", "Histogram", "Recorder", "NullRecorder",
           "monotonic", "perf_ns"]

perf_ns = time.perf_counter_ns


def monotonic() -> float:
    """The repo's one monotonic wall clock (seconds, float).

    Use for *durations*: ``time.time()`` deltas go negative under NTP
    clock steps. ``time.time()`` remains correct for wall-clock
    *stamps* (checkpoint manifests keep it).
    """
    return time.perf_counter()


# ------------------------------------------------------------------ #
# metrics
# ------------------------------------------------------------------ #


class Counter:
    """Monotone accumulator (int or float)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, v=1):
        self.value += v


class Gauge:
    """Last-value-wins scalar."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v):
        self.value = float(v)


class Histogram:
    """Scalar distribution: count/sum/min/max + power-of-two buckets.

    Buckets hold counts per ``ceil(log2(v))`` so percentiles are
    estimated to within a factor of two at any scale with O(1) memory —
    good enough for latency attribution, bounded for long-lived
    engines (unlike keeping samples).
    """

    __slots__ = ("count", "total", "vmin", "vmax", "buckets")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.buckets: Dict[int, int] = {}

    def observe(self, v):
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        b = math.ceil(math.log2(v)) if v > 0 else -1074  # 0/neg underflow bin
        self.buckets[b] = self.buckets.get(b, 0) + 1

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket holding the q-quantile sample."""
        if not self.count:
            return 0.0
        target = max(math.ceil(q * self.count), 1)
        seen = 0
        for b in sorted(self.buckets):
            seen += self.buckets[b]
            if seen >= target:
                return float(2.0 ** b) if b > -1074 else 0.0
        return self.vmax

    def summary(self) -> Dict[str, Any]:
        """Self-contained snapshot row: moments, computed percentiles,
        AND the raw power-of-two buckets (keyed by the stringified
        exponent so the dict survives a JSON round-trip) — a BENCH file
        is diffable without access to the live Histogram."""
        if not self.count:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0,
                    "buckets": {}}
        return {"count": self.count, "sum": self.total,
                "min": self.vmin, "max": self.vmax,
                "mean": self.total / self.count,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99),
                "buckets": {str(b): self.buckets[b]
                            for b in sorted(self.buckets)}}


# ------------------------------------------------------------------ #
# spans
# ------------------------------------------------------------------ #


class _Span:
    """One live span; re-use via ``with rec.span(...) as sp`` and read
    ``sp.dur_ns`` after exit (e.g. to feed a histogram)."""

    __slots__ = ("rec", "name", "track", "args", "t0", "depth", "dur_ns")

    def __init__(self, rec: "Recorder", name: str, track: str, args):
        self.rec = rec
        self.name = name
        self.track = track
        self.args = args
        self.t0 = 0
        self.depth = 0
        self.dur_ns = 0

    def __enter__(self):
        stack = self.rec._stack()
        self.depth = len(stack)
        stack.append(self)
        self.t0 = perf_ns()
        return self

    def __exit__(self, *exc):
        self.dur_ns = perf_ns() - self.t0
        self.rec._stack().pop()
        self.rec._finish(self)
        return False


class _NullSpan:
    """The shared no-op span: zero allocations on the disabled path."""

    __slots__ = ()
    dur_ns = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


class _NullMetric:
    """The shared no-op Counter/Gauge/Histogram."""

    __slots__ = ()
    value = 0
    count = 0

    def inc(self, v=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    def summary(self):
        return {}


_NULL_SPAN = _NullSpan()
_NULL_METRIC = _NullMetric()


# ------------------------------------------------------------------ #
# recorders
# ------------------------------------------------------------------ #


class Recorder:
    """An armed flight recorder. Install via ``obs.install`` /
    ``obs.configure``; read back via ``snapshot()`` (metrics dict) and
    ``obs.export.chrome_trace`` (span/event timeline)."""

    enabled = True

    def __init__(self):
        self.t0_ns = perf_ns()
        self.spans: List[Dict[str, Any]] = []   # finished, completion order
        self.events: List[Dict[str, Any]] = []
        self.memory = MemoryLedger()            # tagged live-bytes registry
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self._lock = threading.Lock()
        self._tls = threading.local()

    # ---- spans -------------------------------------------------------- #
    def span(self, name: str, track: str = "main", **args) -> _Span:
        return _Span(self, name, track, args or None)

    def _stack(self) -> list:
        s = getattr(self._tls, "stack", None)
        if s is None:
            s = self._tls.stack = []
        return s

    def _finish(self, sp: _Span):
        rec = {"name": sp.name, "track": sp.track,
               "ts": sp.t0 - self.t0_ns, "dur": sp.dur_ns,
               "depth": sp.depth}
        if sp.args:
            rec["args"] = sp.args
        with self._lock:
            self.spans.append(rec)

    # ---- events ------------------------------------------------------- #
    def event(self, name: str, track: str = "main",
              level: str = "info", **fields):
        rec = {"name": name, "track": track, "level": level,
               "ts": perf_ns() - self.t0_ns}
        if fields:
            rec["fields"] = fields
        with self._lock:
            self.events.append(rec)

    # ---- metrics ------------------------------------------------------ #
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter())
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge())
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(name, Histogram())
        return h

    # ---- readback ----------------------------------------------------- #
    def span_totals(self) -> Dict[str, Dict[str, float]]:
        """Aggregate finished spans by name: count / total / mean ms."""
        agg: Dict[str, Dict[str, float]] = {}
        with self._lock:
            spans = list(self.spans)
        for s in spans:
            a = agg.setdefault(s["name"], {"count": 0, "total_ms": 0.0})
            a["count"] += 1
            a["total_ms"] += s["dur"] / 1e6
        for a in agg.values():
            a["mean_ms"] = a["total_ms"] / a["count"]
        return agg

    def snapshot(self) -> Dict[str, Any]:
        """The metrics snapshot dict benchmarks merge into BENCH_*.json."""
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self._hists.items())},
            "spans": self.span_totals(),
            "memory": self.memory.snapshot(),
        }

    def reset(self):
        """Drop all recorded data (keeps the registry identity)."""
        with self._lock:
            self.spans.clear()
            self.events.clear()
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self.t0_ns = perf_ns()
        self.memory.reset()


class NullRecorder:
    """The default: every primitive returns a cached no-op object.

    A disabled call site costs one method call and allocates nothing —
    hot loops additionally guard with ``rec.enabled`` so even the call
    disappears (and device syncs never run).
    """

    enabled = False
    spans: List[Dict[str, Any]] = []     # always empty; read-only views
    events: List[Dict[str, Any]] = []
    memory = NullMemoryLedger()          # shared no-op ledger

    def span(self, name, track="main", **args):
        return _NULL_SPAN

    def event(self, name, track="main", level="info", **fields):
        pass

    def counter(self, name):
        return _NULL_METRIC

    def gauge(self, name):
        return _NULL_METRIC

    def histogram(self, name):
        return _NULL_METRIC

    def span_totals(self):
        return {}

    def snapshot(self):
        return {}

    def reset(self):
        pass
