"""The measured memory ledger: tagged live-bytes + JAX reconciliation.

The paper's headline claim is a *memory* tradeoff (ZO trains in nearly
inference memory; ElasticZO's BP tail adds 0.072-1.7%; INT8 cuts usage
1.46-1.60x), and until this module the repo only evaluated it
analytically (Eqs. 2-4 / 13-15 in benchmarks/paper_tables.py). This is
the instrument that turns those derivations into measurements. Three
layers:

  * **tagged registry** (``MemoryLedger``) — each subsystem registers
    the buffers it owns under a dotted tag (``train.params``,
    ``serve.kv_pages``, ``fleet.ledger.zo`` ... see
    docs/observability.md for the catalog) with O(1) alloc/free
    accounting, per-tag and total high-water marks, and optional *keys*
    for double-free / leak detection. ``region(name)`` brackets a code
    range and records its total-live high-water mark, the per-span
    analogue of a peak-RSS probe.
  * **sampling hook** (``sample``) — walks ``jax.live_arrays()`` (and
    device ``memory_stats()`` where the backend has them; CPU returns
    none) and reconciles what JAX actually holds against the tagged
    total, reporting the **untagged residual**. A residual that grows
    is a subsystem allocating outside its tag — exactly the silent
    regression the analytic tables can never see.
  * **compiled footprint** (``compiled_footprint``) — XLA's
    buffer-assignment stats (``Compiled.memory_analysis()``) for one
    jitted program: argument/output/temp bytes and their aliasing.
    ``jax.live_arrays()`` cannot see inside a jitted program, so this
    is the measured-peak instrument for a *step* — it is what puts
    measured numbers next to the paper's Eq. 2-4/13-15 analytic model
    in BENCH_paper.json (benchmarks/paper_tables.py).

Like every recorder primitive the ledger is numerics-inert (pinned by
tests/test_obs_inert.py with memory tracking armed): it only ever reads
host-visible metadata (``.nbytes`` — never a device sync) and the
NullRecorder carries a no-op ``NullMemoryLedger`` so untagged processes
pay one attribute check per call site.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Hashable, Optional

__all__ = ["MemoryLedger", "NullMemoryLedger", "tree_nbytes",
           "compiled_footprint", "device_memory_stats", "sample"]


def tree_nbytes(tree) -> int:
    """Total bytes of a pytree's array leaves.

    Reads ``.nbytes`` metadata only — never forces a transfer or sync,
    so it is safe on the hot path. Leaves without ``.nbytes`` (python
    scalars, None) contribute 0. Works on jax Arrays, numpy arrays, and
    QTensor trees alike (QTensor is a pytree of arrays).
    """
    import jax
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        nb = getattr(leaf, "nbytes", None)
        if nb is not None:
            total += int(nb)
    return total


def device_memory_stats() -> Optional[Dict[str, int]]:
    """Byte-valued ``memory_stats()`` of device 0, or None.

    The CPU backend has no allocator stats (returns None) — callers
    must treat this as best-effort; ``jax.live_arrays()`` is the
    portable source of truth.
    """
    import jax
    try:
        st = jax.devices()[0].memory_stats()
    except Exception:
        return None
    if not st:
        return None
    return {k: int(v) for k, v in st.items()
            if "bytes" in k and isinstance(v, (int, float))}


def compiled_footprint(fn, *args, static_argnums=(), donate_argnums=()):
    """Measured XLA buffer-assignment footprint of ``fn(*args)``.

    Lowers and compiles (without executing) and reads
    ``Compiled.memory_analysis()``:

      * ``argument_bytes`` — live inputs (params, batch, masks);
      * ``output_bytes``  — live outputs (new state, metrics);
      * ``temp_bytes``    — XLA's temp allocation: the peak of all
        intermediates (activations, ZO perturbations, tail grads) under
        its buffer-assignment liveness analysis;
      * ``alias_bytes``   — input/output aliasing (donation) credit;
      * ``peak_bytes``    — argument + output + temp - alias: what the
        device must hold to run one step.

    ``fn`` may be a plain callable (it is jitted here) or an already
    ``jax.jit``-wrapped function. Returns None if the backend offers no
    memory analysis.
    """
    import jax
    jfn = fn if isinstance(fn, jax.stages.Wrapped) else jax.jit(
        fn, static_argnums=static_argnums, donate_argnums=donate_argnums)
    ma = jfn.lower(*args).compile().memory_analysis()
    if ma is None:
        return None

    def _get(attr):
        v = getattr(ma, attr, 0)
        return int(v) if v else 0

    arg = _get("argument_size_in_bytes")
    out = _get("output_size_in_bytes")
    tmp = _get("temp_size_in_bytes")
    alias = _get("alias_size_in_bytes")
    return {"argument_bytes": arg, "output_bytes": out, "temp_bytes": tmp,
            "generated_code_bytes": _get("generated_code_size_in_bytes"),
            "alias_bytes": alias,
            "peak_bytes": arg + out + tmp - alias}


class _Region:
    """An open total-live watermark bracket; ``with led.region("x"):``.

    Reads ``peak_bytes`` / ``floor_bytes`` after exit; the ledger also
    keeps a max-merged summary per region name in its snapshot.
    """

    __slots__ = ("ledger", "name", "floor_bytes", "peak_bytes")

    def __init__(self, ledger: "MemoryLedger", name: str):
        self.ledger = ledger
        self.name = name
        self.floor_bytes = 0
        self.peak_bytes = 0

    def __enter__(self):
        led = self.ledger
        with led._lock:
            self.floor_bytes = self.peak_bytes = led.total_live
            led._open_regions.append(self)
        return self

    def __exit__(self, *exc):
        led = self.ledger
        with led._lock:
            led._open_regions.remove(self)
            r = led.regions.setdefault(
                self.name, {"count": 0, "peak_bytes": 0, "hwm_delta_bytes": 0})
            r["count"] += 1
            r["peak_bytes"] = max(r["peak_bytes"], self.peak_bytes)
            r["hwm_delta_bytes"] = max(r["hwm_delta_bytes"],
                                       self.peak_bytes - self.floor_bytes)
        return False


class _NullRegion:
    __slots__ = ()
    floor_bytes = 0
    peak_bytes = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_REGION = _NullRegion()


class MemoryLedger:
    """Tagged live-bytes accounting with peaks, keys, and reconciliation.

    Two registration styles:

      * ``alloc(tag, nbytes, key=...)`` / ``free(tag, key=...)`` — paired
        lifetime tracking. A ``key`` (any hashable) arms double-alloc /
        double-free detection and lets ``free`` omit the size;
        ``leaks()`` lists whatever keyed allocations are still
        outstanding.
      * ``rebind(tag, nbytes, key)`` — idempotent registration for
        long-lived buffers that are *replaced*, not freed (params after
        an optimizer step): live bytes adjust by the delta.

    All mutation happens under one lock; reads used on hot paths
    (``total_live``) are plain attribute loads.
    """

    armed = True

    def __init__(self):
        self._lock = threading.Lock()
        self.live: Dict[str, int] = {}
        self.peak: Dict[str, int] = {}
        self.total_live = 0
        self.total_peak = 0
        self.n_allocs = 0
        self.n_frees = 0
        self.regions: Dict[str, Dict[str, int]] = {}
        self.last_sample: Optional[Dict[str, Any]] = None
        self._keyed: Dict[tuple, int] = {}
        self._open_regions: list = []

    # ---- registry ----------------------------------------------------- #
    def _bump(self, tag: str, delta: int):
        v = self.live.get(tag, 0) + delta
        self.live[tag] = v
        self.total_live += delta
        if v > self.peak.get(tag, 0):
            self.peak[tag] = v
        if self.total_live > self.total_peak:
            self.total_peak = self.total_live
        for r in self._open_regions:
            if self.total_live > r.peak_bytes:
                r.peak_bytes = self.total_live

    def alloc(self, tag: str, nbytes: int, key: Hashable = None) -> int:
        nbytes = int(nbytes)
        if nbytes < 0:
            raise ValueError(f"alloc({tag!r}) with negative size {nbytes}")
        with self._lock:
            if key is not None:
                k = (tag, key)
                if k in self._keyed:
                    raise KeyError(f"double alloc of {tag}:{key!r}")
                self._keyed[k] = nbytes
            self._bump(tag, nbytes)
            self.n_allocs += 1
        return nbytes

    def free(self, tag: str, nbytes: Optional[int] = None,
             key: Hashable = None):
        with self._lock:
            if key is not None:
                k = (tag, key)
                if k not in self._keyed:
                    raise KeyError(
                        f"double free / unknown allocation {tag}:{key!r}")
                bound = self._keyed.pop(k)
                if nbytes is None:
                    nbytes = bound
                elif int(nbytes) != bound:
                    raise ValueError(
                        f"free({tag}:{key!r}) size {nbytes} != "
                        f"allocated {bound}")
            if nbytes is None:
                raise ValueError("free() needs nbytes or key")
            nbytes = int(nbytes)
            if nbytes > self.live.get(tag, 0):
                raise ValueError(
                    f"free({tag!r}) of {nbytes} bytes exceeds live "
                    f"{self.live.get(tag, 0)}")
            self._bump(tag, -nbytes)
            self.n_frees += 1

    def rebind(self, tag: str, nbytes: int, key: Hashable) -> int:
        nbytes = int(nbytes)
        if nbytes < 0:
            raise ValueError(f"rebind({tag!r}) with negative size {nbytes}")
        with self._lock:
            k = (tag, key)
            old = self._keyed.get(k)
            if old is None:
                self.n_allocs += 1
                old = 0
            self._keyed[k] = nbytes
            self._bump(tag, nbytes - old)
        return nbytes

    def region(self, name: str) -> _Region:
        return _Region(self, name)

    def leaks(self) -> Dict[str, int]:
        """Outstanding keyed allocations as {"tag:key": nbytes}."""
        with self._lock:
            return {f"{tag}:{key}": nb
                    for (tag, key), nb in sorted(
                        self._keyed.items(), key=lambda kv: str(kv[0]))}

    # ---- reconciliation ----------------------------------------------- #
    def sample(self) -> Dict[str, Any]:
        """Reconcile tagged bytes against what JAX actually holds.

        ``untagged_bytes`` is the residual: device-resident arrays no
        subsystem has claimed. It can be negative when a tag registers
        logical bytes for host-side state (e.g. the fleet ledger's wire
        records live in numpy, outside jax.live_arrays()).
        """
        import jax
        live = 0
        n = 0
        for a in jax.live_arrays():
            nb = getattr(a, "nbytes", None)
            if nb is not None:
                live += int(nb)
                n += 1
        out: Dict[str, Any] = {
            "jax_live_bytes": live, "jax_live_arrays": n,
            "tagged_bytes": self.total_live,
            "untagged_bytes": live - self.total_live,
        }
        dstats = device_memory_stats()
        if dstats is not None:
            out["device"] = dstats
        with self._lock:
            self.last_sample = out
        return out

    # ---- readback ----------------------------------------------------- #
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "live": dict(sorted(self.live.items())),
                "peak": dict(sorted(self.peak.items())),
                "total_live_bytes": self.total_live,
                "total_peak_bytes": self.total_peak,
                "n_allocs": self.n_allocs,
                "n_frees": self.n_frees,
                "n_outstanding": len(self._keyed),
                "regions": {k: dict(v)
                            for k, v in sorted(self.regions.items())},
                "sample": dict(self.last_sample) if self.last_sample else None,
            }

    def reset(self):
        with self._lock:
            self.live.clear()
            self.peak.clear()
            self.total_live = 0
            self.total_peak = 0
            self.n_allocs = 0
            self.n_frees = 0
            self.regions.clear()
            self.last_sample = None
            self._keyed.clear()
            self._open_regions.clear()


class NullMemoryLedger:
    """The no-op twin riding NullRecorder: every call disappears."""

    armed = False
    live: Dict[str, int] = {}
    peak: Dict[str, int] = {}
    total_live = 0
    total_peak = 0

    def alloc(self, tag, nbytes, key=None):
        return 0

    def free(self, tag, nbytes=None, key=None):
        pass

    def rebind(self, tag, nbytes, key):
        return 0

    def region(self, name):
        return _NULL_REGION

    def leaks(self):
        return {}

    def sample(self):
        return None

    def snapshot(self):
        return {}

    def reset(self):
        pass


def sample() -> Optional[Dict[str, Any]]:
    """Sample + reconcile via the installed recorder; sets memory.*
    gauges (memory.tagged_bytes / jax_live_bytes / untagged_bytes).
    No-op (returns None) when no recorder is armed.
    """
    from . import get
    rec = get()
    led = rec.memory
    if not led.armed:
        return None
    s = led.sample()
    rec.gauge("memory.tagged_bytes").set(s["tagged_bytes"])
    rec.gauge("memory.jax_live_bytes").set(s["jax_live_bytes"])
    rec.gauge("memory.untagged_bytes").set(s["untagged_bytes"])
    return s
