"""Single-process reference of the fleet semantics, for train_loop.run.

The acceptance bar for repro.fleet is not "close": an 8-worker chaos run
must reproduce a single-process run bit-exactly — in both lanes, and
now *with Byzantine workers in the loop*. This module is that single
process: one step function that computes every worker's probe block
(fp32: quantizing every worker's tail with its own error-feedback
residual; int8: exact NITI payloads, no residual), applies the same
deterministic record tampering (fleet/adversary.py), routes the result
through the same Byzantine-robust gate (fleet/robust.py) the
coordinator runs, and applies the identical engine-routed replay update
— sharing the very same jitted callables (worker.make_probe_fn /
make_int8_probe_fn / make_quantize_fn) the fleet workers use, so there
is no cross-program rounding to hand-wave about.

Two driving modes, selected by the schema:

  * filter-free (fleet.robust is None and no byzantine specs): the
    probe_mask fed by LoopConfig.mask_fn is the *realized commit mask*
    of a fleet run — the pre-robust contract, unchanged.
  * Byzantine (robust config and/or byzantine specs present): the
    probe_mask is the *realized candidate mask* (FleetResult.
    arrival_masks — on-time arrivals plus late admissions, before any
    gate verdict); the reference re-derives validation, quarantine, and
    the scalar/loss filter itself through the verbatim commit-rule
    pipeline (fleet/commit_rule.py) and its own RobustGate, and must
    land on the bit-identical Commit (v2) and parameter stream — no
    matter which topology (star coordinator or leaderless gossip
    peers) produced the masks.

It is a host-side composite (run it with LoopConfig(jit=False)): jitting
the whole step would re-fuse the shared sub-programs and shift the fp32
stream by FMA-contraction ulps (see kernels/ref.zo_fused_replay_ref).

Worker-local state (the fp32 EF residuals) rides inside ``state.params``
as ``{"model": ..., "residual": [one tail tree per worker]}`` so restart
semantics stay a pure function of the checkpointed state. The int8 lane
has no residual (its payloads are exact); the slot holds Nones. A
Byzantine worker's residual follows the *honest* pending residual —
tampering is wire-only (fleet/adversary.py), exactly like the fleet.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

import jax.numpy as jnp

from ..configs.base import LaneConfig
from ..core.elastic import TrainState
from .adversary import Adversary, build_adversaries
from .commit_rule import close_candidates, committed_arrays, step_loss
from .ledger import Commit
from .replay import ReplaySchema, apply_committed, probe_seeds
from .robust import RobustGate
from .worker import (compute_record, make_probe_fn, make_quantize_fn,
                     zero_residual)


def reference_state(params, schema: ReplaySchema, seed) -> TrainState:
    """Initial TrainState with per-worker EF residuals alongside the model."""
    residual = [zero_residual(schema)
                for _ in range(schema.fleet.num_workers)]
    return TrainState({"model": params, "residual": residual},
                      jnp.int32(0), jnp.asarray(seed))


def make_reference_step(loss_fn: Callable, schema: ReplaySchema,
                        probe_fn=None, quantize_fn=None,
                        adversaries: Optional[Dict[int, Adversary]] = None):
    """(state, batch, probe_mask) -> (state, metrics), fleet semantics.

    probe_mask fp32[n_probes] is block-constant per worker; pass the
    realized masks of a fleet run via LoopConfig.mask_fn to reproduce it
    (arrival_masks for Byzantine runs, masks otherwise), or a drop-rate
    stream to simulate one. For the int8 lane pass the shared
    ``probe_fn`` built by worker.make_int8_probe_fn (there is no
    loss_fn-derived default). ``adversaries`` defaults to the schema's
    own byzantine specs — pass {} to force the honest reference.
    """
    lane: LaneConfig = schema.lane
    fleet = schema.fleet
    W, m = fleet.num_workers, fleet.probes_per_worker
    if probe_fn is None:
        if schema.numerics != "fp32":
            raise ValueError(
                "int8 reference needs the shared make_int8_probe_fn "
                "callable")
        probe_fn = make_probe_fn(loss_fn, lane, schema.partition_fn)
    if quantize_fn is None and schema.numerics == "fp32":
        quantize_fn = make_quantize_fn()
    if adversaries is None:
        adversaries = build_adversaries(fleet)
    byzantine_path = bool(adversaries) or fleet.robust is not None
    gate = RobustGate(schema) if byzantine_path else None

    def step(state: TrainState, batch, probe_mask):
        t = int(state.step)
        model = state.params["model"]
        residuals = state.params["residual"]
        mask = np.asarray(probe_mask, np.float32)
        if mask.shape != (W * m,):
            raise ValueError(f"probe_mask shape {mask.shape} != "
                             f"({W * m},) for {W} workers x {m} probes")

        records, pendings = {}, {}
        for w in range(W):
            rec, pending = compute_record(model, residuals[w], batch, t, w,
                                          schema, probe_fn, quantize_fn)
            if w in adversaries:
                rec = adversaries[w].tamper(rec, t)
            records[w] = rec
            pendings[w] = pending

        if byzantine_path:
            # probe_mask = realized CANDIDATE mask (on-time | late-
            # admitted): close exactly like any leaderless closer — the
            # verbatim commit_rule pipeline (validation -> quarantine ->
            # filter), which over an all-on-time candidate set is the
            # coordinator's final gate verdict
            candidates = {w: records[w] for w in range(W) if mask[w * m] > 0}
            outcome = close_candidates(gate, t, candidates)
            gate.advance(t, outcome)
            commit = outcome.commit
        else:
            accepted_bits = 0
            for w in range(W):
                if mask[w * m] > 0:
                    accepted_bits |= 1 << w
            commit = Commit(t, accepted_bits)

        new_residuals = []
        for w in range(W):
            if commit.accepted >> w & 1:
                new_residuals.append(pendings[w])
            else:
                new_residuals.append(zero_residual(schema))
        cstep = committed_arrays(commit, records, schema)
        new_model = apply_committed(model, t, cstep, schema)
        # the canonical loss observation — a no-op step carries the
        # previous loss, exactly like every closer's loss_history
        loss = step_loss(cstep, schema, step.prev_loss)
        step.prev_loss = loss
        if schema.numerics == "int8":
            g = np.abs(np.asarray(cstep.deltas, np.float32))
        else:
            g = np.abs(np.asarray(cstep.deltas, np.float32)) \
                / np.float32(2.0 * lane.zo_eps)
        metrics = {"loss": jnp.float32(loss),
                   "zo_g": jnp.float32(float(np.sum(g)) / (W * m))}
        step.commits.append(commit)
        return TrainState({"model": new_model, "residual": new_residuals},
                          state.step + 1, state.seed), metrics

    step.commits = []     # derived Commit stream, for test cross-checks
    step.prev_loss = None  # carried across steps by step_loss
    return step


__all__ = ["make_reference_step", "reference_state", "probe_seeds"]
