"""Single-process reference of the fleet semantics, for train_loop.run.

The acceptance bar for repro.fleet is not "close": an 8-worker chaos run
must reproduce a single-process run bit-exactly — in both lanes. This
module is that single process: one step function that computes every
worker's probe block (fp32: quantizing every worker's tail with its own
error-feedback residual; int8: exact NITI payloads, no residual) and
applies the identical engine-routed replay update — sharing the very
same jitted callables (worker.make_probe_fn / make_int8_probe_fn /
make_quantize_fn) the fleet workers use, so there is no cross-program
rounding to hand-wave about.

It is a host-side composite (run it with LoopConfig(jit=False)): jitting
the whole step would re-fuse the shared sub-programs and shift the fp32
stream by FMA-contraction ulps (see kernels/ref.zo_fused_replay_ref).

Worker-local state (the fp32 EF residuals) rides inside ``state.params``
as ``{"model": ..., "residual": [one tail tree per worker]}`` so restart
semantics stay a pure function of the checkpointed state. The int8 lane
has no residual (its payloads are exact); the slot holds Nones.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.base import LaneConfig
from ..core.elastic import TrainState
from .ledger import Commit
from .replay import ReplaySchema, apply_step, probe_seeds, step_arrays
from .worker import (compute_record, make_probe_fn, make_quantize_fn,
                     zero_residual)


def reference_state(params, schema: ReplaySchema, seed) -> TrainState:
    """Initial TrainState with per-worker EF residuals alongside the model."""
    residual = [zero_residual(schema)
                for _ in range(schema.fleet.num_workers)]
    return TrainState({"model": params, "residual": residual},
                      jnp.int32(0), jnp.asarray(seed))


def make_reference_step(loss_fn: Callable, schema: ReplaySchema,
                        probe_fn=None, quantize_fn=None):
    """(state, batch, probe_mask) -> (state, metrics), fleet semantics.

    probe_mask fp32[n_probes] is block-constant per worker (the commit
    bitmask expanded); pass the realized masks of a fleet run via
    LoopConfig.mask_fn to reproduce it, or a drop-rate stream to simulate
    one. For the int8 lane pass the shared ``probe_fn`` built by
    worker.make_int8_probe_fn (there is no loss_fn-derived default).
    """
    lane: LaneConfig = schema.lane
    fleet = schema.fleet
    W, m = fleet.num_workers, fleet.probes_per_worker
    if probe_fn is None:
        assert schema.numerics == "fp32", \
            "int8 reference needs the shared make_int8_probe_fn callable"
        probe_fn = make_probe_fn(loss_fn, lane, schema.partition_fn)
    if quantize_fn is None and schema.numerics == "fp32":
        quantize_fn = make_quantize_fn()

    def step(state: TrainState, batch, probe_mask):
        t = int(state.step)
        model = state.params["model"]
        residuals = state.params["residual"]
        mask = np.asarray(probe_mask, np.float32)
        assert mask.shape == (W * m,)

        accepted_bits = 0
        records, new_residuals = {}, []
        for w in range(W):
            rec, pending = compute_record(model, residuals[w], batch, t, w,
                                          schema, probe_fn, quantize_fn)
            records[w] = rec
            if mask[w * m] > 0:
                accepted_bits |= 1 << w
                new_residuals.append(pending)
            else:
                new_residuals.append(zero_residual(schema))
        commit = Commit(t, accepted_bits)
        seeds, deltas, cmask, _ = step_arrays(commit, records, schema)
        new_model = apply_step(model, t, seeds, deltas, cmask, records,
                               schema)
        valid = max(float(cmask.sum()), 1.0)
        loss = sum(records[w].loss * m
                   for w in commit.workers(W)) / valid
        if schema.numerics == "int8":
            g = np.abs(np.asarray(deltas, np.float32))
        else:
            g = np.abs(deltas) / np.float32(2.0 * lane.zo_eps)
        metrics = {"loss": jnp.float32(loss),
                   "zo_g": jnp.float32(float(np.sum(g)) / (W * m))}
        return TrainState({"model": new_model, "residual": new_residuals},
                          state.step + 1, state.seed), metrics

    return step


__all__ = ["make_reference_step", "reference_state", "probe_seeds"]
