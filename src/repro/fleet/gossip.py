"""Leaderless fleet: epidemic record exchange, coordinator-free commits.

The star topology's single point of failure is the coordinator — not
because it owns any special math (a commit is a pure function of
(records, accepted mask), PR 2-4), but because only it was *allowed* to
close a step. This module cashes that purity in: ZO seed-ledger records
are 9-12 B/probe, so flooding every record to every peer costs almost
nothing, and once all peers of a connected component hold the same
record multiset, each closes the step independently through the SAME
pure pipeline (fleet/commit_rule.py) the coordinator uses — same
deadline gating on origin fates, same RobustGate, same
highest-worker-id tiebreak — and derives the **bit-identical** Commit
v2 without a round of consensus. The fleet survives any minority of
node losses, including the node that would have been the coordinator.

Determinism contract (docs/fleet.md, "Leaderless commits"):

  * a record's admissibility is judged by its **origin fate**
    (``ChaosTransport.fate`` — did the publication enter the mesh, how
    late), never by the gossip path it took to reach a peer;
  * epidemic spread (``rounds`` push rounds at ``fanout``, then an
    anti-entropy ring sweep to quiescence) only decides *availability*,
    and quiescence makes availability identical across a component;
  * a network partition splits the fleet along a deterministic schedule
    (GossipConfig.partitions). The side with the strict majority of
    workers (tie: the side holding the highest worker id) keeps
    committing; minority peers stall — params intact — and reconcile at
    heal by replaying the quorum's ledger slice from their own stalled
    step, plus a tiny closing-state transfer (quarantine window,
    realized histories) that rides the same catch-up channel.

Every peer is a full participant: Worker (probe compute, residual
protocol) + the same canon-keeping closer the star coordinator runs
(ledger, snapshots, loss history), so any surviving peer can serve as a
catch-up donor for crashed or partitioned peers.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

import jax

from .. import obs
from ..configs.fleet import GossipConfig
from .adversary import build_adversaries
from .coordinator import Coordinator
from .ledger import Ledger, Record
from .replay import ReplaySchema, replay
from .robust import RobustGate
from .transport import ChaosTransport, Fate
from .worker import Worker, zero_residual

_SEL_SALT = 0x600D  # domain-separates peer selection from link fates


def quorum_side(group_bits: int, num_workers: int) -> int:
    """The committing side of a partition: strict majority of worker
    ids; a tie breaks toward the side holding the highest worker id —
    the same leaderless tiebreak the commit rule uses, so every peer
    (and the reference, and a replayer) derives it without talking."""
    full = (1 << num_workers) - 1
    a, b = group_bits & full, full & ~group_bits
    ca, cb = bin(a).count("1"), bin(b).count("1")
    if ca != cb:
        return a if ca > cb else b
    return a if a >> (num_workers - 1) & 1 else b


def clone_gate(gate: RobustGate, schema) -> RobustGate:
    """A state-copy of a gate for closing-state transfer at catch-up.
    Copies the quarantine tracker's host scalars (window history, active
    timers, event log) — never the schema's jitted machinery."""
    g = RobustGate(schema)
    if gate.tracker is not None and g.tracker is not None:
        g.tracker.hist = {w: list(h) for w, h in gate.tracker.hist.items()}
        g.tracker.until = dict(gate.tracker.until)
        g.tracker.events = list(gate.tracker.events)
    return g


class GossipPeer(Worker):
    """One leaderless participant: a Worker that also closes steps.

    ``closer`` is literally a Coordinator — the canon-keeping half
    (gate, append-only ledger, snapshots, loss/arrival histories) is
    identical machinery; what changed in PR 5 is that the close pipeline
    it invokes became a pure function every peer can run. The peer's
    params and its closer's params are the same object: ``close_step``
    applies the canonical update once, ``apply_commit`` then only runs
    the worker-side residual/checkpoint protocol.
    """

    def __init__(self, worker_id: int, params, schema: ReplaySchema,
                 probe_fn, quantize_fn=None, ckpt_dir: Optional[str] = None,
                 keep_snapshots: int = 2):
        super().__init__(worker_id, params, schema, probe_fn, quantize_fn,
                         ckpt_dir)
        self.keep_snapshots = keep_snapshots
        self.closer = Coordinator(params, schema, keep_snapshots)
        self.ledger_since = 0      # first step this peer's ledger covers

    # ---- donor surface (duck-typed like the star coordinator) ---------- #
    @property
    def ledger(self) -> Ledger:
        return self.closer.ledger

    def template(self):
        return self.closer.template()

    def nearest_snapshot(self, step: int):
        return self.closer.nearest_snapshot(step)

    # ---- leaderless step ------------------------------------------------ #
    def close_and_apply(self, step: int,
                        arrivals: List[Tuple[Record, Fate]]):
        """Close one step via the shared pure pipeline and advance."""
        commit, records = self.closer.close_step(step, arrivals)
        self.apply_commit(step, commit, records,
                          new_params=self.closer.params)
        return commit, records

    # ---- failure / recovery --------------------------------------------- #
    def crash(self):
        super().crash()
        self.closer = None

    def restart(self, donor: "GossipPeer", now_step: int):
        """Rejoin from a surviving peer: params by fused ledger replay
        (Worker.restart), closing state by transfer — the quarantine
        verdict window and realized histories are host scalars that ride
        the same catch-up channel (commits carry each step's *active*
        quarantine set, but not the sliding window that feeds future
        entries)."""
        base_step, slice_bytes = super().restart(donor, now_step)
        closer = Coordinator(self.params, self.schema, self.keep_snapshots,
                             at_step=now_step)
        self._adopt_closing_state(closer, donor, slice_bytes)
        self.closer = closer
        self.ledger_since = base_step

    def reconcile(self, donor: "GossipPeer", now_step: int):
        """Heal after a partition stall: the minority peer kept its
        params at its stalled step, so it replays the quorum's ledger
        slice [self.step, now) from its OWN params — no snapshot needed
        — and re-syncs closing state from the donor."""
        if now_step <= self.step:
            return
        slice_bytes = donor.ledger.slice_bytes(self.step, now_step)
        self.catchup_bytes += len(slice_bytes)
        led = Ledger.from_bytes(slice_bytes)
        self.params = replay(self.params, led, self.schema, self.step,
                             now_step)
        self.residual = zero_residual(self.schema)
        self._pending_residual = None
        closer = self.closer
        _adopt_slice(closer, led)
        closer.params = self.params
        closer.step = now_step
        closer.snapshots = {now_step: jax.tree.map(np.asarray, self.params)}
        self._copy_histories(closer, donor)
        self.step = now_step

    def _adopt_closing_state(self, closer: Coordinator, donor: "GossipPeer",
                             slice_bytes: bytes):
        _adopt_slice(closer, Ledger.from_bytes(slice_bytes))
        self._copy_histories(closer, donor)

    def _copy_histories(self, closer: Coordinator, donor: "GossipPeer"):
        closer.gate = clone_gate(donor.closer.gate, self.schema)
        closer.loss_history = list(donor.closer.loss_history)
        closer.ontime_history = list(donor.closer.ontime_history)
        closer.late_admit_history = list(donor.closer.late_admit_history)
        closer.n_rejected = donor.closer.n_rejected
        closer.n_filtered = donor.closer.n_filtered


def _adopt_slice(closer: Coordinator, led: Ledger):
    """Append a caught-up ledger slice into a closer's own ledger — the
    one adoption path shared by crash-restart and partition-reconcile."""
    for t in sorted(led.commits):
        for w in sorted(led.records.get(t, {})):
            closer.ledger.append_record(led.records[t][w])
        closer.ledger.append_commit(led.commits[t])


# ------------------------------------------------------------------ #
# epidemic exchange (deterministic; availability only)
# ------------------------------------------------------------------ #


def exchange(transport: ChaosTransport, gcfg: GossipConfig, step: int,
             ids: List[int], arrivals: List[Tuple[Record, Fate]]):
    """Spread this step's delivered records across the component.

    ``rounds`` synchronous push rounds: every peer sends the records it
    held at round start to ``fanout`` deterministically-chosen peers
    over lossy links (bytes accounted per record copy; exchanges are
    digest-coordinated, so only records the destination lacks travel).
    Then an anti-entropy ring sweep runs to quiescence — after it, every
    peer of the component holds exactly the delivered-record set, which
    is what makes the leaderless close bit-identical. Records whose
    origin fate dropped never entered the mesh (the author's copy is
    stranded behind its dead uplink, mirroring the star uplink loss).
    """
    recs = {rec.worker: rec for rec, fate in arrivals if fate.delivered}
    ids = sorted(ids)
    if not recs or len(ids) < 2:
        return
    rec_obs = obs.get()
    have: Dict[int, set] = {p: {p} & set(recs) for p in ids}
    with rec_obs.span("gossip/push_rounds", track="fleet", step=step):
        for rnd in range(gcfg.rounds):
            snap = {p: frozenset(have[p]) for p in ids}
            for src in ids:
                others = [d for d in ids if d != src]
                rng = np.random.default_rng(np.random.SeedSequence(
                    (transport.cfg.chaos_seed, step, rnd, src, _SEL_SALT)))
                picks = rng.choice(others,
                                   size=min(gcfg.fanout, len(others)),
                                   replace=False)
                for dst in (int(d) for d in picks):
                    novel = sorted(snap[src] - have[dst])
                    if not novel:
                        continue      # digest round-trip, nothing to move
                    if not transport.peer_fate(step, src, dst,
                                               rnd).delivered:
                        transport.n_gossip_dropped += len(novel)
                        rec_obs.counter(
                            "fleet.wire.n_gossip_dropped").inc(len(novel))
                        continue
                    for w in novel:
                        transport.gossip_hop(recs[w])
                        have[dst].add(w)
    # anti-entropy: lossless ring sweeps until the component is quiescent
    target = set(recs)
    with rec_obs.span("gossip/anti_entropy", track="fleet", step=step):
        while any(have[p] != target for p in ids):
            for i, src in enumerate(ids):
                dst = ids[(i + 1) % len(ids)]
                for w in sorted(have[src] - have[dst]):
                    transport.gossip_hop(recs[w])
                    have[dst].add(w)


# ------------------------------------------------------------------ #
# the leaderless simulation loop
# ------------------------------------------------------------------ #


def _pick_donor(peers: List[GossipPeer], quorum: int, step: int,
                exclude: int = -1) -> Optional[GossipPeer]:
    """Deterministic donor choice for catch-up: an alive, caught-up,
    quorum-side peer — full-ledger peers first, then highest id (the
    leaderless tiebreak again)."""
    cands = [p for p in peers
             if p.alive and p.id != exclude and quorum >> p.id & 1
             and p.step == step]
    if not cands:
        return None
    return max(cands, key=lambda p: (p.ledger_since == 0, p.id))


def run_gossip_fleet(schema: ReplaySchema, loss_fn: Callable, params,
                     batch_fn: Callable[[int], Any], steps: int,
                     trace: bool = False,
                     worker_ckpt_dirs: Optional[List] = None,
                     log_every: int = 0, probe_fn=None):
    """Leaderless twin of simulation.run_fleet (same FleetResult)."""
    from .simulation import (FleetResult, _bits_to_mask, crash_schedule,
                             history_masks, resolve_probe_fns)
    fleet_cfg = schema.fleet
    W = fleet_cfg.num_workers
    full = (1 << W) - 1
    gcfg = fleet_cfg.gossip or GossipConfig()
    probe_fn, quantize_fn = resolve_probe_fns(schema, loss_fn, probe_fn)
    transport = ChaosTransport(fleet_cfg)
    dirs = worker_ckpt_dirs or [None] * W
    peers = [GossipPeer(w, params, schema, probe_fn, quantize_fn, dirs[w])
             for w in range(W)]
    adversaries = build_adversaries(fleet_cfg)
    crash_at, restart_at = crash_schedule(fleet_cfg)

    fleet_events: List[str] = []
    masks, param_trace = [], []
    n_catchups = n_reconciles = 0
    partition_prev: Optional[int] = None
    pending_restarts: List[int] = []
    rec_obs = obs.get()
    t0 = obs.monotonic()
    for step in range(steps):
        group = gcfg.active_partition(step)
        quorum = quorum_side(group, W) if group is not None else full
        if group != partition_prev:   # also logs back-to-back windows
            if partition_prev is not None:
                fleet_events.append(f"step {step}: partition healed")
                rec_obs.event("partition_heal", track="fleet", step=step)
            if group is not None:
                fleet_events.append(
                    f"step {step}: partition begins (quorum "
                    f"{bin(quorum)}, minority stalls)")
                rec_obs.event("partition_begin", track="fleet", step=step,
                              quorum=quorum)
        partition_prev = group

        # rejoins — deferred while the rejoiner is cut off from a donor
        pending_restarts += restart_at.get(step, [])
        still_pending = []
        for w in pending_restarts:
            donor = _pick_donor(peers, quorum, step, exclude=w) \
                if quorum >> w & 1 else None
            if donor is None:
                still_pending.append(w)      # retry next step (partition)
                continue
            peers[w].restart(donor, step)
            n_catchups += 1
            fleet_events.append(f"step {step}: peer {w} rejoined via "
                                f"ledger replay from peer {donor.id}")
        pending_restarts = still_pending
        # heal-reconcile: stalled minority peers back on the quorum side
        for p in peers:
            if p.alive and p.step < step and quorum >> p.id & 1:
                donor = _pick_donor(peers, quorum, step, exclude=p.id)
                if donor is None:
                    raise ValueError(
                        f"step {step}: no donor to reconcile peer {p.id}")
                with rec_obs.span("gossip/reconcile", track="fleet",
                                  step=step, peer=p.id):
                    p.reconcile(donor, step)
                n_reconciles += 1
                fleet_events.append(f"step {step}: peer {p.id} reconciled "
                                    "after partition (from peer "
                                    f"{donor.id})")
                rec_obs.event("reconcile", track="fleet", step=step,
                              peer=p.id, donor=donor.id)
        for w, until in crash_at.get(step, []):
            peers[w].crash()
            fleet_events.append(f"step {step}: peer {w} crashed "
                                f"(down until {until})")

        batch = batch_fn(step)
        active = [p for p in peers
                  if p.alive and p.step == step and quorum >> p.id & 1]
        if not active:
            raise ValueError(
                f"step {step}: crash/partition schedule left the quorum "
                "component empty")
        with rec_obs.span("gossip/step", track="fleet", step=step), \
                rec_obs.memory.region("gossip/step"):
            arrivals = []
            with rec_obs.span("gossip/probe", track="fleet", step=step):
                for p in active:
                    rec = p.compute_record(step, batch)
                    if p.id in adversaries:
                        rec = adversaries[p.id].tamper(rec, step)
                    fate = transport.fate(step, p.id)
                    transport.send(rec, fate)
                    arrivals.append((rec, fate))
            with rec_obs.span("gossip/exchange", track="fleet", step=step):
                exchange(transport, gcfg, step, [p.id for p in active],
                         arrivals)

            # every peer closes independently — and must land on the same
            # bytes
            wire = commit = records = None
            with rec_obs.span("gossip/commit", track="fleet", step=step):
                for p in active:
                    c, r = p.close_and_apply(step, arrivals)
                    b = c.to_bytes()
                    if wire is None:
                        wire, commit, records = b, c, r
                    elif b != wire:
                        raise RuntimeError(
                            f"leaderless commit diverged at step {step}: "
                            f"peer {p.id} closed {b!r} vs {wire!r} — the "
                            "commit rule is not the pure function it "
                            "must be")
            # explicit retry accounting, once per step (not per peer): the
            # never-empty fallback can pull back a record the transport
            # dropped — the redelivery is real bytes even when the gate
            # then rejects the record (identical to the star
            # coordinator's books)
            retried = active[0].closer.last_outcome.retried
            if retried is not None:
                transport.redeliver(retried)
            masks.append(_bits_to_mask(commit.accepted, schema))
            if trace:
                param_trace.append(jax.tree.map(np.asarray,
                                                active[-1].params))
        if log_every and (step % log_every == 0 or step == steps - 1):
            s, loss = active[-1].closer.loss_history[-1]
            n_acc = bin(commit.accepted).count("1")
            obs.log("gossip",
                    f"step {s:5d} loss {loss:.4f} accepted "
                    f"{n_acc}/{W} (peers closing: {len(active)})",
                    step=s, loss=loss, accepted=n_acc,
                    closing=len(active))

    # a run that ends mid-partition heals at the end: stalled minority
    # peers reconcile so every surviving peer lands on the canon
    for p in peers:
        if p.alive and p.step < steps:
            donor = _pick_donor(peers, full, steps, exclude=p.id)
            if donor is not None:
                p.reconcile(donor, steps)
                n_reconciles += 1
                fleet_events.append(f"end: peer {p.id} reconciled after "
                                    "run-final heal")

    survivors = [p for p in peers if p.alive and p.step == steps]
    if not survivors:
        raise ValueError("no surviving peer completed the run")
    canon = max(survivors, key=lambda p: (p.ledger_since == 0, p.id))
    if rec_obs.enabled:
        obs.memory.sample()      # end-of-run tagged vs jax reconciliation
    canon.closer.events = fleet_events + canon.closer.events
    quarantine_events = canon.closer.gate.quarantine_events()
    led = canon.closer.ledger
    stats = {
        "topology": "gossip",
        "steps": steps,
        "workers": W,
        "wall_s": obs.monotonic() - t0,
        "bytes_uplink": transport.bytes_sent,
        "bytes_broadcast": 0,            # nobody broadcasts: peers gossip
        "bytes_gossip": transport.bytes_gossip,
        "bytes_catchup": sum(p.catchup_bytes for p in peers),
        "ledger_bytes_zo": led.bytes_zo,
        "ledger_bytes_tail": led.bytes_tail,
        "n_dropped": transport.n_dropped,
        "n_straggled": transport.n_straggled,
        "n_redelivered": transport.n_redelivered,
        "n_gossip_dropped": transport.n_gossip_dropped,
        "n_catchups": n_catchups,
        "n_reconciles": n_reconciles,
        "n_rejected": canon.closer.n_rejected,
        "n_filtered_probes": canon.closer.n_filtered,
        "n_quarantines": sum(1 for *_, kind in quarantine_events
                             if kind == "enter"),
    }
    hist = history_masks(canon.closer, schema)
    return FleetResult(canon.closer, list(peers), schema, masks,
                       param_trace, stats, hist["arrival"], hist["ontime"])
