"""Canonical ledger semantics: (commit, records) -> parameter update.

Everything that holds model parameters — the coordinator, every worker, a
late joiner catching up, the delta-checkpoint restore path, and the
single-process reference (fleet/reference.py) — applies ledger steps
through the functions in this module. The *arithmetic* is not defined
here: this module decodes wire bytes and routes them through the
lane-polymorphic update engine (core/engine.py, docs/design.md §10) —
the same engine object whose ``make_step`` builds the live train step —
so the fleet and the single-process lanes share literally one
accumulation order and one per-step cast/clamp.

Per committed step, with n = fleet probes, mask in {0,1}^n from the
commit bitmask:

  fp32  ZO    theta <- cast(theta_f32 - sum_i coeff_i * z(seed_i))
              coeff_i = eta(step) * clip(delta_i / 2eps) * mask_i / valid
        tail  p <- cast(p_f32 - eta_tail(step) * sum_w dequant(payload_w)
                                                  / valid)
  int8  ZO    theta <- clamp(theta - sum_i psr(g_i * z(seed_i), shift))
              (g_i = masked ternary sign; masked probes are exact no-ops)
        tail  w <- clamp(w - sum_w payload_w)   (int32-exact sum)

valid = max(sum mask, 1). A K-step catch-up replays the ZO half in a
single fused kernel pass (kernels/zo_fused_replay.py; off-TPU the eager
ref keeps the stream bitwise) and the tail sequentially — the two halves
touch disjoint leaves, so fusing one and not the other is still exact.

Scalar hyperparameter math (eta decay, clipping, masking) runs host-side
in strict numpy float32 (core/engine.py ``host_coeffs``) so every
participant derives identical coeffs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.base import LaneConfig
from ..configs.fleet import FleetConfig
from ..core import elastic, prng
from ..core.engine import UpdateEngine, engine_for
from ..core.int8 import QTensor
from .commit_rule import CommittedStep, committed_arrays
from .ledger import Commit, Ledger, Record


@dataclass
class ReplaySchema:
    """Out-of-band protocol state shared at enrollment.

    Everything a participant needs to turn ledger bytes into a parameter
    update: the lane hyperparameters (bound into the engine), the fleet
    topology, the base PRNG key (probe seeds are re-derivable, records
    carrying them is a wire convenience), the ZO/BP partition, and the
    tail leaf layout that int8 payloads are flattened against.
    """
    lane: LaneConfig
    fleet: FleetConfig
    base_seed: np.ndarray                      # uint32[2] key data
    partition_fn: Callable[[Any], Tuple[Any, Any]]
    tail_shapes: List[Tuple[int, ...]] = field(default_factory=list)
    tail_dtypes: List[Any] = field(default_factory=list)
    tail_treedef: Any = None
    # always set by make_schema (the only constructor); Optional so a
    # partially-built schema fails a type check, not an attribute deref
    engine: Optional[UpdateEngine] = None
    # per-step seed memo: W workers + the coordinator + the reference all
    # derive the same array each step; compute it once (bounded cache)
    _seed_cache: Dict[int, np.ndarray] = field(default_factory=dict,
                                               repr=False, compare=False)

    @property
    def n_probes(self) -> int:
        return self.fleet.n_probes

    @property
    def numerics(self) -> str:
        return self.engine.numerics


def _is_q(x) -> bool:
    return isinstance(x, QTensor)


def make_schema(params, lane: LaneConfig, fleet_cfg: FleetConfig,
                base_seed, partition_fn=None) -> ReplaySchema:
    engine = engine_for(lane, partition_fn)
    _, bp_part = engine.partition(params)
    if engine.numerics == "int8":
        # int8 tails are QTensor weights; the wire payload is the flat
        # int8 update against each leaf's .data (exponents are static)
        flat, treedef = jax.tree_util.tree_flatten(bp_part, is_leaf=_is_q)
        shapes = [tuple(q.data.shape) for q in flat]
        dtypes = [jnp.int8 for _ in flat]
    else:
        flat, treedef = jax.tree_util.tree_flatten(bp_part)
        shapes = [tuple(x.shape) for x in flat]
        dtypes = [x.dtype for x in flat]
    return ReplaySchema(
        lane=lane, fleet=fleet_cfg,
        base_seed=np.asarray(base_seed, np.uint32),
        partition_fn=engine.partition,
        tail_shapes=shapes, tail_dtypes=dtypes, tail_treedef=treedef,
        engine=engine)


def probe_seeds(schema: ReplaySchema, step: int) -> np.ndarray:
    """uint64[n]: the hash seeds of this step's probe keys.

    Identical to what the engine's probe loop feeds core/prng.py —
    fold_in(fold_in(base, step), i), collapsed by prng.seed_from_key.
    """
    cached = schema._seed_cache.get(step)
    if cached is not None:
        return cached
    base = jax.random.wrap_key_data(jnp.asarray(schema.base_seed))
    key = jax.random.fold_in(base, step)
    seeds = np.asarray(
        [np.uint64(prng.seed_from_key(jax.random.fold_in(key, i)))
         for i in range(schema.n_probes)], np.uint64)
    schema._seed_cache[step] = seeds
    while len(schema._seed_cache) > 64:
        schema._seed_cache.pop(next(iter(schema._seed_cache)))
    return seeds


def step_coeffs(schema: ReplaySchema, step: int, deltas: np.ndarray,
                mask: np.ndarray) -> Tuple[np.ndarray, np.float32]:
    """(coeffs[n], valid) — the lane's scalar coeff transform, host
    domain (strict fp32 for the fp32 lane, ternary ints for int8)."""
    return schema.engine.host_coeffs(step, deltas, mask)


def step_arrays(commit: Commit, records: Dict[int, Record],
                schema: ReplaySchema):
    """(seeds u64[n], deltas [n], mask f32[n], records) for one commit.

    Thin compatibility view over commit_rule.committed_arrays — THE
    commit -> update-inputs derivation every participant shares.
    ``deltas`` is the per-probe wire scalar in the lane dtype (fp32
    loss-diffs, int8 ternary signs). Masked probes carry seed 0 /
    delta 0 — their coefficient is exactly zero, so the seed value never
    reaches the parameters. ``records`` may contain non-accepted entries
    (the reference computes all of them); only committed workers' blocks
    are read.

    v2 commits are routed through the Byzantine-robust filter
    (fleet/robust.py): the returned arrays are *post-filter*, identical
    for every participant because the filter is a pure function of
    (records, accepted mask). v1 commits pass through untouched.
    """
    cs = committed_arrays(commit, records, schema)
    return cs.seeds, cs.deltas, cs.mask, records


def ledger_step_arrays(ledger: Ledger, step: int, schema: ReplaySchema):
    commit, records = ledger.step_entries(step)
    return step_arrays(commit, records, schema)


def _tail_tree(rec: Record, schema: ReplaySchema):
    """Decode one record's tail payload into a bp-shaped tree.

    fp32: dequantized fp32 grads (q * scale); int8: int32 updates. The
    combine/apply arithmetic lives in the engine, not here.
    """
    leaves = []
    if schema.numerics == "int8":
        for q, shape in zip(rec.tail_q, schema.tail_shapes):
            leaves.append(jnp.asarray(q, jnp.int8).astype(jnp.int32)
                          .reshape(shape))
    else:
        for q, sc, shape in zip(rec.tail_q, rec.tail_scales,
                                schema.tail_shapes):
            leaves.append(jnp.asarray(q, jnp.int8).astype(jnp.float32)
                          .reshape(shape) * jnp.float32(sc))
    return jax.tree_util.tree_unflatten(schema.tail_treedef, leaves)


def _apply_tail(bp_part, step: int, records, accepted: List[int],
                valid: np.float32, schema: ReplaySchema):
    if not jax.tree_util.tree_leaves(bp_part) or not accepted:
        return bp_part
    trees = [_tail_tree(records[w], schema) for w in accepted]
    return schema.engine.apply_tail_records(bp_part, step, trees, valid)


def apply_committed(params, step: int, cstep: CommittedStep,
                    schema: ReplaySchema):
    """One committed step: the canonical params(t) -> params(t+1).

    ``cstep`` is commit_rule.committed_arrays' derivation — post-filter
    arrays plus the tail-eligible worker set (loss-consistency rule),
    so a worker with one band-rejected ZO probe keeps contributing its
    sound first-order tail signal (the PR 5 tail fix).
    """
    zo_part, bp_part = schema.partition_fn(params)
    coeffs, valid = step_coeffs(schema, step, cstep.deltas, cstep.mask)
    new_zo = schema.engine.apply_zo_records(zo_part, cstep.seeds[None, :],
                                            coeffs[None, :])
    new_bp = _apply_tail(bp_part, step, cstep.records,
                         list(cstep.tail_ws), valid, schema)
    return elastic.merge(new_zo, new_bp)


def replay(params, ledger: Ledger, schema: ReplaySchema,
           lo: int, hi: int):
    """Catch up params from step `lo` to step `hi` by ledger replay.

    The ZO half of all hi-lo steps runs as ONE fused kernel pass per leaf
    (1R+1W of HBM regardless of how far behind the worker is); the tail
    (small by construction) replays sequentially. Bitwise equal to having
    applied every step live.
    """
    if hi <= lo:
        return params
    per_step, scalar = [], []
    for step in range(lo, hi):
        if step not in ledger.commits:
            raise ValueError(f"ledger gap at step {step}")
        commit, records = ledger.step_entries(step)
        cs = committed_arrays(commit, records, schema)
        per_step.append(cs)
        scalar.append(step_coeffs(schema, step, cs.deltas, cs.mask))
    seeds = np.stack([cs.seeds for cs in per_step])           # [S, n]
    all_coeffs = np.stack([c for c, _ in scalar])             # [S, n]
    zo_part, bp_part = schema.partition_fn(params)
    new_zo = schema.engine.apply_zo_records(zo_part, seeds, all_coeffs)
    for i, cs in enumerate(per_step):
        bp_part = _apply_tail(bp_part, lo + i, cs.records,
                              list(cs.tail_ws), scalar[i][1], schema)
    return elastic.merge(new_zo, bp_part)


def make_replay_fn(schema: ReplaySchema):
    """Adapter for train/checkpoint.py delta mode: bytes -> replay."""
    def replay_fn(params, ledger_bytes: bytes, base_step: int, step: int):
        ledger = Ledger.from_bytes(ledger_bytes)
        return replay(params, ledger, schema, base_step, step)
    return replay_fn
