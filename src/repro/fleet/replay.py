"""Canonical ledger semantics: (commit, records) -> parameter update.

Everything that holds model parameters — the coordinator, every worker, a
late joiner catching up, the delta-checkpoint restore path, and the
single-process reference (fleet/reference.py) — applies ledger steps
through the functions in this module, and *only* through them. That is
the entire bit-exactness story: one implementation of the update, one
accumulation order, one per-step cast.

Per committed step, with n = fleet probes, mask in {0,1}^n from the
commit bitmask:

  ZO half    theta <- cast(theta_f32 - sum_i coeff_i * z(seed_i))
             coeff_i = -eta(step) * clip(delta_i / 2eps) * mask_i / valid
  BP tail    p <- cast(p_f32 - eta_tail(step) * sum_w dequant(payload_w)
                                                 / valid)

valid = max(sum mask, 1). A K-step catch-up replays the ZO half in a
single fused kernel pass (kernels/zo_fused_replay.py; off-TPU the eager
ref keeps the stream bitwise) and the tail sequentially — the two halves
touch disjoint leaves, so fusing one and not the other is still exact.

Scalar hyperparameter math (eta decay, clipping, masking) runs host-side
in strict numpy float32 so every participant derives identical coeffs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.base import LaneConfig
from ..configs.fleet import FleetConfig
from ..core import elastic, prng, zo
from ..kernels import ops
from .ledger import Commit, Ledger, Record


@dataclass
class ReplaySchema:
    """Out-of-band protocol state shared at enrollment.

    Everything a participant needs to turn ledger bytes into a parameter
    update: the lane hyperparameters, the fleet topology, the base PRNG
    key (probe seeds are re-derivable, records carrying them is a wire
    convenience), the ZO/BP partition, and the tail leaf layout that int8
    payloads are flattened against.
    """
    lane: LaneConfig
    fleet: FleetConfig
    base_seed: np.ndarray                      # uint32[2] key data
    partition_fn: Callable[[Any], Tuple[Any, Any]]
    tail_shapes: List[Tuple[int, ...]] = field(default_factory=list)
    tail_dtypes: List[Any] = field(default_factory=list)
    tail_treedef: Any = None
    # per-step seed memo: W workers + the coordinator + the reference all
    # derive the same array each step; compute it once (bounded cache)
    _seed_cache: Dict[int, np.ndarray] = field(default_factory=dict,
                                               repr=False, compare=False)

    @property
    def n_probes(self) -> int:
        return self.fleet.n_probes


def make_schema(params, lane: LaneConfig, fleet_cfg: FleetConfig,
                base_seed, partition_fn=None) -> ReplaySchema:
    if partition_fn is None:
        partition_fn = lambda p: elastic.partition(p, lane)  # noqa: E731
    _, bp_part = partition_fn(params)
    flat, treedef = jax.tree_util.tree_flatten(bp_part)
    return ReplaySchema(
        lane=lane, fleet=fleet_cfg,
        base_seed=np.asarray(base_seed, np.uint32),
        partition_fn=partition_fn,
        tail_shapes=[tuple(x.shape) for x in flat],
        tail_dtypes=[x.dtype for x in flat],
        tail_treedef=treedef)


def probe_seeds(schema: ReplaySchema, step: int) -> np.ndarray:
    """uint64[n]: the hash seeds of this step's probe keys.

    Identical to what the worker's probe loop feeds core/prng.py —
    fold_in(fold_in(base, step), i), collapsed by prng.seed_from_key.
    """
    cached = schema._seed_cache.get(step)
    if cached is not None:
        return cached
    base = jax.random.wrap_key_data(jnp.asarray(schema.base_seed))
    key = jax.random.fold_in(base, step)
    seeds = np.asarray(
        [np.uint64(prng.seed_from_key(jax.random.fold_in(key, i)))
         for i in range(schema.n_probes)], np.uint64)
    schema._seed_cache[step] = seeds
    while len(schema._seed_cache) > 64:
        schema._seed_cache.pop(next(iter(schema._seed_cache)))
    return seeds


def _decay32(lane: LaneConfig, step: int) -> np.float32:
    if lane.lr_decay_every <= 0 or lane.lr_decay_factor == 1.0:
        return np.float32(1.0)
    k = np.float32(np.floor(np.float32(step) / np.float32(lane.lr_decay_every)))
    return np.power(np.float32(lane.lr_decay_factor), k)


def step_coeffs(schema: ReplaySchema, step: int, deltas: np.ndarray,
                mask: np.ndarray) -> Tuple[np.ndarray, np.float32]:
    """(coeffs fp32[n], valid) — the ZO scalar pipeline, strict fp32."""
    lane = schema.lane
    deltas = np.asarray(deltas, np.float32)
    mask = np.asarray(mask, np.float32)
    g = deltas / np.float32(2.0 * lane.zo_eps)
    if lane.zo_clip is not None and lane.zo_clip > 0:
        g = np.clip(g, np.float32(-lane.zo_clip), np.float32(lane.zo_clip))
    g = g * mask
    valid = np.float32(max(float(mask.sum()), 1.0))
    eta = np.float32(lane.learning_rate) * _decay32(lane, step)
    return -(eta * g) / valid, valid


def step_arrays(commit: Commit, records: Dict[int, Record],
                schema: ReplaySchema):
    """(seeds u64[n], deltas f32[n], mask f32[n], records) for one commit.

    Masked probes carry seed 0 / delta 0 — their coefficient is exactly
    zero, so the seed value never reaches the parameters. `records` may
    contain non-accepted entries (the reference computes all of them);
    only committed workers' blocks are read.
    """
    n, m = schema.n_probes, schema.fleet.probes_per_worker
    seeds = np.zeros((n,), np.uint64)
    deltas = np.zeros((n,), np.float32)
    mask = np.zeros((n,), np.float32)
    for w in commit.workers(schema.fleet.num_workers):
        rec = records[w]
        sl = slice(w * m, (w + 1) * m)
        seeds[sl] = rec.seeds
        deltas[sl] = rec.deltas
        mask[sl] = 1.0
    return seeds, deltas, mask, records


def ledger_step_arrays(ledger: Ledger, step: int, schema: ReplaySchema):
    commit, records = ledger.step_entries(step)
    return step_arrays(commit, records, schema)


def _apply_zo(zo_part, seeds: np.ndarray, coeffs: np.ndarray):
    """seeds u64 [S, n], coeffs f32 [S, n] over every ZO leaf."""
    def f(path, leaf):
        return ops.zo_fused_replay(leaf, seeds.astype(np.uint32), coeffs,
                                   zo.path_salt(path))
    return jax.tree_util.tree_map_with_path(f, zo_part)


def _dequant_sum(records: Dict[int, Record], accepted: List[int],
                 schema: ReplaySchema):
    """sum_w q_w * scale_w over accepted workers, in worker-id order."""
    acc = None
    for w in accepted:
        rec = records[w]
        leaves = []
        for q, sc, shape in zip(rec.tail_q, rec.tail_scales,
                                schema.tail_shapes):
            leaves.append(jnp.asarray(q, jnp.int8).astype(jnp.float32)
                          .reshape(shape) * jnp.float32(sc))
        part = jax.tree_util.tree_unflatten(schema.tail_treedef, leaves)
        acc = part if acc is None else jax.tree.map(jnp.add, acc, part)
    return acc


def _apply_tail(bp_part, step: int, records, accepted: List[int],
                valid: np.float32, schema: ReplaySchema):
    if not jax.tree_util.tree_leaves(bp_part) or not accepted:
        return bp_part
    lane = schema.lane
    avg = _dequant_sum(records, accepted, schema)
    avg = jax.tree.map(lambda a: a / jnp.float32(valid), avg)
    base_eta = lane.learning_rate if lane.tail_learning_rate is None \
        else lane.tail_learning_rate
    eta = np.float32(base_eta) * _decay32(lane, step)
    return jax.tree.map(
        lambda p, a: (p.astype(jnp.float32)
                      - jnp.float32(eta) * a).astype(p.dtype),
        bp_part, avg)


def apply_step(params, step: int, seeds: np.ndarray, deltas: np.ndarray,
               mask: np.ndarray, records: Dict[int, Record],
               schema: ReplaySchema):
    """One committed step: the canonical params(t) -> params(t+1)."""
    zo_part, bp_part = schema.partition_fn(params)
    coeffs, valid = step_coeffs(schema, step, deltas, mask)
    new_zo = _apply_zo(zo_part, seeds[None, :], coeffs[None, :])
    m = schema.fleet.probes_per_worker
    accepted = sorted(w for w in records if mask[w * m] > 0)
    new_bp = _apply_tail(bp_part, step, records, accepted, valid, schema)
    return elastic.merge(new_zo, new_bp)


def replay(params, ledger: Ledger, schema: ReplaySchema,
           lo: int, hi: int):
    """Catch up params from step `lo` to step `hi` by ledger replay.

    The ZO half of all hi-lo steps runs as ONE fused kernel pass per leaf
    (1R+1W of HBM regardless of how far behind the worker is); the tail
    (small by construction) replays sequentially. Bitwise equal to having
    applied every step live.
    """
    if hi <= lo:
        return params
    per_step, scalar = [], []
    for step in range(lo, hi):
        assert step in ledger.commits, f"ledger gap at step {step}"
        arrays = ledger_step_arrays(ledger, step, schema)
        per_step.append(arrays)
        scalar.append(step_coeffs(schema, step, arrays[1], arrays[2]))
    seeds = np.stack([s for s, _, _, _ in per_step])          # [S, n]
    all_coeffs = np.stack([c for c, _ in scalar])             # [S, n]
    zo_part, bp_part = schema.partition_fn(params)
    new_zo = _apply_zo(zo_part, seeds, all_coeffs)
    m = schema.fleet.probes_per_worker
    for i, (_, _, mk, records) in enumerate(per_step):
        accepted = sorted(w for w in records if mk[w * m] > 0)
        bp_part = _apply_tail(bp_part, lo + i, records, accepted,
                              scalar[i][1], schema)
    return elastic.merge(new_zo, bp_part)


def make_replay_fn(schema: ReplaySchema):
    """Adapter for train/checkpoint.py delta mode: bytes -> replay."""
    def replay_fn(params, ledger_bytes: bytes, base_step: int, step: int):
        ledger = Ledger.from_bytes(ledger_bytes)
        return replay(params, ledger, schema, base_step, step)
    return replay_fn
