"""Fleet coordinator: gather records, commit steps, keep the canon.

Per step the coordinator waits ``deadline`` virtual ticks, accepts every
record that made it, and closes the step with a Commit whose bitmask IS
the probe mask — straggler mitigation is the same masking/renormalization
the single-process loop uses for dropped probes (docs/design.md §8),
promoted to a wire protocol. At least one record is always accepted: if
the deadline passes empty the coordinator keeps waiting for the earliest
delivery (infinite-retry semantics in the simulation), so a step can be
late but never empty.

The coordinator also maintains the canonical parameter stream (applying
exactly the same replay-module update as everyone else), periodic host
snapshots that serve as replay bases for crashed workers, and the
append-only ledger that late joiners slice instead of copying
checkpoints.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .ledger import Commit, Ledger, Record
from .replay import ReplaySchema, apply_step, probe_seeds, step_arrays
from .transport import Fate


class Coordinator:
    def __init__(self, params, schema: ReplaySchema,
                 keep_snapshots: int = 2):
        self.schema = schema
        self.params = params
        self.ledger = Ledger()
        self.snapshots: Dict[int, object] = {0: jax.tree.map(np.asarray,
                                                             params)}
        self.keep_snapshots = max(keep_snapshots, 1)
        self.step = 0
        self.loss_history: List[Tuple[int, float]] = []
        self.events: List[str] = []

    # ---- step protocol ------------------------------------------------- #
    def close_step(self, step: int,
                   arrivals: List[Tuple[Record, Fate]]) -> Tuple[Commit, Dict[int, Record]]:
        """Deadline-gate the arrivals, commit, advance the canon."""
        assert step == self.step and arrivals
        deadline = self.schema.fleet.deadline
        on_time = [(r, f) for r, f in arrivals
                   if f.arrived_by(deadline)]
        if not on_time:
            # nobody made the deadline: wait for the earliest delivery
            # (or, if the transport dropped everything, the earliest
            # retry) — a step is never empty.
            pool = [(r, f) for r, f in arrivals if f.delivered] or arrivals
            pick = min(pool, key=lambda rf: (rf[1].delay, rf[0].worker))
            on_time = [pick]
            self.events.append(f"step {step}: empty deadline, waited for "
                               f"worker {pick[0].worker}")
        accepted_mask = 0
        records: Dict[int, Record] = {}
        expect = probe_seeds(self.schema, step)
        m = self.schema.fleet.probes_per_worker
        for rec, _ in on_time:
            w = rec.worker
            assert np.array_equal(rec.seeds, expect[w * m:(w + 1) * m]), \
                f"worker {w} seed schedule diverged at step {step}"
            accepted_mask |= 1 << w
            records[w] = rec
        commit = Commit(step, accepted_mask)
        for w in sorted(records):
            self.ledger.append_record(records[w])
        self.ledger.append_commit(commit)

        seeds, deltas, mask, _ = step_arrays(commit, records, self.schema)
        self.params = apply_step(self.params, step, seeds, deltas, mask,
                                 records, self.schema)
        valid = max(float(mask.sum()), 1.0)
        loss = sum(records[w].loss * m for w in records) / valid
        self.loss_history.append((step, loss))
        self.step = step + 1
        if self.schema.fleet.snapshot_every and \
                self.step % self.schema.fleet.snapshot_every == 0:
            self.snapshots[self.step] = jax.tree.map(np.asarray, self.params)
            # restarts only ever need a recent base (now >= latest
            # snapshot); don't hold every historical parameter image
            for s in sorted(self.snapshots)[:-self.keep_snapshots]:
                del self.snapshots[s]
        return commit, records

    # ---- catch-up service ---------------------------------------------- #
    def template(self):
        """Pytree template for checkpoint restores (structure only)."""
        return self.params

    def nearest_snapshot(self, step: int):
        """(base_step, host params) — newest snapshot at or before `step`."""
        base = max(s for s in self.snapshots if s <= step)
        return base, jax.tree.map(jnp.asarray, self.snapshots[base])
