"""Fleet coordinator: gather records, gate them, commit steps, keep the canon.

Per step the coordinator waits ``deadline`` virtual ticks, routes every
record that made it through the Byzantine-robust gate
(fleet/robust.py: validation -> quarantine -> scalar/loss filter), and
closes the step with a Commit whose bitmask IS the probe mask —
straggler mitigation is the same masking/renormalization the
single-process loop uses for dropped probes (docs/design.md §8),
promoted to a wire protocol, and Byzantine mitigation is a refinement
of the same mask (Commit v2 carries the post-filter probe bits and the
quarantine set). Validation **rejects, never asserts**: a record with a
diverged seed schedule, a stale step field, or the wrong numerics tag
is dropped (and counted toward quarantine) instead of killing the
fleet — the pre-robust ``assert`` here died under ``python -O`` and let
one lying worker take everyone down.

The coordinator keeps the "a step is never empty" liveness rule on a
best-effort basis: if the deadline passes with no arrivals it waits for
the earliest delivery, and if the gate rejects everything it admits
later arrivals one at a time (earliest first). A step where *no* sound
record exists commits empty — an exact parameter no-op — rather than
accepting garbage.

The coordinator also maintains the canonical parameter stream (applying
exactly the same replay-module update as everyone else), periodic host
snapshots that serve as replay bases for crashed workers, and the
append-only ledger that late joiners slice instead of copying
checkpoints.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .ledger import Commit, Ledger, Record
from .replay import ReplaySchema, apply_step, step_arrays
from .robust import RobustGate
from .transport import Fate


class Coordinator:
    def __init__(self, params, schema: ReplaySchema,
                 keep_snapshots: int = 2):
        self.schema = schema
        self.params = params
        self.ledger = Ledger()
        self.snapshots: Dict[int, object] = {0: jax.tree.map(np.asarray,
                                                             params)}
        self.keep_snapshots = max(keep_snapshots, 1)
        self.step = 0
        self.loss_history: List[Tuple[int, float]] = []
        self.events: List[str] = []
        self.gate = RobustGate(schema)
        self.arrival_history: List[int] = []   # realized on-time bits/step
        self.n_rejected = 0                    # validation rejections
        self.n_filtered = 0                    # filter-masked probes

    # ---- step protocol ------------------------------------------------- #
    def close_step(self, step: int,
                   arrivals: List[Tuple[Record, Fate]]) -> Tuple[Commit, Dict[int, Record]]:
        """Deadline-gate the arrivals, filter, commit, advance the canon."""
        if step != self.step or not arrivals:
            raise ValueError(f"close_step({step}) out of order "
                             f"(coordinator at {self.step})")
        deadline = self.schema.fleet.deadline
        on_time = [(r, f) for r, f in arrivals
                   if f.arrived_by(deadline)]
        if not on_time:
            # nobody made the deadline: wait for the earliest delivery
            # (or, if the transport dropped everything, the earliest
            # retry) — a step is never empty for lack of patience.
            pool = [(r, f) for r, f in arrivals if f.delivered] or arrivals
            pick = min(pool, key=lambda rf: (rf[1].delay, rf[0].worker))
            on_time = [pick]
            self.events.append(f"step {step}: empty deadline, waited for "
                               f"worker {pick[0].worker}")
        # late arrivals the gate may pull in if it rejects everything,
        # earliest-delivery first (deterministic)
        on_time_ids = {id(r) for r, _ in on_time}
        late = sorted(((r, f) for r, f in arrivals
                       if id(r) not in on_time_ids and f.delivered),
                      key=lambda rf: (rf[1].delay, rf[0].worker))
        candidates = {rec.worker: rec for rec, _ in on_time}
        result = self.gate.evaluate(step, candidates)
        while result.commit.accepted == 0 and late:
            rec, _ = late.pop(0)
            if rec.worker in candidates:
                continue
            candidates[rec.worker] = rec
            self.events.append(f"step {step}: gate empty, admitted late "
                               f"worker {rec.worker}")
            result = self.gate.evaluate(step, candidates)
        self.gate.advance(step, result)
        self.arrival_history.append(
            sum(1 << w for w in candidates))
        for w, reason in result.rejected:
            self.n_rejected += reason != "quarantined"
            self.events.append(f"step {step}: rejected worker {w} "
                               f"({reason})")
        for s, w, kind in self.gate.quarantine_events():
            tag = f"step {s}: worker {w} quarantine {kind}"
            if tag not in self.events:
                self.events.append(tag)
        commit, records = result.commit, result.records
        if commit.accepted == 0:
            self.events.append(f"step {step}: no sound record survived "
                               f"the gate — empty commit (no-op step)")
        for w in sorted(records):
            self.ledger.append_record(records[w])
        self.ledger.append_commit(commit)

        seeds, deltas, mask, _ = step_arrays(commit, records, self.schema)
        m = self.schema.fleet.probes_per_worker
        self.n_filtered += int(sum(
            m - mask[w * m:(w + 1) * m].sum()
            for w in commit.workers(self.schema.fleet.num_workers)))
        self.params = apply_step(self.params, step, seeds, deltas, mask,
                                 records, self.schema)
        if mask.sum() > 0:
            loss = sum(records[w].loss
                       * float(mask[w * m:(w + 1) * m].sum())
                       for w in records) / float(mask.sum())
        else:
            # no-op step (everything rejected/filtered): no observation —
            # carry the last loss instead of recording a fictitious 0.0
            loss = self.loss_history[-1][1] if self.loss_history \
                else float("nan")
        self.loss_history.append((step, loss))
        self.step = step + 1
        if self.schema.fleet.snapshot_every and \
                self.step % self.schema.fleet.snapshot_every == 0:
            self.snapshots[self.step] = jax.tree.map(np.asarray, self.params)
            # restarts only ever need a recent base (now >= latest
            # snapshot); don't hold every historical parameter image
            for s in sorted(self.snapshots)[:-self.keep_snapshots]:
                del self.snapshots[s]
        return commit, records

    # ---- catch-up service ---------------------------------------------- #
    def template(self):
        """Pytree template for checkpoint restores (structure only)."""
        return self.params

    def nearest_snapshot(self, step: int):
        """(base_step, host params) — newest snapshot at or before `step`.

        Raises ValueError (not an unhelpful ``max() of empty sequence``)
        when every snapshot at or before `step` has been pruned — the
        caller asked to restore into the past of the retention window.
        """
        held = [s for s in self.snapshots if s <= step]
        if not held:
            raise ValueError(
                f"no snapshot at or before step {step}: retained "
                f"{sorted(self.snapshots)} (keep_snapshots="
                f"{self.keep_snapshots}); replay cannot run backwards")
        base = max(held)
        return base, jax.tree.map(jnp.asarray, self.snapshots[base])
