"""Fleet coordinator: gather records, close steps, keep the canon.

Since PR 5 the coordinator owns nothing protocol-critical: the whole
deadline-gate -> never-empty fallback -> Byzantine-robust gate ->
admit-late -> Commit pipeline lives in fleet/commit_rule.py as a pure
function of (gate state, arrivals), and this class merely invokes it —
exactly as every leaderless gossip peer (fleet/gossip.py), the
single-process reference (fleet/reference.py), and cold ledger replay
do. The star topology is now just the degenerate deployment where one
node happens to close every step; losing that node is survivable by
running ``--topology gossip`` instead (docs/fleet.md).

What the coordinator still keeps, per step:

  * the canonical parameter stream (applying exactly the same
    replay-module update as everyone else),
  * the append-only ledger that late joiners slice instead of copying
    checkpoints, and periodic host snapshots as replay bases,
  * the realized arrival bookkeeping, SPLIT by admission path (the PR 5
    arrival-mask fix): ``ontime_history`` holds the pre-gate bits of
    records that made the deadline, ``late_admit_history`` the workers
    pulled in past it (never-empty fallback + gate-empty admissions).
    Their union — ``candidate_history`` — is what drives the reference
    re-derivation; conflating the two under one "on-time" name is what
    used to mislabel late admissions on gate-empty steps.

Validation **rejects, never asserts**: a record with a diverged seed
schedule, a stale step field, or the wrong numerics tag is dropped (and
counted toward quarantine) instead of killing the fleet. A step where
*no* sound record exists commits empty — an exact parameter no-op —
rather than accepting garbage. When the never-empty fallback has to
retry a record the transport dropped, the retry is accounted
(``ChaosTransport.redeliver``) — commits never contain phantom bytes.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .. import obs
from . import commit_rule
from .ledger import Commit, Ledger, Record
from .replay import ReplaySchema, apply_committed
from .robust import RobustGate
from .transport import ChaosTransport, Fate


class Coordinator:
    def __init__(self, params, schema: ReplaySchema,
                 keep_snapshots: int = 2,
                 transport: Optional[ChaosTransport] = None,
                 at_step: int = 0):
        self.schema = schema
        self.params = params
        self.transport = transport
        self.ledger = Ledger()
        self.snapshots: Dict[int, object] = {
            at_step: jax.tree.map(np.asarray, params)}
        self.keep_snapshots = max(keep_snapshots, 1)
        self.step = at_step
        self.loss_history: List[Tuple[int, float]] = []
        self.events: List[str] = []
        self.gate = RobustGate(schema)
        self.ontime_history: List[int] = []      # pre-gate on-time bits/step
        self.late_admit_history: List[int] = []  # admitted past the deadline
        self.n_rejected = 0                      # validation rejections
        self.n_filtered = 0                      # filter-masked probes
        # the most recent CloseOutcome — leaderless callers account its
        # ``retried`` record once per step (this closer has no transport)
        self.last_outcome: Optional[commit_rule.CloseOutcome] = None

    @property
    def candidate_history(self) -> List[int]:
        """Realized candidate bits per step (on-time | late-admitted) —
        the mask stream the single-process reference re-gates from."""
        return [o | l for o, l in zip(self.ontime_history,
                                      self.late_admit_history)]

    # ---- step protocol ------------------------------------------------- #
    def close_step(self, step: int,
                   arrivals: List[Tuple[Record, Fate]]) -> Tuple[Commit, Dict[int, Record]]:
        """Close one step via the shared pure pipeline, advance the canon."""
        if step != self.step or not arrivals:
            raise ValueError(f"close_step({step}) out of order "
                             f"(coordinator at {self.step})")
        outcome = commit_rule.close_step(self.gate, step, arrivals)
        self.last_outcome = outcome
        if outcome.retried is not None and self.transport is not None:
            self.transport.redeliver(outcome.retried)
        self.gate.advance(step, outcome)
        self.record_outcome(step, outcome)
        commit, records = outcome.commit, outcome.records
        cstep = commit_rule.committed_arrays(commit, records, self.schema)
        self.account_filtered(cstep)
        self.params = apply_committed(self.params, step, cstep, self.schema)
        prev = self.loss_history[-1][1] if self.loss_history else None
        self.loss_history.append(
            (step, commit_rule.step_loss(cstep, self.schema, prev)))
        self.step = step + 1
        self.maybe_snapshot()
        return commit, records

    # ---- bookkeeping shared with gossip peers --------------------------- #
    def record_outcome(self, step: int, outcome: commit_rule.CloseOutcome):
        """Histories, events, rejection counters, ledger appends."""
        rec_obs = obs.get()
        self.ontime_history.append(outcome.ontime_bits)
        self.late_admit_history.append(outcome.late_admit_bits)
        self.events.extend(outcome.events)
        for w, reason in outcome.rejected:
            self.n_rejected += reason != "quarantined"
            rec_obs.counter(f"fleet.rejected.{reason}").inc()
        for s, w, kind in self.gate.quarantine_events():
            tag = f"step {s}: worker {w} quarantine {kind}"
            if tag not in self.events:
                self.events.append(tag)
                rec_obs.event(f"quarantine_{kind}", track="fleet",
                              step=s, worker=w)
        for w in sorted(outcome.records):
            self.ledger.append_record(outcome.records[w])
        self.ledger.append_commit(outcome.commit)

    def account_filtered(self, cstep: commit_rule.CommittedStep):
        m = self.schema.fleet.probes_per_worker
        n = int(sum(
            m - cstep.mask[w * m:(w + 1) * m].sum()
            for w in cstep.commit.workers(self.schema.fleet.num_workers)))
        self.n_filtered += n
        if n:
            obs.get().counter("fleet.filtered_probes").inc(n)

    def maybe_snapshot(self):
        if self.schema.fleet.snapshot_every and \
                self.step % self.schema.fleet.snapshot_every == 0:
            self.snapshots[self.step] = jax.tree.map(np.asarray, self.params)
            # restarts only ever need a recent base (now >= latest
            # snapshot); don't hold every historical parameter image
            for s in sorted(self.snapshots)[:-self.keep_snapshots]:
                del self.snapshots[s]

    # ---- catch-up service ---------------------------------------------- #
    def template(self):
        """Pytree template for checkpoint restores (structure only)."""
        return self.params

    def nearest_snapshot(self, step: int):
        """(base_step, host params) — newest snapshot at or before `step`.

        Raises ValueError (not an unhelpful ``max() of empty sequence``)
        when every snapshot at or before `step` has been pruned — the
        caller asked to restore into the past of the retention window.
        """
        held = [s for s in self.snapshots if s <= step]
        if not held:
            raise ValueError(
                f"no snapshot at or before step {step}: retained "
                f"{sorted(self.snapshots)} (keep_snapshots="
                f"{self.keep_snapshots}); replay cannot run backwards")
        base = max(held)
        return base, jax.tree.map(jnp.asarray, self.snapshots[base])
