"""Deterministic fleet simulation: N workers, chaos transport, one canon.

Drives synchronous training rounds over an in-process fleet along a
``topology`` axis (FleetConfig.topology):

  * ``"star"`` — one coordinator deadline-gathers, closes every step via
    the shared commit rule (fleet/commit_rule.py), and broadcasts.
  * ``"gossip"`` — no coordinator: peers exchange records epidemically
    (fleet/gossip.py) and every peer closes each step independently via
    the SAME commit rule, deriving the bit-identical Commit v2. The
    chaos matrix (dropout, stragglers, crash-rejoin, adversaries) plus
    peer death and temporary network partitions with deterministic
    heal-and-reconcile all apply.

All randomness (transport fates, crash schedule, gossip peer selection)
is seeded, so a run is a reproducible fixture: tests replay the realized
probe masks through the single-process reference and assert the
parameter streams are bit-identical.

Per star step: alive workers compute records -> Byzantine workers tamper
their wire copy (fleet/adversary.py, deterministic) -> chaos transport
delivers (or not, or late) -> coordinator gates (validation, quarantine,
robust filter) and commits -> commit+records broadcast -> every
participant applies the canonical update. Crashed workers rejoin by
ledger replay (fleet/worker.py restart), never by copying the full
model.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax

from .. import obs
from ..configs.base import LaneConfig
from ..configs.fleet import FleetConfig
from .adversary import build_adversaries
from .coordinator import Coordinator
from .ledger import Ledger
from .replay import ReplaySchema, make_schema
from .transport import ChaosTransport
from .worker import Worker, make_probe_fn, make_quantize_fn


@dataclass
class FleetResult:
    # the canon-keeping view: the Coordinator in star topology, the
    # highest-id surviving peer's closer in gossip (all surviving peers
    # are bit-identical — that is the leaderless acceptance bar)
    coordinator: Coordinator
    workers: List[Worker]
    schema: ReplaySchema
    masks: List[np.ndarray]            # realized per-step COMMIT probe masks
    param_trace: List[Any]             # canon after each step (host copies)
    stats: Dict[str, Any] = field(default_factory=dict)
    # realized per-step CANDIDATE probe masks (pre-gate: on-time arrivals
    # plus late admissions) — what drives the Byzantine reference, which
    # then re-derives validation/quarantine/filter itself
    arrival_masks: List[np.ndarray] = field(default_factory=list)
    # realized per-step ON-TIME probe masks (deadline survivors only;
    # arrival_masks minus the late-admitted workers). Split from
    # arrival_masks by the PR 5 conflation fix — gate-empty steps admit
    # late records, which are candidates but were never on time.
    ontime_masks: List[np.ndarray] = field(default_factory=list)

    @property
    def peers(self) -> Optional[List[Any]]:
        """The GossipPeers of a leaderless run (alias of ``workers`` —
        every gossip participant is a full worker); None for star."""
        return self.workers if self.stats.get("topology") == "gossip" \
            else None

    @property
    def ledger(self) -> Ledger:
        return self.coordinator.ledger

    @property
    def params(self):
        return self.coordinator.params


def _bits_to_mask(bits: int, schema: ReplaySchema) -> np.ndarray:
    m = schema.fleet.probes_per_worker
    out = np.zeros((schema.n_probes,), np.float32)
    for w in range(schema.fleet.num_workers):
        if bits >> w & 1:
            out[w * m:(w + 1) * m] = 1.0
    return out


def history_masks(closer: Coordinator,
                  schema: ReplaySchema) -> Dict[str, List[np.ndarray]]:
    """Expand a closer's realized bit histories into probe-mask streams."""
    return {
        "arrival": [_bits_to_mask(b, schema)
                    for b in closer.candidate_history],
        "ontime": [_bits_to_mask(b, schema)
                   for b in closer.ontime_history],
    }


def resolve_probe_fns(schema: ReplaySchema, loss_fn, probe_fn):
    """(probe_fn, quantize_fn) for a lane — shared by both topologies."""
    if probe_fn is None:
        if schema.numerics != "fp32":
            raise ValueError(
                "int8 fleets need a make_int8_probe_fn-built probe_fn")
        probe_fn = make_probe_fn(loss_fn, schema.lane, schema.partition_fn)
    quantize_fn = make_quantize_fn() if schema.numerics == "fp32" else None
    return probe_fn, quantize_fn


def crash_schedule(fleet_cfg: FleetConfig):
    crash_at: Dict[int, List[tuple]] = {}
    restart_at: Dict[int, List[int]] = {}
    for w, cs, down in fleet_cfg.crashes:
        crash_at.setdefault(cs, []).append((w, cs + down))
        restart_at.setdefault(cs + down, []).append(w)
    return crash_at, restart_at


def run_fleet(loss_fn: Callable, params, lane: LaneConfig,
              fleet_cfg: FleetConfig, batch_fn: Callable[[int], Any],
              steps: int, base_seed, partition_fn=None,
              trace: bool = False, worker_ckpt_dirs: Optional[List] = None,
              log_every: int = 0, probe_fn=None) -> FleetResult:
    """Train `steps` rounds on a simulated fleet; return the full state.

    batch_fn(step) must be a pure function of the step index (the repo's
    data contract, docs/design.md §9) — it is what lets every worker see
    the same batch without a data channel.

    For the int8 lane (lane.lane == "elastic_zo_int8") pass ``probe_fn``
    built by worker.make_int8_probe_fn (it binds the integer forward and
    the tail-FC layout); ``loss_fn`` is then unused and may be None.

    ``fleet_cfg.topology == "gossip"`` runs the leaderless protocol
    instead (fleet/gossip.py) — same signature, same FleetResult, no
    coordinator anywhere in the loop.
    """
    schema = make_schema(params, lane, fleet_cfg, base_seed, partition_fn)
    if fleet_cfg.topology == "gossip":
        from .gossip import run_gossip_fleet
        return run_gossip_fleet(schema, loss_fn, params, batch_fn, steps,
                                trace=trace,
                                worker_ckpt_dirs=worker_ckpt_dirs,
                                log_every=log_every, probe_fn=probe_fn)
    probe_fn, quantize_fn = resolve_probe_fns(schema, loss_fn, probe_fn)
    transport = ChaosTransport(fleet_cfg)
    coordinator = Coordinator(params, schema, transport=transport)
    dirs = worker_ckpt_dirs or [None] * fleet_cfg.num_workers
    workers = [Worker(w, params, schema, probe_fn, quantize_fn, dirs[w])
               for w in range(fleet_cfg.num_workers)]
    rec_obs = obs.get()
    if rec_obs.enabled:
        rec_obs.memory.rebind("fleet.canon.params",
                              obs.memory.tree_nbytes(coordinator.params),
                              key=("canon", id(coordinator)))

    adversaries = build_adversaries(fleet_cfg)
    crash_at, restart_at = crash_schedule(fleet_cfg)

    masks, param_trace = [], []
    bytes_broadcast = 0
    n_catchups = 0
    t0 = obs.monotonic()
    for step in range(steps):
        with rec_obs.span("fleet/step", track="fleet", step=step), \
                rec_obs.memory.region("fleet/step"):
            for w in restart_at.get(step, []):
                workers[w].restart(coordinator, step)
                n_catchups += 1
                coordinator.events.append(f"step {step}: worker {w} rejoined "
                                          "via ledger replay")
                rec_obs.event("worker_rejoin", track="fleet", step=step,
                              worker=w)
            for w, until in crash_at.get(step, []):
                workers[w].crash()
                coordinator.events.append(f"step {step}: worker {w} crashed "
                                          f"(down until {until})")
                rec_obs.event("worker_crash", track="fleet", step=step,
                              worker=w, until=until)
            batch = batch_fn(step)
            arrivals = []
            with rec_obs.span("fleet/probe", track="fleet", step=step):
                for worker in workers:
                    if not worker.alive:
                        continue
                    rec = worker.compute_record(step, batch)
                    if worker.id in adversaries:
                        # wire-only tampering: the worker's local state
                        # (params, EF residual) stays honest, like a
                        # compromised uplink
                        rec = adversaries[worker.id].tamper(rec, step)
                    fate = transport.fate(step, worker.id)
                    transport.send(rec, fate)
                    arrivals.append((rec, fate))
            if not arrivals:
                raise ValueError("crash schedule left the fleet empty")
            with rec_obs.span("fleet/commit", track="fleet", step=step):
                commit, records = coordinator.close_step(step, arrivals)
            step_bytes = commit.nbytes + sum(r.nbytes
                                             for r in records.values())
            bytes_broadcast += step_bytes
            rec_obs.counter("fleet.wire.broadcast_bytes").inc(step_bytes)
            masks.append(_bits_to_mask(commit.accepted, schema))
            with rec_obs.span("fleet/apply", track="fleet", step=step):
                for worker in workers:
                    if worker.alive:
                        worker.apply_commit(step, commit, records)
            if trace:
                param_trace.append(jax.tree.map(np.asarray,
                                                coordinator.params))
        if log_every and (step % log_every == 0 or step == steps - 1):
            s, loss = coordinator.loss_history[-1]
            n_acc = bin(commit.accepted).count("1")
            obs.log("fleet",
                    f"step {s:5d} loss {loss:.4f} "
                    f"accepted {n_acc}/{fleet_cfg.num_workers}",
                    step=s, loss=loss, accepted=n_acc)

    if rec_obs.enabled:
        obs.memory.sample()      # end-of-run tagged vs jax reconciliation
    led = coordinator.ledger
    quarantine_events = coordinator.gate.quarantine_events()
    stats = {
        "topology": "star",
        "steps": steps,
        "workers": fleet_cfg.num_workers,
        "wall_s": obs.monotonic() - t0,
        "bytes_uplink": transport.bytes_sent,
        "bytes_broadcast": bytes_broadcast,
        "bytes_gossip": 0,
        "bytes_catchup": sum(w.catchup_bytes for w in workers),
        "ledger_bytes_zo": led.bytes_zo,
        "ledger_bytes_tail": led.bytes_tail,
        "n_dropped": transport.n_dropped,
        "n_straggled": transport.n_straggled,
        "n_redelivered": transport.n_redelivered,
        "n_catchups": n_catchups,
        "n_rejected": coordinator.n_rejected,
        "n_filtered_probes": coordinator.n_filtered,
        "n_quarantines": sum(1 for *_, kind in quarantine_events
                             if kind == "enter"),
    }
    hist = history_masks(coordinator, schema)
    return FleetResult(coordinator, workers, schema, masks, param_trace,
                       stats, hist["arrival"], hist["ontime"])
