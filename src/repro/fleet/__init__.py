"""repro.fleet — seed-ledger distributed ZO training (docs/fleet.md).

ElasticZO collapses the ZO half of a training step to (probe seed,
projected-grad scalar) pairs; this subsystem turns that into a wire
protocol. Workers publish per-step ledger records; a step is closed by
ONE pure pipeline (fleet/commit_rule.py) — run by a star coordinator,
or by every peer independently in the leaderless gossip topology
(fleet/gossip.py: epidemic record exchange, deterministic
coordinator-free commits, partition heal-and-reconcile) — and every
participant (closer, worker, late joiner replaying the ledger, and the
single-process reference) runs the identical canonical update, so the
whole fleet stays bit-exact.

Public surface: FleetConfig / RobustConfig / GossipConfig /
ByzantineSpec (configs/fleet.py), Ledger / Record / Commit,
ChaosTransport, Worker, Coordinator, GossipPeer, run_fleet,
make_reference_step, ReplaySchema / replay / make_replay_fn,
Adversary / build_adversaries (fleet/adversary.py), the commit-rule
primitives close_step / close_candidates / committed_arrays
(fleet/commit_rule.py), and the robust-filter primitives RobustGate /
filter_decision / QuarantineTracker (fleet/robust.py).
"""
from ..configs.fleet import (ByzantineSpec, FleetConfig, GossipConfig,
                             RobustConfig)
from .adversary import Adversary, build_adversaries, parse_byzantine
from .commit_rule import (CloseOutcome, CommittedStep, close_candidates,
                          close_step, committed_arrays, step_loss)
from .coordinator import Coordinator
from .gossip import GossipPeer, quorum_side, run_gossip_fleet
from .ledger import Commit, Ledger, Record
from .reference import make_reference_step, reference_state
from .replay import (ReplaySchema, apply_committed, ledger_step_arrays,
                     make_replay_fn, make_schema, probe_seeds, replay,
                     step_arrays, step_coeffs)
from .robust import (FilterDecision, QuarantineTracker, RobustGate,
                     filter_decision)
from .simulation import FleetResult, run_fleet
from .transport import ChaosTransport
from .worker import Worker, make_int8_probe_fn, make_probe_fn

__all__ = ["FleetConfig", "RobustConfig", "GossipConfig", "ByzantineSpec",
           "Ledger", "Record", "Commit", "ChaosTransport", "Worker",
           "Coordinator", "GossipPeer", "quorum_side", "run_gossip_fleet",
           "run_fleet", "FleetResult", "Adversary", "build_adversaries",
           "parse_byzantine", "RobustGate", "FilterDecision",
           "QuarantineTracker", "filter_decision",
           "CloseOutcome", "CommittedStep", "close_step",
           "close_candidates", "committed_arrays", "step_loss",
           "make_probe_fn", "make_int8_probe_fn", "make_reference_step",
           "reference_state", "ReplaySchema", "make_schema",
           "apply_committed", "replay", "make_replay_fn",
           "ledger_step_arrays", "step_arrays", "step_coeffs",
           "probe_seeds"]
