"""Deterministic lossy/latency-injecting in-process transport.

Every message fate — delivered?, delay ticks — is a pure function of
the chaos seed, so a fleet run with dropouts and stragglers is exactly
reproducible: rerunning the simulation, the single-process reference
(fleet/reference.py), and a post-hoc replay all see the same probe
masks. This is chaos testing as a deterministic fixture, the same
philosophy as the step-indexed synthetic data (docs/design.md §9).

Two fate families share the machinery:

  * ``fate(step, worker)`` — the record's **origin fate**: did the
    worker's publication make it into the protocol at all, and how
    late. In the star topology this is the worker->coordinator uplink;
    in the gossip topology it is the first hop into the epidemic mesh.
    Either way it is what the deadline gate judges (docs/fleet.md,
    "Leaderless commits"): a record's timeliness must not depend on the
    path it took to reach a given peer, or peers would disagree.
  * ``peer_fate(step, src, dst, rnd)`` — one gossip link's fate in
    exchange round ``rnd``. Lossy links slow epidemic spread (the
    anti-entropy sweep still converges the component); they never
    change a record's origin fate.

Physical mapping: "dropped" = the publication never entered the mesh;
"straggler" = it arrived after the per-step deadline. Both end up
probe-masked in the commit. ``redeliver`` accounts the never-empty
fallback's explicit retry of a dropped record — a commit must never
contain bytes the transport doesn't know about (the PR 5 phantom-commit
fix).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .. import obs
from ..configs.fleet import FleetConfig

_P2P_SALT = 0x9067  # domain-separates peer links from origin fates


@dataclass(frozen=True)
class Fate:
    delivered: bool
    delay: int

    def arrived_by(self, deadline: int) -> bool:
        return self.delivered and self.delay <= deadline


class ChaosTransport:
    def __init__(self, cfg: FleetConfig):
        self.cfg = cfg
        self.bytes_sent = 0           # publications + redeliveries
        self.bytes_gossip = 0         # epidemic record copies (p2p hops)
        self.n_dropped = 0
        self.n_straggled = 0
        self.n_redelivered = 0        # dropped records retried by the
        #                               never-empty fallback
        self.n_gossip_dropped = 0     # record copies lost to failed p2p
        #                               links (spread-only; counted only
        #                               when the link had copies to move)

    def fate(self, step: int, worker: int) -> Fate:
        """The (delivered, delay) origin fate of worker's step record."""
        rng = np.random.default_rng(
            np.random.SeedSequence((self.cfg.chaos_seed, step, worker)))
        delivered = bool(rng.uniform() >= self.cfg.dropout)
        delay = int(rng.integers(0, self.cfg.max_delay + 1)) \
            if self.cfg.max_delay else 0
        return Fate(delivered, delay)

    def peer_fate(self, step: int, src: int, dst: int, rnd: int) -> Fate:
        """One gossip link's fate (pure in the chaos seed). Links share
        the origin dropout probability; delay is irrelevant for spread
        (deadline gating judges origin fates only) and is always 0."""
        rng = np.random.default_rng(np.random.SeedSequence(
            (self.cfg.chaos_seed, step, src, dst, rnd, _P2P_SALT)))
        return Fate(bool(rng.uniform() >= self.cfg.dropout), 0)

    def send(self, record, fate: Fate) -> bool:
        """Account a record publication; True if it entered the mesh."""
        rec = obs.get()
        if not fate.delivered:
            self.n_dropped += 1
            rec.counter("fleet.wire.n_dropped").inc()
            return False
        self.bytes_sent += record.nbytes
        rec.counter("fleet.wire.uplink_bytes").inc(record.nbytes)
        if rec.enabled:
            self._account_split(rec, record)
        if fate.delay > self.cfg.deadline:
            self.n_straggled += 1
            rec.counter("fleet.wire.n_straggled").inc()
        return True

    def redeliver(self, record):
        """Account the never-empty fallback's explicit retry of a record
        the transport originally dropped. The retry rides the same
        uplink, so its bytes land in ``bytes_sent`` — the steps where
        the network was worst are exactly the ones whose accounting used
        to be wrong."""
        self.bytes_sent += record.nbytes
        self.n_redelivered += 1
        rec = obs.get()
        rec.counter("fleet.wire.uplink_bytes").inc(record.nbytes)
        rec.counter("fleet.wire.n_redelivered").inc()
        if rec.enabled:
            self._account_split(rec, record)

    @staticmethod
    def _account_split(rec, record):
        """Split one uplink publication into its ZO and tail halves —
        per worker for the tail, because that is where the asymmetry
        lives: ~12 B/probe of ZO scalars vs the KBs of int8 tail payload
        (the ROADMAP's 'tail bytes are invisible' item)."""
        rec.counter("fleet.wire.zo_bytes").inc(record.zo_nbytes)
        rec.counter("fleet.wire.tail_bytes").inc(record.tail_nbytes)
        rec.counter(
            f"fleet.wire.tail_bytes.w{record.worker:02d}").inc(
            record.tail_nbytes)

    def gossip_hop(self, record):
        """Account one delivered epidemic copy of `record` over a p2p
        link. Failed links are accounted by the caller per suppressed
        record copy (``n_gossip_dropped``) — the link fate is decided
        before any copy is attempted (fleet/gossip.py exchange)."""
        self.bytes_gossip += record.nbytes
        obs.get().counter("fleet.wire.gossip_bytes").inc(record.nbytes)
