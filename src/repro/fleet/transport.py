"""Deterministic lossy/latency-injecting in-process transport.

Every (step, worker) message fate — delivered?, delay ticks — is a pure
function of the chaos seed, so a fleet run with dropouts and stragglers
is exactly reproducible: rerunning the simulation, the single-process
reference (fleet/reference.py), and a post-hoc replay all see the same
probe masks. This is chaos testing as a deterministic fixture, the same
philosophy as the step-indexed synthetic data (docs/design.md §9).

Physical mapping: "dropped" = the worker->coordinator link lost the
record; "straggler" = it arrived after the coordinator's per-step
deadline. Both end up probe-masked in the commit. Commits flow on the
reliable coordinator->worker broadcast (docs/fleet.md failure model).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..configs.fleet import FleetConfig


@dataclass(frozen=True)
class Fate:
    delivered: bool
    delay: int

    def arrived_by(self, deadline: int) -> bool:
        return self.delivered and self.delay <= deadline


class ChaosTransport:
    def __init__(self, cfg: FleetConfig):
        self.cfg = cfg
        self.bytes_sent = 0           # worker -> coordinator, delivered only
        self.n_dropped = 0
        self.n_straggled = 0

    def fate(self, step: int, worker: int) -> Fate:
        """The (delivered, delay) fate of worker's step-`step` record."""
        rng = np.random.default_rng(
            np.random.SeedSequence((self.cfg.chaos_seed, step, worker)))
        delivered = bool(rng.uniform() >= self.cfg.dropout)
        delay = int(rng.integers(0, self.cfg.max_delay + 1)) \
            if self.cfg.max_delay else 0
        return Fate(delivered, delay)

    def send(self, record, fate: Fate) -> bool:
        """Account a worker->coordinator record send; True if delivered."""
        if not fate.delivered:
            self.n_dropped += 1
            return False
        self.bytes_sent += record.nbytes
        if fate.delay > self.cfg.deadline:
            self.n_straggled += 1
        return True
