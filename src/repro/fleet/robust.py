"""Byzantine-robust commit filtering: deterministic scalar filters,
worker quarantine, and the gate shared by every participant.

The seed ledger makes robustness cheap: a worker's entire ZO
contribution is a per-probe scalar, so robust aggregation is scalar
statistics, not tensor math. The design constraint inherited from the
rest of the fleet (docs/fleet.md) is **bit-exact reproducibility**: the
filter verdict must be a *pure function of (records, accepted mask)* so
the coordinator, every worker, the single-process reference, and a
ledger replay all derive the identical post-filter probe mask. Hence:

  * all scalar math runs host-side in strict numpy float32 (the same
    discipline as ``engine.host_coeffs``);
  * the verdict is iterated to a **fixpoint** (removing an outlier
    shifts the median/MAD, which may expose another), which makes the
    filter idempotent by construction — re-filtering filtered arrays is
    a no-op, a property tests/test_fleet_robust.py pins with hypothesis;
  * quarantine decisions ride in the commit (ledger.Commit v2), so a
    replayed ledger reproduces quarantine entry/exit without needing the
    coordinator's sliding-window state.

Filter channels, per lane:

  fp32   per-probe loss-diff **magnitudes**: median-of-means center +
         k·MAD band over |Δ|. Honest antithetic loss-diffs are
         sign-symmetric (each probe direction is random), so a signed
         band would straddle a bimodal distribution and flag one sign
         cluster as outliers; magnitude is the actual attack surface —
         a probe's influence on the update scales with |Δ| (and the
         sign is unfalsifiable without recomputing the loss; an
         in-band flip is influence-bounded, like int8's ternary bound).
         ``mode="mask"`` rejects probes with |Δ| above the band (the
         commit's filter bitmask); ``mode="clip"`` clips the loss-diff
         to ±hi instead, preserving its sign.
  int8   the wire scalar is a ternary sign: the band degenerates to the
         sign-consistency check |g| <= 1 (any stronger scalar attack is
         out of the representable range; an in-range flip is influence-
         bounded by ternary clipping itself — the paper's sign
         compression doubles as a Byzantine defense).
  both   per-record loss consistency (the int8 lane's "majority"
         channel): every worker evaluates the same batch at eps-sized
         perturbations of the same params, so honest reported losses
         cluster tightly around the fleet median; a record outside
         loss_k_mad · MAD (with an absolute floor) has all its probes
         rejected — this is what catches freeloaders whose scalars are
         individually unremarkable.

Validation (seed schedule, step field, numerics tag, probe count,
finiteness) is always on — independent of ``RobustConfig`` — and
**rejects instead of asserting**: a lying worker must not be able to
kill the fleet, including under ``python -O``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..configs.fleet import RobustConfig
from .ledger import Commit, Record, pack_bits

# ------------------------------------------------------------------ #
# robust scalar statistics (strict fp32 host math)
# ------------------------------------------------------------------ #


def mom_center(vals: np.ndarray, groups: int) -> np.float32:
    """Median-of-means: sort, split into `groups` contiguous chunks,
    median of the chunk means. Sorting first makes the estimate a pure
    function of the value *multiset* (worker-order invariant).

    ``groups=0`` (the default) means one group per value — the plain
    median, with its maximal 50% breakdown point. With g < n the
    estimator trades breakdown for variance reduction: a clique of k
    colluders can own up to k sorted chunks, so it only tolerates
    k < g/2 (see RobustConfig.mom_groups)."""
    vals = np.sort(np.asarray(vals, np.float32))
    g = vals.size if groups == 0 else max(1, min(int(groups), vals.size))
    if g == vals.size:
        return np.float32(np.median(vals))
    means = np.asarray([np.float32(np.mean(c)) for c in
                        np.array_split(vals, g)], np.float32)
    return np.float32(np.median(means))


def mad_scale(vals: np.ndarray, center: np.float32) -> np.float32:
    """Median absolute deviation from `center`."""
    vals = np.asarray(vals, np.float32)
    return np.float32(np.median(np.abs(vals - np.float32(center))))


# ------------------------------------------------------------------ #
# the filter verdict — a pure function of (records, accepted mask)
# ------------------------------------------------------------------ #


@dataclass(frozen=True)
class FilterDecision:
    """One step's verdict. ``inband[i]`` is False only for accepted
    probes the filter rejected (non-accepted probes are in-band by
    convention, so the commit bitmask is well-defined over all n)."""
    inband: np.ndarray          # bool[n]
    outliers: int               # worker bits: >=1 rejected probe or loss
    loss_reject: int            # worker bits rejected by the loss channel
    lo: np.float32              # scalar band used for mode="clip"
    hi: np.float32


def record_losses(records: Dict[int, Record], accepted: int,
                  num_workers: int) -> np.ndarray:
    """f32[W] of accepted workers' reported losses (NaN where absent)."""
    out = np.full((num_workers,), np.nan, np.float32)
    for w in range(num_workers):
        if accepted >> w & 1 and w in records:
            out[w] = np.float32(records[w].loss)
    return out


def filter_decision(deltas: np.ndarray, losses: np.ndarray,
                    mask: np.ndarray, m: int, cfg: RobustConfig,
                    numerics: str) -> FilterDecision:
    """THE filter: (per-probe scalars, per-worker losses, accepted probe
    mask) -> FilterDecision. Pure, strict-fp32, iterated to a joint
    fixpoint of the loss and scalar channels (=> idempotent)."""
    mask = np.asarray(mask, np.float32) > 0
    n = mask.size
    W = n // m
    losses = np.asarray(losses, np.float32)
    cand = mask.copy()               # probes still under consideration
    loss_reject = 0
    lo, hi = np.float32(0), np.float32(0)
    if numerics == "int8":
        # sign-consistency: the wire scalar must be a ternary sign
        lo, hi = np.float32(-1), np.float32(1)
        cand &= np.abs(np.asarray(deltas, np.int64)) <= 1
    d32 = np.asarray(deltas, np.float32)

    for _ in range(n + W + 1):       # both channels only ever shrink
        changed = False
        # -- loss channel (worker-level) --
        active = np.asarray([cand[w * m:(w + 1) * m].any()
                             for w in range(W)])
        finite = np.isfinite(losses)
        lvals = losses[active & finite]
        if lvals.size:
            c = np.float32(np.median(lvals))
            s = mad_scale(lvals, c)
            band = np.float32(cfg.loss_k_mad) * np.maximum(
                s, np.float32(cfg.loss_floor))
            for w in range(W):
                if not active[w] or loss_reject >> w & 1:
                    continue
                bad = (not finite[w]) or \
                    np.float32(abs(losses[w] - c)) > band
                if bad:
                    loss_reject |= 1 << w
                    cand[w * m:(w + 1) * m] = False
                    changed = True
        elif active.any():
            # every active record reported a non-finite loss: reject all
            for w in range(W):
                if active[w] and not loss_reject >> w & 1:
                    loss_reject |= 1 << w
                    cand[w * m:(w + 1) * m] = False
                    changed = True
        # -- scalar channel (per-probe |loss-diff|, fp32 lane only) --
        if numerics != "int8":
            mags = np.abs(d32)
            vals = mags[cand]
            if vals.size:
                c = mom_center(vals, cfg.mom_groups)
                s = mad_scale(vals, c)
                band = np.float32(c) + np.float32(cfg.k_mad) * np.maximum(
                    s, np.float32(cfg.scale_floor))
                lo, hi = np.float32(-band), np.float32(band)
                new = cand & (mags <= hi)
                if not np.array_equal(new, cand):
                    cand = new
                    changed = True
        if not changed:
            break

    inband = cand | ~mask            # no verdict on non-accepted probes
    outliers = loss_reject
    for w in range(W):
        blk = slice(w * m, (w + 1) * m)
        if mask[blk].any() and not inband[blk].all():
            outliers |= 1 << w
    return FilterDecision(inband, outliers, loss_reject, lo, hi)


def apply_decision(seeds: np.ndarray, deltas: np.ndarray,
                   mask: np.ndarray, decision: FilterDecision,
                   cfg: RobustConfig, m: int):
    """(seeds, deltas, mask) -> post-filter arrays, per cfg.mode.

    mask mode: rejected probes get mask 0 / delta 0 (the renormalizing
    `valid` shrinks with them). clip mode: band outliers keep their mask
    but their scalar is clipped to [lo, hi]; loss-rejected workers are
    masked in both modes (a lying loss poisons the whole record)."""
    mask = np.asarray(mask, np.float32).copy()
    deltas = np.array(deltas, copy=True)
    inband = decision.inband
    if cfg.mode == "clip":
        lr = np.zeros(mask.shape, bool)
        W = mask.size // m
        for w in range(W):
            if decision.loss_reject >> w & 1:
                lr[w * m:(w + 1) * m] = True
        clipped = (~inband) & (mask > 0) & ~lr
        if deltas.dtype == np.int8:
            deltas[clipped] = np.clip(deltas[clipped], -1, 1)
        else:
            deltas[clipped] = np.clip(
                np.asarray(deltas[clipped], np.float32),
                decision.lo, decision.hi)
        mask[lr] = 0.0
        deltas[lr] = 0
    else:
        out = ~inband
        mask[out] = 0.0
        deltas[out] = 0
    return seeds, deltas, mask


# ------------------------------------------------------------------ #
# record validation (always on; never an assert)
# ------------------------------------------------------------------ #


def validate_record(rec: Record, worker: int, step: int, schema,
                    expect_seeds: np.ndarray) -> Optional[str]:
    """Rejection reason for a malformed/lying record, or None if sound."""
    m = schema.fleet.probes_per_worker
    if rec.worker != worker:
        return f"claims worker {rec.worker}"
    if rec.step != step:
        return f"stale/foreign step {rec.step}"
    if rec.numerics != schema.numerics:
        return f"numerics {rec.numerics!r} (lane runs {schema.numerics!r})"
    if len(rec.seeds) != m or len(rec.deltas) != m:
        return f"probe count {len(rec.seeds)} (schema says {m})"
    if not np.array_equal(np.asarray(rec.seeds, np.uint64),
                          expect_seeds[worker * m:(worker + 1) * m]):
        return "seed schedule diverged"
    if not np.isfinite(np.float32(rec.loss)):
        return "non-finite loss"
    if schema.numerics == "fp32" and \
            not np.all(np.isfinite(np.asarray(rec.deltas, np.float32))):
        return "non-finite loss-diff"
    return None


# ------------------------------------------------------------------ #
# quarantine state machine
# ------------------------------------------------------------------ #


class QuarantineTracker:
    """Sliding-window persistence: a worker with `quarantine_after`
    outlier verdicts within the last `window` steps is excluded from
    commits for `quarantine_steps` steps (0 = permanently). Decisions at
    step t take effect at t+1 (step t's commit is already gated), are
    made in worker-id order, and never quarantine the last active
    worker. The per-step quarantine set rides in Commit v2, so ledger
    replay reproduces entry/exit without this object's state."""

    def __init__(self, cfg: RobustConfig, num_workers: int):
        self.cfg = cfg
        self.W = num_workers
        self.hist: Dict[int, List[int]] = {w: [] for w in range(num_workers)}
        self.until: Dict[int, int] = {}      # worker -> exclusive end step
        self.events: List[Tuple[int, int, str]] = []   # (step, worker, kind)

    def active_bits(self, step: int) -> int:
        bits = 0
        for w, until in self.until.items():
            if until < 0 or step < until:
                bits |= 1 << w
        return bits

    def observe(self, step: int, outlier_bits: int):
        # expire finished quarantines first (exit logged at release step)
        for w in sorted(self.until):
            if 0 <= self.until[w] <= step:
                del self.until[w]
                self.events.append((step, w, "exit"))
        active = self.active_bits(step)
        cfg = self.cfg
        for w in range(self.W):
            if active >> w & 1:
                continue                     # timer runs; no new verdicts
            if outlier_bits >> w & 1:
                self.hist[w].append(step)
            self.hist[w] = [s for s in self.hist[w]
                            if s > step - cfg.window]
            if len(self.hist[w]) >= cfg.quarantine_after:
                if bin(self.active_bits(step)).count("1") >= self.W - 1:
                    continue                 # never quarantine everyone
                self.until[w] = -1 if cfg.quarantine_steps == 0 \
                    else step + 1 + cfg.quarantine_steps
                self.hist[w] = []
                self.events.append((step + 1, w, "enter"))


# ------------------------------------------------------------------ #
# the gate: validation + quarantine + filter -> Commit (v1 or v2)
# ------------------------------------------------------------------ #


@dataclass
class GateResult:
    commit: Commit
    records: Dict[int, Record]           # accepted: these enter the ledger
    rejected: List[Tuple[int, str]]      # (worker, reason)
    outliers: int                        # worker bits, feeds the tracker
    decision: Optional[FilterDecision]


class RobustGate:
    """The accept/filter pipeline shared verbatim — via
    fleet/commit_rule.py — by the star coordinator, every leaderless
    gossip peer, and the single-process reference (fleet/reference.py),
    so all of them derive the same Commit from the same candidate
    records. ``evaluate`` is pure given the tracker state; ``advance``
    consumes one step's verdicts (call it exactly once per step, with
    the final GateResult or commit_rule.CloseOutcome — anything carrying
    ``outliers`` bits)."""

    def __init__(self, schema):
        self.schema = schema
        self.cfg: Optional[RobustConfig] = schema.fleet.robust
        self.tracker = QuarantineTracker(self.cfg, schema.fleet.num_workers) \
            if self.cfg is not None else None

    def evaluate(self, step: int, on_time: Dict[int, Record]) -> GateResult:
        from .commit_rule import raw_arrays            # import cycle guard
        from .replay import probe_seeds
        schema = self.schema
        W = schema.fleet.num_workers
        m = schema.fleet.probes_per_worker
        expect = probe_seeds(schema, step)
        quarantined = self.tracker.active_bits(step) if self.tracker else 0
        rejected: List[Tuple[int, str]] = []
        outliers = 0
        valid: Dict[int, Record] = {}
        for w in sorted(on_time):
            if not 0 <= w < W:
                rejected.append((w, "worker id out of range"))
                continue
            if quarantined >> w & 1:
                rejected.append((w, "quarantined"))
                continue
            reason = validate_record(on_time[w], w, step, schema, expect)
            if reason is not None:
                rejected.append((w, reason))
                outliers |= 1 << w
                continue
            valid[w] = on_time[w]
        accepted = 0
        for w in valid:
            accepted |= 1 << w
        decision = None
        filtered = None
        if self.cfg is not None:
            pre = Commit(step, accepted)
            _, deltas, mask = raw_arrays(pre, valid, schema)
            losses = record_losses(valid, accepted, W)
            decision = filter_decision(deltas, losses, mask, m, self.cfg,
                                       schema.numerics)
            outliers |= decision.outliers
            filtered = pack_bits(decision.inband)
        commit = Commit(step, accepted, quarantined=quarantined,
                        filtered=filtered)
        return GateResult(commit, valid, rejected, outliers, decision)

    def advance(self, step: int, result: GateResult):
        if self.tracker is not None:
            self.tracker.observe(step, result.outliers)

    def quarantine_events(self) -> List[Tuple[int, int, str]]:
        return list(self.tracker.events) if self.tracker else []
