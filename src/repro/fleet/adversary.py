"""Seeded Byzantine attack models: deterministic record tampering.

Chaos philosophy as everywhere in this repo (docs/design.md §9): an
adversary is a *fixture*, not a fuzzer. Tampering is a deterministic
function of the honest record stream, so the fleet simulation and the
single-process reference (fleet/reference.py) construct byte-identical
tampered records from byte-identical honest ones — which is what lets a
Byzantine chaos run be replayed bit-exactly and asserted against.

Attack models (``ByzantineSpec.attack``), per lane. ``amp`` scales the
attack; 0.0 selects the lane default listed here:

  inflate       fp32: loss-diffs x amp (1e3). int8: the ternary sign is
                replaced by +/-amp (64) — out of the representable
                ternary range, which is the *strongest* scalar attack
                the 1-byte wire admits.
  sign_flip     loss-diffs -> -amp * delta (fp32 32; int8 3). A unit
                flip on the int8 lane is inside the honest envelope
                (|g| <= 1, influence-bounded by ternary clipping), so
                the effective attack flips *and* amplifies; the filter
                catches the amplification, ternary clipping bounds
                whatever would sneak under it.
  freeload      reports zeroed scalars, a zeroed tail payload, and a
                constant fabricated loss (= amp, default 0.0) without
                computing anything. Individually unremarkable scalars —
                only the loss-consistency channel catches it.
  collude       reports the constant loss-diff amp (fp32 1.0; int8 16)
                — give several workers the same spec and they vote as a
                clique trying to drag the center; median-of-means holds
                as long as the clique is a minority.
  seed_lie      shifts the probe seeds by int(amp) (1): a seed-schedule
                divergence. Caught by validation (fleet/robust.py),
                never by statistics — and must *reject*, not crash the
                coordinator (the PR 4 regression).
  stale_replay  re-sends its own record from int(amp) (2) steps ago
                (a replay attack); the step field betrays it.

Tampering happens on the wire copy only: the Byzantine worker's local
state (params, EF residual) stays honest, mirroring a compromised
network stack or a malicious participant that still wants to track the
canon.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Dict, List, Sequence

import numpy as np

from ..configs.fleet import ByzantineSpec, FleetConfig
from .ledger import Record

ATTACKS = ("inflate", "sign_flip", "freeload", "collude", "seed_lie",
           "stale_replay")

_DEFAULT_AMP = {
    ("inflate", "fp32"): 1e3,      ("inflate", "int8"): 64.0,
    ("sign_flip", "fp32"): 32.0,   ("sign_flip", "int8"): 3.0,
    ("freeload", "fp32"): 0.0,     ("freeload", "int8"): 0.0,
    ("collude", "fp32"): 1.0,      ("collude", "int8"): 16.0,
    ("seed_lie", "fp32"): 1.0,     ("seed_lie", "int8"): 1.0,
    ("stale_replay", "fp32"): 2.0, ("stale_replay", "int8"): 2.0,
}


def _zero_like(arrs: Sequence[np.ndarray]) -> List[np.ndarray]:
    return [np.zeros_like(a) for a in arrs]


class Adversary:
    """One worker's deterministic tamper function. Construct one per
    Byzantine worker (both in the fleet simulation and in the
    reference); feed it every honest record in step order.

    ``down`` is the worker's crash-schedule step set: the fleet never
    calls tamper while the worker is down, but the single-process
    reference computes every worker every step — skipping the stash on
    down steps keeps the two adversary instances byte-identical, which
    the bit-exactness contract requires."""

    def __init__(self, spec: ByzantineSpec, down=frozenset()):
        if spec.attack not in ATTACKS:
            raise ValueError(f"unknown attack {spec.attack!r}; "
                             f"available: {ATTACKS}")
        self.spec = spec
        self.down = frozenset(down)
        self._stash: Dict[int, Record] = {}    # honest records, by step

    def amp(self, numerics: str) -> float:
        if self.spec.amp:
            return float(self.spec.amp)
        return _DEFAULT_AMP[(self.spec.attack, numerics)]

    def tamper(self, rec: Record, step: int) -> Record:
        """Honest record -> wire record. Pure given the honest stream."""
        if step in self.down:
            return rec            # reference-side call while crashed:
        #                           no stash, no tampering (never sent)
        a = self.spec.attack
        amp = self.amp(rec.numerics)
        self._stash[step] = rec
        if a == "stale_replay":
            target = max(step - int(amp), 0)
            # a crash gap may have swallowed the target step: replay the
            # newest record this worker actually produced on-or-before it
            # (there is none only right after a from-step-0 crash, in
            # which case the current honest record goes out)
            have = [s for s in self._stash if s <= target]
            return self._stash[max(have)] if have else rec
        if a == "seed_lie":
            seeds = np.asarray(rec.seeds, np.uint64) + np.uint64(int(amp))
            return replace(rec, seeds=seeds)
        if a == "inflate":
            if rec.numerics == "int8":
                g = np.asarray(rec.deltas, np.int32)
                sgn = np.where(g == 0, 1, np.sign(g))
                deltas = np.clip(sgn * int(amp), -127, 127).astype(np.int8)
            else:
                deltas = (np.asarray(rec.deltas, np.float32)
                          * np.float32(amp))
            return replace(rec, deltas=deltas)
        if a == "sign_flip":
            if rec.numerics == "int8":
                g = np.asarray(rec.deltas, np.int32)
                deltas = np.clip(-g * int(amp), -127, 127).astype(np.int8)
            else:
                deltas = (np.asarray(rec.deltas, np.float32)
                          * np.float32(-amp))
            return replace(rec, deltas=deltas)
        if a == "collude":
            if rec.numerics == "int8":
                deltas = np.full_like(np.asarray(rec.deltas, np.int8),
                                      np.clip(int(amp), -127, 127))
            else:
                deltas = np.full_like(np.asarray(rec.deltas, np.float32),
                                      np.float32(amp))
            return replace(rec, deltas=deltas)
        if a == "freeload":
            return replace(
                rec, deltas=np.zeros_like(rec.deltas),
                loss=float(np.float32(amp)),
                tail_q=_zero_like(rec.tail_q),
                tail_scales=np.zeros_like(rec.tail_scales))
        raise AssertionError(a)   # unreachable: checked in __init__


def build_adversaries(cfg: FleetConfig) -> Dict[int, Adversary]:
    """worker id -> Adversary, from the fleet config's byzantine specs
    (crash-schedule-aware, so fleet and reference instances agree)."""
    out = {}
    for spec in cfg.byzantine:
        down = set()
        for w, cs, d in cfg.crashes:
            if w == spec.worker:
                down.update(range(cs, cs + d))
        out[spec.worker] = Adversary(spec, down)
    return out


def parse_byzantine(arg: str) -> tuple:
    """CLI spec parser: 'w:attack[:amp],...' -> ByzantineSpec tuple.

    e.g. ``--byzantine 3:sign_flip,5:inflate:100`` — worker 3 flips
    signs at the lane-default amplitude, worker 5 inflates x100.
    """
    specs = []
    for part in arg.split(","):
        if not part:
            continue
        bits = part.split(":")
        if len(bits) not in (2, 3):
            raise ValueError(f"byzantine entry {part!r} must be "
                             "worker:attack[:amp]")
        amp = float(bits[2]) if len(bits) == 3 else 0.0
        specs.append(ByzantineSpec(int(bits[0]), bits[1], amp))
    return tuple(specs)
