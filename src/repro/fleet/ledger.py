"""The append-only seed ledger: records, commits, binary wire format.

One training step of one worker is a ``Record``. Record v2 carries a
**numerics tag** — the record's wire tag byte selects the lane — with
one probe-entry layout per numerics:

  fp32 ('R'):
    R | step u32 | worker u8 | m u8 | loss f32
      | m x (probe seed u64, loss-diff f32)        <- 12 B/probe (ZO)
      | n_leaves u16 | n x (flat size u32, scale f32) | int8 payload

  int8 ('I', ElasticZO-INT8 / Alg. 2):
    I | step u32 | worker u8 | m u8 | loss f32
      | m x (probe seed u64, ternary g i8)         <- 9 B/probe (ZO)
      | n_leaves u16 | n x (flat size u32) | int8 payload

The ZO part is the paper's punchline made literal: 12 bytes per probe
(8-byte seed + 4-byte scalar) — or **9 bytes** in the int8 lane, where
the projected gradient is the ternary sign — carries the *entire* ZO
gradient of an arbitrarily large model half. ``deltas`` holds the
per-probe scalar in the lane's own dtype: fp32 loss-diffs, or int8
ternary signs.

The tail payload is the worker's BP-tail contribution: fp32 lane — the
probe-summed tail gradient, per-tensor-scaled int8 with error feedback
(train/compress.py); int8 lane — the saturating int8 sum of the NITI
per-probe weight updates (already int8-native, no scale on the wire;
the weight exponents never move, so dequantization state is static
schema).

The coordinator closes a step with a ``Commit``. v1 is filter-free:

    C | step u32 | accepted-worker bitmask u32

v2 additionally carries the Byzantine-robust filter outcome
(fleet/robust.py): the quarantine set active during the step and the
post-filter per-probe in-band bitmask (LSB-first over global probe ids):

    V | step u32 | accepted u32 | quarantined u32
      | n_filter_bytes u8 | filter bitmask bytes

Old v1 commits decode as filter-free (``filtered is None``,
``quarantined == 0``); a v1 writer is emitted whenever both fields are
trivial, so filter-free ledgers stay byte-identical to the pre-robust
protocol. A commit plus its accepted records is a pure function from
params(step) to params(step+1) — see fleet/replay.py — so a ledger slice
*is* a checkpoint delta (train/checkpoint.py delta mode stores exactly
that).

Tail leaf shapes/order are out-of-band schema (ReplaySchema), shared at
enrollment; records carry only flat sizes as a consistency check.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import obs

_REC_HDR = struct.Struct("<BIBBf")        # tag, step, worker, m, loss
_PROBE = struct.Struct("<Qf")             # seed u64, loss-diff f32
_PROBE8 = struct.Struct("<Qb")            # seed u64, ternary g i8
_LEAF_HDR = struct.Struct("<If")          # flat size u32, scale f32
_LEAF_HDR8 = struct.Struct("<I")          # flat size u32 (int8: no scale)
_COMMIT = struct.Struct("<BII")           # tag, step, accepted bitmask
_COMMIT2 = struct.Struct("<BIIIB")        # tag, step, accepted, quarantined,
#                                           n filter-mask bytes
_TAG_R, _TAG_C, _TAG_I = 0x52, 0x43, 0x49  # 'R' fp32, 'C' commit, 'I' int8
_TAG_V = 0x56                              # 'V' commit v2 (robust-filtered)


def pack_bits(bits: np.ndarray) -> bytes:
    """bool[n] -> LSB-first bitmask bytes (bit i of byte i//8 = bits[i])."""
    return np.packbits(np.asarray(bits, bool), bitorder="little").tobytes()


def unpack_bits(buf: bytes, n: int) -> np.ndarray:
    """LSB-first bitmask bytes -> bool[n]."""
    if len(buf) * 8 < n:
        raise ValueError(f"filter bitmask holds {len(buf) * 8} bits, "
                         f"need {n}")
    return np.unpackbits(np.frombuffer(buf, np.uint8), count=n,
                         bitorder="little").astype(bool)


@dataclass
class Record:
    step: int
    worker: int
    seeds: np.ndarray                     # uint64 [m]
    deltas: np.ndarray                    # fp32 loss-diffs | int8 signs
    loss: float                           # mean fp32 loss over probes
    tail_q: List[np.ndarray] = field(default_factory=list)   # int8, flat
    tail_scales: np.ndarray = field(
        default_factory=lambda: np.zeros((0,), np.float32))
    numerics: str = "fp32"                # record-v2 numerics tag

    @property
    def zo_probe_nbytes(self) -> int:
        """Wire bytes of ONE probe entry (the paper's headline number)."""
        return _PROBE8.size if self.numerics == "int8" else _PROBE.size

    @property
    def zo_nbytes(self) -> int:
        """Wire bytes of the ZO part (header + probe entries)."""
        return _REC_HDR.size + self.zo_probe_nbytes * len(self.seeds)

    @property
    def tail_nbytes(self) -> int:
        leaf_hdr = _LEAF_HDR8 if self.numerics == "int8" else _LEAF_HDR
        return 2 + sum(leaf_hdr.size + q.size for q in self.tail_q)

    @property
    def nbytes(self) -> int:
        return self.zo_nbytes + self.tail_nbytes

    def to_bytes(self) -> bytes:
        tag = _TAG_I if self.numerics == "int8" else _TAG_R
        out = [_REC_HDR.pack(tag, self.step, self.worker,
                             len(self.seeds), float(self.loss))]
        if self.numerics == "int8":
            for s, g in zip(self.seeds, self.deltas):
                out.append(_PROBE8.pack(int(s), int(g)))
            out.append(struct.pack("<H", len(self.tail_q)))
            for q in self.tail_q:
                out.append(_LEAF_HDR8.pack(q.size))
        else:
            for s, d in zip(self.seeds, self.deltas):
                out.append(_PROBE.pack(int(s), float(d)))
            out.append(struct.pack("<H", len(self.tail_q)))
            for q, sc in zip(self.tail_q, self.tail_scales):
                out.append(_LEAF_HDR.pack(q.size, float(sc)))
        for q in self.tail_q:
            out.append(np.ascontiguousarray(q, np.int8).tobytes())
        return b"".join(out)


@dataclass
class Commit:
    step: int
    accepted: int                         # bitmask over worker ids
    # -- v2 (Byzantine-robust) fields; trivial values write the v1 form --
    quarantined: int = 0                  # bitmask: excluded this step
    filtered: Optional[bytes] = None      # per-probe in-band bitmask
    #                                       (LSB-first); None = filter-free

    def workers(self, num_workers: int) -> List[int]:
        return [w for w in range(num_workers) if self.accepted >> w & 1]

    @property
    def version(self) -> int:
        return 2 if (self.quarantined or self.filtered is not None) else 1

    def inband(self, n_probes: int) -> np.ndarray:
        """bool[n]: the post-filter in-band verdict (all ones if v1)."""
        if self.filtered is None:
            return np.ones((n_probes,), bool)
        return unpack_bits(self.filtered, n_probes)

    @property
    def nbytes(self) -> int:
        if self.version == 1:
            return _COMMIT.size
        return _COMMIT2.size + len(self.filtered or b"")

    def to_bytes(self) -> bytes:
        if self.version == 1:
            return _COMMIT.pack(_TAG_C, self.step, self.accepted)
        bits = self.filtered or b""
        if len(bits) > 255:
            raise ValueError("commit filter mask exceeds u8 length field")
        return _COMMIT2.pack(_TAG_V, self.step, self.accepted,
                             self.quarantined, len(bits)) + bits


def _parse_record(buf: bytes, off: int, numerics: str):
    _, step, worker, m, loss = _REC_HDR.unpack_from(buf, off)
    off += _REC_HDR.size
    seeds = np.zeros((m,), np.uint64)
    if numerics == "int8":
        deltas = np.zeros((m,), np.int8)
        for i in range(m):
            s, g = _PROBE8.unpack_from(buf, off)
            off += _PROBE8.size
            seeds[i], deltas[i] = s, np.int8(g)
    else:
        deltas = np.zeros((m,), np.float32)
        for i in range(m):
            s, d = _PROBE.unpack_from(buf, off)
            off += _PROBE.size
            seeds[i], deltas[i] = s, np.float32(d)
    (n_leaves,) = struct.unpack_from("<H", buf, off)
    off += 2
    sizes: List[int] = []
    if numerics == "int8":
        scales = np.zeros((0,), np.float32)
        for _ in range(n_leaves):
            (sz,) = _LEAF_HDR8.unpack_from(buf, off)
            off += _LEAF_HDR8.size
            sizes.append(sz)
    else:
        scales = np.zeros((n_leaves,), np.float32)
        for i in range(n_leaves):
            sz, sc = _LEAF_HDR.unpack_from(buf, off)
            off += _LEAF_HDR.size
            sizes.append(sz)
            scales[i] = np.float32(sc)
    tail_q = []
    for sz in sizes:
        if off + sz > len(buf):
            raise ValueError(f"truncated ledger payload at offset {off}")
        tail_q.append(np.frombuffer(buf, np.int8, count=sz, offset=off).copy())
        off += sz
    rec = Record(step, worker, seeds, deltas, float(np.float32(loss)),
                 tail_q, scales, numerics=numerics)
    return rec, off


class Ledger:
    """Append-only store of records and commits, with bytes accounting.

    ``records[step][worker]`` holds only records the coordinator accepted
    (dropped/straggler records never enter the canonical ledger — their
    probes are masked by the commit instead).
    """

    def __init__(self):
        self.records: Dict[int, Dict[int, Record]] = {}
        self.commits: Dict[int, Commit] = {}
        self.bytes_zo = 0
        self.bytes_tail = 0

    @property
    def nbytes(self) -> int:
        return self.bytes_zo + self.bytes_tail \
            + _COMMIT.size * len(self.commits)

    def append_record(self, rec: Record):
        self.records.setdefault(rec.step, {})[rec.worker] = rec
        self.bytes_zo += rec.zo_nbytes
        self.bytes_tail += rec.tail_nbytes
        led = obs.get().memory
        if led.armed:
            # append-only by design: ledgers only ever grow, so these
            # tags are never freed — live == cumulative appended bytes
            # across every Ledger instance (coordinator, gossip peers,
            # and transient replay slices alike)
            led.alloc("fleet.ledger.zo", rec.zo_nbytes)
            led.alloc("fleet.ledger.tail", rec.tail_nbytes)

    def append_commit(self, commit: Commit):
        if commit.step in self.commits:    # raise, not assert: must hold
            raise ValueError(               # under python -O too
                f"ledger is append-only: step {commit.step} already closed")
        self.commits[commit.step] = commit
        led = obs.get().memory
        if led.armed:
            led.alloc("fleet.ledger.commit", commit.nbytes)

    def last_step(self) -> Optional[int]:
        return max(self.commits) if self.commits else None

    def step_entries(self, step: int) -> Tuple[Commit, Dict[int, Record]]:
        return self.commits[step], self.records.get(step, {})

    # ---- wire / persistence -------------------------------------------- #
    def slice_bytes(self, lo: int, hi: int) -> bytes:
        """Serialized commits + accepted records for steps in [lo, hi)."""
        out = []
        for step in range(lo, hi):
            if step not in self.commits:
                continue
            out.append(self.commits[step].to_bytes())
            for w in sorted(self.records.get(step, {})):
                out.append(self.records[step][w].to_bytes())
        return b"".join(out)

    def to_bytes(self) -> bytes:
        if not self.commits:
            return b""
        return self.slice_bytes(min(self.commits), max(self.commits) + 1)

    @classmethod
    def from_bytes(cls, buf: bytes) -> "Ledger":
        led = cls()
        off = 0
        try:
            while off < len(buf):
                tag = buf[off]
                if tag == _TAG_C:
                    _, step, mask = _COMMIT.unpack_from(buf, off)
                    off += _COMMIT.size
                    led.append_commit(Commit(step, mask))
                elif tag == _TAG_V:
                    _, step, mask, quar, nb = _COMMIT2.unpack_from(buf, off)
                    off += _COMMIT2.size
                    if off + nb > len(buf):
                        raise ValueError(
                            f"truncated commit filter mask at offset {off}")
                    bits = buf[off:off + nb] if nb else None
                    off += nb
                    led.append_commit(Commit(step, mask, quarantined=quar,
                                             filtered=bits))
                elif tag == _TAG_R:
                    rec, off = _parse_record(buf, off, "fp32")
                    led.append_record(rec)
                elif tag == _TAG_I:
                    rec, off = _parse_record(buf, off, "int8")
                    led.append_record(rec)
                else:
                    raise ValueError(
                        f"bad ledger tag {tag:#x} at offset {off}")
        except struct.error as e:
            raise ValueError(f"truncated ledger buffer at offset {off}: {e}") \
                from e
        return led
