"""The append-only seed ledger: records, commits, binary wire format.

One training step of one worker is a ``Record``:

    R | step u32 | worker u8 | m u8 | loss f32
      | m x (probe seed u64, loss-diff f32)           <- the ZO part
      | n_leaves u16 | n x (flat size u32, scale f32) | int8 payload

The ZO part is the paper's punchline made literal: 12 bytes per probe
(8-byte seed + 4-byte scalar) carries the *entire* ZO gradient of an
arbitrarily large model half. The int8 payload is the worker's BP-tail
gradient (sum over its probes), per-tensor scaled (train/compress.py
wire format, ~1 byte/element of the small tail).

The coordinator closes a step with a ``Commit``:

    C | step u32 | accepted-worker bitmask u32

A commit plus its accepted records is a pure function from params(step)
to params(step+1) — see fleet/replay.py — so a ledger slice *is* a
checkpoint delta (train/checkpoint.py delta mode stores exactly that).

Tail leaf shapes/order are out-of-band schema (ReplaySchema), shared at
enrollment; records carry only flat sizes as a consistency check.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_REC_HDR = struct.Struct("<BIBBf")        # tag, step, worker, m, loss
_PROBE = struct.Struct("<Qf")             # seed u64, loss-diff f32
_LEAF_HDR = struct.Struct("<If")          # flat size u32, scale f32
_COMMIT = struct.Struct("<BII")           # tag, step, accepted bitmask
_TAG_R, _TAG_C = 0x52, 0x43               # 'R', 'C'


@dataclass
class Record:
    step: int
    worker: int
    seeds: np.ndarray                     # uint64 [m]
    deltas: np.ndarray                    # float32 [m]   (l_plus - l_minus)
    loss: float                           # mean 0.5*(l+ + l-) over probes
    tail_q: List[np.ndarray] = field(default_factory=list)   # int8, flat
    tail_scales: np.ndarray = field(
        default_factory=lambda: np.zeros((0,), np.float32))

    @property
    def zo_nbytes(self) -> int:
        """Wire bytes of the ZO part (header + seed/scalar pairs)."""
        return _REC_HDR.size + _PROBE.size * len(self.seeds)

    @property
    def tail_nbytes(self) -> int:
        return 2 + sum(_LEAF_HDR.size + q.size for q in self.tail_q)

    @property
    def nbytes(self) -> int:
        return self.zo_nbytes + self.tail_nbytes

    def to_bytes(self) -> bytes:
        out = [_REC_HDR.pack(_TAG_R, self.step, self.worker,
                             len(self.seeds), float(self.loss))]
        for s, d in zip(self.seeds, self.deltas):
            out.append(_PROBE.pack(int(s), float(d)))
        out.append(struct.pack("<H", len(self.tail_q)))
        for q, sc in zip(self.tail_q, self.tail_scales):
            out.append(_LEAF_HDR.pack(q.size, float(sc)))
        for q in self.tail_q:
            out.append(np.ascontiguousarray(q, np.int8).tobytes())
        return b"".join(out)


@dataclass
class Commit:
    step: int
    accepted: int                         # bitmask over worker ids

    def workers(self, num_workers: int) -> List[int]:
        return [w for w in range(num_workers) if self.accepted >> w & 1]

    @property
    def nbytes(self) -> int:
        return _COMMIT.size

    def to_bytes(self) -> bytes:
        return _COMMIT.pack(_TAG_C, self.step, self.accepted)


class Ledger:
    """Append-only store of records and commits, with bytes accounting.

    ``records[step][worker]`` holds only records the coordinator accepted
    (dropped/straggler records never enter the canonical ledger — their
    probes are masked by the commit instead).
    """

    def __init__(self):
        self.records: Dict[int, Dict[int, Record]] = {}
        self.commits: Dict[int, Commit] = {}
        self.bytes_zo = 0
        self.bytes_tail = 0

    @property
    def nbytes(self) -> int:
        return self.bytes_zo + self.bytes_tail \
            + _COMMIT.size * len(self.commits)

    def append_record(self, rec: Record):
        self.records.setdefault(rec.step, {})[rec.worker] = rec
        self.bytes_zo += rec.zo_nbytes
        self.bytes_tail += rec.tail_nbytes

    def append_commit(self, commit: Commit):
        assert commit.step not in self.commits, "ledger is append-only"
        self.commits[commit.step] = commit

    def last_step(self) -> Optional[int]:
        return max(self.commits) if self.commits else None

    def step_entries(self, step: int) -> Tuple[Commit, Dict[int, Record]]:
        return self.commits[step], self.records.get(step, {})

    # ---- wire / persistence -------------------------------------------- #
    def slice_bytes(self, lo: int, hi: int) -> bytes:
        """Serialized commits + accepted records for steps in [lo, hi)."""
        out = []
        for step in range(lo, hi):
            if step not in self.commits:
                continue
            out.append(self.commits[step].to_bytes())
            for w in sorted(self.records.get(step, {})):
                out.append(self.records[step][w].to_bytes())
        return b"".join(out)

    def to_bytes(self) -> bytes:
        if not self.commits:
            return b""
        return self.slice_bytes(min(self.commits), max(self.commits) + 1)

    @classmethod
    def from_bytes(cls, buf: bytes) -> "Ledger":
        led = cls()
        off = 0
        while off < len(buf):
            tag = buf[off]
            if tag == _TAG_C:
                _, step, mask = _COMMIT.unpack_from(buf, off)
                off += _COMMIT.size
                led.append_commit(Commit(step, mask))
            elif tag == _TAG_R:
                _, step, worker, m, loss = _REC_HDR.unpack_from(buf, off)
                off += _REC_HDR.size
                seeds = np.zeros((m,), np.uint64)
                deltas = np.zeros((m,), np.float32)
                for i in range(m):
                    s, d = _PROBE.unpack_from(buf, off)
                    off += _PROBE.size
                    seeds[i], deltas[i] = s, np.float32(d)
                (n_leaves,) = struct.unpack_from("<H", buf, off)
                off += 2
                sizes, scales = [], np.zeros((n_leaves,), np.float32)
                for i in range(n_leaves):
                    sz, sc = _LEAF_HDR.unpack_from(buf, off)
                    off += _LEAF_HDR.size
                    sizes.append(sz)
                    scales[i] = np.float32(sc)
                tail_q = []
                for sz in sizes:
                    tail_q.append(np.frombuffer(
                        buf, np.int8, count=sz, offset=off).copy())
                    off += sz
                led.append_record(Record(step, worker, seeds, deltas,
                                         float(np.float32(loss)),
                                         tail_q, scales))
            else:
                raise ValueError(f"bad ledger tag {tag:#x} at offset {off}")
        return led
