"""Fleet worker: probe evaluation, record production, commit application.

A worker's step has two halves with very different costs:

  * compute (jitted, shared): evaluate its probe block's antithetic loss
    pairs on the step-deterministic batch and the BP-tail gradient at the
    perturbed points — fp32 lane: Alg. 1's avg_perturbed mode; int8
    lane: Alg. 2's integer forward pair + NITI tail, both the same math
    the update engine's train step runs (core/engine.py);
  * protocol (host-side, canonical): publish the Record (fp32: quantize
    the tail with error feedback; int8: the tail update is already
    int8-native), and on commit receipt apply the step through
    fleet/replay.py.

``make_probe_fn`` / ``make_int8_probe_fn`` / ``make_quantize_fn`` build
ONE jitted callable each that every worker *and* the single-process
reference share — same executable, same inputs, same bits. That, plus
the engine-routed replay apply, is why W simulated devices and one
process produce identical parameter streams.

Error-feedback residuals (fp32 lane only — the int8 tail payload is
exact by construction) are crash-consistent by protocol: a worker whose
record is not in the commit (dropped, straggled, or crashed) resets its
residual, so a restarted worker with a zero residual is
indistinguishable from an unlucky one — ledger replay needs no residual
state (docs/fleet.md).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .. import obs
from ..configs.base import LaneConfig
from ..core import elastic, prng, zo
from ..core.engine import Int8Engine
from ..train import checkpoint as ckpt
from ..train.compress import compress_tree
from .commit_rule import committed_arrays
from .ledger import Commit, Record
from .replay import ReplaySchema, apply_committed, probe_seeds, replay


def make_probe_fn(loss_fn: Callable, lane: LaneConfig, partition_fn=None):
    """Jitted (params, batch, step, probe_ids, base_seed) ->
    (l_plus[m], l_minus[m], tail_grad_sum fp32 tree).

    probe_ids are *global* probe indices: the key schedule is
    fold_in(fold_in(base, step), probe_id), identical to the reference
    and to replay.probe_seeds, so probe ownership can move between
    workers without changing the noise.
    """
    if partition_fn is None:
        partition_fn = lambda p: elastic.partition(p, lane)  # noqa: E731
    if lane.bp_grad_mode != "avg_perturbed":
        raise ValueError(
            "fleet protocol ships Alg. 1 avg_perturbed tail grads, got "
            f"bp_grad_mode={lane.bp_grad_mode!r}")

    def probe_eval(params, batch, step, probe_ids, base_seed):
        zo_part, bp_part = partition_fn(params)
        has_tail = bool(jax.tree_util.tree_leaves(bp_part))
        base = jax.random.wrap_key_data(base_seed)
        key = jax.random.fold_in(base, step)

        def tail_loss(bp, zo_pert):
            return loss_fn(elastic.merge(zo_pert, bp), batch)

        m = probe_ids.shape[0]
        lps, lms = [], []
        tail_sum = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), bp_part)
        zo_src = zo_part
        for j in range(m):
            pk = jax.random.fold_in(key, probe_ids[j])
            zp = zo.perturb(zo_src, pk, lane.zo_eps)
            if has_tail:
                lp, gp = jax.value_and_grad(tail_loss)(bp_part, zp)
                # sequence minus after plus (activation peaks don't overlap)
                zo_src, lp = jax.lax.optimization_barrier((zo_src, lp))
                zm = zo.perturb(zo_src, pk, -lane.zo_eps)
                lm, gm = jax.value_and_grad(tail_loss)(bp_part, zm)
                g_tail = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  + b.astype(jnp.float32)) * 0.5, gp, gm)
                tail_sum = jax.tree.map(jnp.add, tail_sum, g_tail)
            else:
                lp = loss_fn(elastic.merge(zp, bp_part), batch)
                zo_src, lp = jax.lax.optimization_barrier((zo_src, lp))
                zm = zo.perturb(zo_src, pk, -lane.zo_eps)
                lm = loss_fn(elastic.merge(zm, bp_part), batch)
            lps.append(lp)
            lms.append(lm)
        return jnp.stack(lps), jnp.stack(lms), tail_sum

    return jax.jit(probe_eval)


def make_int8_probe_fn(forward: Callable, lane: LaneConfig, partition_fn,
                       tail_fcs: List[Tuple[str, str]],
                       loss_mode: Optional[str] = None):
    """Jitted (params, batch, step, probe_ids, base_seed) ->
    (gs int32[m], tail payload int8 tree, loss f32[m]) — the int8-lane
    twin of ``make_probe_fn``, built on the same engine phases as the
    single-process Alg. 2 step.

    The tail payload is the saturating int8 combine of the worker's
    per-probe NITI updates — exactly the record-v2 wire value, so
    quantization on this lane is lossless (no error feedback needed).
    """
    engine = Int8Engine(lane, partition_fn, tail_fcs=tail_fcs,
                        loss_mode=loss_mode)
    from ..core.int_loss import float_loss

    def probe_eval(params, batch, step, probe_ids, base_seed):
        zo_part, bp_part = engine.partition(params)
        base = jax.random.wrap_key_data(base_seed)
        key = jax.random.fold_in(base, step)
        m = probe_ids.shape[0]
        gs, losses, upds_list = [], [], []
        for j in range(m):
            seed = prng.seed_from_key(jax.random.fold_in(key, probe_ids[j]))
            g, logits_p, acts_p = engine.probe_pair(
                forward, zo_part, bp_part, batch, seed)
            gs.append(g)
            losses.append(float_loss(logits_p, batch["y"]))
            upds_list.append(engine.tail_updates(bp_part, acts_p, logits_p,
                                                 batch["y"]))
        combined = engine.combine_tail(upds_list)
        # full bp coverage (zeros for non-tail-FC leaves) so the flat
        # payload aligns with the schema's QTensor-leaf order
        payload = {name: combined.get(
            name, jnp.zeros(sub["w"].data.shape, jnp.int8))
            for name, sub in bp_part.items()}
        return jnp.stack(gs), payload, jnp.stack(losses)

    return jax.jit(probe_eval)


def make_quantize_fn():
    """Jitted error-feedback int8 compression (train/compress.py)."""
    return jax.jit(compress_tree)


def zero_residual(schema: ReplaySchema):
    if schema.numerics == "int8":
        return None          # int8 tail payloads are exact: no residual
    return jax.tree_util.tree_unflatten(
        schema.tail_treedef,
        [jnp.zeros(s, jnp.float32) for s in schema.tail_shapes])


def compute_record(params, residual, batch, step: int, worker: int,
                   schema: ReplaySchema, probe_fn, quantize_fn):
    """(Record, pending_residual) — the one producer of wire records.

    Used verbatim by live workers and the single-process reference so a
    record's bytes are a pure function of (params, batch, step, worker,
    residual).
    """
    m = schema.fleet.probes_per_worker
    ids = jnp.arange(worker * m, (worker + 1) * m, dtype=jnp.int32)
    seeds = probe_seeds(schema, step)[worker * m:(worker + 1) * m]
    if schema.numerics == "int8":
        gs, payload, losses = probe_fn(params, batch, jnp.int32(step), ids,
                                       jnp.asarray(schema.base_seed))
        # flatten against the schema's QTensor-leaf order: payload is a
        # {layer: upd} dict over the tail FCs; absent layers ship zeros
        flat, _ = jax.tree_util.tree_flatten(payload)
        rec = Record(
            step=step, worker=worker, seeds=seeds,
            deltas=np.asarray(gs, np.int8),
            loss=float(np.float32(np.mean(np.asarray(losses, np.float32)))),
            tail_q=[np.asarray(x, np.int8).reshape(-1) for x in flat],
            numerics="int8")
        return rec, None
    lp, lm, tail = probe_fn(params, batch, jnp.int32(step), ids,
                            jnp.asarray(schema.base_seed))
    lp = np.asarray(lp, np.float32)
    lm = np.asarray(lm, np.float32)
    q_tree, s_tree, new_res = quantize_fn(tail, residual)
    rec = Record(
        step=step, worker=worker,
        seeds=seeds,
        deltas=lp - lm,
        loss=float(np.float32(np.mean(np.float32(0.5) * (lp + lm)))),
        tail_q=[np.asarray(x).reshape(-1)
                for x in jax.tree_util.tree_leaves(q_tree)],
        tail_scales=np.asarray(
            [float(s) for s in jax.tree_util.tree_leaves(s_tree)],
            np.float32))
    return rec, new_res


class Worker:
    """One simulated edge device. Owns params, an EF residual (fp32
    lane), and its probe block; everything else arrives over the (chaos)
    transport."""

    def __init__(self, worker_id: int, params, schema: ReplaySchema,
                 probe_fn, quantize_fn=None, ckpt_dir: Optional[str] = None):
        self.id = worker_id
        self.schema = schema
        self.params = params
        self.residual = zero_residual(schema)
        self.probe_fn = probe_fn
        self.quantize_fn = quantize_fn
        self.ckpt_dir = ckpt_dir
        self.step = 0
        self.alive = True
        self.catchup_bytes = 0
        self._pending_residual = None
        self._tag_params()

    def _tag_params(self):
        """Re-register this device's parameter copy with the memory
        ledger (rebind: idempotent; crash rebinds to 0, restart back)."""
        led = obs.get().memory
        if led.armed:
            led.rebind("fleet.worker.params",
                       obs.memory.tree_nbytes(self.params),
                       key=("worker", id(self)))

    # ---- live path ----------------------------------------------------- #
    def compute_record(self, step: int, batch) -> Record:
        if not (self.alive and step == self.step):
            raise RuntimeError(
                f"worker {self.id}: compute_record(step={step}) but "
                f"alive={self.alive}, own step={self.step}")
        rec, self._pending_residual = compute_record(
            self.params, self.residual, batch, step, self.id, self.schema,
            self.probe_fn, self.quantize_fn)
        return rec

    def apply_commit(self, step: int, commit: Commit, records,
                     new_params=None):
        """Advance to the committed params. ``new_params`` short-circuits
        the derivation when the caller already holds the canon for this
        commit (a gossip peer's closer applied it once already) — the
        residual/checkpoint protocol below runs either way."""
        if not (self.alive and step == self.step):
            raise RuntimeError(
                f"worker {self.id}: apply_commit(step={step}) but "
                f"alive={self.alive}, own step={self.step}")
        if new_params is None:
            cstep = committed_arrays(commit, records, self.schema)
            new_params = apply_committed(self.params, step, cstep,
                                         self.schema)
        self.params = new_params
        accepted = bool(commit.accepted >> self.id & 1)
        self.residual = (self._pending_residual if accepted
                         else zero_residual(self.schema))
        self._pending_residual = None
        self.step = step + 1
        if self.ckpt_dir and self.step % max(
                self.schema.fleet.local_ckpt_every, 1) == 0 \
                and self.schema.fleet.local_ckpt_every:
            ckpt.save(self.ckpt_dir, self.step, self.params)

    # ---- failure / recovery -------------------------------------------- #
    def crash(self):
        """Lose all volatile state (params, residual, pending record)."""
        self.alive = False
        self.params = None
        self.residual = None
        self._pending_residual = None
        self._tag_params()

    def restart(self, donor, now_step: int):
        """Catch up to `now_step` by ledger replay, not checkpoint copy.

        ``donor`` is any canon keeper with a ``template()``, a
        ``nearest_snapshot()`` and a ``ledger`` — the star coordinator,
        or (leaderless topology) any surviving GossipPeer. Base = own
        local checkpoint if one exists, else the donor's nearest
        snapshot; then replay the [base, now) ledger slice in one fused
        pass. Residual restarts at zero — by protocol that is also what
        the commit history implies (crash steps were not accepted).
        Returns (base_step, slice_bytes) so leaderless peers can adopt
        the same slice into their own closing state.
        """
        base_step, base_params = None, None
        if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) is not None:
            base_params, base_step = ckpt.restore(self.ckpt_dir,
                                                  donor.template())
        # a gossip donor that itself rejoined only holds the ledger from
        # its own replay base (ledger_since); a local checkpoint older
        # than that would replay across a gap — take the donor's
        # snapshot instead (its snapshots never predate its ledger)
        since = getattr(donor, "ledger_since", 0)
        if base_step is None or base_step > now_step or base_step < since:
            base_step, base_params = donor.nearest_snapshot(now_step)
        slice_bytes = donor.ledger.slice_bytes(base_step, now_step)
        self.catchup_bytes += len(slice_bytes)
        from .ledger import Ledger
        self.params = replay(base_params, Ledger.from_bytes(slice_bytes),
                             self.schema, base_step, now_step)
        self.residual = zero_residual(self.schema)
        self.step = now_step
        self.alive = True
        self._tag_params()
        return base_step, slice_bytes
