"""THE step-closing rule: one pure pipeline, every participant.

PR 2-4 made a committed step a pure function of ``(records, accepted
mask)``; this module makes the *closing* of a step a pure function of
``(gate state, arrivals)`` so that no particular node has to own it.
The pipeline — deadline gate -> never-empty fallback pick -> validation/
quarantine/robust filter -> admit-late-on-empty-gate -> Commit — is
invoked verbatim by:

  * the star coordinator (fleet/coordinator.py),
  * every leaderless gossip peer (fleet/gossip.py) — all peers of a
    connected component see the same arrival multiset after epidemic
    exchange, so they derive the **bit-identical** Commit v2 without a
    round of consensus,
  * the single-process reference (fleet/reference.py), which replays a
    realized candidate mask as synthetic on-time arrivals,
  * cold ledger replay (fleet/replay.py), through ``committed_arrays``
    — the one commit -> post-filter arrays + tail-eligibility
    derivation, cross-checked against the commit's carried filter bits.

Determinism rules (docs/fleet.md, "Leaderless commits"):

  * deadline gating judges a record by its **origin fate** — the
    publication fate ``ChaosTransport.fate(step, worker)``, a pure
    function of the chaos seed — never by the path it took to reach a
    given peer, so every holder of a record agrees on its timeliness;
  * when nobody makes the deadline, the fallback picks the earliest
    delivery (or, if the transport dropped everything, the earliest
    *retry* — reported to the caller so the redelivery is accounted,
    never phantom-committed); ties on delay break toward the
    **highest worker id** — the leaderless tiebreak;
  * the gate-empty path admits late deliveries one at a time in the
    same (delay, highest-id) order until a sound record commits, or
    commits empty (an exact parameter no-op).

Everything here is host-side scalar math over wire records — no jax, no
model state — so closing a step is exactly as cheap for a gossip peer
as it was for the coordinator.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .ledger import Commit, Record
from .transport import Fate

# ------------------------------------------------------------------ #
# commit -> post-filter arrays + tail eligibility (consumer side)
# ------------------------------------------------------------------ #


@dataclass(frozen=True)
class CommittedStep:
    """One committed step, fully derived: post-filter wire arrays plus
    the tail-eligible worker set. ``tail_ws`` is the satellite fix for
    the PR 4 all-or-nothing rule: a worker whose *loss-consistency*
    channel passed keeps its BP-tail contribution even when individual
    ZO probes were band-rejected — only a lying loss (which poisons the
    whole record) or non-acceptance drops the tail."""
    commit: Commit
    records: Dict[int, Record]
    seeds: np.ndarray            # uint64[n], 0 where masked
    deltas: np.ndarray           # fp32 loss-diffs | int8 signs, 0 masked
    mask: np.ndarray             # f32[n] post-filter probe mask
    tail_ws: Tuple[int, ...]     # sorted workers whose tail enters the update


def raw_arrays(commit: Commit, records: Dict[int, Record], schema):
    """Pre-filter (seeds, deltas, mask) straight off the commit bitmask.
    Masked probes carry seed 0 / delta 0 — their coefficient is exactly
    zero, so the seed value never reaches the parameters."""
    n, m = schema.n_probes, schema.fleet.probes_per_worker
    seeds = np.zeros((n,), np.uint64)
    deltas = np.zeros(
        (n,), np.int8 if schema.numerics == "int8" else np.float32)
    mask = np.zeros((n,), np.float32)
    for w in commit.workers(schema.fleet.num_workers):
        rec = records[w]
        sl = slice(w * m, (w + 1) * m)
        seeds[sl] = rec.seeds
        deltas[sl] = rec.deltas
        mask[sl] = 1.0
    return seeds, deltas, mask


def committed_arrays(commit: Commit, records: Dict[int, Record],
                     schema) -> CommittedStep:
    """The ONE commit -> update-inputs derivation (coordinator, workers,
    gossip peers, the reference, and cold ledger replay all route
    through here, via replay.step_arrays or directly).

    v1 / filter-free commits pass through untouched; tail eligibility is
    the accepted set (probe blocks are all-or-nothing). For v2 commits
    the filter verdict is *recomputed* from (records, accepted mask) —
    the pure function — and cross-checked against the commit's carried
    bitmask; a mismatch means a corrupt or forged ledger and raises
    ValueError. A v2 ledger without the RobustConfig that produced it
    also raises: the wire bits alone cannot distinguish mask from clip
    semantics, and silently guessing would diverge from the canon (the
    config is out-of-band enrollment schema, like the tail leaf layout).
    """
    from . import robust
    seeds, deltas, mask = raw_arrays(commit, records, schema)
    accepted = commit.workers(schema.fleet.num_workers)
    if commit.filtered is None:
        return CommittedStep(commit, records, seeds, deltas, mask,
                             tuple(sorted(w for w in accepted
                                          if w in records)))
    m = schema.fleet.probes_per_worker
    cfg = schema.fleet.robust
    if cfg is None:
        raise ValueError(
            f"commit {commit.step} is robust-filtered (v2) but the "
            "schema carries no RobustConfig — replaying it without the "
            "filter semantics that produced it would diverge")
    losses = robust.record_losses(records, commit.accepted,
                                  schema.fleet.num_workers)
    decision = robust.filter_decision(deltas, losses, mask, m, cfg,
                                      schema.numerics)
    if not np.array_equal(decision.inband, commit.inband(schema.n_probes)):
        raise ValueError(
            f"commit {commit.step}: carried filter mask does not match "
            "the deterministic recomputation — corrupt or forged ledger")
    seeds, deltas, mask = robust.apply_decision(seeds, deltas, mask,
                                                decision, cfg, m)
    # tail eligibility: loss-consistency IS the tail channel's check —
    # a band-rejected ZO probe masks only itself, the worker's sound
    # first-order signal stays in the update
    tail_ws = tuple(sorted(w for w in accepted if w in records
                           and not decision.loss_reject >> w & 1))
    return CommittedStep(commit, records, seeds, deltas, mask, tail_ws)


# ------------------------------------------------------------------ #
# arrivals -> Commit (producer side): the leaderless close pipeline
# ------------------------------------------------------------------ #


@dataclass(frozen=True)
class CloseOutcome:
    """Everything a closing participant needs to record one step.
    ``outliers`` feeds ``RobustGate.advance`` (quarantine verdicts);
    ``retried`` is a record the transport never delivered that the
    never-empty fallback pulled back — the caller must account it as a
    redelivery (``ChaosTransport.redeliver``), the satellite fix for
    phantom commits that bypassed transport accounting."""
    commit: Commit
    records: Dict[int, Record]          # accepted: these enter the ledger
    ontime_bits: int                    # pre-gate: made the deadline
    late_admit_bits: int                # pulled in past the deadline
    rejected: Tuple[Tuple[int, str], ...]
    outliers: int                       # worker bits, feeds the tracker
    retried: Optional[Record]
    events: Tuple[str, ...]

    @property
    def candidate_bits(self) -> int:
        """The realized candidate set (on-time | late-admitted) — what
        drives the single-process reference re-derivation."""
        return self.ontime_bits | self.late_admit_bits


def _pick_order(rf) -> Tuple[int, int]:
    """Deterministic pick/admit order: earliest delay first, ties broken
    toward the HIGHEST worker id (the leaderless tiebreak — every peer
    lands on the same record without a coordinator to ask)."""
    rec, fate = rf
    return (fate.delay, -rec.worker)


def close_step(gate, step: int,
               arrivals: List[Tuple[Record, Fate]]) -> CloseOutcome:
    """Deadline-gate the arrivals, filter, commit — the pure pipeline.

    ``gate`` is a RobustGate; its quarantine tracker state is read, not
    advanced (call ``gate.advance(step, outcome)`` exactly once with the
    returned outcome). Pure given (gate state, arrivals): closing the
    same arrivals against the same gate state yields the byte-identical
    Commit on every participant.
    """
    if not arrivals:
        raise ValueError(f"close_step({step}): no arrivals")
    deadline = gate.schema.fleet.deadline
    events: List[str] = []
    retried: Optional[Record] = None
    on_time = [(r, f) for r, f in arrivals if f.arrived_by(deadline)]
    ontime_bits = 0
    for r, _ in on_time:
        ontime_bits |= 1 << r.worker
    late_admit_bits = 0
    if not on_time:
        # nobody made the deadline: wait for the earliest delivery (or,
        # if the transport dropped everything, the earliest retry) — a
        # step is never empty for lack of patience.
        pool = [(r, f) for r, f in arrivals if f.delivered] or arrivals
        pick = min(pool, key=_pick_order)
        if not pick[1].delivered:
            retried = pick[0]     # caller accounts the redelivery bytes
        on_time = [pick]
        late_admit_bits |= 1 << pick[0].worker
        events.append(f"step {step}: empty deadline, waited for "
                      f"worker {pick[0].worker}"
                      + (" (redelivery)" if retried is not None else ""))
    # late arrivals the gate may pull in if it rejects everything,
    # earliest-delivery first (deterministic)
    on_time_ids = {id(r) for r, _ in on_time}
    late = sorted(((r, f) for r, f in arrivals
                   if id(r) not in on_time_ids and f.delivered),
                  key=_pick_order)
    candidates = {rec.worker: rec for rec, _ in on_time}
    result = gate.evaluate(step, candidates)
    while result.commit.accepted == 0 and late:
        rec, _ = late.pop(0)
        if rec.worker in candidates:
            continue
        candidates[rec.worker] = rec
        late_admit_bits |= 1 << rec.worker
        events.append(f"step {step}: gate empty, admitted late "
                      f"worker {rec.worker}")
        result = gate.evaluate(step, candidates)
    for w, reason in result.rejected:
        events.append(f"step {step}: rejected worker {w} ({reason})")
    if result.commit.accepted == 0:
        events.append(f"step {step}: no sound record survived the gate "
                      "— empty commit (no-op step)")
    return CloseOutcome(result.commit, result.records,
                        ontime_bits, late_admit_bits & ~ontime_bits,
                        tuple(result.rejected), result.outliers, retried,
                        tuple(events))


def close_candidates(gate, step: int,
                     candidates: Dict[int, Record]) -> CloseOutcome:
    """Close a step from a realized candidate set (no fates): how the
    single-process reference replays a fleet's candidate masks through
    the identical pipeline. Equivalent to ``close_step`` with every
    candidate on time — the final gate verdict over a candidate set does
    not depend on the admission order that produced it."""
    return close_step(gate, step, [(rec, Fate(True, 0))
                                   for _, rec in sorted(candidates.items())])


def step_loss(cstep: CommittedStep, schema,
              prev_loss: Optional[float]) -> float:
    """The canonical per-step training-loss observation: accepted
    records' reported losses, weighted by surviving probe count. A no-op
    step (everything rejected/filtered) has no observation — it carries
    the previous loss instead of recording a fictitious 0.0."""
    m = schema.fleet.probes_per_worker
    mask, records = cstep.mask, cstep.records
    if mask.sum() > 0:
        return sum(records[w].loss * float(mask[w * m:(w + 1) * m].sum())
                   for w in records) / float(mask.sum())
    return prev_loss if prev_loss is not None else float("nan")
