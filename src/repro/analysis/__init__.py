"""reprolint — the repo-invariant static-analysis pass.

The repo's core asset is bit-exact determinism: a fine-tune is a
replayable (seed, scalar) ledger, and every fleet/serve guarantee
collapses if any code path is nondeterministic or silently disabled.
reprolint machine-checks the invariant *classes* prior PRs fixed one
instance at a time — salted builtin hash(), `assert`s that vanish under
python -O, non-monotonic clocks — plus the cross-file contracts
(kernel/ref/ops dispatch triangle, docs/design.md § citations, the
observability metric catalog, the ledger's documented wire sizes) that
per-file linters cannot see.

Usage: ``python -m repro.analysis`` (CLI, docs/analysis.md) or::

    from repro.analysis import run_analysis, ALL_RULES
    report = run_analysis(root, ALL_RULES)
    assert report.clean, report.findings

Pure stdlib — importable (and CI-runnable) without jax.
"""
from .core import (AllowEntry, Finding, Report, Rule, load_allowlist,
                   run_analysis)
from .project import Project, build_project, find_repo_root
from .rules import ALL_RULES, META_RULES, rules_by_id

__all__ = ["AllowEntry", "Finding", "Report", "Rule", "load_allowlist",
           "run_analysis", "Project", "build_project", "find_repo_root",
           "ALL_RULES", "META_RULES", "rules_by_id"]
