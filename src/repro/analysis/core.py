"""reprolint's engine: findings, rules, suppressions, the allowlist.

The flow (`run_analysis`):

1. build the ``Project`` model (project.py),
2. run every registered rule over it,
3. discharge findings against inline suppressions and the committed
   allowlist (``.reprolint.json`` at the repo root),
4. turn *unused* suppressions and allowlist entries into
   ``stale-suppression`` findings and malformed inline allows into
   ``bad-suppression`` findings,
5. report. Exit is clean only when nothing survives: an unexplained
   finding, a reasonless allow, and an allow that no longer matches
   anything are all equally fatal — the suppression inventory is kept
   exactly as live as the violations themselves.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

from .project import Project, build_project

ALLOWLIST_NAME = ".reprolint.json"

# Meta rule ids (engine-emitted; registered for --list-rules alongside
# the analysis rules proper).
BAD_SUPPRESSION = "bad-suppression"
STALE_SUPPRESSION = "stale-suppression"


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str
    col: int = 0

    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}


class Rule:
    """One machine-checked repo invariant.

    Subclasses set ``id``/``title``/``rationale`` and implement
    ``check(project)``. ``rationale`` names the prose contract the rule
    enforces (a docs/design.md section or PR-history bug class) — it is
    what `--list-rules` and docs/analysis.md show.
    """

    id: str = ""
    title: str = ""
    rationale: str = ""

    def check(self, project: Project) -> Iterable[Finding]:
        raise NotImplementedError


@dataclass
class AllowEntry:
    """One committed allowlist entry (grandfathered finding).

    Matches findings by rule id + path, optionally narrowed to source
    lines containing ``contains``. A reason is mandatory. An entry that
    matches nothing is stale — delete it when the underlying code is
    fixed.
    """
    rule: str
    path: str
    reason: str
    contains: Optional[str] = None
    index: int = 0            # position in the file, for error messages
    used: int = 0

    def matches(self, project: Project, f: Finding) -> bool:
        if f.rule != self.rule or f.path != self.path:
            return False
        if self.contains is None:
            return True
        sf = project.get(f.path)
        return sf is not None and self.contains in sf.line_at(f.line)


def load_allowlist(root: Path) -> List[AllowEntry]:
    path = Path(root) / ALLOWLIST_NAME
    if not path.is_file():
        return []
    doc = json.loads(path.read_text(encoding="utf-8"))
    entries = []
    for i, raw in enumerate(doc.get("allow", [])):
        missing = {"rule", "path", "reason"} - set(raw)
        if missing:
            raise ValueError(
                f"{ALLOWLIST_NAME} entry {i} is missing {sorted(missing)}")
        if not str(raw["reason"]).strip():
            raise ValueError(f"{ALLOWLIST_NAME} entry {i} has an empty reason")
        entries.append(AllowEntry(rule=raw["rule"], path=raw["path"],
                                  reason=str(raw["reason"]),
                                  contains=raw.get("contains"), index=i))
    return entries


@dataclass
class Report:
    root: str
    rules: List[str]
    findings: List[Finding]                 # unsuppressed + meta — the gate
    suppressed: List[Finding] = field(default_factory=list)

    @property
    def clean(self) -> bool:
        return not self.findings

    def to_dict(self) -> dict:
        return {
            "tool": "reprolint",
            "root": self.root,
            "rules": self.rules,
            "clean": self.clean,
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
        }


def _syntax_findings(project: Project) -> List[Finding]:
    return [Finding(rule="parse-error", path=sf.path, line=1,
                    message=f"file does not parse: {sf.parse_error}")
            for sf in project.iter_files() if sf.parse_error]


def run_analysis(root: Path, rules: Sequence[Rule],
                 allowlist: Optional[Sequence[AllowEntry]] = None,
                 project: Optional[Project] = None) -> Report:
    """Run ``rules`` over the tree at ``root`` and discharge suppressions."""
    root = Path(root)
    if project is None:
        project = build_project(root)
    if allowlist is None:
        allowlist = load_allowlist(root)

    raw: List[Finding] = list(_syntax_findings(project))
    for rule in rules:
        raw.extend(rule.check(project))

    active: List[Finding] = []
    suppressed: List[Finding] = []
    meta: List[Finding] = []

    for f in sorted(raw, key=lambda f: (f.path, f.line, f.rule)):
        sf = project.get(f.path)
        inline = None
        if sf is not None:
            for sup in sf.suppressions:
                if f.rule in sup.rules and f.line in (sup.covers, sup.line):
                    inline = sup
                    break
        if inline is not None:
            inline.used = True
            if inline.reason:           # reasonless allows suppress nothing
                suppressed.append(f)
                continue
        entry = next((e for e in allowlist if e.matches(project, f)), None)
        if entry is not None:
            entry.used += 1
            suppressed.append(f)
            continue
        active.append(f)

    # ---- meta findings: the suppression inventory must stay live ------- #
    for sf in project.iter_files():
        for sup in sf.suppressions:
            if not sup.reason:
                meta.append(Finding(
                    rule=BAD_SUPPRESSION, path=sf.path, line=sup.line,
                    message="allow() without a reason — write "
                            "`# reprolint: allow(rule-id) -- <why>`"))
            elif not sup.used:
                meta.append(Finding(
                    rule=STALE_SUPPRESSION, path=sf.path, line=sup.line,
                    message=f"allow({', '.join(sup.rules)}) matches no "
                            "finding on its line — delete the comment"))
    for e in allowlist:
        if not e.used:
            meta.append(Finding(
                rule=STALE_SUPPRESSION, path=ALLOWLIST_NAME, line=e.index + 1,
                message=f"allowlist entry {e.index} "
                        f"({e.rule} @ {e.path}) matches no finding — "
                        "delete the entry"))

    return Report(root=str(root), rules=[r.id for r in rules],
                  findings=active + meta, suppressed=suppressed)
