"""kernel-dispatch-complete: the three-file Pallas kernel contract.

Every Pallas kernel in src/repro/kernels/ participates in a three-way
contract (docs/design.md §7; kernels/ops.py module docstring): the
kernel module holds the TPU implementation, kernels/ref.py holds the
pure-jnp reference that *is* the off-TPU numerical contract, and
kernels/ops.py is the one public dispatch point that picks between
them. A kernel missing its ref has no testable numerics off-TPU; a
kernel missing its ops entry invites callers to bypass dispatch; a
signature drift between the three is exactly the class of bug that only
surfaces on TPU hardware.

Machine-checked shape of the contract, per public kernel-module
function that (transitively, within its module) calls
``pl.pallas_call``:

* ops.py defines a function of the same name;
* the ops entry's positional parameters match the kernel's (name and
  order — kernel-tuning keyword-only args like bm/bn/bk are ignored);
* the ops entry takes keyword-only ``force_pallas`` and ``interpret``;
* the ops entry calls exactly one ``ref.<fn>`` fallback, which exists
  in ref.py with the same positional parameters;
* and (reverse direction) every public ``*_ref`` in ref.py is reachable
  from some ops entry — an orphan ref is dead contract.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from ..core import Finding, Rule
from ..project import Project, SourceFile

KERNELS_DIR = "src/repro/kernels"
NON_KERNEL_FILES = {f"{KERNELS_DIR}/__init__.py",
                    f"{KERNELS_DIR}/ref.py",
                    f"{KERNELS_DIR}/ops.py"}
REQUIRED_KWONLY = ("force_pallas", "interpret")


def _top_functions(tree: ast.AST) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body
            if isinstance(n, ast.FunctionDef)}


def _calls_pallas(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and node.attr == "pallas_call":
            return True
        if isinstance(node, ast.Name) and node.id == "pallas_call":
            return True
    return False


def _called_names(fn: ast.FunctionDef) -> Set[str]:
    out = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            out.add(node.func.id)
    return out


def _pallas_kernels(tree: ast.AST) -> List[ast.FunctionDef]:
    """Public top-level fns that reach pallas_call within their module."""
    fns = _top_functions(tree)
    direct = {name for name, fn in fns.items() if _calls_pallas(fn)}
    # one transitive closure over same-module calls (helpers wrapping
    # the pallas_call for grid/spec setup)
    reach = set(direct)
    changed = True
    while changed:
        changed = False
        for name, fn in fns.items():
            if name not in reach and _called_names(fn) & reach:
                reach.add(name)
                changed = True
    return [fns[n] for n in sorted(reach) if not n.startswith("_")]


def _positional(fn: ast.FunctionDef) -> List[str]:
    return [a.arg for a in fn.args.posonlyargs + fn.args.args]


def _kwonly(fn: ast.FunctionDef) -> List[str]:
    return [a.arg for a in fn.args.kwonlyargs]


def _ref_calls(fn: ast.FunctionDef) -> List[str]:
    """Names called as ``ref.<name>(...)`` inside ``fn``."""
    out = []
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "ref"):
            out.append(node.func.attr)
    return out


class KernelDispatchComplete(Rule):
    id = "kernel-dispatch-complete"
    title = "every Pallas kernel has a ref counterpart and an ops dispatch"
    rationale = (
        "kernels/ref.py is the off-TPU numerical contract and "
        "kernels/ops.py the one dispatch seam (docs/design.md §7); a "
        "kernel outside that triangle — or drifting from it in "
        "signature — only fails on TPU hardware.")

    def check(self, project: Project) -> Iterable[Finding]:
        ops_sf = project.get(f"{KERNELS_DIR}/ops.py")
        ref_sf = project.get(f"{KERNELS_DIR}/ref.py")
        kernel_files = [sf for sf in project.iter_files(KERNELS_DIR)
                        if sf.path not in NON_KERNEL_FILES
                        and sf.tree is not None]
        if not kernel_files:
            return
        ops_fns = _top_functions(ops_sf.tree) if ops_sf and ops_sf.tree \
            else {}
        ref_fns = _top_functions(ref_sf.tree) if ref_sf and ref_sf.tree \
            else {}
        used_refs: Set[str] = set()

        for sf in kernel_files:
            for kern in _pallas_kernels(sf.tree):
                yield from self._check_kernel(sf, kern, ops_sf, ops_fns,
                                              ref_sf, ref_fns, used_refs)

        # reverse direction: orphan public refs
        if ref_sf is not None:
            for name in sorted(ref_fns):
                if name.startswith("_"):
                    continue
                if name not in used_refs:
                    yield Finding(
                        rule=self.id, path=ref_sf.path,
                        line=ref_fns[name].lineno,
                        message=f"ref.{name} is not reachable from any "
                                "ops.py dispatch entry — orphaned "
                                "reference implementation")

    def _check_kernel(self, sf: SourceFile, kern: ast.FunctionDef,
                      ops_sf: Optional[SourceFile],
                      ops_fns: Dict[str, ast.FunctionDef],
                      ref_sf: Optional[SourceFile],
                      ref_fns: Dict[str, ast.FunctionDef],
                      used_refs: Set[str]) -> Iterable[Finding]:
        name = kern.name
        entry = ops_fns.get(name)
        if entry is None:
            yield Finding(
                rule=self.id, path=sf.path, line=kern.lineno,
                message=f"Pallas kernel `{name}` has no ops.py dispatch "
                        "entry — callers would bind to the TPU "
                        "implementation directly")
            return
        kern_pos = _positional(kern)
        ops_pos = _positional(entry)
        if ops_pos != kern_pos:
            yield Finding(
                rule=self.id, path=f"{KERNELS_DIR}/ops.py",
                line=entry.lineno,
                message=f"ops.{name} positional signature {ops_pos} != "
                        f"kernel signature {kern_pos} ({sf.path})")
        missing_kw = [k for k in REQUIRED_KWONLY if k not in _kwonly(entry)]
        if missing_kw:
            yield Finding(
                rule=self.id, path=f"{KERNELS_DIR}/ops.py",
                line=entry.lineno,
                message=f"ops.{name} is missing keyword-only "
                        f"{missing_kw} — every dispatch entry exposes "
                        "force_pallas/interpret")
        refs = _ref_calls(entry)
        if len(set(refs)) != 1:
            yield Finding(
                rule=self.id, path=f"{KERNELS_DIR}/ops.py",
                line=entry.lineno,
                message=f"ops.{name} must fall back to exactly one "
                        f"ref.<fn> (found {sorted(set(refs)) or 'none'})")
            return
        ref_name = refs[0]
        used_refs.add(ref_name)
        ref_fn = ref_fns.get(ref_name)
        if ref_fn is None:
            yield Finding(
                rule=self.id, path=f"{KERNELS_DIR}/ops.py",
                line=entry.lineno,
                message=f"ops.{name} falls back to ref.{ref_name}, which "
                        "does not exist in kernels/ref.py")
            return
        ref_pos = _positional(ref_fn)
        if ref_pos != kern_pos:
            yield Finding(
                rule=self.id, path=f"{KERNELS_DIR}/ref.py",
                line=ref_fn.lineno,
                message=f"ref.{ref_name} positional signature {ref_pos} != "
                        f"kernel `{name}` signature {kern_pos}")
