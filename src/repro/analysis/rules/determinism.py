"""Per-construct determinism rules.

These codify the bug classes three prior PRs fixed by hand, so the
classes stay fixed while the tree refactors freely:

* salted builtin ``hash()`` made init streams irreproducible across
  processes (fixed once in models/layers.py — docs/design.md §9);
* invariant ``assert``s vanish under ``python -O`` (a lying fleet
  worker could crash the coordinator — or sail through — docs/design.md
  §11; CI runs the fleet suites under PYTHONOPTIMIZE=1 for exactly this
  reason);
* ``time.time()`` deltas go negative under NTP steps (the flight
  recorder exists to own monotonic timing — docs/observability.md,
  "clock policy");
* ``set`` iteration order is salted-hash order for strings and
  insertion-history order for everything else — feeding it into wire
  encoding or commit paths breaks the bit-identical-close guarantee
  (docs/design.md §12).
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator, List, Set, Tuple

from ..core import Finding, Rule
from ..project import Project

LIB = "src/repro"


def _walk_funcs(tree: ast.AST) -> Iterator[ast.AST]:
    yield from (n for n in ast.walk(tree)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)))


class NoInvariantAssert(Rule):
    id = "no-invariant-assert"
    title = "library code must raise, not assert"
    rationale = (
        "`assert` compiles away under python -O, silently disabling the "
        "invariant (docs/design.md §11; CI's PYTHONOPTIMIZE=1 jobs). "
        "Library code in src/repro raises ValueError/RuntimeError instead. "
        "Genuine jit-trace-time shape asserts are allowlistable.")

    def check(self, project: Project) -> Iterable[Finding]:
        for sf in project.iter_files(LIB):
            if sf.tree is None:
                continue
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Assert):
                    yield Finding(
                        rule=self.id, path=sf.path, line=node.lineno,
                        col=node.col_offset,
                        message="invariant guarded by `assert` disappears "
                                "under python -O — raise ValueError/"
                                "RuntimeError instead")


class NoBuiltinHash(Rule):
    id = "no-builtin-hash"
    title = "builtin hash() is process-salted"
    rationale = (
        "str hashes are salted per process (PYTHONHASHSEED), so any "
        "seed/init/wire derivation through builtin hash() is "
        "irreproducible across processes — the PR-3 layers.subkey bug "
        "class (docs/design.md §9). Use zlib.crc32 or hashlib.")

    def check(self, project: Project) -> Iterable[Finding]:
        for sf in project.iter_files(LIB, "benchmarks"):
            if sf.tree is None:
                continue
            for node in ast.walk(sf.tree):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)
                        and node.func.id == "hash"):
                    yield Finding(
                        rule=self.id, path=sf.path, line=node.lineno,
                        col=node.col_offset,
                        message="builtin hash() is salted per process — "
                                "derive streams via zlib.crc32/hashlib "
                                "(docs/design.md §9)")


def _is_time_time(node: ast.Call, from_imports: Set[str]) -> bool:
    f = node.func
    if (isinstance(f, ast.Attribute) and f.attr == "time"
            and isinstance(f.value, ast.Name) and f.value.id == "time"):
        return True
    return isinstance(f, ast.Name) and f.id == "time" and "time" in from_imports


class MonotonicClock(Rule):
    id = "monotonic-clock"
    title = "durations come from the monotonic clock"
    rationale = (
        "time.time() steps backwards under NTP, so deltas go negative — "
        "the PR-6 bug class. Durations go through repro.obs.monotonic()/"
        "perf_ns(); time.time() is allowed only as the checkpoint "
        "manifest's wall-clock stamp (inline-suppressed there).")

    def check(self, project: Project) -> Iterable[Finding]:
        for sf in project.iter_files(LIB, "benchmarks"):
            if sf.tree is None:
                continue
            from_imports = {
                a.asname or a.name
                for node in ast.walk(sf.tree)
                if isinstance(node, ast.ImportFrom) and node.module == "time"
                for a in node.names if a.name == "time"}
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Call) and _is_time_time(node,
                                                                from_imports):
                    yield Finding(
                        rule=self.id, path=sf.path, line=node.lineno,
                        col=node.col_offset,
                        message="time.time() is not monotonic — use "
                                "repro.obs.monotonic()/perf_ns() for "
                                "durations (wall-clock stamps must carry "
                                "an inline allow)")


# Modules whose iteration order reaches the wire, a digest, or a commit
# decision. Everything a gossip peer or the coordinator serializes or
# closes over must iterate in a canonical (sorted) order.
WIRE_MODULES = (
    "src/repro/fleet/ledger.py",
    "src/repro/fleet/commit_rule.py",
    "src/repro/fleet/coordinator.py",
    "src/repro/fleet/gossip.py",
    "src/repro/fleet/replay.py",
    "src/repro/fleet/transport.py",
    "src/repro/fleet/robust.py",
    "src/repro/train/checkpoint.py",
)

_SET_CALLS = {"set", "frozenset"}


class _SetTracker:
    """Syntactic set-typed-ness, with single-assignment local tracking."""

    def __init__(self, scope: ast.AST):
        self.setish_names: Set[str] = set()
        assigns: dict = {}
        for node in ast.walk(scope):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        assigns.setdefault(tgt.id, []).append(node.value)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                if isinstance(node.target, ast.Name):
                    assigns.setdefault(node.target.id, []).append(node.value)
        for name, values in assigns.items():
            if len(values) == 1 and self._expr_setish(values[0], depth=0):
                self.setish_names.add(name)

    def _expr_setish(self, e: ast.AST, depth: int = 1) -> bool:
        if isinstance(e, (ast.Set, ast.SetComp)):
            return True
        if (isinstance(e, ast.Call) and isinstance(e.func, ast.Name)
                and e.func.id in _SET_CALLS):
            return True
        if isinstance(e, ast.BinOp) and isinstance(
                e.op, (ast.Sub, ast.BitAnd, ast.BitOr, ast.BitXor)):
            return self._expr_setish(e.left, depth) \
                or self._expr_setish(e.right, depth)
        if depth and isinstance(e, ast.Name):
            return e.id in self.setish_names
        return False

    def setish(self, e: ast.AST) -> bool:
        return self._expr_setish(e)


class NondeterministicIteration(Rule):
    id = "nondeterministic-iteration"
    title = "no raw set iteration on wire/digest/commit paths"
    rationale = (
        "set iteration order is not canonical across processes; on the "
        "modules that encode records, compute digests, or close commits "
        "it must go through sorted() (docs/design.md §12 — every peer "
        "must serialize and close in one order).")

    def check(self, project: Project) -> Iterable[Finding]:
        for sf in project.iter_files(*WIRE_MODULES):
            if sf.tree is None:
                continue
            for scope in (sf.tree, *_walk_funcs(sf.tree)):
                tracker = _SetTracker(scope)
                for node, iter_expr in self._iterations(scope):
                    if tracker.setish(iter_expr):
                        yield Finding(
                            rule=self.id, path=sf.path,
                            line=iter_expr.lineno, col=iter_expr.col_offset,
                            message="iteration over a set feeds a wire/"
                                    "commit path — wrap it in sorted() "
                                    "for a canonical order")

    @staticmethod
    def _iterations(scope: ast.AST) \
            -> List[Tuple[ast.AST, ast.expr]]:
        """(node, iterated expr) pairs directly inside ``scope``."""
        out: List[Tuple[ast.AST, ast.expr]] = []
        nested = {id(n) for f in _walk_funcs(scope) if f is not scope
                  for n in ast.walk(f)}
        for node in ast.walk(scope):
            if id(node) in nested:
                continue
            if isinstance(node, (ast.For, ast.AsyncFor)):
                out.append((node, node.iter))
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                out.extend((node, g.iter) for g in node.generators)
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Name)
                  and node.func.id in ("list", "tuple") and node.args):
                out.append((node, node.args[0]))
        return out
