"""Cross-artifact rules: code and committed docs must not drift.

Three contracts the repo states in prose get machine-checked here:

* ``design-cite-resolves`` — `§N` citations (docstrings, comments,
  other docs) must point at a section that exists in docs/design.md;
  PR 2 repaired 28 dangling cites by hand, this keeps the count at
  zero.
* ``metric-catalog-sync`` — the observability surface is a contract
  (docs/observability.md, "Metric catalog"): every span/counter/gauge/
  histogram/event/memory-tag literal registered through repro.obs must
  have a catalog row, and every catalog row must have a registration
  site. No phantom metrics, no phantom docs rows.
* ``wire-bytes-consistent`` — the struct formats in fleet/ledger.py
  must produce exactly the documented record sizes (docs/fleet.md,
  "Ledger record format": 11 B header, 12 B/probe fp32, 9 B/probe
  int8). The paper's headline wire numbers are not allowed to rot.
"""
from __future__ import annotations

import ast
import fnmatch
import re
import struct
from typing import Dict, Iterable, Iterator, List, Set, Tuple

from ..core import Finding, Rule
from ..project import Project

LIB = "src/repro"


# --------------------------------------------------------------------- #
# design-cite-resolves
# --------------------------------------------------------------------- #
_CITE_RE = re.compile(r"§(\d+)")
_HEADING_RE = re.compile(r"^##\s+§\d+\b")


class DesignCiteResolves(Rule):
    id = "design-cite-resolves"
    title = "§N citations resolve to a docs/design.md section"
    rationale = (
        "docs/design.md sections are numbered contracts; a citation to "
        "a section that does not exist is unverifiable prose (PR 2 "
        "repointed 28 dangling cites — this keeps it at zero).")

    def check(self, project: Project) -> Iterable[Finding]:
        sections = set(project.design_sections())
        if not sections:
            if any(True for sf in project.iter_files()
                   for _ in self._citations_of_text(sf.text)):
                yield Finding(rule=self.id, path="docs/design.md", line=1,
                              message="sources cite §N sections but "
                                      "docs/design.md has none")
            return
        for sf in project.iter_files():
            for line_no, n in self._citations_of_text(sf.text):
                if n not in sections:
                    yield Finding(
                        rule=self.id, path=sf.path, line=line_no,
                        message=f"cites docs/design.md §{n}, which does "
                                "not exist (sections: "
                                f"§1–§{max(sections)})")
        for rel, text in sorted(project.docs.items()):
            for i, line in enumerate(text.splitlines(), 1):
                if rel == "docs/design.md" and _HEADING_RE.match(line):
                    continue
                for m in _CITE_RE.finditer(line):
                    n = int(m.group(1))
                    if n not in sections:
                        yield Finding(
                            rule=self.id, path=rel, line=i,
                            message=f"cites §{n}, which does not exist "
                                    "in docs/design.md (sections: "
                                    f"§1–§{max(sections)})")

    @staticmethod
    def _citations_of_text(text: str) -> Iterator[Tuple[int, int]]:
        for i, line in enumerate(text.splitlines(), 1):
            for m in _CITE_RE.finditer(line):
                yield i, int(m.group(1))


# --------------------------------------------------------------------- #
# metric-catalog-sync
# --------------------------------------------------------------------- #
_METRIC_METHODS = {"counter": "counter", "gauge": "gauge",
                   "histogram": "histogram", "span": "span",
                   "event": "event"}
_MEMORY_METHODS = {"alloc", "rebind", "free"}
_PLACEHOLDER_RE = re.compile(r"<[^<>]+>")


def _literal_pattern(node: ast.expr) -> str | None:
    """str literal or f-string as a match pattern ({}-fields -> '*')."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.JoinedStr):
        parts = []
        for v in node.values:
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                parts.append(v.value)
            else:
                parts.append("*")
        return "".join(parts)
    return None


def _compatible(a: str, b: str) -> bool:
    """Do two name patterns ('*' wildcards) plausibly name each other?"""
    return (fnmatch.fnmatchcase(a.replace("*", "\x01"), b)
            or fnmatch.fnmatchcase(b.replace("*", "\x01"), a))


def _expand_cell_names(cell: str, kind: str) -> List[str]:
    """Backticked names from one catalog cell, sibling-expanded.

    `fleet.wire.zo_bytes` / `tail_bytes` names two counters: a bare
    token inherits the previous full name's prefix; a `.suffix` token
    replaces after the previous name's parent. `<x>` placeholders
    become '*' wildcards.
    """
    out: List[str] = []
    for tok in re.findall(r"`([^`]+)`", cell):
        name = _PLACEHOLDER_RE.sub("*", tok.strip())
        if kind == "span" or "/" in name or name.startswith("memory."):
            full = name
        elif name.startswith("."):
            full = (out[-1].rsplit(".", 1)[0] + name) if out else name
        elif "." in name or not out:
            full = name
        else:                       # bare sibling: swap the last segment
            full = out[-1].rsplit(".", 1)[0] + "." + name
        out.append(full)
    return out


_CATALOG_KINDS = {"spans": "span", "counters": "counter", "gauges": "gauge",
                  "histograms": "histogram", "events": "event",
                  "memory tags": "memory"}


def parse_metric_catalog(text: str) -> Dict[str, List[Tuple[str, int]]]:
    """docs/observability.md catalog -> {kind: [(name pattern, line)]}.

    The catalog is the region from '## Metric catalog' to the next
    '## ' heading, plus the memory 'Tag catalog:' table. Each kind is
    introduced by a '<Kind>...:' lead-in line followed by a markdown
    table whose name column is 'span', 'name' or 'tag'.
    """
    lines = text.splitlines()
    out: Dict[str, List[Tuple[str, int]]] = {k: [] for k in
                                             _CATALOG_KINDS.values()}
    kind = None
    in_catalog = False
    name_col = None
    for i, line in enumerate(lines, 1):
        low = line.strip().lower()
        if low.startswith("## "):
            in_catalog = low == "## metric catalog"
            kind = None
            continue
        for lead, k in _CATALOG_KINDS.items():
            if low.startswith(lead) and low.endswith(":"):
                kind = k if (in_catalog or k == "memory") else None
                name_col = None
                break
        if kind is None and low.startswith("tag catalog"):
            kind, name_col = "memory", None
        if kind is None or not line.strip().startswith("|"):
            continue
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if name_col is None:                      # header row
            headers = [c.lower() for c in cells]
            for cand in ("span", "name", "tag"):
                if cand in headers:
                    name_col = headers.index(cand)
                    break
            continue
        if set("".join(cells)) <= {"-", ":", " "}:   # separator row
            continue
        if name_col < len(cells):
            for name in _expand_cell_names(cells[name_col], kind):
                out[kind].append((name, i))
    return out


def collect_metric_sites(project: Project) \
        -> List[Tuple[str, str, str, int]]:
    """(kind, name pattern, path, line) for every literal registration."""
    sites: List[Tuple[str, str, str, int]] = []
    for sf in project.iter_files(LIB, "benchmarks"):
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            attr = node.func.attr
            if attr in _METRIC_METHODS:
                kind = _METRIC_METHODS[attr]
            elif attr in _MEMORY_METHODS:
                kind = "memory"
            else:
                continue
            if not node.args:
                continue
            pat = _literal_pattern(node.args[0])
            if pat is None:
                continue
            if kind == "memory" and "." not in pat:
                continue          # not a dotted ledger tag (e.g. pool.free)
            sites.append((kind, pat, sf.path, node.lineno))
    return sites


class MetricCatalogSync(Rule):
    id = "metric-catalog-sync"
    title = "observability names match the docs/observability.md catalog"
    rationale = (
        "dashboards and the BENCH regression gate key on metric names; "
        "an undocumented metric is unreviewable and a documented-but-"
        "unregistered one is a dead dashboard row. The catalog and the "
        "code must name exactly the same surface, both directions.")

    # emitted only inside repro.obs itself, where the generic plumbing
    # lives (obs.log events, reconciliation gauges) — still cataloged.
    def check(self, project: Project) -> Iterable[Finding]:
        doc_text = project.doc("docs/observability.md")
        sites = collect_metric_sites(project)
        if not doc_text:
            if sites:
                yield Finding(
                    rule=self.id, path="docs/observability.md", line=1,
                    message="metrics are registered in code but "
                            "docs/observability.md is missing")
            return
        catalog = parse_metric_catalog(doc_text)
        # code -> doc: no phantom metrics
        for kind, pat, path, line in sites:
            entries = catalog.get(kind, [])
            if not any(_compatible(pat, doc_pat) for doc_pat, _ in entries):
                yield Finding(
                    rule=self.id, path=path, line=line,
                    message=f"{kind} `{pat}` is not in the "
                            "docs/observability.md catalog — add a row "
                            "(phantom metric)")
        # doc -> code: no phantom catalog rows
        by_kind: Dict[str, Set[str]] = {}
        for kind, pat, _, _ in sites:
            by_kind.setdefault(kind, set()).add(pat)
        for kind, entries in catalog.items():
            for doc_pat, line in entries:
                if not any(_compatible(code_pat, doc_pat)
                           for code_pat in by_kind.get(kind, ())):
                    yield Finding(
                        rule=self.id, path="docs/observability.md",
                        line=line,
                        message=f"catalog {kind} `{doc_pat}` has no "
                                "registration site in src/repro or "
                                "benchmarks (phantom docs row)")


# --------------------------------------------------------------------- #
# wire-bytes-consistent
# --------------------------------------------------------------------- #
LEDGER = "src/repro/fleet/ledger.py"
# The struct constants that ARE the documented wire contract.
_CONTRACT_STRUCTS = {"_REC_HDR": "record header",
                     "_PROBE": "fp32 probe entry",
                     "_PROBE8": "int8 probe entry"}


def ledger_struct_sizes(project: Project) -> Dict[str, Tuple[int, int]]:
    """{const name: (calcsize, line)} for fleet/ledger.py Struct consts."""
    sf = project.get(LEDGER)
    out: Dict[str, Tuple[int, int]] = {}
    if sf is None or sf.tree is None:
        return out
    for node in sf.tree.body:
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            continue
        v = node.value
        if (isinstance(v, ast.Call) and isinstance(v.func, ast.Attribute)
                and v.func.attr == "Struct" and v.args
                and isinstance(v.args[0], ast.Constant)
                and isinstance(v.args[0].value, str)):
            try:
                size = struct.calcsize(v.args[0].value)
            except struct.error:
                continue
            out[node.targets[0].id] = (size, node.lineno)
    return out


def parse_wire_doc(text: str) -> Dict[str, Tuple[int, int]]:
    """docs/fleet.md 'Ledger record format' -> {fact: (bytes, line)}.

    Facts: header / fp32_probe / int8_probe, each read from BOTH the
    wire diagram ('N B header', 'N B per probe' inside the lane's code
    fence block) and the bytes-per-probe table ('| fp32 | **N B** ...
    `H + Nm` B |'); a disagreement between the two is reported as a
    0-size sentinel by the caller noticing the mismatch.
    """
    out: Dict[str, Tuple[int, int]] = {}
    lines = text.splitlines()
    in_section = False
    lane = None
    for i, line in enumerate(lines, 1):
        s = line.strip()
        if s.startswith("## "):
            in_section = s.lower().startswith("## ledger record format")
            continue
        if not in_section:
            continue
        if re.match(r"fp32\s*\(", s):
            lane = "fp32"
        elif re.match(r"int8\s*\(", s):
            lane = "int8"
        m = re.search(r"(\d+)\s*B header", s)
        if m:
            out.setdefault("header", (int(m.group(1)), i))
        m = re.search(r"(\d+)\s*B per probe", s)
        if m and lane:
            out.setdefault(f"{lane}_probe", (int(m.group(1)), i))
        m = re.match(r"\|\s*(fp32|int8)\s*\|\s*\*\*(\d+)\s*B\*\*.*?"
                     r"`(\d+)\s*\+\s*(\d+)m`", s)
        if m:
            out.setdefault(f"{m.group(1)}_table_probe",
                           (int(m.group(2)), i))
            out.setdefault(f"{m.group(1)}_table_header",
                           (int(m.group(3)), i))
            out.setdefault(f"{m.group(1)}_table_per_probe",
                           (int(m.group(4)), i))
    return out


class WireBytesConsistent(Rule):
    id = "wire-bytes-consistent"
    title = "ledger struct formats match the documented record sizes"
    rationale = (
        "12 B/probe fp32 and 9 B/probe int8 are the paper's headline "
        "wire numbers (docs/fleet.md record tables; tests assert the "
        "budgets) — the struct format strings in fleet/ledger.py must "
        "produce exactly those sizes.")

    def check(self, project: Project) -> Iterable[Finding]:
        structs = ledger_struct_sizes(project)
        if not structs and project.get(LEDGER) is None:
            return                      # no ledger in this tree
        doc = parse_wire_doc(project.doc("docs/fleet.md"))
        if not doc:
            yield Finding(
                rule=self.id, path="docs/fleet.md", line=1,
                message="ledger wire sizes are not documented (no "
                        "parseable 'Ledger record format' section)")
            return
        for const, what in _CONTRACT_STRUCTS.items():
            if const not in structs:
                yield Finding(
                    rule=self.id, path=LEDGER, line=1,
                    message=f"struct constant {const} ({what}) is gone — "
                            "the documented wire contract names it")
        checks = [("_REC_HDR", "header", "record header"),
                  ("_PROBE", "fp32_probe", "fp32 probe entry"),
                  ("_PROBE8", "int8_probe", "int8 probe entry"),
                  ("_REC_HDR", "fp32_table_header", "record header"),
                  ("_REC_HDR", "int8_table_header", "record header"),
                  ("_PROBE", "fp32_table_probe", "fp32 probe entry"),
                  ("_PROBE", "fp32_table_per_probe", "fp32 probe entry"),
                  ("_PROBE8", "int8_table_probe", "int8 probe entry"),
                  ("_PROBE8", "int8_table_per_probe", "int8 probe entry")]
        for const, fact, what in checks:
            if const not in structs or fact not in doc:
                if fact not in doc and const in structs:
                    yield Finding(
                        rule=self.id, path="docs/fleet.md", line=1,
                        message=f"documented size for {what} ({fact}) "
                                "not found in the record-format section")
                continue
            size, code_line = structs[const]
            doc_size, doc_line = doc[fact]
            if size != doc_size:
                yield Finding(
                    rule=self.id, path=LEDGER, line=code_line,
                    message=f"{const} ({what}) is {size} B but "
                            f"docs/fleet.md:{doc_line} documents "
                            f"{doc_size} B — wire format and doc drifted")
