"""reprolint's rule registry.

Adding a rule (docs/analysis.md, "Adding a rule"): subclass
``repro.analysis.core.Rule`` in one of the modules here (or a new one),
give it a kebab-case ``id``, a one-line ``title`` and a ``rationale``
naming the prose contract it enforces, implement ``check(project)``,
append an instance to ``ALL_RULES``, and commit a red + green fixture
under tests/analysis_fixtures/<rule-id>/.
"""
from __future__ import annotations

from typing import Dict, List

from ..core import BAD_SUPPRESSION, STALE_SUPPRESSION, Rule
from .determinism import (MonotonicClock, NoBuiltinHash,
                          NondeterministicIteration, NoInvariantAssert)
from .docs_sync import (DesignCiteResolves, MetricCatalogSync,
                        WireBytesConsistent)
from .kernels import KernelDispatchComplete

ALL_RULES: List[Rule] = [
    NoInvariantAssert(),
    NoBuiltinHash(),
    MonotonicClock(),
    KernelDispatchComplete(),
    DesignCiteResolves(),
    MetricCatalogSync(),
    NondeterministicIteration(),
    WireBytesConsistent(),
]


class _MetaRule(Rule):
    """Engine-emitted rules, registered so --list-rules shows them."""

    def __init__(self, id_: str, title: str, rationale: str):
        self.id, self.title, self.rationale = id_, title, rationale

    def check(self, project):
        return ()


META_RULES: List[Rule] = [
    _MetaRule(BAD_SUPPRESSION, "inline allows must carry a reason",
              "`# reprolint: allow(rule) -- <why>`: a suppression "
              "without its why is an unreviewable exemption."),
    _MetaRule(STALE_SUPPRESSION, "suppressions must still suppress",
              "an allow (inline or allowlist) matching no finding is "
              "debt: the violation was fixed, delete the exemption."),
]


def rules_by_id() -> Dict[str, Rule]:
    return {r.id: r for r in ALL_RULES}
