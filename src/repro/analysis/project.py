"""The whole-project model reprolint's rules check against.

A ``Project`` is the parsed view of one repository checkout: every
Python file under the scan roots (AST + raw text + inline
suppressions), plus the committed design/observability/fleet documents
the cross-artifact rules reconcile code against. Building it never
imports the code under analysis — everything is ``ast``/text, so the
linter runs in a bare interpreter with no jax installed (CI's
static-analysis job relies on this).

Rules receive the *whole* project, not one file at a time: that is what
lets kernel-dispatch-complete see ``kernels/*.py``, ``ref.py`` and
``ops.py`` together, and metric-catalog-sync reconcile call sites
against docs/observability.md in both directions.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence

# Directories scanned for Python sources, relative to the repo root.
SCAN_ROOTS = ("src/repro", "benchmarks", "tests", "examples")
# Never scanned: rule fixtures are *intentional* violations, results/
# is generated output.
EXCLUDED = ("tests/analysis_fixtures", "results", "__pycache__")

# Inline suppression grammar (docs/analysis.md, "Suppressions"):
#     # reprolint: allow(rule-id[, rule-id...]) -- <why>
# The reason after ``--`` is mandatory; an allow without one is itself
# a finding (bad-suppression).
_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*allow\(\s*([a-z0-9_,\s-]*?)\s*\)"
    r"(?:\s*--\s*(\S.*?))?\s*$")


@dataclass
class Suppression:
    """One inline ``# reprolint: allow(...)`` comment."""
    path: str
    line: int                 # line the comment sits on
    rules: List[str]
    reason: Optional[str]     # None => bad-suppression
    covers: int               # line whose findings it suppresses
    used: bool = False


@dataclass
class SourceFile:
    path: str                 # repo-root-relative, posix separators
    text: str
    lines: List[str] = field(default_factory=list)
    tree: Optional[ast.AST] = None
    parse_error: Optional[str] = None
    suppressions: List[Suppression] = field(default_factory=list)

    def line_at(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


def _parse_suppressions(path: str, text: str,
                        lines: Sequence[str]) -> List[Suppression]:
    """Real COMMENT tokens only — the same text inside a string literal
    (e.g. this linter's own sources) is not a suppression."""
    out = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return out                     # unparsable files surface elsewhere
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        i = tok.start[0]
        rules = [r.strip() for r in m.group(1).split(",") if r.strip()]
        reason = m.group(2)
        # a comment alone on its line covers the next line; a trailing
        # comment covers its own line
        alone = lines[i - 1].lstrip().startswith("#")
        out.append(Suppression(path=path, line=i, rules=rules,
                               reason=reason, covers=i + 1 if alone else i))
    return out


def _load_source(root: Path, rel: str) -> SourceFile:
    text = (root / rel).read_text(encoding="utf-8")
    lines = text.splitlines()
    sf = SourceFile(path=rel, text=text, lines=lines,
                    suppressions=_parse_suppressions(rel, text, lines))
    try:
        sf.tree = ast.parse(text, filename=rel)
    except SyntaxError as e:      # surfaced as a finding by the engine
        sf.parse_error = f"{e.msg} (line {e.lineno})"
    return sf


class Project:
    """Parsed repository: Python sources + the contract documents."""

    def __init__(self, root: Path, files: Dict[str, SourceFile],
                 docs: Dict[str, str]):
        self.root = Path(root)
        self.files = files            # rel path -> SourceFile
        self.docs = docs              # rel path -> raw markdown ('' if absent)

    # ---- source access ------------------------------------------------ #
    def iter_files(self, *prefixes: str) -> Iterator[SourceFile]:
        """Parsed sources under the given path prefixes (all if none)."""
        for rel in sorted(self.files):
            if not prefixes or any(rel == p or rel.startswith(p.rstrip("/") + "/")
                                   for p in prefixes):
                yield self.files[rel]

    def get(self, rel: str) -> Optional[SourceFile]:
        return self.files.get(rel)

    def doc(self, rel: str) -> str:
        """Raw text of a committed markdown doc ('' when missing)."""
        return self.docs.get(rel, "")

    # ---- doc views used by the cross-artifact rules -------------------- #
    def design_sections(self) -> Dict[int, int]:
        """{section number: heading line} parsed from docs/design.md."""
        out: Dict[int, int] = {}
        for i, line in enumerate(self.doc("docs/design.md").splitlines(), 1):
            m = re.match(r"##\s+§(\d+)\b", line)
            if m:
                out[int(m.group(1))] = i
        return out


def find_repo_root(start: Optional[Path] = None) -> Path:
    """Walk up from ``start`` (default cwd) to the pyproject.toml root."""
    cur = Path(start or Path.cwd()).resolve()
    for cand in (cur, *cur.parents):
        if (cand / "pyproject.toml").is_file():
            return cand
    raise FileNotFoundError(
        f"no pyproject.toml above {cur}; pass --root explicitly")


def build_project(root: Path,
                  scan_roots: Sequence[str] = SCAN_ROOTS) -> Project:
    root = Path(root)
    files: Dict[str, SourceFile] = {}
    for scan in scan_roots:
        base = root / scan
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            rel = p.relative_to(root).as_posix()
            if any(part in ("__pycache__",) for part in p.parts):
                continue
            if any(rel == ex or rel.startswith(ex + "/") for ex in EXCLUDED):
                continue
            files[rel] = _load_source(root, rel)
    docs: Dict[str, str] = {}
    docs_dir = root / "docs"
    if docs_dir.is_dir():
        for p in sorted(docs_dir.glob("*.md")):
            rel = p.relative_to(root).as_posix()
            docs[rel] = p.read_text(encoding="utf-8")
    return Project(root, files, docs)
