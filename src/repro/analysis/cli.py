"""``python -m repro.analysis`` — run reprolint over the repository.

Pure stdlib on purpose: the static-analysis CI job runs this in a bare
interpreter, before (and independent of) the jax test environment.

Exit codes: 0 clean, 1 findings (including stale/bad suppressions),
2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from .core import Report, run_analysis
from .project import find_repo_root
from .rules import ALL_RULES, META_RULES, rules_by_id


def _human_report(report: Report, verbose: bool) -> str:
    out: List[str] = []
    for f in report.findings:
        out.append(f"{f.location()}: [{f.rule}] {f.message}")
    if verbose and report.suppressed:
        out.append("")
        for f in report.suppressed:
            out.append(f"{f.location()}: [{f.rule}] suppressed")
    n, s = len(report.findings), len(report.suppressed)
    out.append(f"reprolint: {n} finding{'s' * (n != 1)}, "
               f"{s} suppressed, {len(report.rules)} rules")
    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: machine-check the repo's determinism, "
                    "kernel-contract and observability invariants "
                    "(docs/analysis.md)")
    parser.add_argument("--root", type=Path, default=None,
                        help="repo root (default: nearest pyproject.toml)")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule ids (default: all)")
    parser.add_argument("--report", type=Path, default=None, metavar="JSON",
                        help="write the machine-readable report here")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    parser.add_argument("-v", "--verbose", action="store_true",
                        help="also print suppressed findings")
    args = parser.parse_args(argv)

    if args.list_rules:
        for r in (*ALL_RULES, *META_RULES):
            print(f"{r.id:26s} {r.title}")
        return 0

    try:
        root = args.root or find_repo_root()
    except FileNotFoundError as e:
        print(f"reprolint: {e}", file=sys.stderr)
        return 2
    rules = ALL_RULES
    if args.rules:
        by_id = rules_by_id()
        unknown = [r for r in args.rules.split(",") if r not in by_id]
        if unknown:
            print(f"reprolint: unknown rule ids {unknown} "
                  "(try --list-rules)", file=sys.stderr)
            return 2
        rules = [by_id[r] for r in args.rules.split(",")]

    try:
        report = run_analysis(root, rules)
    except ValueError as e:            # malformed allowlist
        print(f"reprolint: {e}", file=sys.stderr)
        return 2

    if args.report:
        args.report.write_text(json.dumps(report.to_dict(), indent=2) + "\n",
                               encoding="utf-8")
    print(_human_report(report, args.verbose))
    return 0 if report.clean else 1
