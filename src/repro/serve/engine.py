"""Serving engine: prefill -> paged continuous-batching decode -> streams.

One ``Engine.step()`` is one scheduler iteration:

  1. admit waiting requests as a wave: ONE batched prefill + ONE pool
     scatter per distinct (bucketed) prompt length, then one batched call
     sampling every admission's first token (recurrent state goes into
     the batch slots);
  2. assemble the step (page table + seq lens + per-row sampling knobs),
     preempting newest-first if the pool can't grow someone's cache;
  3. ask the scheduler how many ticks the plan is provably stable for
     (``Scheduler.steady_horizon``) and run that many fused decode+sample
     ticks in ONE device call (``_megastep`` — a ``lax.scan`` whose carry
     feeds each tick's sampled tokens into the next decode on device);
  4. commit the megastep's tokens tick by tick, emitting stream events
     and evicting finished sequences (their pages return to the pool
     immediately).

Prefill compiles per distinct prompt length; ``ServeConfig.bucket_prompts``
buckets lengths to powers of two for attention-only archs (right-padding
is invisible to causal attention, and logits are gathered at the true last
position — SSM/RWKV state would absorb the pad tokens, so those archs
always prefill at exact length).

``dense_generate`` is the static-batch greedy baseline (the old
launch/serve.py loop with the cache-growth heuristic replaced by the
path-aware ``grow_dense_caches``) — the parity tests and
benchmarks/bench_serve.py compare against it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence as Seq

import numpy as np

import jax
import jax.numpy as jnp

from .. import obs
from ..configs.base import ATTN, LaneConfig, ModelConfig, ShapeConfig
from ..configs.serve import ServeConfig
from ..core import api
from ..models.transformer import make_paged_caches
from ..sharding.rules import ShardingRules
from . import kv_pages, sampler
from .sampler import SamplingParams
from .scheduler import Scheduler

__all__ = ["Engine", "StreamEvent", "ServeConfig", "SamplingParams",
           "dense_generate"]


@dataclass
class StreamEvent:
    rid: int
    token: int
    text: str
    finished: bool = False


def _default_detok(token: int) -> str:
    return f"{token} "


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class Engine:
    def __init__(self, cfg: ModelConfig, serve: Optional[ServeConfig] = None,
                 lane: Optional[LaneConfig] = None, params=None,
                 init_seed: int = 0,
                 detok: Optional[Callable[[int], str]] = None):
        self.cfg = cfg
        self.serve = serve or ServeConfig()
        self.lane = lane or LaneConfig()
        self.detok = detok or _default_detok
        s = self.serve
        worst = s.max_pages_per_seq
        if cfg.sliding_window:
            # SWA reclamation bounds a sequence's footprint by its window
            # (scheduler._worst_case_pages), not by max_seq_len
            worst = min(worst, s.pages_for(cfg.sliding_window) + 1)
        if worst > s.num_pages - 1:
            raise ValueError(
                f"pool of {s.num_pages - 1} usable pages cannot hold one "
                f"max-length sequence ({worst} pages); raise "
                "num_pages or lower max_seq_len")
        self._attn_only = all(k == ATTN for k in cfg.pattern)

        dshape = ShapeConfig("serve_decode", seq_len=s.max_seq_len,
                             global_batch=s.max_batch_slots, kind="decode")
        self._drules = ShardingRules(None, cfg, dshape)
        self._md = api.build(cfg, dshape, self.lane, self._drules)
        self._decode = jax.jit(self._md.decode_step_paged,
                               donate_argnums=(2,))
        # multi-tick megastep: `horizon` decode+sample ticks fused into one
        # device call (lax.scan), legal whenever the scheduler proves the
        # plan epoch-stable that long (Scheduler.steady_horizon). Amortizes
        # per-call dispatch exactly like the dense baseline's tight loop —
        # but over fewer, bigger calls. Compiles per (horizon, greedy).
        self._fused = jax.jit(self._megastep,
                              static_argnames=("horizon", "greedy"),
                              donate_argnums=(1,))
        self.params = params if params is not None \
            else self._init_params(init_seed)
        raw = make_paged_caches(cfg, s.max_batch_slots, s.num_pages,
                                s.page_size, self._drules)
        self.caches = api.split_caches(raw, cfg, self.lane)
        self.sched = Scheduler(s, window=cfg.sliding_window or 0)
        self._prefill_cache: Dict[int, tuple] = {}
        # persistent device-side step plan, keyed on the scheduler's
        # plan_epoch: in steady state (no admissions/evictions/page moves)
        # the next tick's plan is this tick's advanced on device — tokens
        # are the sampler output, pos/sample-index bump by the active
        # mask — so the host uploads nothing and the only device<->host
        # traffic per token is the single sampled-token download
        self._dev_plan: Optional[Dict[str, jax.Array]] = None
        self._host_plan: Dict[str, np.ndarray] = {}   # last-uploaded bytes
        self.steps_run = 0
        # memory ledger: the page pool is allocated up front and lives as
        # long as the engine — register the whole block plus the params
        # (docs/observability.md tag catalog); per-page granularity feeds
        # the serve.kv_pages_used_bytes gauge each tick
        rec = obs.get()
        self._pool_nbytes = 0
        if rec.enabled:
            self._pool_nbytes = obs.memory.tree_nbytes(self.caches)
            rec.memory.rebind("serve.kv_pages", self._pool_nbytes,
                              key=("engine", id(self)))
            rec.memory.rebind("serve.params",
                              obs.memory.tree_nbytes(self.params),
                              key=("engine", id(self)))

    # ------------------------------------------------------------- #
    def _init_params(self, seed: int):
        pshape = ShapeConfig("serve_init", seq_len=self.serve.max_seq_len,
                             global_batch=1, kind="prefill")
        m = api.build(self.cfg, pshape, self.lane,
                      ShardingRules(None, self.cfg, pshape))
        return m.init(jax.random.key(seed))

    def _get_prefill(self, s_tok: int, nb: int = 1):
        """(BuiltModel, jitted prefill_logits) for `nb` prompts of s_tok
        text tokens each (caches compile per distinct (length, wave size);
        bucketing bounds the number of distinct lengths, max_batch_slots
        bounds the wave sizes)."""
        if (s_tok, nb) not in self._prefill_cache:
            seq_len = s_tok + self.cfg.num_image_tokens
            shape = ShapeConfig(f"serve_p{s_tok}x{nb}", seq_len=seq_len,
                                global_batch=nb, kind="prefill")
            m = api.build(self.cfg, shape, self.lane,
                          ShardingRules(None, self.cfg, shape))
            self._prefill_cache[(s_tok, nb)] = (m, jax.jit(m.prefill_logits))
        return self._prefill_cache[(s_tok, nb)]

    # ------------------------------------------------------------- #
    def submit(self, prompt: Seq[int],
               sampling: Optional[SamplingParams] = None,
               max_new_tokens: Optional[int] = None) -> int:
        return self.sched.submit(prompt, sampling or SamplingParams(),
                                 max_new_tokens,
                                 prefix_extra=self.cfg.num_image_tokens)

    def _sample_admitted(self, seqs, logits_parts,
                         events: List[StreamEvent]) -> None:
        """Sample the first token of every admission in ONE batched call —
        one device->host transfer for the whole admission wave instead of
        one per prompt (the per-row streams are row-independent, so the
        tokens are bitwise the old one-call-per-row path)."""
        if not seqs:
            return
        logits = logits_parts[0] if len(logits_parts) == 1 \
            else jnp.concatenate(logits_parts, axis=0)
        sps = [s.req.sampling for s in seqs]
        if all(sp.temperature <= 0 for sp in sps):
            # all-greedy wave: sample_tokens returns greedy_tokens(logits)
            # verbatim for temp <= 0 rows — skip the filter/PRNG work and
            # the five knob-array uploads
            toks = np.asarray(sampler.greedy_tokens(logits))
        else:
            toks = np.asarray(sampler.sample_tokens(
                logits,
                jnp.asarray([sp.temperature for sp in sps], jnp.float32),
                jnp.asarray([sp.top_k for sp in sps], jnp.int32),
                jnp.asarray([sp.top_p for sp in sps], jnp.float32),
                jnp.asarray([np.uint32(sp.seed) for sp in sps], jnp.uint32),
                jnp.asarray([len(s.generated) for s in seqs], jnp.int32),
                vocab_size=self.cfg.vocab_size))
        for seq, tok in zip(seqs, toks):
            tok = int(tok)
            finished = self.sched.record_first_token(seq, tok)
            events.append(StreamEvent(seq.req.rid, tok, self.detok(tok),
                                      finished))

    def _prefill_len(self, seq) -> int:
        s_tok = len(seq.cached_prompt)
        if self.serve.bucket_prompts and self._attn_only:
            s_tok = min(_next_pow2(s_tok),
                        self.serve.max_seq_len - self.cfg.num_image_tokens)
        return s_tok

    def _admit_wave(self, seqs):
        """Prefill + page-scatter a whole admission wave: one prefill call
        and one jitted pool scatter per distinct (bucketed) prompt length
        instead of one of each per sequence. Returns (seqs in processing
        order, their prefill-logit blocks) for batched first-token
        sampling."""
        cfg, s = self.cfg, self.serve
        groups: Dict[int, list] = {}
        for seq in seqs:                       # group, keep arrival order
            groups.setdefault(self._prefill_len(seq), []).append(seq)
        ordered, logits_parts = [], []
        for s_tok, group in groups.items():
            nb = len(group)
            m, fn = self._get_prefill(s_tok, nb)
            toks = np.zeros((nb, s_tok), np.int32)
            last = np.empty(nb, np.int32)
            for i, seq in enumerate(group):
                prompt = seq.cached_prompt
                toks[i, :len(prompt)] = prompt
                last[i] = seq.pos - 1          # absolute, incl. image tokens
            batch = {"tokens": jnp.asarray(toks)}
            dt = jnp.dtype(cfg.dtype)
            if cfg.encoder_layers:
                batch["frames"] = jnp.zeros(
                    (nb, cfg.encoder_seq, cfg.d_model), dt)
            if cfg.num_image_tokens:
                batch["img"] = jnp.zeros(
                    (nb, cfg.num_image_tokens, cfg.d_model), dt)
            logits, dense = fn(self.params, batch, jnp.asarray(last))
            self.caches = kv_pages.admit_prefill(
                self.caches, dense, cfg, [q.slot for q in group],
                [q.pages for q in group], s.page_size,
                table_width=s.max_pages_per_seq)
            ordered.extend(group)
            logits_parts.append(logits)
        return ordered, logits_parts

    def _megastep(self, params, caches, tokens, page_table, seq_lens, mask,
                  temperature, top_k, top_p, seed, step, *, horizon, greedy):
        """`horizon` fused decode+sample ticks: each tick decodes one token
        per row, samples the next, and advances positions/sample indices by
        the active mask — all on device, tokens never round-tripping to the
        host. Returns ([horizon, slots] sampled tokens, last tokens, caches,
        advanced seq_lens, advanced step) — bitwise the sequence of
        single-tick calls it replaces (same per-tick math, page table and
        knobs constant across the horizon by construction)."""
        def tick(carry, _):
            tok, caches, sl, st = carry
            logits, caches = self._md.decode_step_paged(
                params, tok[:, None], caches, page_table, sl)
            if greedy:
                nxt = sampler.greedy_tokens(logits)
            else:
                nxt = sampler.sample_tokens(
                    logits, temperature, top_k, top_p, seed, st,
                    vocab_size=self.cfg.vocab_size)
            return (nxt, caches, sl + mask, st + mask), nxt
        (tok, caches, sl, st), toks = jax.lax.scan(
            tick, (tokens, caches, seq_lens, step), None, length=horizon)
        return toks, tok, caches, sl, st

    def _upload_plan(self, plan) -> Dict[str, jax.Array]:
        """Host->device upload of a step plan (epoch-change path).

        tokens/seq_lens/step advance every tick, so they always re-upload;
        the slow-moving fields (page table, active mask, sampling knobs)
        usually survive an epoch bump unchanged — those reuse the previous
        device buffer when their host bytes are identical, so a typical
        epoch change (one page grown, one request finished) uploads two or
        three small arrays, not ten."""
        prev_host, prev_dev = self._host_plan, self._dev_plan
        dev = {
            "epoch": self.sched.plan_epoch,
            "tokens": jnp.asarray(plan.tokens),
            "seq_lens": jnp.asarray(plan.seq_lens),
            "step": jnp.asarray(plan.step),
        }
        host: Dict[str, np.ndarray] = {}
        slow = {"page_table": plan.page_table,
                "mask": plan.active.astype(np.int32),
                "temperature": plan.temperature,
                "top_k": plan.top_k,
                "top_p": plan.top_p,
                "seed": plan.seed}
        for name, arr in slow.items():
            if prev_dev is not None and \
                    np.array_equal(prev_host[name], arr):
                dev[name] = prev_dev[name]
                host[name] = prev_host[name]
            else:
                dev[name] = jnp.asarray(arr)
                host[name] = arr.copy()
        self._host_plan = host
        return dev

    # ------------------------------------------------------------- #
    def step(self) -> List[StreamEvent]:
        """One engine iteration; returns the stream events it produced."""
        rec = obs.get()
        with rec.span("serve/tick", track="serve"):
            events: List[StreamEvent] = []
            with rec.span("serve/prefill", track="serve"):
                waiting = self.sched.poll_admissions()
                if waiting:
                    seqs, logits_parts = self._admit_wave(waiting)
                    self._sample_admitted(seqs, logits_parts, events)
            plan = self.sched.prepare_step()
            if plan is None:
                return events
            dev = self._dev_plan
            if dev is None or dev["epoch"] != self.sched.plan_epoch:
                dev = self._dev_plan = self._upload_plan(plan)
            H = self.sched.steady_horizon()
            # all-greedy megasteps skip the sampler's filters/PRNG
            # entirely (bitwise the sampler's greedy branch — one shared
            # definition, sampler.greedy_tokens)
            greedy = not bool(plan.temperature.any())
            with rec.span("serve/decode", track="serve",
                          rows=plan.num_active, ticks=H) as dsp:
                toks_dev, last_dev, self.caches, sl_dev, st_dev = \
                    self._fused(
                        self.params, self.caches, dev["tokens"],
                        dev["page_table"], dev["seq_lens"], dev["mask"],
                        dev["temperature"], dev["top_k"], dev["top_p"],
                        dev["seed"], dev["step"], horizon=H, greedy=greedy)
                toks_dev.block_until_ready()
            with rec.span("serve/sample", track="serve",
                          rows=plan.num_active):
                toks = np.asarray(toks_dev)   # [H, slots]: the megastep's
                #                               ONE device->host transfer
            # advance the device plan to the next tick's steady state:
            # the megastep already returned it — last sampled tokens feed
            # the next decode without a round-trip; commit below may bump
            # the epoch, forcing a re-upload anyway
            dev["tokens"] = last_dev
            dev["seq_lens"] = sl_dev
            dev["step"] = st_dev
            if rec.enabled and plan.num_active:
                # decode span blocked on the tokens, so dur_ns is true
                # device time for the whole megastep; the per-row-per-tick
                # quotient is the per-token latency
                rec.histogram("serve.decode_token_ms").observe(
                    dsp.dur_ns / 1e6 / (plan.num_active * H))
                rec.counter("serve.decode_tokens").inc(plan.num_active * H)
                # occupied slice of the (up-front) pool allocation
                rec.gauge("serve.kv_pages_used_bytes").set(
                    self._pool_nbytes * self.sched.pool.used_pages
                    // max(self.serve.num_pages - 1, 1))
            for t in range(H):
                active = list(self.sched.running)
                done = {s.req.rid for s in self.sched.commit_step(toks[t])}
                for seq in active:
                    tok = seq.generated[-1]
                    events.append(StreamEvent(seq.req.rid, tok,
                                              self.detok(tok),
                                              seq.req.rid in done))
            self.steps_run += 1
            return events

    def run(self, callback: Optional[Callable[[StreamEvent], None]] = None,
            max_steps: int = 100_000) -> Dict[int, List[int]]:
        """Drive until every submitted request finishes. Returns
        rid -> generated tokens for requests that finished during THIS
        call; `callback` sees every stream event. A long-lived server
        should periodically `sched.clear_finished()` to bound memory."""
        start = len(self.sched.finished)
        with obs.get().span("serve/run", track="serve"):
            for _ in range(max_steps):
                if not self.sched.has_work():
                    break
                for ev in self.step():
                    if callback is not None:
                        callback(ev)
            else:
                raise RuntimeError("engine did not drain within max_steps")
        self.sched.check_invariants()
        return {s.req.rid: list(s.generated)
                for s in self.sched.finished[start:]}

    def generate(self, prompts: Seq[Seq[int]],
                 sampling: Optional[SamplingParams] = None,
                 max_new_tokens: Optional[int] = None) -> List[List[int]]:
        rids = [self.submit(p, sampling, max_new_tokens) for p in prompts]
        out = self.run()
        return [out[r] for r in rids]

    def release_memory_tags(self):
        """Rebind this engine's ledger registrations to zero. Call when
        retiring an engine whose process keeps running (benchmarks build
        several engines sequentially); live bytes otherwise keep
        counting the dead pool."""
        rec = obs.get()
        if rec.enabled and self._pool_nbytes:
            rec.memory.rebind("serve.kv_pages", 0, key=("engine", id(self)))
            rec.memory.rebind("serve.params", 0, key=("engine", id(self)))
            self._pool_nbytes = 0

    def page_utilization(self) -> Dict[str, float]:
        total = self.serve.num_pages - 1
        s = self.sched
        mean = s.util_sum / s.util_steps if s.util_steps else 0.0
        return {"total_pages": total,
                "peak_pages": int(s.util_peak),
                "mean_pages": mean,
                "peak_util": s.util_peak / total,
                "mean_util": mean / total,
                "reclaimed_pages": int(s.reclaimed_pages)}


# ----------------------------------------------------------------- #
# dense static-batch baseline
# ----------------------------------------------------------------- #
class DenseServer:
    """Greedy static-batch decode with a dense grown KV cache — the legacy
    serve path, kept as the benchmark/parity baseline. Reusable so repeat
    ``generate`` calls hit the compile cache (bench_serve warms it, then
    times the best of several calls)."""

    def __init__(self, cfg: ModelConfig, params, batch: int,
                 prompt_len: int, max_new_tokens: int,
                 lane: Optional[LaneConfig] = None):
        self.cfg, self.params = cfg, params
        self.lane = lane or LaneConfig()
        self.B, self.Lp = batch, prompt_len
        self.max_new = max_new_tokens
        n_img = cfg.num_image_tokens
        self.total = prompt_len + n_img + max_new_tokens
        pshape = ShapeConfig("dense_p", seq_len=prompt_len + n_img,
                             global_batch=batch, kind="prefill")
        dshape = ShapeConfig("dense_d", seq_len=self.total,
                             global_batch=batch, kind="decode")
        mp = api.build(cfg, pshape, self.lane,
                       ShardingRules(None, cfg, pshape))
        md = api.build(cfg, dshape, self.lane,
                       ShardingRules(None, cfg, dshape))
        self._prefill = jax.jit(mp.prefill_step)
        self._decode = jax.jit(md.decode_step, donate_argnums=(2,))

    def generate(self, prompts: np.ndarray) -> np.ndarray:
        """prompts [B, Lp] int -> [B, max_new_tokens] int32."""
        cfg, B = self.cfg, self.B
        if prompts.shape != (B, self.Lp):
            raise ValueError(f"prompts shape {prompts.shape} != "
                             f"{(B, self.Lp)}")
        n_img = cfg.num_image_tokens
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        dt = jnp.dtype(cfg.dtype)
        if cfg.encoder_layers:
            batch["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                        dt)
        if n_img:
            batch["img"] = jnp.zeros((B, n_img, cfg.d_model), dt)
        nxt, caches = self._prefill(self.params, batch)
        caches = kv_pages.grow_dense_caches(caches, cfg, self.total)
        out = [nxt]
        cur = self.Lp + n_img
        for _ in range(self.max_new - 1):
            nxt, caches = self._decode(self.params, nxt, caches,
                                       jnp.int32(cur))
            out.append(nxt)
            cur += 1
        return np.asarray(jnp.concatenate(out, axis=1))


def dense_generate(cfg: ModelConfig, params, prompts: np.ndarray,
                   max_new_tokens: int,
                   lane: Optional[LaneConfig] = None) -> np.ndarray:
    """One-shot convenience wrapper around DenseServer."""
    B, Lp = prompts.shape
    return DenseServer(cfg, params, B, Lp, max_new_tokens,
                       lane).generate(prompts)
