"""Serving engine: prefill -> paged continuous-batching decode -> streams.

One ``Engine.step()`` is one scheduler iteration:

  1. admit waiting requests (prefill each at its prompt length, sample the
     first token from the prefill logits, scatter the dense prompt KV into
     freshly allocated pages, write recurrent state into the batch slot);
  2. assemble the step (page table + seq lens + per-row sampling knobs),
     preempting newest-first if the pool can't grow someone's cache;
  3. run one fused paged decode step over all slots and sample;
  4. commit tokens, emitting stream events and evicting finished
     sequences (their pages return to the pool immediately).

Prefill compiles per distinct prompt length; ``ServeConfig.bucket_prompts``
buckets lengths to powers of two for attention-only archs (right-padding
is invisible to causal attention, and logits are gathered at the true last
position — SSM/RWKV state would absorb the pad tokens, so those archs
always prefill at exact length).

``dense_generate`` is the static-batch greedy baseline (the old
launch/serve.py loop with the cache-growth heuristic replaced by the
path-aware ``grow_dense_caches``) — the parity tests and
benchmarks/bench_serve.py compare against it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence as Seq

import numpy as np

import jax
import jax.numpy as jnp

from .. import obs
from ..configs.base import ATTN, LaneConfig, ModelConfig, ShapeConfig
from ..configs.serve import ServeConfig
from ..core import api
from ..models.transformer import make_paged_caches
from ..sharding.rules import ShardingRules
from . import kv_pages, sampler
from .sampler import SamplingParams
from .scheduler import Scheduler

__all__ = ["Engine", "StreamEvent", "ServeConfig", "SamplingParams",
           "dense_generate"]


@dataclass
class StreamEvent:
    rid: int
    token: int
    text: str
    finished: bool = False


def _default_detok(token: int) -> str:
    return f"{token} "


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class Engine:
    def __init__(self, cfg: ModelConfig, serve: Optional[ServeConfig] = None,
                 lane: Optional[LaneConfig] = None, params=None,
                 init_seed: int = 0,
                 detok: Optional[Callable[[int], str]] = None):
        self.cfg = cfg
        self.serve = serve or ServeConfig()
        self.lane = lane or LaneConfig()
        self.detok = detok or _default_detok
        s = self.serve
        if s.max_pages_per_seq > s.num_pages - 1:
            raise ValueError(
                f"pool of {s.num_pages - 1} usable pages cannot hold one "
                f"max-length sequence ({s.max_pages_per_seq} pages); raise "
                f"num_pages or lower max_seq_len")
        self._attn_only = all(k == ATTN for k in cfg.pattern)

        dshape = ShapeConfig("serve_decode", seq_len=s.max_seq_len,
                             global_batch=s.max_batch_slots, kind="decode")
        self._drules = ShardingRules(None, cfg, dshape)
        self._md = api.build(cfg, dshape, self.lane, self._drules)
        self._decode = jax.jit(self._md.decode_step_paged,
                               donate_argnums=(2,))
        self.params = params if params is not None \
            else self._init_params(init_seed)
        raw = make_paged_caches(cfg, s.max_batch_slots, s.num_pages,
                                s.page_size, self._drules)
        self.caches = api.split_caches(raw, cfg, self.lane)
        self.sched = Scheduler(s)
        self._prefill_cache: Dict[int, tuple] = {}
        self.steps_run = 0
        # memory ledger: the page pool is allocated up front and lives as
        # long as the engine — register the whole block plus the params
        # (docs/observability.md tag catalog); per-page granularity feeds
        # the serve.kv_pages_used_bytes gauge each tick
        rec = obs.get()
        self._pool_nbytes = 0
        if rec.enabled:
            self._pool_nbytes = obs.memory.tree_nbytes(self.caches)
            rec.memory.rebind("serve.kv_pages", self._pool_nbytes,
                              key=("engine", id(self)))
            rec.memory.rebind("serve.params",
                              obs.memory.tree_nbytes(self.params),
                              key=("engine", id(self)))

    # ------------------------------------------------------------- #
    def _init_params(self, seed: int):
        pshape = ShapeConfig("serve_init", seq_len=self.serve.max_seq_len,
                             global_batch=1, kind="prefill")
        m = api.build(self.cfg, pshape, self.lane,
                      ShardingRules(None, self.cfg, pshape))
        return m.init(jax.random.key(seed))

    def _get_prefill(self, s_tok: int):
        """(BuiltModel, jitted prefill_logits) for a prompt of s_tok text
        tokens (caches compile per distinct length; bucketing bounds the
        number of distinct lengths)."""
        if s_tok not in self._prefill_cache:
            seq_len = s_tok + self.cfg.num_image_tokens
            shape = ShapeConfig(f"serve_p{s_tok}", seq_len=seq_len,
                                global_batch=1, kind="prefill")
            m = api.build(self.cfg, shape, self.lane,
                          ShardingRules(None, self.cfg, shape))
            self._prefill_cache[s_tok] = (m, jax.jit(m.prefill_logits))
        return self._prefill_cache[s_tok]

    # ------------------------------------------------------------- #
    def submit(self, prompt: Seq[int],
               sampling: Optional[SamplingParams] = None,
               max_new_tokens: Optional[int] = None) -> int:
        return self.sched.submit(prompt, sampling or SamplingParams(),
                                 max_new_tokens,
                                 prefix_extra=self.cfg.num_image_tokens)

    def _sample_row(self, logits, seq):
        sp = seq.req.sampling
        return int(np.asarray(sampler.sample_tokens(
            logits,
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32),
            jnp.asarray([np.uint32(sp.seed)], jnp.uint32),
            jnp.asarray([len(seq.generated)], jnp.int32),
            vocab_size=self.cfg.vocab_size))[0])

    def _admit(self, seq, events: List[StreamEvent]) -> None:
        cfg, s = self.cfg, self.serve
        tokens = seq.cached_prompt
        s_tok = len(tokens)
        if s.bucket_prompts and self._attn_only:
            s_tok = min(_next_pow2(s_tok),
                        s.max_seq_len - cfg.num_image_tokens)
        m, fn = self._get_prefill(s_tok)
        toks = np.zeros((1, s_tok), np.int32)
        toks[0, :len(tokens)] = tokens
        batch = {"tokens": jnp.asarray(toks)}
        dt = jnp.dtype(cfg.dtype)
        if cfg.encoder_layers:
            batch["frames"] = jnp.zeros(
                (1, cfg.encoder_seq, cfg.d_model), dt)
        if cfg.num_image_tokens:
            batch["img"] = jnp.zeros(
                (1, cfg.num_image_tokens, cfg.d_model), dt)
        last = seq.pos - 1                     # absolute, incl. image tokens
        logits, dense = fn(self.params, batch,
                           jnp.asarray([last], jnp.int32))
        self.caches = kv_pages.admit_prefill(
            self.caches, dense, cfg, seq.slot, seq.pages, s.page_size,
            table_width=s.max_pages_per_seq)
        tok = self._sample_row(logits, seq)
        finished = self.sched.record_first_token(seq, tok)
        events.append(StreamEvent(seq.req.rid, tok, self.detok(tok),
                                  finished))

    # ------------------------------------------------------------- #
    def step(self) -> List[StreamEvent]:
        """One engine iteration; returns the stream events it produced."""
        rec = obs.get()
        with rec.span("serve/tick", track="serve"):
            events: List[StreamEvent] = []
            with rec.span("serve/prefill", track="serve"):
                for seq in self.sched.poll_admissions():
                    self._admit(seq, events)
            plan = self.sched.prepare_step()
            if plan is None:
                return events
            with rec.span("serve/decode", track="serve",
                          rows=plan.num_active) as dsp:
                logits, self.caches = self._decode(
                    self.params, jnp.asarray(plan.tokens)[:, None],
                    self.caches, jnp.asarray(plan.page_table),
                    jnp.asarray(plan.seq_lens))
                if not plan.temperature.any():
                    # all-greedy step: skip the sampler's full-vocab
                    # sorts/PRNG (bitwise the sampler's greedy branch)
                    toks = np.asarray(
                        jnp.argmax(logits, axis=-1).astype(jnp.int32))
                else:
                    toks = np.asarray(sampler.sample_tokens(
                        logits, jnp.asarray(plan.temperature),
                        jnp.asarray(plan.top_k), jnp.asarray(plan.top_p),
                        jnp.asarray(plan.seed), jnp.asarray(plan.step),
                        vocab_size=self.cfg.vocab_size))
            if rec.enabled and plan.num_active:
                # np.asarray already synced the device work; the per-row
                # quotient is the per-token decode latency
                rec.histogram("serve.decode_token_ms").observe(
                    dsp.dur_ns / 1e6 / plan.num_active)
                rec.counter("serve.decode_tokens").inc(plan.num_active)
                # occupied slice of the (up-front) pool allocation
                rec.gauge("serve.kv_pages_used_bytes").set(
                    self._pool_nbytes * self.sched.pool.used_pages
                    // max(self.serve.num_pages - 1, 1))
            active = list(self.sched.running)
            done = {s.req.rid for s in self.sched.commit_step(toks)}
            for seq in active:
                tok = seq.generated[-1]
                events.append(StreamEvent(seq.req.rid, tok,
                                          self.detok(tok),
                                          seq.req.rid in done))
            self.steps_run += 1
            return events

    def run(self, callback: Optional[Callable[[StreamEvent], None]] = None,
            max_steps: int = 100_000) -> Dict[int, List[int]]:
        """Drive until every submitted request finishes. Returns
        rid -> generated tokens for requests that finished during THIS
        call; `callback` sees every stream event. A long-lived server
        should periodically `sched.clear_finished()` to bound memory."""
        start = len(self.sched.finished)
        with obs.get().span("serve/run", track="serve"):
            for _ in range(max_steps):
                if not self.sched.has_work():
                    break
                for ev in self.step():
                    if callback is not None:
                        callback(ev)
            else:
                raise RuntimeError("engine did not drain within max_steps")
        self.sched.check_invariants()
        return {s.req.rid: list(s.generated)
                for s in self.sched.finished[start:]}

    def generate(self, prompts: Seq[Seq[int]],
                 sampling: Optional[SamplingParams] = None,
                 max_new_tokens: Optional[int] = None) -> List[List[int]]:
        rids = [self.submit(p, sampling, max_new_tokens) for p in prompts]
        out = self.run()
        return [out[r] for r in rids]

    def release_memory_tags(self):
        """Rebind this engine's ledger registrations to zero. Call when
        retiring an engine whose process keeps running (benchmarks build
        several engines sequentially); live bytes otherwise keep
        counting the dead pool."""
        rec = obs.get()
        if rec.enabled and self._pool_nbytes:
            rec.memory.rebind("serve.kv_pages", 0, key=("engine", id(self)))
            rec.memory.rebind("serve.params", 0, key=("engine", id(self)))
            self._pool_nbytes = 0

    def page_utilization(self) -> Dict[str, float]:
        total = self.serve.num_pages - 1
        s = self.sched
        mean = s.util_sum / s.util_steps if s.util_steps else 0.0
        return {"total_pages": total,
                "peak_pages": int(s.util_peak),
                "mean_pages": mean,
                "peak_util": s.util_peak / total,
                "mean_util": mean / total}


# ----------------------------------------------------------------- #
# dense static-batch baseline
# ----------------------------------------------------------------- #
class DenseServer:
    """Greedy static-batch decode with a dense grown KV cache — the legacy
    serve path, kept as the benchmark/parity baseline. Reusable so repeat
    ``generate`` calls hit the compile cache (bench_serve times the second
    call)."""

    def __init__(self, cfg: ModelConfig, params, batch: int,
                 prompt_len: int, max_new_tokens: int,
                 lane: Optional[LaneConfig] = None):
        self.cfg, self.params = cfg, params
        self.lane = lane or LaneConfig()
        self.B, self.Lp = batch, prompt_len
        self.max_new = max_new_tokens
        n_img = cfg.num_image_tokens
        self.total = prompt_len + n_img + max_new_tokens
        pshape = ShapeConfig("dense_p", seq_len=prompt_len + n_img,
                             global_batch=batch, kind="prefill")
        dshape = ShapeConfig("dense_d", seq_len=self.total,
                             global_batch=batch, kind="decode")
        mp = api.build(cfg, pshape, self.lane,
                       ShardingRules(None, cfg, pshape))
        md = api.build(cfg, dshape, self.lane,
                       ShardingRules(None, cfg, dshape))
        self._prefill = jax.jit(mp.prefill_step)
        self._decode = jax.jit(md.decode_step, donate_argnums=(2,))

    def generate(self, prompts: np.ndarray) -> np.ndarray:
        """prompts [B, Lp] int -> [B, max_new_tokens] int32."""
        cfg, B = self.cfg, self.B
        assert prompts.shape == (B, self.Lp), prompts.shape
        n_img = cfg.num_image_tokens
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        dt = jnp.dtype(cfg.dtype)
        if cfg.encoder_layers:
            batch["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                        dt)
        if n_img:
            batch["img"] = jnp.zeros((B, n_img, cfg.d_model), dt)
        nxt, caches = self._prefill(self.params, batch)
        caches = kv_pages.grow_dense_caches(caches, cfg, self.total)
        out = [nxt]
        cur = self.Lp + n_img
        for _ in range(self.max_new - 1):
            nxt, caches = self._decode(self.params, nxt, caches,
                                       jnp.int32(cur))
            out.append(nxt)
            cur += 1
        return np.asarray(jnp.concatenate(out, axis=1))


def dense_generate(cfg: ModelConfig, params, prompts: np.ndarray,
                   max_new_tokens: int,
                   lane: Optional[LaneConfig] = None) -> np.ndarray:
    """One-shot convenience wrapper around DenseServer."""
    B, Lp = prompts.shape
    return DenseServer(cfg, params, B, Lp, max_new_tokens,
                       lane).generate(prompts)
