"""Batched temperature / top-k / top-p sampling with per-request seeds.

Randomness comes from core/prng.py's counter-based hash (the same
regeneration-stable generator the ZO trainer uses), keyed on
(request seed, sample index): resampling a request with the same seed
reproduces its stream token-for-token regardless of which batch slots or
engine steps it shared with other requests — the serving twin of the
trainer's seed-replay property. ``temperature <= 0`` rows take the greedy
argmax (bitwise the dense ``decode_step`` path, which the parity tests
use).

All knobs are per-row traced values, so one compiled sampler serves any
mix of requests. ``sample_tokens`` filters through the sort-free
threshold-refine selector (kernels/ops.py ``topk_topp_mask`` — Pallas on
TPU, jnp radix ref elsewhere), which replaces the two full-vocab argsorts
that dominated large-vocab sampling. ``sample_tokens_reference`` keeps the
original full-sort pipeline as the semantic oracle; the two agree
token-for-token except when ``p`` lands within one float rounding step of
a tie-run boundary (see kernels/ref.py ``topk_topp_mask_ref``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from ..core import prng
from ..kernels import ops

NEG_INF = -1e30
_SALT_GUMBEL = 0x5E17E_1
_STEP_MIX = np.uint32(2654435761)        # Knuth multiplicative hash


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0             # 0 => greedy
    top_k: int = 0                       # 0 => disabled
    top_p: float = 1.0                   # 1 => disabled
    seed: int = 0


@jax.jit
def greedy_tokens(logits):
    """argmax over the vocab axis — the one greedy definition shared by
    the engine's all-greedy fast path, the dense baseline, and the
    sampler's ``temperature <= 0`` branch (parity tests pin all three)."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _top_k_mask(logits, k):
    """Keep the k largest per row; k[b] <= 0 disables the filter."""
    V = logits.shape[-1]
    sorted_desc = -jnp.sort(-logits, axis=-1)
    idx = jnp.clip(k - 1, 0, V - 1)
    thresh = jnp.take_along_axis(sorted_desc, idx[:, None], axis=-1)
    keep = (logits >= thresh) | (k <= 0)[:, None]
    return jnp.where(keep, logits, NEG_INF)


def _top_p_mask(logits, p):
    """Nucleus filter; p[b] >= 1 disables. Always keeps the argmax."""
    order = jnp.argsort(-logits, axis=-1)
    sl = jnp.take_along_axis(logits, order, axis=-1)
    probs = jax.nn.softmax(sl, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep_sorted = (cum - probs) < p[:, None]       # head kept: cum-prob == 0
    inv = jnp.argsort(order, axis=-1)
    keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
    keep |= (p >= 1.0)[:, None]
    return jnp.where(keep, logits, NEG_INF)


def _gumbel_noise(seed, step, V):
    """Per-row Gumbel(0, 1) stream keyed on (request seed, sample index)."""
    row_seed = seed.astype(jnp.uint32) ^ \
        (step.astype(jnp.uint32) * _STEP_MIX)
    bits = jax.vmap(
        lambda s: prng.uniform_bits(s, _SALT_GUMBEL, (V,)))(row_seed)
    u = (bits >> np.uint32(8)).astype(jnp.float32) * np.float32(2 ** -24) \
        + np.float32(2 ** -25)                     # (0, 1]
    return -jnp.log(-jnp.log(u))


def _sample(logits, temperature, top_k, top_p, seed, step, vocab_size,
            filter_fn):
    B, V = logits.shape
    greedy = greedy_tokens(logits)

    masked = logits
    if 0 < vocab_size < V:
        masked = jnp.where(jnp.arange(V) < vocab_size, masked, NEG_INF)
    # temperature FIRST, filters on the actual sampling distribution
    # (HF/vLLM convention — top_p of the flattened distribution)
    t = jnp.maximum(temperature, 1e-6)[:, None]
    masked = filter_fn(masked / t, top_k, top_p)
    g = _gumbel_noise(seed, step, V)
    sampled = jnp.argmax(masked + g, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)


@functools.partial(jax.jit, static_argnames=("vocab_size",))
def sample_tokens(logits, temperature, top_k, top_p, seed, step,
                  vocab_size: int = 0):
    """logits [B, V] f32; per-row knobs [B] -> tokens [B] int32.

    seed uint32 (request seed), step int32 (per-request sample index).
    vocab_size > 0 masks the padded-vocab columns [vocab_size, V) out of
    the *sampled* branch (their unembed rows are arbitrary, so Gumbel
    noise could otherwise emit invalid ids); greedy stays unmasked to
    remain bitwise the dense ``decode_step`` argmax.
    """
    return _sample(logits, temperature, top_k, top_p, seed, step,
                   vocab_size, ops.topk_topp_mask)


@functools.partial(jax.jit, static_argnames=("vocab_size",))
def sample_tokens_reference(logits, temperature, top_k, top_p, seed, step,
                            vocab_size: int = 0):
    """Full-sort oracle for ``sample_tokens`` — identical Gumbel stream and
    greedy branch, filters via the original argsort pipeline."""
    return _sample(logits, temperature, top_k, top_p, seed, step,
                   vocab_size,
                   lambda x, k, p: _top_p_mask(_top_k_mask(x, k), p))
