"""repro.serve — paged-KV serving engine with continuous batching.

Public surface: Engine / ServeConfig / SamplingParams / dense_generate
(see docs/serving.md for the page-table layout and scheduler states).
"""
from ..configs.serve import ServeConfig
from .engine import DenseServer, Engine, StreamEvent, dense_generate
from .kv_pages import PagePool, admit_prefill, grow_dense_caches
from .sampler import SamplingParams, sample_tokens
from .scheduler import Request, Scheduler, StepPlan

__all__ = ["Engine", "DenseServer", "StreamEvent", "ServeConfig",
           "SamplingParams", "sample_tokens", "PagePool", "admit_prefill",
           "grow_dense_caches", "Request", "Scheduler", "StepPlan",
           "dense_generate"]
