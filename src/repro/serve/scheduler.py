"""Continuous-batching scheduler: admission, page growth, preemption.

Pure host-side state machine (numpy only — property-testable without JAX).
Sequence lifecycle:

    WAITING --admit--> RUNNING --commit--> FINISHED
        ^                  |
        +----preempt-------+        (recompute-style: pages freed, prompt
                                     re-extended with generated tokens,
                                     re-prefilled at next admission)

SWA reclamation: for sliding-window archs (``window > 0``) a sequence's
page list is *position-indexed with holes* — entry ``lp`` maps logical
page ``lp`` and holds ``NULL_PAGE`` once every position on that page has
slid out of the attention window. Reclaimed pages return to the pool
immediately (before growth allocations each step), the null entries flow
into the step's page table, and the decode kernel skips them; long
decodes therefore run in a pool bounded by the window, not the sequence
length. Admission allocates holes up front for prompt positions already
out of window (their prefill KV chunks land in the never-read null page).

Invariants the property tests (tests/test_serve_scheduler.py) enforce:
  * page conservation — live pages + free pages == num_pages - 1 (null);
  * no starvation — FIFO admission + LIFO ("newest victim") preemption
    means the oldest running sequence is only ever preempted when it is
    alone, which cannot happen because ``submit`` rejects sequences whose
    worst-case footprint exceeds the pool;
  * a slot never holds two sequences, a page never backs two sequences.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Sequence as Seq

import numpy as np

from .. import obs
from ..configs.serve import ServeConfig
from .kv_pages import NULL_PAGE, PagePool
from .sampler import SamplingParams

WAITING, RUNNING, FINISHED = "waiting", "running", "finished"


@dataclass
class Request:
    """One generation request. `prefix_extra` counts non-text cache tokens
    (e.g. VLM image tokens) that prefill writes before the prompt."""
    rid: int
    prompt: List[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    max_new_tokens: int = 16
    prefix_extra: int = 0


@dataclass
class _Sequence:
    req: Request
    state: str = WAITING
    slot: int = -1
    pages: List[int] = field(default_factory=list)   # NULL_PAGE = reclaimed
    pos: int = 0                     # tokens currently cached (incl. extra)
    generated: List[int] = field(default_factory=list)
    next_token: int = 0              # token to feed at the next decode step
    preemptions: int = 0
    submit_ns: int = 0               # obs TTFT stamp (0 = recorder off)

    @property
    def cached_prompt(self) -> List[int]:
        """Tokens to prefill on (re-)admission: prompt + prior generations."""
        return list(self.req.prompt) + self.generated

    @property
    def budget_left(self) -> int:
        return self.req.max_new_tokens - len(self.generated)


@dataclass
class StepPlan:
    """Device-ready assembly of one decode step."""
    tokens: np.ndarray               # [slots] int32, next token per row
    page_table: np.ndarray           # [slots, max_pages_per_seq] int32
    seq_lens: np.ndarray             # [slots] int32 (0 = inactive row)
    active: np.ndarray               # [slots] bool
    temperature: np.ndarray          # [slots] f32
    top_k: np.ndarray                # [slots] int32
    top_p: np.ndarray                # [slots] f32
    seed: np.ndarray                 # [slots] uint32
    step: np.ndarray                 # [slots] int32 (per-seq sample index)

    @property
    def num_active(self) -> int:
        return int(self.active.sum())


class Scheduler:
    def __init__(self, serve: ServeConfig, window: int = 0):
        self.serve = serve
        self.window = window             # model sliding window (0 = full)
        self.reclaimed_pages = 0         # SWA pages returned mid-sequence
        # bumped whenever the next StepPlan differs from the previous one
        # by more than the steady-state advance (active rows' pos and
        # sample index +1, tokens = last sampled): admissions, evictions,
        # preemptions, page growth, SWA reclamation. The engine keys its
        # persistent device-side plan buffers on it — an unchanged epoch
        # means the buffers can advance on device with zero host uploads.
        self.plan_epoch = 0
        self.pool = PagePool(serve.num_pages)
        self.waiting: Deque[_Sequence] = deque()
        self.slots: List[Optional[_Sequence]] = \
            [None] * serve.max_batch_slots
        self.finished: List[_Sequence] = []
        self._admit_order: List[_Sequence] = []   # running, oldest first
        self._rid = itertools.count()
        # page-utilization running aggregates (bounded, unlike a sample
        # list, for long-lived engines)
        self.util_peak = 0
        self.util_sum = 0
        self.util_steps = 0

    # ---------------- submission ----------------------------------- #
    def submit(self, prompt: Seq[int], sampling: SamplingParams = None,
               max_new_tokens: int = None, prefix_extra: int = 0) -> int:
        s = self.serve
        if not len(prompt):
            raise ValueError("empty prompt")
        max_new = max_new_tokens if max_new_tokens is not None \
            else s.max_new_tokens
        if max_new < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new}")
        total = prefix_extra + len(prompt) + max_new
        if total > s.max_seq_len:
            raise ValueError(
                f"request needs {total} cache tokens > max_seq_len "
                f"{s.max_seq_len}")
        if self._worst_case_pages(total + 1) > s.num_pages - 1:
            raise ValueError(
                f"request worst case {self._worst_case_pages(total + 1)} "
                f"pages > pool {s.num_pages - 1}; would deadlock")
        req = Request(next(self._rid), list(prompt),
                      sampling or SamplingParams(), max_new, prefix_extra)
        rec = obs.get()
        self.waiting.append(_Sequence(
            req, submit_ns=obs.perf_ns() if rec.enabled else 0))
        rec.gauge("serve.queue_depth").set(len(self.waiting))
        return req.rid

    # ---------------- SWA reclamation ------------------------------- #
    def _page_dead(self, lp: int, pos: int) -> bool:
        """True when logical page lp holds no position a decode step at
        write position `pos` (or any later one) can still attend: the
        kernel masks t > pos - window, and pos only grows."""
        return self.window > 0 and \
            (lp + 1) * self.serve.page_size - 1 <= pos - self.window

    def _worst_case_pages(self, tokens: int) -> int:
        """Peak pages one sequence can hold at once. With a sliding
        window, fully out-of-window pages are reclaimed each step, so the
        footprint is bounded by the pages a window-length span can
        straddle (+1 for the page being written), not by `tokens`."""
        p = self.serve.pages_for(tokens)
        if self.window > 0:
            p = min(p, self.serve.pages_for(self.window) + 1)
        return p

    def _reclaim(self, seq: _Sequence) -> None:
        """Free pages that slid fully out of seq's window; null their
        table entries so the kernel never touches them again."""
        dead = [lp for lp, pg in enumerate(seq.pages)
                if pg != NULL_PAGE and self._page_dead(lp, seq.pos)]
        if not dead:
            return
        self.pool.free([seq.pages[lp] for lp in dead])
        for lp in dead:
            seq.pages[lp] = NULL_PAGE
        self.plan_epoch += 1
        self.reclaimed_pages += len(dead)
        obs.get().counter("serve.page_reclaims").inc(len(dead))

    def has_work(self) -> bool:
        return bool(self.waiting) or any(self.slots)

    @property
    def running(self) -> List[_Sequence]:
        return list(self._admit_order)

    # ---------------- admission ------------------------------------ #
    def poll_admissions(self) -> List[_Sequence]:
        """Admit waiting sequences while a slot is free and the pool can
        hold their current prompt. Returns sequences the engine must
        prefill (pages already allocated, slot assigned, pos set)."""
        out = []
        while self.waiting:
            free_slots = [i for i, s in enumerate(self.slots) if s is None]
            if not free_slots:
                break
            seq = self.waiting[0]
            need = seq.req.prefix_extra + len(seq.cached_prompt)
            # prompt positions already out of window get holes up front:
            # their prefill KV chunks land in the never-read null page
            n_log = self.serve.pages_for(need)
            live = [lp for lp in range(n_log)
                    if not self._page_dead(lp, need)]
            pages = self.pool.alloc(len(live))
            if pages is None:
                break
            self.waiting.popleft()
            seq.state = RUNNING
            seq.slot = free_slots[0]
            seq.pages = [NULL_PAGE] * n_log
            for lp, pg in zip(live, pages):
                seq.pages[lp] = pg
            seq.pos = need
            self.slots[seq.slot] = seq
            self._admit_order.append(seq)
            out.append(seq)
        if out:
            self.plan_epoch += 1
        rec = obs.get()
        if rec.enabled:
            rec.gauge("serve.queue_depth").set(len(self.waiting))
            if out:
                rec.counter("serve.admissions").inc(len(out))
        return out

    # ---------------- per-step assembly ----------------------------- #
    def _evict(self, seq: _Sequence) -> None:
        self.plan_epoch += 1
        obs.get().counter("serve.evictions").inc()
        self.pool.free([p for p in seq.pages if p != NULL_PAGE])
        seq.pages = []
        self.slots[seq.slot] = None
        seq.slot = -1
        self._admit_order.remove(seq)

    def prepare_step(self) -> Optional[StepPlan]:
        """Ensure every running sequence has a page mapped for the position
        it is about to write; preempt (newest-first) on exhaustion. Returns
        None when nothing is running."""
        ps = self.serve.page_size
        if self.window > 0:
            # reclaim before growth so freed pages can back this very
            # step's new allocations (bounded-pool long decode)
            for seq in self._admit_order:
                self._reclaim(seq)
        for seq in list(self._admit_order):
            if seq.state != RUNNING:
                continue
            if seq.pos % ps == 0:            # next write opens a new page
                while True:
                    page = self.pool.alloc(1)
                    if page is not None:
                        seq.pages.extend(page)
                        self.plan_epoch += 1
                        break
                    # newest victim; never preempt `seq` unless it is alone
                    victim = self._admit_order[-1]
                    if victim is seq and len(self._admit_order) > 1:
                        victim = self._admit_order[-2]
                    if victim is seq:
                        # alone and out of pages: impossible under the
                        # submit() guard unless the pool leaked
                        raise RuntimeError(
                            "page pool exhausted by a single sequence")
                    self._preempt_seq(victim)
                if seq.state != RUNNING:
                    continue
        if not self._admit_order:
            return None

        n, P = self.serve.max_batch_slots, self.serve.max_pages_per_seq
        plan = StepPlan(
            tokens=np.zeros(n, np.int32),
            page_table=np.full((n, P), NULL_PAGE, np.int32),
            seq_lens=np.zeros(n, np.int32),
            active=np.zeros(n, bool),
            temperature=np.zeros(n, np.float32),
            top_k=np.zeros(n, np.int32),
            top_p=np.ones(n, np.float32),
            seed=np.zeros(n, np.uint32),
            step=np.zeros(n, np.int32),
        )
        for seq in self._admit_order:
            i = seq.slot
            sp = seq.req.sampling
            plan.tokens[i] = seq.next_token
            plan.page_table[i, :len(seq.pages)] = seq.pages
            plan.seq_lens[i] = seq.pos
            plan.active[i] = True
            plan.temperature[i] = sp.temperature
            plan.top_k[i] = sp.top_k
            plan.top_p[i] = sp.top_p
            plan.seed[i] = np.uint32(sp.seed)
            plan.step[i] = len(seq.generated)
        used = self.pool.used_pages
        self.util_peak = max(self.util_peak, used)
        self.util_sum += used
        self.util_steps += 1
        obs.get().gauge("serve.page_util").set(
            used / max(self.serve.num_pages - 1, 1))
        return plan

    def steady_horizon(self) -> int:
        """Decode ticks (>= 1) for which the plan just returned by
        ``prepare_step`` is *provably* epoch-stable, so the engine may fuse
        them into one device megastep. Within the horizon no plan-changing
        event can fire: no row crosses a page boundary (growth), no row
        exhausts its budget before the final tick (finish/evict), and —
        since nothing finishes, grows, or is preempted — no pages or slots
        free up, so blocked admissions stay blocked. EOS can end a row on
        any sampled token, so an armed ``eos_id`` pins the horizon to 1;
        SWA reclamation is merely postponed to the horizon's end, which is
        safe (dead pages are already masked out of attention) and keeps the
        reclaim-before-growth ordering the bounded-pool guarantee needs."""
        h = self.serve.megastep
        if h <= 1 or self.serve.eos_id >= 0:
            return 1
        ps = self.serve.page_size
        for seq in self._admit_order:
            h = min(h, seq.budget_left,            # finish only at the end
                    ps - (seq.pos % ps))           # ticks to next new page
        return max(h, 1)

    def _preempt_seq(self, victim: _Sequence) -> None:
        self._evict(victim)
        victim.state = WAITING
        victim.pos = 0
        victim.preemptions += 1
        self.waiting.appendleft(victim)
        rec = obs.get()
        rec.counter("serve.preemptions").inc()
        if rec.enabled:
            rec.event("preempt", track="serve", rid=victim.req.rid,
                      generated=len(victim.generated))

    # ---------------- commit ---------------------------------------- #
    def record_first_token(self, seq: _Sequence, token: int) -> bool:
        """Record the token sampled from prefill logits. Returns True if
        the sequence finished immediately (budget 1 or EOS)."""
        return self._append(seq, token)

    def commit_step(self, sampled: np.ndarray) -> List[_Sequence]:
        """Apply sampled tokens [slots] after a decode step; the fed token
        is now cached, so pos advances. Returns newly finished sequences."""
        done = []
        for seq in list(self._admit_order):
            tok = int(sampled[seq.slot])
            seq.pos += 1
            if self._append(seq, tok):
                done.append(seq)
        return done

    def _append(self, seq: _Sequence, token: int) -> bool:
        seq.generated.append(token)
        seq.next_token = token
        if seq.submit_ns and len(seq.generated) == 1:
            obs.get().histogram("serve.ttft_ms").observe(
                (obs.perf_ns() - seq.submit_ns) / 1e6)
        eos = self.serve.eos_id
        if seq.budget_left <= 0 or (eos >= 0 and token == eos):
            self._evict(seq)
            seq.state = FINISHED
            self.finished.append(seq)
            return True
        return False

    # ---------------- accounting ------------------------------------ #
    def clear_finished(self) -> List[_Sequence]:
        """Hand over and drop the finished-sequence history (long-lived
        servers call this after consuming results to bound memory)."""
        done, self.finished = self.finished, []
        return done

    def check_invariants(self) -> None:
        live = [p for s in self._admit_order for p in s.pages
                if p != NULL_PAGE]
        if len(live) != len(set(live)):
            raise RuntimeError("page double-booked")
        if len(live) + self.pool.free_pages != self.serve.num_pages - 1:
            raise RuntimeError("page leak")
        for i, s in enumerate(self.slots):
            if s is not None and s.slot != i:
                raise RuntimeError("slot table corrupt: sequence in "
                                   f"slot {i} thinks it is in {s.slot}")
