"""Paged KV-cache pool: host-side page allocator + device admission writes.

Layout (docs/serving.md): every attention layer owns a pool of
``num_pages`` fixed-size pages, [periods, num_pages, page_size, KVd, Dh].
A sequence's cache is an ordered list of physical page ids; the decode
step receives the list as a row of the [slots, max_pages_per_seq] page
table. Page 0 is the reserved **null page**: unmapped table entries point
at it, inactive batch rows write their garbage token into it, and it is
never allocated, so nothing that matters is ever read from or lost to it.

The allocator is pure host-side bookkeeping (a free list of ints) — no
device traffic. Device-side state changes are two jitted writes:
``admit_prefill`` scatters a prefilled dense cache into freshly allocated
pages (one reshape + one indexed set per KV leaf), and the per-step token
write lives inside the decode step itself (models/layers.py paged path).
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from ..configs.base import ATTN, ModelConfig

NULL_PAGE = 0


class PagePool:
    """Free-list page allocator. Page 0 is reserved (null page)."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError(
                f"num_pages={num_pages}: need at least 1 allocatable page "
                "+ null page")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, 0, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """All-or-nothing allocation of n pages (None on exhaustion)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        return pages

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p == NULL_PAGE:
                raise ValueError("null page is not allocatable")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)


# --------------------------------------------------------------------- #
# device-side admission
# --------------------------------------------------------------------- #
def _scatter_kv(pool, dense, page_rows, page_size):
    """pool [pp, N, ps, KVd, Dh] <- dense [pp, nb, L, ...], each row chunked
    into the pages of its `page_rows` row [nb, P] (fixed width; unused tail
    entries are the null page, which swallows the spill chunks — never
    read, and real decode writes land in each slot before the seq-len mask
    ever exposes it). Rows own disjoint pages, so the flattened scatter
    only ever collides on the null page, where any winner is fine."""
    pp, nb, L, KVd, Dh = dense.shape
    P = page_rows.shape[1]
    pad = P * page_size - L
    d = dense
    if pad:
        d = jnp.pad(d, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    d = d.reshape(pp, nb * P, page_size, KVd, Dh).astype(pool.dtype)
    return pool.at[:, page_rows.reshape(-1)].set(d)


@functools.partial(jax.jit, static_argnames=("pattern", "page_size"),
                   donate_argnums=(0,))
def _admit(paged, dense, slots, page_rows, *, pattern, page_size):
    out = {}
    for part in ("zo", "bp"):
        entries = []
        for i, kind in enumerate(pattern):
            pe, de = paged[part][i], dense[part][i]
            if kind == ATTN:
                ne = dict(pe)
                ne["k"] = _scatter_kv(pe["k"], de["k"], page_rows,
                                      page_size)
                ne["v"] = _scatter_kv(pe["v"], de["v"], page_rows,
                                      page_size)
                for ck in ("ck", "cv"):      # cross-attn KV: dense per slot
                    if ck in pe:
                        ne[ck] = pe[ck].at[:, slots].set(
                            de[ck].astype(pe[ck].dtype))
            else:                            # recurrent state: dense per slot
                ne = jax.tree.map(
                    lambda p, d: p.at[:, slots].set(d.astype(p.dtype)),
                    pe, de)
            entries.append(ne)
        out[part] = tuple(entries)
    return out


def admit_prefill(paged_caches, dense_caches, cfg: ModelConfig,
                  slots: Sequence[int], page_ids: Sequence[Sequence[int]],
                  page_size: int, table_width: int):
    """Write a batch-nb prefilled dense cache into the paged caches — the
    whole admission wave in ONE jitted scatter (one reshape + one indexed
    set per KV leaf, regardless of wave size).

    Row i of the dense cache goes to `slots[i]` / `page_ids[i]`. Each page
    list is padded to the fixed `table_width` (ServeConfig.max_pages_per_seq)
    so the scatter compiles per dense-cache shape only — not per admission
    length (re-admissions after preemption have ever-changing lengths).
    Pad/spill chunks land in the null page. Recurrent/cross state goes
    into the slot rows. Donates the old paged caches.
    """
    rows = [list(p) + [NULL_PAGE] * (table_width - len(p))
            for p in page_ids]
    return _admit(paged_caches, dense_caches,
                  jnp.asarray(list(slots), jnp.int32),
                  jnp.asarray(rows, jnp.int32),
                  pattern=cfg.pattern, page_size=page_size)


# --------------------------------------------------------------------- #
# dense-cache growth (legacy non-paged serve path)
# --------------------------------------------------------------------- #
def grow_dense_caches(caches, cfg: ModelConfig, total: int):
    """Pad a prefilled dense cache's *self-attention* KV to `total` slots.

    Replaces the old launch/serve.py shape heuristic (any dim-2 == prompt
    length), which false-positived on cross-attn KV, mamba conv state, or
    any arch with d_model == prompt length. Here the structure is walked by
    pattern position and key name, so only attn "k"/"v" leaves grow; the
    SWA ring stays capped at the window.
    """
    tgt = min(total, cfg.sliding_window) if cfg.sliding_window else total

    def _grow(leaf):
        T = leaf.shape[2]
        if T >= tgt:
            return leaf
        pad = [(0, 0)] * leaf.ndim
        pad[2] = (0, tgt - T)
        return jnp.pad(leaf, pad)

    out = {}
    for part in ("zo", "bp"):
        entries = []
        for i, kind in enumerate(cfg.pattern):
            e = caches[part][i]
            if kind == ATTN:
                e = dict(e)
                e["k"] = _grow(e["k"])
                e["v"] = _grow(e["v"])
            entries.append(e)
        out[part] = tuple(entries)
    return out
