"""Integer-arithmetic zeroth-order gradient sign (paper §4.3, Eqs. 7-12).

Given two int8 logit sets (alpha, s_alpha), (beta, s_beta) and labels, the
loss difference L(alpha) - L(beta) is evaluated as a *sign* using only
integer ops:

  1. rescale both to the common exponent s = min(s_a, s_b)       (Eq. 8)
  2. exp(x * 2^s) -> 2^(47274 * x * 2^(s-15))  (log2 e ~ 47274/2^15, Eq. 9)
  3. clamp exponents into a 10-bit window below the pairwise max  (p_max-10)
  4. B=1:  sign(sum_j 2^a~ - sum_j 2^b~)                          (Eq. 10)
     B>1:  sign(sum_b floor(log2 sum_j 2^a~) - ...)               (Eq. 12)

floor(log2 n) is computed by integer compares (a clz in spirit). The paper
measures ~95% sign agreement with the FP32 loss difference; tests assert
the same on random logits.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .int8 import QTensor

LOG2E_Q15 = 47274          # log2(e) * 2^15
WINDOW = 10                # 2^10 clamp window (paper: p = p_max - 10)


def _hat_exponents(logits: QTensor, labels: jax.Array, s_common) -> jax.Array:
    """47274 * (x_j - x_i) * 2^(s-15) as int32 per (sample, class)."""
    x = logits.data.astype(jnp.int32)
    shift = (logits.exp - s_common).astype(jnp.int32)       # >= 0
    x = jax.lax.shift_left(x, shift)                        # rescale (Eq. 8)
    xi = jnp.take_along_axis(x, labels[:, None].astype(jnp.int32), axis=-1)
    delta = x - xi                                          # [B, C]
    t = delta * LOG2E_Q15                                   # |delta|<=2^9ish
    k = (15 - s_common).astype(jnp.int32)
    # t * 2^(s-15): arithmetic shift in either direction
    pos = jax.lax.shift_left(t, jnp.maximum(-k, 0))
    return jnp.where(k >= 0,
                     jax.lax.shift_right_arithmetic(t, jnp.maximum(k, 0)),
                     pos)


def _floor_log2(n: jax.Array, maxbits: int = 26) -> jax.Array:
    n = jnp.maximum(n, 1)
    b = jnp.zeros_like(n)
    for k in range(1, maxbits):
        b = b + (n >= (1 << k)).astype(n.dtype)
    return b


def pow2_scores(logits: QTensor) -> jax.Array:
    """Integer pseudo-softmax scores 2^(x~) <= 2^10 (shared with int8 bwd)."""
    x = logits.data.astype(jnp.int32)
    t = (x - jnp.max(x, axis=-1, keepdims=True)) * LOG2E_Q15
    k = (15 - logits.exp).astype(jnp.int32)
    hat = jax.lax.shift_right_arithmetic(t, jnp.maximum(k, 0))
    hat = jnp.where(k < 0, jax.lax.shift_left(t, jnp.maximum(-k, 0)), hat)
    hat = jnp.clip(hat + WINDOW, 0, WINDOW)                 # window below max
    return jax.lax.shift_left(jnp.ones_like(hat), hat) * (hat > 0)


def int_loss_sign(alpha: QTensor, beta: QTensor,
                  labels: jax.Array) -> jax.Array:
    """sgn(L(alpha) - L(beta)) in {-1, 0, +1} (int32 scalar), integer-only."""
    s = jnp.minimum(alpha.exp, beta.exp)
    a_hat = _hat_exponents(alpha, labels, s)                # [B, C]
    b_hat = _hat_exponents(beta, labels, s)
    p_max = jnp.maximum(jnp.max(a_hat, axis=-1), jnp.max(b_hat, axis=-1))
    p = (p_max - WINDOW)[:, None]
    a_t = jnp.clip(a_hat - p, 0, WINDOW)
    b_t = jnp.clip(b_hat - p, 0, WINDOW)
    # keep only terms >= p (clamped-to-zero exponents may still contribute
    # 2^0; the paper accepts this approximation)
    A = jnp.sum(jax.lax.shift_left(jnp.ones_like(a_t), a_t), axis=-1)
    Bv = jnp.sum(jax.lax.shift_left(jnp.ones_like(b_t), b_t), axis=-1)
    batch = labels.shape[0]
    if batch == 1:
        diff = A[0] - Bv[0]                                 # Eq. 10
    else:
        diff = jnp.sum(_floor_log2(A) - _floor_log2(Bv))    # Eq. 12
    return jnp.sign(diff).astype(jnp.int32)


def float_loss(logits: QTensor, labels: jax.Array) -> jax.Array:
    """FP32 reference CE on dequantized logits (INT8 vs INT8* comparison)."""
    x = logits.data.astype(jnp.float32) * jnp.exp2(logits.exp.astype(jnp.float32))
    logz = jax.nn.logsumexp(x, axis=-1)
    ll = jnp.take_along_axis(x, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(logz - ll)
