"""Public API: build (init, train_step, prefill_step, decode_step,
input_specs, shardings) for any (arch, shape, lane, mesh).

This is the layer the launcher, dry-run, benchmarks and examples consume.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import LaneConfig, ModelConfig, ShapeConfig
from ..models import transformer as tf
from ..models.transformer import (embed, head_logits, lm_loss, make_caches,
                                  run_encoder, run_periods)
from ..sharding.rules import ShardingRules
from . import elastic
from .elastic import TrainState


def tail_periods(cfg: ModelConfig, lane: LaneConfig) -> int:
    """BP-tail size in periods (>=1, < num_periods)."""
    plen = len(cfg.pattern)
    k = max(1, -(-lane.bp_tail_layers // plen))          # ceil
    return min(k, cfg.num_periods - 1)


@dataclass
class BuiltModel:
    cfg: ModelConfig
    shape: ShapeConfig
    lane: LaneConfig
    rules: ShardingRules
    init: Callable
    loss_fn: Callable
    train_step: Callable
    prefill_step: Callable
    decode_step: Callable
    # serve subsystem entry points (src/repro/serve/): sampled serving needs
    # raw logits, and the paged variants address the KV pool via page tables.
    # Optional: builds that predate the serve path may leave them unset.
    prefill_logits: Optional[Callable] = None
    decode_step_paged: Optional[Callable] = None

    # ---- host-side helpers -------------------------------------------- #
    def input_specs(self) -> Dict[str, jax.ShapeDtypeStruct]:
        return build_input_specs(self.cfg, self.shape, self.lane, self.rules)

    def abstract_params(self):
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))

    def abstract_state(self):
        params = self.abstract_params()
        return TrainState(params,
                          jax.ShapeDtypeStruct((), jnp.int32),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))

    def abstract_caches(self):
        return jax.eval_shape(
            lambda: split_caches(
                make_caches(self.cfg, self.shape.global_batch,
                            self.shape.seq_len, self.rules),
                self.cfg, self.lane))


def split_caches(caches, cfg: ModelConfig, lane: LaneConfig):
    k = tail_periods(cfg, lane)
    pz = cfg.num_periods - k
    zo_c = jax.tree.map(lambda a: a[:pz], caches)
    bp_c = jax.tree.map(lambda a: a[pz:], caches)
    return {"zo": zo_c, "bp": bp_c}


def build(cfg: ModelConfig, shape: ShapeConfig, lane: LaneConfig,
          rules: ShardingRules, remat: bool = True,
          scan_unroll: bool = False) -> BuiltModel:
    K = tail_periods(cfg, lane)
    PZ = cfg.num_periods - K
    n_img = cfg.num_image_tokens
    dtype = jnp.dtype(cfg.dtype)
    # ElasticZO: the ZO head is never differentiated — cut the grad chain so
    # the head's scan saves no residuals (the paper's memory claim; Eq. 4).
    stop_zo_grad = lane.lane != "full_bp"

    # ---------------- init -------------------------------------------- #
    def init(key):
        params = tf.init_lm(key, cfg, max_seq=shape.seq_len, dtype=dtype)
        periods = params.pop("periods")
        params["periods_zo"] = jax.tree.map(lambda a: a[:PZ], periods)
        params["periods_bp"] = jax.tree.map(lambda a: a[PZ:], periods)
        return params

    # ---------------- forward ------------------------------------------ #
    def backbone(params, tokens, positions, mode, *, img_embeds=None,
                 frames=None, caches=None, cache_len=None, paged=None,
                 full_kv=False):
        enc_out = None
        if cfg.encoder_layers and mode != "decode":
            enc_out = run_encoder(params, frames, cfg, rules,
                                  unroll=scan_unroll)
        x = embed(params, tokens, cfg, rules, positions, img_embeds)
        cz = caches["zo"] if caches is not None else None
        cb = caches["bp"] if caches is not None else None
        x, ncz = run_periods(params["periods_zo"], x, cfg, rules,
                             positions=positions, mode=mode, caches=cz,
                             cache_len=cache_len, enc_out=enc_out,
                             remat=remat, unroll=scan_unroll, paged=paged,
                             full_kv=full_kv)
        if stop_zo_grad and mode == "train":
            x = jax.lax.stop_gradient(x)
            if enc_out is not None:
                enc_out = jax.lax.stop_gradient(enc_out)
        x, ncb = run_periods(params["periods_bp"], x, cfg, rules,
                             positions=positions, mode=mode, caches=cb,
                             cache_len=cache_len, enc_out=enc_out,
                             remat=remat, unroll=scan_unroll, paged=paged,
                             full_kv=full_kv)
        new_caches = ({"zo": ncz, "bp": ncb}
                      if mode in ("decode", "prefill") else None)
        return x, new_caches

    # ---------------- train -------------------------------------------- #
    def loss_fn(params, batch):
        tokens = batch["tokens"]
        B, S_tok = tokens.shape
        S_tot = S_tok + n_img
        positions = jnp.broadcast_to(
            jnp.arange(S_tot, dtype=jnp.int32), (B, S_tot))
        x, _ = backbone(params, tokens, positions, "train",
                        img_embeds=batch.get("img"), frames=batch.get("frames"))
        if n_img:
            x = x[:, n_img:]
        return lm_loss(params, x, batch["labels"], batch["mask"], cfg, rules)

    paired_loss_fn = None
    if lane.fused_probes and lane.lane == "elastic_zo":
        from ..models.transformer import run_periods_paired
        from . import prng, zo as zo_mod

        def paired_loss(bp_part, zo_part, batch, key):
            tokens = batch["tokens"]
            B, S_tok = tokens.shape
            S_tot = S_tok + n_img
            positions = jnp.broadcast_to(
                jnp.arange(S_tot, dtype=jnp.int32), (B, S_tot))
            seed = prng.seed_from_key(key)
            rest = {k: v for k, v in zo_part.items() if k != "periods_zo"}
            rest_p = zo_mod.perturb(rest, key, lane.zo_eps)
            rest_m = zo_mod.perturb(rest, key, -lane.zo_eps)
            enc_pair = (None, None)
            if cfg.encoder_layers:      # whisper: encoder stays unfused
                enc_pair = (run_encoder(rest_p, batch["frames"], cfg, rules,
                                        unroll=scan_unroll),
                            run_encoder(rest_m, batch["frames"], cfg, rules,
                                        unroll=scan_unroll))
            xp = embed(rest_p, tokens, cfg, rules, positions,
                       batch.get("img"))
            xm = embed(rest_m, tokens, cfg, rules, positions,
                       batch.get("img"))
            periods = zo_part["periods_zo"]
            n_per = jax.tree.leaves(periods)[0].shape[0]
            salts = jax.tree_util.tree_map_with_path(
                lambda p, _: zo_mod.path_salt(p, "['periods_zo']"), periods)
            sizes = jax.tree.map(lambda a: a.size // n_per, periods)
            xp, xm = run_periods_paired(
                periods, (xp, xm), cfg, rules, positions=positions,
                seed=seed, eps=lane.zo_eps, salts=salts, sizes=sizes,
                remat=remat, unroll=scan_unroll, enc_pair=enc_pair)
            xp = jax.lax.stop_gradient(xp)
            xm = jax.lax.stop_gradient(xm)
            losses = []
            for x in (xp, xm):
                x, _ = run_periods(bp_part["periods_bp"], x, cfg, rules,
                                   positions=positions, mode="train",
                                   enc_out=jax.lax.stop_gradient(enc_pair[0])
                                   if enc_pair[0] is not None else None,
                                   remat=remat, unroll=scan_unroll)
                if n_img:
                    x = x[:, n_img:]
                losses.append(lm_loss(bp_part, x, batch["labels"],
                                      batch["mask"], cfg, rules))
            return losses[0], losses[1]

        paired_loss_fn = paired_loss

    train_step = elastic.make_elastic_step(loss_fn, lane,
                                           paired_loss_fn=paired_loss_fn)

    # ---------------- serve -------------------------------------------- #
    def prefill_step(params, batch):
        tokens = batch["tokens"]
        B, S_tok = tokens.shape
        S_tot = S_tok + n_img
        positions = jnp.broadcast_to(
            jnp.arange(S_tot, dtype=jnp.int32), (B, S_tot))
        x, caches = backbone(params, tokens, positions, "prefill",
                             img_embeds=batch.get("img"),
                             frames=batch.get("frames"))
        logits = head_logits(params, x[:, -1:], cfg, rules)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    def decode_step(params, tokens, caches, cache_len):
        B = tokens.shape[0]
        positions = jnp.broadcast_to(cache_len.astype(jnp.int32), (B, 1))
        x, new_caches = backbone(params, tokens, positions, "decode",
                                 caches=caches, cache_len=cache_len)
        logits = head_logits(params, x, cfg, rules)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_caches

    def prefill_logits(params, batch, last_pos):
        """Prefill returning raw next-token logits gathered at per-row
        ``last_pos`` (absolute index incl. image tokens — supports
        right-padded/bucketed prompts), plus full-length un-rolled caches
        for paged admission. Returns (logits [B, Vp] f32, caches)."""
        tokens = batch["tokens"]
        B, S_tok = tokens.shape
        S_tot = S_tok + n_img
        positions = jnp.broadcast_to(
            jnp.arange(S_tot, dtype=jnp.int32), (B, S_tot))
        x, caches = backbone(params, tokens, positions, "prefill",
                             img_embeds=batch.get("img"),
                             frames=batch.get("frames"), full_kv=True)
        idx = jnp.broadcast_to(last_pos.astype(jnp.int32)[:, None, None],
                               (B, 1, x.shape[-1]))
        xl = jnp.take_along_axis(x, idx, axis=1)
        logits = head_logits(params, xl, cfg, rules)
        return logits[:, 0].astype(jnp.float32), caches

    def decode_step_paged(params, tokens, caches, page_table, seq_lens):
        """One continuous-batching decode step against the paged KV pool.

        tokens [B, 1]; page_table [B, P] int32 (physical page per logical
        block, 0 = null); seq_lens [B] int32 (tokens already cached per
        row — also the write position of this step's token). Rows with
        seq_len 0 and an all-null table are inactive padding slots.
        Returns (logits [B, Vp] f32, new_caches).
        """
        positions = seq_lens.astype(jnp.int32)[:, None]
        x, new_caches = backbone(params, tokens, positions, "decode",
                                 caches=caches,
                                 paged=(page_table, seq_lens))
        logits = head_logits(params, x, cfg, rules)
        return logits[:, 0].astype(jnp.float32), new_caches

    return BuiltModel(cfg, shape, lane, rules, init, loss_fn,
                      train_step, prefill_step, decode_step,
                      prefill_logits, decode_step_paged)


# ------------------------------------------------------------------ #
# input specs (ShapeDtypeStructs; no allocation)
# ------------------------------------------------------------------ #
def build_input_specs(cfg: ModelConfig, shape: ShapeConfig, lane: LaneConfig,
                      rules: ShardingRules) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    n_img = cfg.num_image_tokens
    dtype = jnp.dtype(cfg.dtype)
    S_tok = S - n_img if shape.kind in ("train", "prefill") else S
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S_tok), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S_tok), jnp.int32)
        specs["mask"] = jax.ShapeDtypeStruct((B, S_tok), jnp.float32)
        specs["probe_mask"] = jax.ShapeDtypeStruct(
            (lane.zo_num_probes,), jnp.float32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S_tok), jnp.int32)
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        specs["cache_len"] = jax.ShapeDtypeStruct((), jnp.int32)
    if cfg.encoder_layers and shape.kind in ("train", "prefill"):
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), dtype)
    if n_img and shape.kind in ("train", "prefill"):
        specs["img"] = jax.ShapeDtypeStruct((B, n_img, cfg.d_model), dtype)
    return specs


def batch_shardings(specs, rules: ShardingRules):
    """NamedShardings for the input-spec dict (None mesh -> None)."""
    if rules.mesh is None:
        return jax.tree.map(lambda _: None, specs)
    out = {}
    for k, v in specs.items():
        if k in ("probe_mask", "cache_len"):
            out[k] = NamedSharding(rules.mesh, P())
        elif v.ndim == 3:
            out[k] = NamedSharding(rules.mesh, P(rules.batch, None, None))
        else:
            out[k] = NamedSharding(rules.mesh, P(rules.batch, None))
        # batch dim must divide the data axes; replicate tiny batches
        bsize = 1
        for a in (rules.batch or ()):
            bsize *= rules.mesh.shape[a]
        if v.shape and v.shape[0] % max(bsize, 1) != 0:
            out[k] = NamedSharding(rules.mesh, P(*((None,) * v.ndim)))
    return out
