"""Counter-based, shardable, mesh-independent Gaussian noise.

``jax.random.normal`` ops are replicated by GSPMD (every device generates
the full array, then slices its shard) — for ZO that means full-parameter
fp32 noise resident per device. Instead we derive noise elementwise from a
murmur3-style integer hash of (global index, seed): pure elementwise ops on
a ``broadcasted_iota``, which GSPMD partitions like any other op.

Properties the framework relies on:
  * regeneration-stable: same (seed, shape) -> bitwise-same z (the MeZO
    seed-replay trick);
  * mesh-independent: z depends on the *global* index only, so elastic
    restarts on a different mesh reproduce the same perturbations —
    plain `jax.random` sharded generation cannot do this;
  * cheap: ~10 int ops + Box-Muller per element, fused into the parameter
    update stream (see kernels/zo_perturb.py for the Pallas twin).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_PHI = np.uint32(0x9E3779B9)


def _fmix32(h):
    h = h ^ (h >> np.uint32(16))
    h = h * _M1
    h = h ^ (h >> np.uint32(13))
    h = h * _M2
    h = h ^ (h >> np.uint32(16))
    return h


def uniform_bits(seed: jax.Array, salt, shape, offset=0) -> jax.Array:
    """uint32 hash bits for every element of `shape`.

    seed: uint32 scalar (traced ok); salt: python int / uint32 stream id.
    offset: flat-index offset (traced ok) — ``bits(shape, off)[i] ==
    bits(bigger_shape)[off + i]``, which is what lets a layer-scan slice
    reproduce exactly the noise of the stacked parameter leaf.
    """
    n = 1
    for d in shape:
        n *= int(d)
    idx = jax.lax.iota(jnp.uint32, max(n, 1))
    idx = (idx + jnp.asarray(offset, jnp.uint32)).reshape(shape or ())
    h = idx * _PHI + jnp.asarray(salt, jnp.uint32)
    h = _fmix32(h ^ seed.astype(jnp.uint32))
    h = _fmix32(h + seed.astype(jnp.uint32) * _M2)
    return h


def normal(seed: jax.Array, salt, shape, offset=0) -> jax.Array:
    """Standard normal fp32 via Box-Muller on two hashed uniform streams."""
    b1 = uniform_bits(seed, 2 * np.uint32(salt) + np.uint32(1), shape, offset)
    b2 = uniform_bits(seed, 2 * np.uint32(salt) + np.uint32(2), shape, offset)
    # u1 in (0,1]: top 24 bits, offset so log() is finite
    u1 = (b1 >> np.uint32(8)).astype(jnp.float32) * np.float32(2 ** -24) \
        + np.float32(2 ** -25)
    u2 = (b2 >> np.uint32(8)).astype(jnp.float32) * np.float32(2 ** -24)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(np.float32(2.0 * np.pi) * u2)


def seed_from_key(key: jax.Array) -> jax.Array:
    """uint32 scalar from a jax PRNG key (traced-safe)."""
    data = jax.random.key_data(key).astype(jnp.uint32)
    return (data[..., 0] ^ (data[..., -1] * _M1)).reshape(())
