"""The lane-polymorphic update engine (docs/design.md §10).

The canonical ElasticZO train step is ONE decomposition, stated here and
only here:

    partition -> probe(seeds, +/-eps) -> loss-diff -> coeff transform
              -> ZO update -> BP-tail update

with a numerics plugin per lane:

  * ``Fp32Engine`` (lanes full_zo / elastic_zo / full_bp, Alg. 1):
    g = clip(delta / 2eps); coeff = eta(t) * g * mask / valid; the ZO
    update accumulates the probe contributions **in probe order in
    fp32, subtracts once, and casts once per step**
    (accumulate-then-cast); the BP tail averages the perturbed-point
    gradients and applies one fp32-accumulate/cast SGD step.

  * ``Int8Engine`` (lane elastic_zo_int8, Alg. 2): g = sgn(L+ - L-) in
    {-1, 0, +1} (integer logits via core/int_loss.py, or the sign of
    the fp32 loss diff); the ZO update accumulates the per-probe
    pseudo-stochastically-rounded integer updates psr(g*z, shift) in
    int32 **in probe order and clamps once per step** to [-127, 127];
    the BP tail is the NITI FC backward, combined as a saturating int8
    sum.

Every phase exists in two dtype domains with identical semantics:

  * *traced* — inside the jitted train step built by ``make_step``
    (``core/elastic.py`` and ``core/elastic_int8.py`` are thin lane
    wrappers over this);
  * *ledger* — host-driven application of committed fleet records
    (``fleet/replay.py`` decodes wire bytes and calls ``host_coeffs`` /
    ``apply_zo_records`` / ``apply_tail_records``). Scalar
    hyperparameter math on this path runs in strict numpy float32 so
    every fleet participant derives identical coefficients; the bulk
    ZO apply dispatches to kernels/zo_fused_replay.py (TPU) or its
    eager oracle in kernels/ref.py, both of which pin the same
    accumulate-then-cast (fp32) / accumulate-then-clamp (int8) order.

Probes are keyed ``fold_in(fold_in(base_key, step), probe_id)`` with
*global* probe ids in both domains — the fleet's probe-parallel layout
is the single-process step with probe blocks assigned to workers.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.base import LaneConfig
from . import prng, zo

# ------------------------------------------------------------------ #
# shared scalar schedule — one formula, two dtype domains
# ------------------------------------------------------------------ #


def decay_traced(lane: LaneConfig, step: jax.Array) -> jax.Array:
    if lane.lr_decay_every <= 0 or lane.lr_decay_factor == 1.0:
        return jnp.float32(1.0)
    k = jnp.floor(step.astype(jnp.float32) / lane.lr_decay_every)
    return jnp.power(jnp.float32(lane.lr_decay_factor), k)


def decay_host(lane: LaneConfig, step: int) -> np.float32:
    """Strict-fp32 host twin of ``decay_traced`` (same rounding)."""
    if lane.lr_decay_every <= 0 or lane.lr_decay_factor == 1.0:
        return np.float32(1.0)
    k = np.float32(np.floor(np.float32(step) / np.float32(lane.lr_decay_every)))
    return np.power(np.float32(lane.lr_decay_factor), k)


def tail_learning_rate(lane: LaneConfig) -> float:
    # `is None` test: an explicit tail LR of 0.0 means "freeze the tail"
    return lane.learning_rate if lane.tail_learning_rate is None \
        else lane.tail_learning_rate


class UpdateEngine:
    """Base: lane binding + the partition phase. Subclasses are the
    numerics plugins; ``engine_for`` picks one from the lane config."""

    numerics: str = "?"

    def __init__(self, lane: LaneConfig,
                 partition_fn: Optional[Callable] = None):
        self.lane = lane
        if partition_fn is None:
            from . import elastic
            partition_fn = lambda p: elastic.partition(p, lane)  # noqa: E731
        self.partition = partition_fn


# ------------------------------------------------------------------ #
# fp32 lanes (Alg. 1)
# ------------------------------------------------------------------ #
class Fp32Engine(UpdateEngine):
    numerics = "fp32"

    def __init__(self, lane: LaneConfig,
                 partition_fn: Optional[Callable] = None,
                 paired_loss_fn: Optional[Callable] = None):
        super().__init__(lane, partition_fn)
        self.paired_loss_fn = paired_loss_fn

    # ---- coeff transform (ledger domain, strict fp32) ----------------- #
    def host_coeffs(self, step: int, deltas: np.ndarray,
                    mask: np.ndarray) -> Tuple[np.ndarray, np.float32]:
        """(coeffs fp32[n], valid): coeff_i = eta(t)*clip(d_i/2eps)*m_i/valid.

        The update applies ``theta <- cast(theta_f32 - sum_i coeff_i *
        z(seed_i))`` — the same descent direction as the traced step.
        """
        lane = self.lane
        deltas = np.asarray(deltas, np.float32)
        mask = np.asarray(mask, np.float32)
        g = deltas / np.float32(2.0 * lane.zo_eps)
        if lane.zo_clip is not None and lane.zo_clip > 0:
            g = np.clip(g, np.float32(-lane.zo_clip), np.float32(lane.zo_clip))
        g = g * mask
        valid = np.float32(max(float(mask.sum()), 1.0))
        eta = np.float32(lane.learning_rate) * decay_host(lane, step)
        return (eta * g) / valid, valid

    # ---- ZO update (traced domain) ------------------------------------ #
    @staticmethod
    def zo_apply(zo_part, terms: Sequence[Tuple[jax.Array, jax.Array]]):
        """theta <- cast(theta_f32 - sum_p coeff_p * z_p), probe order.

        terms: [(probe key, coeff scalar)] — coeff is the traced twin of
        ``host_coeffs`` (eta*g*mask/valid). The accumulate-then-cast
        order here is normative; kernels/zo_fused_replay.py and
        kernels/ref.zo_fused_replay_ref state the identical order for
        the ledger domain.
        """
        def f(path, leaf):
            acc = None
            for key, coeff in terms:
                t = coeff * zo.leaf_noise(key, path, leaf)
                acc = t if acc is None else acc + t
            if acc is None:
                return leaf
            return (leaf.astype(jnp.float32) - acc).astype(leaf.dtype)
        return jax.tree_util.tree_map_with_path(f, zo_part)

    # ---- ZO update (ledger domain) ------------------------------------ #
    @staticmethod
    def apply_zo_records(zo_part, seeds: np.ndarray, coeffs: np.ndarray):
        """Apply S committed steps x n probes to every ZO leaf in one
        fused pass (seeds u64/u32 [S, n], coeffs fp32 [S, n])."""
        from ..kernels import ops

        def f(path, leaf):
            return ops.zo_fused_replay(leaf, seeds.astype(np.uint32), coeffs,
                                       zo.path_salt(path))
        return jax.tree_util.tree_map_with_path(f, zo_part)

    # ---- BP-tail update (shared expression) --------------------------- #
    @staticmethod
    def tail_apply(bp_part, grad_avg, eta):
        """p <- cast(p_f32 - eta * g_f32); eta traced or host fp32."""
        return jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - eta * g.astype(jnp.float32)).astype(p.dtype),
            bp_part, grad_avg)

    def apply_tail_records(self, bp_part, step: int,
                           worker_grads: List[Any], valid: np.float32):
        """Ledger-domain tail: sum the accepted workers' dequantized
        grad trees (worker-id order), average by `valid`, apply."""
        if not jax.tree_util.tree_leaves(bp_part) or not worker_grads:
            return bp_part
        acc = None
        for part in worker_grads:
            acc = part if acc is None else jax.tree.map(jnp.add, acc, part)
        avg = jax.tree.map(lambda a: a / jnp.float32(valid), acc)
        eta = np.float32(tail_learning_rate(self.lane)) \
            * decay_host(self.lane, step)
        return self.tail_apply(bp_part, avg, jnp.float32(eta))

    # ---- the train step (traced domain) ------------------------------- #
    def make_step(self, loss_fn: Callable[[Any, Any], jax.Array]):
        """(state, batch, probe_mask fp32[n]) -> (state, metrics)."""
        from .elastic import TrainState, merge
        lane = self.lane
        n = lane.zo_num_probes
        base_eta_tail = tail_learning_rate(lane)
        paired_loss_fn = self.paired_loss_fn

        def step(state: TrainState, batch, probe_mask: jax.Array):
            assert probe_mask.shape == (n,), \
                (f"probe_mask has shape {probe_mask.shape} but lane "
                 f"{lane.lane!r} runs {n} probes — derive LoopConfig."
                 "n_probes from the lane (LoopConfig.for_lane)")
            decay = decay_traced(lane, state.step)
            eta_zo = lane.learning_rate * decay
            eta_tail = base_eta_tail * decay
            params = state.params
            zo_part, bp_part = self.partition(params)
            base = jax.random.wrap_key_data(state.seed)
            key = jax.random.fold_in(base, state.step)

            if lane.lane == "full_bp":
                loss, grads = jax.value_and_grad(
                    lambda bp: loss_fn(bp, batch))(bp_part)
                new_params = self.tail_apply(bp_part, grads, eta_tail)
                metrics = {"loss": loss, "zo_g": jnp.float32(0)}
                return (TrainState(new_params, state.step + 1, state.seed),
                        metrics)

            def tail_loss(bp, zo_pert):
                return loss_fn(merge(zo_pert, bp), batch)

            has_tail = bool(bp_part) and lane.lane == "elastic_zo"
            zo_terms = []           # (probe key, coeff) in probe order
            tail_grad = None
            loss_acc = jnp.float32(0)
            g_acc = jnp.float32(0)
            valid = jnp.maximum(jnp.sum(probe_mask), 1.0)

            zo_src = zo_part
            for i in range(n):
                pk = jax.random.fold_in(key, i)
                if paired_loss_fn is not None and has_tail:
                    # fused antithetic pair: one layer traversal for both
                    # probes; grad of the mean IS the averaged tail grad.
                    def f(bp, _zo=zo_src, _pk=pk):
                        lp_, lm_ = paired_loss_fn(bp, _zo, batch, _pk)
                        return 0.5 * (lp_ + lm_), (lp_, lm_)
                    (_, (lp, lm)), g_tail_i = jax.value_and_grad(
                        f, has_aux=True)(bp_part)
                else:
                    zp = zo.perturb(zo_src, pk, lane.zo_eps)
                    if has_tail:
                        lp, gp = jax.value_and_grad(tail_loss)(bp_part, zp)
                        # sequence the minus pass after the plus pass so
                        # their activation peaks don't overlap
                        zo_src, lp = jax.lax.optimization_barrier((zo_src, lp))
                        zm = zo.perturb(zo_src, pk, -lane.zo_eps)
                        lm, gm = jax.value_and_grad(tail_loss)(bp_part, zm)
                        if lane.bp_grad_mode == "clean":
                            _, g_tail_i = jax.value_and_grad(tail_loss)(
                                bp_part, zo_part)
                        else:
                            g_tail_i = jax.tree.map(
                                lambda a, b: (a + b) * 0.5, gp, gm)
                    else:
                        lp = loss_fn(merge(zp, bp_part), batch)
                        zo_src, lp = jax.lax.optimization_barrier((zo_src, lp))
                        zm = zo.perturb(zo_src, pk, -lane.zo_eps)
                        lm = loss_fn(merge(zm, bp_part), batch)
                if has_tail:
                    g_tail_i = jax.tree.map(
                        lambda x, m=probe_mask[i]: m * x.astype(jnp.float32),
                        g_tail_i)
                    tail_grad = g_tail_i if tail_grad is None else \
                        jax.tree.map(jnp.add, tail_grad, g_tail_i)
                g = zo.projected_gradient(lp, lm, lane.zo_eps, lane.zo_clip)
                g = g * probe_mask[i]
                zo_terms.append((pk, eta_zo * g / valid))
                loss_acc = loss_acc + 0.5 * (lp + lm) * probe_mask[i]
                g_acc = g_acc + jnp.abs(g)

            new_zo = self.zo_apply(zo_part, zo_terms)
            if has_tail:
                tail_grad = jax.tree.map(lambda gt: gt / valid, tail_grad)
                new_bp = self.tail_apply(bp_part, tail_grad, eta_tail)
            else:
                new_bp = bp_part

            new_params = merge(new_zo, new_bp)
            metrics = {"loss": loss_acc / valid, "zo_g": g_acc / n}
            return TrainState(new_params, state.step + 1, state.seed), metrics

        return step


# ------------------------------------------------------------------ #
# int8 lane (Alg. 2)
# ------------------------------------------------------------------ #
class Int8Engine(UpdateEngine):
    numerics = "int8"

    def __init__(self, lane: LaneConfig,
                 partition_fn: Optional[Callable] = None,
                 tail_fcs: Optional[List[Tuple[str, str]]] = None,
                 loss_mode: Optional[str] = None,
                 p_zero: Optional[float] = None):
        super().__init__(lane, partition_fn)
        self.tail_fcs = tail_fcs or []
        self.loss_mode = lane.int8_loss_mode if loss_mode is None \
            else loss_mode
        self.r_max = lane.int8_r_max
        self.p_zero = lane.int8_p_zero if p_zero is None else p_zero
        # static twin of int8.bitwidth(r_max) - b_zo (Alg. 2 shift)
        self.zo_shift = max(int(self.r_max).bit_length() - lane.int8_b_zo, 0)

    # ---- coeff transform (ledger domain) ------------------------------ #
    def host_coeffs(self, step: int, gs: np.ndarray,
                    mask: np.ndarray) -> Tuple[np.ndarray, np.float32]:
        """(coeffs int32[n], valid). The int8 coeff IS the masked ternary
        sign — sgn coeffs are applied per probe, never renormalized
        (masked probes have g=0, an exact no-op of the integer update)."""
        gs = np.asarray(gs, np.int32)
        mask = np.asarray(mask, np.float32)
        valid = np.float32(max(float(mask.sum()), 1.0))
        return gs * mask.astype(np.int32), valid

    # ---- ZO update (traced domain) ------------------------------------ #
    def zo_apply(self, zo_part, terms: Sequence[Tuple[jax.Array, jax.Array]]):
        """theta <- clamp(theta - sum_p psr(g_p * z_p, shift), -127, 127).

        terms: [(probe uint32 seed, ternary g int32)] in probe order;
        int32 accumulation, ONE clamp per step — the integer twin of the
        fp32 accumulate-then-cast.
        """
        from .int8 import QTensor, int8_noise, psr_shift
        shift = jnp.int32(self.zo_shift)

        def f(path, leaf):
            if not isinstance(leaf, QTensor):
                return leaf
            salt = zo.path_salt(path)
            acc = None
            for seed, g in terms:
                z = int8_noise(seed, salt, leaf.data.shape, self.r_max,
                               jnp.float32(self.p_zero))
                t = psr_shift(g * z, shift)
                acc = t if acc is None else acc + t
            if acc is None:
                return leaf
            d = jnp.clip(leaf.data.astype(jnp.int32) - acc, -127, 127)
            return QTensor(d.astype(jnp.int8), leaf.exp)
        return jax.tree_util.tree_map_with_path(
            f, zo_part, is_leaf=lambda x: isinstance(x, QTensor))

    # ---- ZO update (ledger domain) ------------------------------------ #
    def apply_zo_records(self, zo_part, seeds: np.ndarray, gs: np.ndarray):
        """S committed steps x n probes on every int8 QTensor leaf
        (seeds u64/u32 [S, n], gs int32 [S, n]; masked probes g=0)."""
        from ..kernels import ops
        from .int8 import QTensor

        def f(path, leaf):
            if not isinstance(leaf, QTensor):
                return leaf
            data = ops.zo_fused_replay_int8(
                leaf.data, seeds.astype(np.uint32), gs.astype(np.int32),
                zo.path_salt(path), self.r_max, np.float32(self.p_zero),
                self.zo_shift)
            return QTensor(data, leaf.exp)
        return jax.tree_util.tree_map_with_path(
            f, zo_part, is_leaf=lambda x: isinstance(x, QTensor))

    # ---- probe phase (one statement; live step AND fleet probe_fn) ---- #
    def probe_pair(self, forward: Callable, zo_part, bp_part, batch,
                   seed: jax.Array):
        """One probe's Alg. 2 evaluation: functional +/- perturbation
        pair (the paper's in-place +1/-2/+1 replay minus its
        double-clamp asymmetry, docs/design.md §9), two integer
        forwards, ternary loss-diff. Returns (g int32, logits_p,
        acts_p). Shared verbatim by ``make_step`` and
        worker.make_int8_probe_fn so the two domains cannot drift.
        """
        from .int8 import perturb_int8
        from .int_loss import float_loss, int_loss_sign
        pzero = jnp.float32(self.p_zero)
        zo_p = perturb_int8(zo_part, seed, +1, self.r_max, pzero)
        logits_p, acts_p = forward({**zo_p, **bp_part}, batch["x"])
        zo_m = perturb_int8(zo_part, seed, -1, self.r_max, pzero)
        logits_m, _ = forward({**zo_m, **bp_part}, batch["x"])
        if self.loss_mode == "int":
            g = int_loss_sign(logits_p, logits_m, batch["y"])
        else:
            lf_p = float_loss(logits_p, batch["y"])
            lf_m = float_loss(logits_m, batch["y"])
            g = jnp.sign(lf_p - lf_m).astype(jnp.int32)
        return g, logits_p, acts_p

    # ---- BP tail ------------------------------------------------------- #
    def tail_updates(self, bp_part, acts, logits, labels):
        """One probe's NITI backward: {layer: upd int32} (not applied).

        The propagated error chain uses the *pre-update* weights, so
        computing all updates first and applying once is exactly the
        sequential Alg. 2 application.
        """
        from .int8 import QTensor, fc_backward_int8, output_error_int8
        upds: Dict[str, jax.Array] = {}
        if not self.tail_fcs:
            return upds
        e = output_error_int8(logits, labels)
        for name, act_key in reversed(self.tail_fcs):
            w = bp_part[name]["w"]
            a_in: QTensor = acts[act_key]
            new_w, e = fc_backward_int8(w, a_in, e, self.lane.int8_b_bp)
            upds[name] = w.data.astype(jnp.int32) - new_w.data.astype(jnp.int32)
            # relu mask for the propagated error (pre-activation of the
            # previous layer is >0 exactly where its output is >0)
            e = e * (a_in.data.astype(jnp.int32) > 0)
        return upds

    @staticmethod
    def combine_tail(upds_list: Sequence[Dict[str, jax.Array]]):
        """Saturating-int8 combine of per-probe updates (wire-exact: the
        ledger carries this as the record's int8 tail payload)."""
        acc: Dict[str, jax.Array] = {}
        for upds in upds_list:
            for name, u in upds.items():
                acc[name] = u if name not in acc else acc[name] + u
        return {n: jnp.clip(u, -127, 127).astype(jnp.int8)
                for n, u in acc.items()}

    @staticmethod
    def tail_apply(bp_part, combined: Dict[str, Any]):
        """w <- clamp(w - sum(upd), -127, 127); exponents unchanged."""
        from .int8 import QTensor
        new_bp = dict(bp_part)
        for name, u in combined.items():
            w = bp_part[name]["w"]
            d = jnp.clip(w.data.astype(jnp.int32) - u.astype(jnp.int32),
                         -127, 127)
            new_bp[name] = {"w": QTensor(d.astype(jnp.int8), w.exp)}
        return new_bp

    def apply_tail_records(self, bp_part, step: int,
                           worker_upds: List[Any], valid=None):
        """Ledger-domain tail: int32 sum of the accepted workers' int8
        payload trees (exact, order-free), one saturating apply.

        worker_upds are bp-shaped ``{layer: {"w": upd}}`` trees (the
        record's payload unflattened against the schema treedef).
        """
        if not jax.tree_util.tree_leaves(bp_part) or not worker_upds:
            return bp_part
        acc = None
        for part in worker_upds:
            part = jax.tree.map(lambda u: u.astype(jnp.int32), part)
            acc = part if acc is None else jax.tree.map(jnp.add, acc, part)
        return self.tail_apply(bp_part, {n: sub["w"] for n, sub in
                                         acc.items()})

    # ---- the train step (traced domain) ------------------------------- #
    def make_step(self, forward: Callable):
        """forward(params, x) -> (logits QTensor, acts). Returned step:
        (state, batch, probe_mask fp32[n]) -> (state, metrics)."""
        from .elastic import TrainState
        from .int_loss import float_loss
        lane = self.lane
        n = lane.zo_num_probes

        def step(state: TrainState, batch, probe_mask):
            assert probe_mask.shape == (n,), \
                (f"probe_mask has shape {probe_mask.shape} but lane "
                 f"{lane.lane!r} runs {n} probes")
            params = state.params
            zo_part, bp_part = self.partition(params)
            base = jax.random.wrap_key_data(state.seed)
            key = jax.random.fold_in(base, state.step)

            zo_terms = []
            tail_upds = []
            loss_acc = jnp.float32(0)
            g_acc = jnp.float32(0)
            acc_acc = jnp.float32(0)
            valid = jnp.maximum(jnp.sum(probe_mask), 1.0)
            for i in range(n):
                seed = prng.seed_from_key(jax.random.fold_in(key, i))
                g, logits_p, acts_p = self.probe_pair(
                    forward, zo_part, bp_part, batch, seed)
                g = g * probe_mask[i].astype(jnp.int32)
                zo_terms.append((seed, g))
                upds = self.tail_updates(bp_part, acts_p, logits_p,
                                         batch["y"])
                mi = probe_mask[i].astype(jnp.int32)
                tail_upds.append({k: mi * u for k, u in upds.items()})
                loss_acc = loss_acc + float_loss(logits_p, batch["y"]) \
                    * probe_mask[i]
                g_acc = g_acc + g.astype(jnp.float32)
                acc_acc = acc_acc + probe_mask[i] * jnp.mean(
                    (jnp.argmax(logits_p.data, -1) == batch["y"])
                    .astype(jnp.float32))

            new_zo = self.zo_apply(zo_part, zo_terms)
            new_bp = self.tail_apply(bp_part, self.combine_tail(tail_upds)) \
                if self.tail_fcs else dict(bp_part)
            metrics = {
                "loss": loss_acc / valid,
                "g": g_acc / valid,
                "acc": acc_acc / valid,
            }
            return (TrainState({**new_zo, **new_bp}, state.step + 1,
                               state.seed), metrics)

        return step


def engine_for(lane: LaneConfig, partition_fn: Optional[Callable] = None,
               **kwargs) -> UpdateEngine:
    """The one lane -> numerics-plugin mapping."""
    if lane.lane == "elastic_zo_int8":
        return Int8Engine(lane, partition_fn, **kwargs)
    return Fp32Engine(lane, partition_fn, **kwargs)


# ------------------------------------------------------------------ #
# phase profiler (diagnostic path, opt-in)
# ------------------------------------------------------------------ #
def profile_step_phases(engine: UpdateEngine, fn: Callable, state, batch,
                        iters: int = 3) -> Dict[str, float]:
    """Time the canonical phases one by one; returns {phase: mean_us}.

    This is a *diagnostic* decomposition, deliberately separate from the
    production train step: the production step is ONE jitted program
    (host timers cannot see inside it), and re-building it as a chain of
    separately-jitted phase programs re-fuses differently — FMA
    contraction shifts the fp32 stream by ~1 ulp (the same reason
    fleet/reference.py runs under ``LoopConfig(jit=False)``). So the
    profiler builds its own per-phase programs — the same kernels the
    real step traces — warms them, and times each with a
    ``jax.block_until_ready`` device sync. The production step and its
    numerics are untouched; the parameter state is never written.

    ``fn`` is the lane's step builder argument: ``loss_fn`` for fp32
    lanes, ``forward`` for int8. Spans land on the "engine" track of the
    active recorder plus ``engine.phase.<name>_ms`` histograms.
    """
    from .. import obs
    rec = obs.get()
    lane = engine.lane
    n = lane.zo_num_probes
    params = state.params
    base = jax.random.wrap_key_data(jnp.asarray(state.seed))
    key = jax.random.fold_in(base, state.step)
    out: Dict[str, float] = {}

    def timed(name, f, *a):
        jax.block_until_ready(f(*a))       # compile + warm
        tot = 0.0
        for _ in range(iters):
            with rec.span(f"engine/{name}", track="engine") as sp:
                t0 = obs.monotonic()
                jax.block_until_ready(f(*a))
                tot += obs.monotonic() - t0
            rec.histogram(f"engine.phase.{name}_ms").observe(sp.dur_ns / 1e6)
        out[name] = tot / iters * 1e6
        return out[name]

    timed("partition", lambda p: jax.tree_util.tree_leaves(
        engine.partition(p)), params)
    zo_part, bp_part = engine.partition(params)

    if engine.numerics == "int8":
        loss_fn = None
        forward = fn
        seeds = [prng.seed_from_key(jax.random.fold_in(key, i))
                 for i in range(n)]

        def probe_prog(zp, bp):
            # loss-diff (the ternary sign) is fused into the probe pair
            return jnp.stack([engine.probe_pair(forward, zp, bp, batch,
                                                s)[0] for s in seeds])
        gs = jax.jit(probe_prog)(zo_part, bp_part)
        timed("probe", jax.jit(probe_prog), zo_part, bp_part)
        mask = np.ones((n,), np.float32)
        timed("coeff", lambda: engine.host_coeffs(
            int(state.step), np.asarray(gs), mask))
        terms = [(s, g) for s, g in zip(seeds, gs)]
        timed("zo_update", jax.jit(
            lambda zp: jax.tree_util.tree_leaves(engine.zo_apply(zp, terms))),
            zo_part)
        if engine.tail_fcs:
            def tail_prog(bp, zp):
                g, logits_p, acts_p = engine.probe_pair(forward, zp, bp,
                                                        batch, seeds[0])
                upds = engine.tail_updates(bp, acts_p, logits_p, batch["y"])
                return jax.tree_util.tree_leaves(
                    engine.tail_apply(bp, engine.combine_tail([upds])))
            timed("bp_tail", jax.jit(tail_prog), bp_part, zo_part)
        return out

    loss_fn = fn
    from .elastic import merge
    keys = [jax.random.fold_in(key, i) for i in range(n)]

    def probe_prog(zp, bp):
        ls = []
        for pk in keys:
            ls.append(loss_fn(merge(zo.perturb(zp, pk, lane.zo_eps), bp),
                              batch))
            ls.append(loss_fn(merge(zo.perturb(zp, pk, -lane.zo_eps), bp),
                              batch))
        return jnp.stack(ls)
    losses = np.asarray(jax.jit(probe_prog)(zo_part, bp_part))
    timed("probe", jax.jit(probe_prog), zo_part, bp_part)
    lp, lm = losses[0::2], losses[1::2]
    timed("loss_diff", lambda: np.float32(lp) - np.float32(lm))
    deltas = np.float32(lp) - np.float32(lm)
    mask = np.ones((n,), np.float32)
    timed("coeff", lambda: engine.host_coeffs(int(state.step), deltas, mask))
    coeffs, _ = engine.host_coeffs(int(state.step), deltas, mask)
    terms = [(pk, jnp.float32(c)) for pk, c in zip(keys, coeffs)]
    timed("zo_update", jax.jit(
        lambda zp: jax.tree_util.tree_leaves(engine.zo_apply(zp, terms))),
        zo_part)
    if jax.tree_util.tree_leaves(bp_part) and lane.lane == "elastic_zo":
        eta = jnp.float32(tail_learning_rate(lane))

        def tail_prog(bp, zp):
            g = jax.grad(lambda b: loss_fn(merge(zp, b), batch))(bp)
            return jax.tree_util.tree_leaves(engine.tail_apply(bp, g, eta))
        timed("bp_tail", jax.jit(tail_prog), bp_part, zo_part)
    return out


# ------------------------------------------------------------------ #
# step memory analysis (diagnostic path, opt-in)
# ------------------------------------------------------------------ #
def step_memory_analysis(step_fn: Callable, state, batch,
                         probe_mask) -> Optional[Dict[str, int]]:
    """Measured XLA footprint of ONE train step, without executing it.

    The time profiler above cannot see memory and ``jax.live_arrays()``
    cannot see inside a jitted program, so this is the measured twin of
    the paper's analytic model (Eqs. 2-4 / 13-15): the step is lowered
    and compiled exactly as the production path runs it (same donation)
    and XLA's buffer assignment reports argument/output/temp/alias bytes
    (obs/memory.compiled_footprint). benchmarks/paper_tables.py puts
    these next to the Eq. values per lane; the difference is the
    reconciliation residual in BENCH_paper.json's ``memory`` section.
    """
    from ..obs.memory import compiled_footprint
    mask = jnp.asarray(np.asarray(probe_mask, np.float32))
    return compiled_footprint(step_fn, state, batch, mask,
                              donate_argnums=(0,))
