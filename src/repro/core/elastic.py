"""ElasticZO (Alg. 1): ZO for the first C layers, BP for the last L-C.

Parameter partition is structural: the LM parameter tree stores the layer
stack as two period-stacks, ``periods_zo`` (first P-K periods) and
``periods_bp`` (last K periods). Lanes assign top-level groups:

  elastic_zo : ZO = {embed, pos_embed, encoder, periods_zo}
               BP = {periods_bp, final_norm, unembed}
  full_zo    : ZO = everything            (paper baseline, C = L)
  full_bp    : BP = everything            (paper baseline, C = 0)

The BP-tail gradient is taken at the *perturbed* points and averaged
(Alg. 1 keeps activations from the l+ and l- passes instead of running a
third forward; ``bp_grad_mode="clean"`` selects the third-pass variant).
Because only tail leaves are differentiated, XLA drops all head residuals
— the paper's memory claim, realized through DCE instead of manual buffer
management.

Multi-probe (n>1) antithetic SPSA with a runtime ``probe_mask`` implements
straggler mitigation: a dropped probe is masked out and the update is
renormalized by the surviving count — no recompile, no waiting
(docs/design.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import LaneConfig
from . import zo

ZO_GROUPS = ("embed", "pos_embed", "encoder", "periods_zo")
BP_GROUPS = ("periods_bp", "final_norm", "unembed")


class TrainState(NamedTuple):
    params: Any
    step: jax.Array            # i32 scalar
    seed: jax.Array            # uint32[2] base PRNG key data


def partition(params: Dict[str, Any], lane: LaneConfig):
    """Split the top-level param dict into (zo_part, bp_part)."""
    if lane.lane == "full_bp":
        return {}, dict(params)
    if lane.lane == "full_zo":
        return dict(params), {}
    zo_part = {k: v for k, v in params.items() if k in ZO_GROUPS}
    bp_part = {k: v for k, v in params.items() if k in BP_GROUPS}
    leftover = set(params) - set(zo_part) - set(bp_part)
    assert not leftover, f"unpartitioned param groups: {leftover}"
    return zo_part, bp_part


def merge(zo_part, bp_part):
    return {**zo_part, **bp_part}


def make_elastic_step(loss_fn: Callable[[Any, Any], jax.Array],
                      lane: LaneConfig,
                      partition_fn: Optional[Callable] = None,
                      paired_loss_fn: Optional[Callable] = None):
    """Build the ElasticZO train step.

    loss_fn(params, batch) -> scalar fp32 (global mean under GSPMD).
    partition_fn(params) -> (zo_part, bp_part); defaults to the LM
    top-level-group partition. Returned step:
    (state, batch, probe_mask) -> (state, metrics).
    probe_mask: fp32[n_probes]; all-ones for a healthy fleet.
    """
    n = lane.zo_num_probes
    # `is None` test: an explicit tail LR of 0.0 means "freeze the tail"
    base_eta_tail = lane.learning_rate if lane.tail_learning_rate is None \
        else lane.tail_learning_rate

    def _decay(step):
        if lane.lr_decay_every <= 0 or lane.lr_decay_factor == 1.0:
            return jnp.float32(1.0)
        k = jnp.floor(step.astype(jnp.float32) / lane.lr_decay_every)
        return jnp.power(jnp.float32(lane.lr_decay_factor), k)

    def step(state: TrainState, batch, probe_mask: jax.Array):
        decay = _decay(state.step)
        eta_zo = lane.learning_rate * decay
        eta_tail = base_eta_tail * decay
        params = state.params
        if partition_fn is not None:
            zo_part, bp_part = partition_fn(params)
        else:
            zo_part, bp_part = partition(params, lane)
        base = jax.random.wrap_key_data(state.seed)
        key = jax.random.fold_in(base, state.step)

        if lane.lane == "full_bp":
            loss, grads = jax.value_and_grad(
                lambda bp: loss_fn(bp, batch))(bp_part)
            new_bp = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - eta_tail * g.astype(jnp.float32)).astype(p.dtype),
                bp_part, grads)
            new_params = new_bp
            metrics = {"loss": loss, "zo_g": jnp.float32(0)}
            return TrainState(new_params, state.step + 1, state.seed), metrics

        def tail_loss(bp, zo_pert):
            return loss_fn(merge(zo_pert, bp), batch)

        has_tail = bool(bp_part) and lane.lane == "elastic_zo"
        new_zo = zo_part
        tail_grad = None
        loss_acc = jnp.float32(0)
        g_acc = jnp.float32(0)
        valid = jnp.maximum(jnp.sum(probe_mask), 1.0)

        zo_src = zo_part
        for i in range(n):
            pk = jax.random.fold_in(key, i)
            if paired_loss_fn is not None and has_tail:
                # fused antithetic pair: one layer traversal for both
                # probes; grad of the mean IS the averaged tail gradient.
                def f(bp, _zo=zo_src, _pk=pk):
                    lp_, lm_ = paired_loss_fn(bp, _zo, batch, _pk)
                    return 0.5 * (lp_ + lm_), (lp_, lm_)
                (_, (lp, lm)), g_tail_i = jax.value_and_grad(
                    f, has_aux=True)(bp_part)
                g_tail_i = jax.tree.map(
                    lambda x, m=probe_mask[i]: m * x.astype(jnp.float32),
                    g_tail_i)
                tail_grad = g_tail_i if tail_grad is None else jax.tree.map(
                    jnp.add, tail_grad, g_tail_i)
                g = zo.projected_gradient(lp, lm, lane.zo_eps, lane.zo_clip)
                g = g * probe_mask[i]
                new_zo = zo.zo_update(new_zo, pk, eta_zo * g / valid)
                loss_acc = loss_acc + 0.5 * (lp + lm) * probe_mask[i]
                g_acc = g_acc + jnp.abs(g)
                continue
            zp = zo.perturb(zo_src, pk, lane.zo_eps)
            if has_tail:
                lp, gp = jax.value_and_grad(tail_loss)(bp_part, zp)
                # sequence the minus pass after the plus pass so their
                # activation peaks don't overlap (MaxText-style barrier)
                zo_src, lp = jax.lax.optimization_barrier((zo_src, lp))
                zm = zo.perturb(zo_src, pk, -lane.zo_eps)
                lm, gm = jax.value_and_grad(tail_loss)(bp_part, zm)
                if lane.bp_grad_mode == "clean":
                    _, gc = jax.value_and_grad(tail_loss)(bp_part, zo_part)
                    g_tail_i = gc
                else:
                    g_tail_i = jax.tree.map(lambda a, b: (a + b) * 0.5, gp, gm)
                g_tail_i = jax.tree.map(
                    lambda x, m=probe_mask[i]: m * x.astype(jnp.float32),
                    g_tail_i)
                tail_grad = g_tail_i if tail_grad is None else jax.tree.map(
                    jnp.add, tail_grad, g_tail_i)
            else:
                lp = loss_fn(merge(zp, bp_part), batch)
                zo_src, lp = jax.lax.optimization_barrier((zo_src, lp))
                zm = zo.perturb(zo_src, pk, -lane.zo_eps)
                lm = loss_fn(merge(zm, bp_part), batch)
            g = zo.projected_gradient(lp, lm, lane.zo_eps, lane.zo_clip)
            g = g * probe_mask[i]
            # fused ZO update for this probe: theta <- theta - (eta*g/valid) z
            new_zo = zo.zo_update(new_zo, pk, eta_zo * g / valid)
            loss_acc = loss_acc + 0.5 * (lp + lm) * probe_mask[i]
            g_acc = g_acc + jnp.abs(g)

        if has_tail:
            tail_grad = jax.tree.map(lambda gt: gt / valid, tail_grad)
            new_bp = jax.tree.map(
                lambda p, gt: (p.astype(jnp.float32)
                               - eta_tail * gt.astype(jnp.float32)).astype(p.dtype),
                bp_part, tail_grad)
        else:
            new_bp = bp_part

        new_params = merge(new_zo, new_bp)
        metrics = {"loss": loss_acc / valid, "zo_g": g_acc / n}
        return TrainState(new_params, state.step + 1, state.seed), metrics

    return step
