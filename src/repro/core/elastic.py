"""ElasticZO (Alg. 1): ZO for the first C layers, BP for the last L-C.

Parameter partition is structural: the LM parameter tree stores the layer
stack as two period-stacks, ``periods_zo`` (first P-K periods) and
``periods_bp`` (last K periods). Lanes assign top-level groups:

  elastic_zo : ZO = {embed, pos_embed, encoder, periods_zo}
               BP = {periods_bp, final_norm, unembed}
  full_zo    : ZO = everything            (paper baseline, C = L)
  full_bp    : BP = everything            (paper baseline, C = 0)

The BP-tail gradient is taken at the *perturbed* points and averaged
(Alg. 1 keeps activations from the l+ and l- passes instead of running a
third forward; ``bp_grad_mode="clean"`` selects the third-pass variant).
Because only tail leaves are differentiated, XLA drops all head residuals
— the paper's memory claim, realized through DCE instead of manual buffer
management.

Multi-probe (n>1) antithetic SPSA with a runtime ``probe_mask`` implements
straggler mitigation: a dropped probe is masked out and the update is
renormalized by the surviving count — no recompile, no waiting
(docs/design.md §8).

This module is the fp32 *lane definition*: the partition and the
``TrainState``. The step itself — probe schedule, coeff transform, the
accumulate-then-cast ZO update, the tail SGD — is built by the
lane-polymorphic update engine (core/engine.py, docs/design.md §10),
which the fleet's ledger replay derives from as well.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax

from ..configs.base import LaneConfig
from .engine import Fp32Engine

ZO_GROUPS = ("embed", "pos_embed", "encoder", "periods_zo")
BP_GROUPS = ("periods_bp", "final_norm", "unembed")


class TrainState(NamedTuple):
    params: Any
    step: jax.Array            # i32 scalar
    seed: jax.Array            # uint32[2] base PRNG key data


def partition(params: Dict[str, Any], lane: LaneConfig):
    """Split the top-level param dict into (zo_part, bp_part)."""
    if lane.lane == "full_bp":
        return {}, dict(params)
    if lane.lane == "full_zo":
        return dict(params), {}
    zo_part = {k: v for k, v in params.items() if k in ZO_GROUPS}
    bp_part = {k: v for k, v in params.items() if k in BP_GROUPS}
    leftover = set(params) - set(zo_part) - set(bp_part)
    if leftover:
        raise ValueError(f"unpartitioned param groups: {sorted(leftover)}")
    return zo_part, bp_part


def merge(zo_part, bp_part):
    return {**zo_part, **bp_part}


def make_elastic_step(loss_fn: Callable[[Any, Any], jax.Array],
                      lane: LaneConfig,
                      partition_fn: Optional[Callable] = None,
                      paired_loss_fn: Optional[Callable] = None):
    """Build the ElasticZO train step (engine-built, fp32 numerics).

    loss_fn(params, batch) -> scalar fp32 (global mean under GSPMD).
    partition_fn(params) -> (zo_part, bp_part); defaults to the LM
    top-level-group partition. Returned step:
    (state, batch, probe_mask) -> (state, metrics).
    probe_mask: fp32[n_probes]; all-ones for a healthy fleet.
    """
    return Fp32Engine(lane, partition_fn,
                      paired_loss_fn=paired_loss_fn).make_step(loss_fn)
