"""Zeroth-order (SPSA) machinery with the MeZO seed-replay trick.

The perturbation ``z ~ N(0, I)`` is never materialized as a stored buffer:
it is regenerated from a per-step key every time it is needed (perturb +,
perturb -, update), exactly like Alg. 1's ``PerturbParameters`` /
``ZOUpdateParameters`` replaying a seed. Under XLA the RNG + add fuses into
a single elementwise pass over the parameters, so the ZO part of a step is
a pure read-modify-write stream of theta (1R + 1W of HBM traffic) — see
kernels/zo_perturb.py for the explicit Pallas version of the same op.

The projected gradient ``g = (l+ - l-)/(2 eps)`` is a *scalar*; in the
data-parallel setting it is the only thing the ZO part of the model ever
all-reduces (docs/design.md §2).
"""
from __future__ import annotations

import zlib
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import prng


def path_salt(path, prefix: str = "") -> int:
    return zlib.crc32((prefix + jax.tree_util.keystr(path)).encode()) \
        & 0x3FFFFFFF


def leaf_noise(key, path, leaf) -> jax.Array:
    """The z for one parameter leaf (fp32, cast at the use site).

    Counter-based hash noise (core/prng.py): shardable elementwise ops, so
    GSPMD never materializes a replicated full-size z, and the value is
    independent of the mesh (elastic-restart safe).
    """
    return prng.normal(prng.seed_from_key(key), path_salt(path), leaf.shape)


def perturb_slice(pparams, salts, sizes, p_idx, seed, scale):
    """Perturb one scanned layer-slice so it matches the stacked leaf's
    noise exactly: z_slice = z_stacked[p_idx] via the flat-index offset.

    pparams: this period's param slice; salts/sizes: static pytrees (crc32
    of the *stacked* leaf path, per-period flat size); p_idx: traced scan
    index; seed: uint32 scalar (prng.seed_from_key of the probe key).
    """
    def f(leaf, salt, size):
        off = p_idx.astype(jnp.uint32) * jnp.uint32(size)
        z = prng.normal(seed, salt, leaf.shape, offset=off)
        return (leaf.astype(jnp.float32) + scale * z).astype(leaf.dtype)
    return jax.tree.map(f, pparams, salts, sizes)


def perturb(params, key, scale: float | jax.Array):
    """theta + scale * z, z regenerated from `key` (leafwise)."""
    def f(path, leaf):
        z = leaf_noise(key, path, leaf)
        return (leaf.astype(jnp.float32) + scale * z).astype(leaf.dtype)
    return jax.tree_util.tree_map_with_path(f, params)


def zo_update(params, key, step_size):
    """theta - step_size * z  (z replayed from `key`). step_size may be a
    traced scalar (eta * g)."""
    def f(path, leaf):
        z = leaf_noise(key, path, leaf)
        return (leaf.astype(jnp.float32) - step_size * z).astype(leaf.dtype)
    return jax.tree_util.tree_map_with_path(f, params)


def projected_gradient(l_plus, l_minus, eps, clip: Optional[float] = None):
    g = (l_plus - l_minus) / (2.0 * eps)
    if clip is not None and clip > 0:
        g = jnp.clip(g, -clip, clip)
    return g


def spsa_gradient_estimate(loss_fn: Callable[[Any], jax.Array], params, key,
                           eps: float, clip: Optional[float] = None):
    """Reference two-point SPSA estimator (used by tests / Full-ZO lane).

    Returns (g, l_plus, l_minus); the caller applies ``zo_update`` with the
    same key.
    """
    l_plus = loss_fn(perturb(params, key, eps))
    l_minus = loss_fn(perturb(params, key, -eps))
    g = projected_gradient(l_plus, l_minus, eps, clip)
    return g, l_plus, l_minus
