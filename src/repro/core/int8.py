"""NITI-style int8 training substrate (ElasticZO-INT8, Alg. 2).

Tensors are ``QTensor``s: int8 data + int32 scaling exponent, representing
``data * 2^exp``. Matmuls/convs accumulate in int32 (TPU MXU-native; see
kernels/int8_matmul.py for the Pallas tile), activations are rescaled back
to 8 bits with NITI's dynamic-bitwidth rule, and updates use
pseudo-stochastic rounding where the discarded low bits of the value itself
act as the randomness source — fully deterministic, integer-only.

The ZO pieces follow Alg. 2 exactly:
  * perturbation: sparse uniform int8 noise z = m (.) u, m ~ Bern(1-p_zero),
    u ~ U(-r_max, r_max), replayed from a counter-based hash (core/prng.py)
    instead of stored;
  * ternary projected gradient g = sgn(l+ - l-) from integer logits
    (core/int_loss.py);
  * update: theta <- clamp(theta - psr(g*z, b_zo), -127, 127), in-place.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from . import prng


class QTensor(NamedTuple):
    data: jax.Array            # int8
    exp: jax.Array             # int32 scalar


def qtensor(data, exp):
    return QTensor(jnp.asarray(data, jnp.int8), jnp.asarray(exp, jnp.int32))


def dequant(q: QTensor) -> jax.Array:
    return q.data.astype(jnp.float32) * jnp.exp2(q.exp.astype(jnp.float32))


def quant_from_float(x, bits=7):
    """Quantize fp32 -> QTensor with max-|x| scaling (init / input path)."""
    m = jnp.max(jnp.abs(x))
    m = jnp.maximum(m, 1e-30)
    exp = jnp.ceil(jnp.log2(m)) - bits
    data = jnp.clip(jnp.round(x / jnp.exp2(exp)), -127, 127).astype(jnp.int8)
    return QTensor(data, exp.astype(jnp.int32))


# ------------------------------------------------------------------ #
# pseudo-stochastic rounding (NITI §IV): the bits below the cut are the
# randomness; E[psr(x, s)] = x / 2^s.
# ------------------------------------------------------------------ #
def psr_shift(x: jax.Array, s: jax.Array) -> jax.Array:
    """Round x (int32) right by s bits, pseudo-stochastically."""
    s = jnp.asarray(s, jnp.int32)
    sign = jnp.sign(x)
    mag = jnp.abs(x)
    base = jax.lax.shift_right_logical(mag, s)
    rem = mag - jax.lax.shift_left(base, s)
    # hash the remainder to get the pseudo-random threshold
    h = (rem.astype(jnp.uint32) * np.uint32(0x9E3779B9)) ^ mag.astype(jnp.uint32)
    h = h ^ (h >> np.uint32(16))
    thresh = jax.lax.shift_right_logical(
        h, jnp.asarray(32, jnp.uint32) - s.astype(jnp.uint32)).astype(jnp.int32)
    up = (thresh < rem).astype(jnp.int32)
    out = jnp.where(s > 0, base + up, mag)
    return sign * out


def bitwidth(x_max: jax.Array) -> jax.Array:
    """floor(log2(max)) + 1 via integer compares (no float ops)."""
    x_max = jnp.maximum(x_max.astype(jnp.int32), 1)
    b = jnp.zeros((), jnp.int32)
    for k in range(31):
        b = b + (x_max >= (1 << k)).astype(jnp.int32)
    return b


def rescale_int32(acc: jax.Array, exp: jax.Array) -> QTensor:
    """NITI forward rescale: int32 accumulator -> int8 + adjusted exponent."""
    b = bitwidth(jnp.max(jnp.abs(acc)))
    shift = jnp.maximum(b - 7, 0)
    data = jnp.clip(psr_shift(acc, shift), -127, 127).astype(jnp.int8)
    return QTensor(data, exp + shift)


# ------------------------------------------------------------------ #
# int8 compute ops (XLA path; kernels/ops.py dispatches the Pallas twin)
# ------------------------------------------------------------------ #
def int8_matmul(a: jax.Array, w: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 (a: [..., K], w: [K, N])."""
    return jax.lax.dot_general(
        a.astype(jnp.int32), w.astype(jnp.int32),
        (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def qdense(x: QTensor, w: QTensor) -> QTensor:
    acc = int8_matmul(x.data, w.data)
    return rescale_int32(acc, x.exp + w.exp)


def qconv2d(x: QTensor, w: QTensor, stride=1) -> QTensor:
    """int8 conv via im2col + int8 GEMM (TPU adaptation, docs/design.md §4).

    x: [B,H,W,C] int8; w: [kh,kw,C,O] int8.
    """
    kh, kw, C, O = w.data.shape
    B, H, W, _ = x.data.shape
    Ho, Wo = (H - kh) // stride + 1, (W - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            patches.append(jax.lax.slice(
                x.data, (0, i, j, 0),
                (B, i + Ho * stride, j + Wo * stride, C),
                (1, stride, stride, 1)))
    col = jnp.stack(patches, axis=3).reshape(B, Ho, Wo, kh * kw * C)
    acc = int8_matmul(col, w.data.reshape(kh * kw * C, O))
    return rescale_int32(acc, x.exp + w.exp)


def qrelu(x: QTensor) -> QTensor:
    return QTensor(jnp.maximum(x.data, 0), x.exp)


def qmaxpool2(x: QTensor) -> QTensor:
    d = x.data
    B, H, W, C = d.shape
    d = d.reshape(B, H // 2, 2, W // 2, 2, C).max(axis=(2, 4))
    return QTensor(d, x.exp)


def qglobal_maxpool(x: QTensor, axis=1) -> QTensor:
    return QTensor(jnp.max(x.data, axis=axis), x.exp)


# ------------------------------------------------------------------ #
# ZO perturbation / update (Alg. 2 lines 12-24)
# ------------------------------------------------------------------ #
def int8_noise(seed: jax.Array, salt: int, shape,
               r_max: int, p_zero: jax.Array) -> jax.Array:
    """Sparse uniform int8 perturbation z = m (.) u (replayable)."""
    bits_u = prng.uniform_bits(seed, 3 * np.uint32(salt) + np.uint32(1), shape)
    bits_m = prng.uniform_bits(seed, 3 * np.uint32(salt) + np.uint32(2), shape)
    u = (bits_u % np.uint32(2 * r_max + 1)).astype(jnp.int32) - r_max
    keep_thresh = ((1.0 - p_zero) * (2.0 ** 32)).astype(jnp.float32)
    m = (bits_m.astype(jnp.float32) < keep_thresh).astype(jnp.int32)
    return (u * m).astype(jnp.int32)


def perturb_int8(params, seed, k: int, r_max: int, p_zero) -> Any:
    """theta <- clamp(theta + k*z, -127, 127) on every QTensor leaf."""
    def f(path, leaf):
        if not isinstance(leaf, QTensor):
            return leaf
        import zlib
        salt = zlib.crc32(jax.tree_util.keystr(path).encode()) & 0x3FFFFFFF
        z = int8_noise(seed, salt, leaf.data.shape, r_max, p_zero)
        d = jnp.clip(leaf.data.astype(jnp.int32) + k * z, -127, 127)
        return QTensor(d.astype(jnp.int8), leaf.exp)
    return jax.tree_util.tree_map_with_path(
        f, params, is_leaf=lambda x: isinstance(x, QTensor))


def zo_update_int8(params, seed, g, r_max: int, p_zero, b_zo: int) -> Any:
    """theta <- clamp(theta - psr(g*z, b_zo), -127, 127) (Alg. 2 line 23-24)."""
    shift = jnp.maximum(bitwidth(jnp.asarray(r_max)) - b_zo, 0)

    def f(path, leaf):
        if not isinstance(leaf, QTensor):
            return leaf
        import zlib
        salt = zlib.crc32(jax.tree_util.keystr(path).encode()) & 0x3FFFFFFF
        z = int8_noise(seed, salt, leaf.data.shape, r_max, p_zero)
        upd = psr_shift(g * z, shift)
        d = jnp.clip(leaf.data.astype(jnp.int32) - upd, -127, 127)
        return QTensor(d.astype(jnp.int8), leaf.exp)
    return jax.tree_util.tree_map_with_path(
        f, params, is_leaf=lambda x: isinstance(x, QTensor))


# ------------------------------------------------------------------ #
# int8 backward for FC tails (NITI backward, used by ElasticZO-INT8's BP part)
# ------------------------------------------------------------------ #
def output_error_int8(logits: QTensor, labels: jax.Array) -> jax.Array:
    """e_L ~ softmax - onehot, quantized to int8 range [-127,127] (int32).

    NITI approximates the softmax gradient in integer arithmetic; we use the
    same power-of-two trick as the loss (core/int_loss.py) to get integer
    pseudo-probabilities.
    """
    from .int_loss import pow2_scores
    scores = pow2_scores(logits)               # int32 [B, C], <= 2^10
    tot = jnp.sum(scores, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(labels, logits.data.shape[-1], dtype=jnp.int32)
    # e = 127 * (p - y); p ~ scores/tot
    e = (127 * scores) // jnp.maximum(tot, 1) - 127 * onehot
    return jnp.clip(e, -127, 127)


def fc_backward_int8(w: QTensor, a_in: QTensor, e_out: jax.Array,
                     b_bp: int) -> Tuple[QTensor, jax.Array]:
    """One FC layer's NITI backward: returns (updated w, e_in int32[-127,127]).

    e_out: int32 in int8 range. Gradient g = a_in^T e_out (int32), rounded to
    b_bp bits; update applied in the weight's own scale (exponent fixed).
    """
    g = jax.lax.dot_general(
        a_in.data.astype(jnp.int32), e_out.astype(jnp.int32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    b = bitwidth(jnp.max(jnp.abs(g)))
    shift = jnp.maximum(b - b_bp, 0)
    upd = psr_shift(g, shift)
    new_w = QTensor(jnp.clip(w.data.astype(jnp.int32) - upd,
                             -127, 127).astype(jnp.int8), w.exp)
    e_in = jax.lax.dot_general(
        e_out.astype(jnp.int32), w.data.astype(jnp.int32),
        (((e_out.ndim - 1,), (1,)), ((), ())), preferred_element_type=jnp.int32)
    b_e = bitwidth(jnp.max(jnp.abs(e_in)))
    e_in = psr_shift(e_in, jnp.maximum(b_e - 7, 0))
    return new_w, jnp.clip(e_in, -127, 127)
