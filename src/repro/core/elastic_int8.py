"""ElasticZO-INT8 train step (Alg. 2): integer-only hybrid ZO/BP training.

Works on any model exposing ``forward_int8(params, x) -> (logits QTensor,
acts)`` whose BP tail consists of FC layers (the paper's configuration:
ZO-Feat-Cls1/2 put only the last 1-2 FC layers in the BP part).

``loss_mode``:
  "int"   — ternary g = sgn(L+ - L-) from integer logits (INT8*, Eq. 7-12)
  "float" — g = sgn of the fp32 loss difference (the paper's INT8 column)
"""
from __future__ import annotations

from typing import Callable, Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import LaneConfig
from . import prng
from .elastic import TrainState
from .int8 import (QTensor, fc_backward_int8, output_error_int8,
                   perturb_int8, zo_update_int8)
from .int_loss import float_loss, int_loss_sign


def make_int8_elastic_step(forward: Callable, partition_fn: Callable,
                           tail_fcs: List[Tuple[str, str]],
                           lane: LaneConfig, loss_mode: str = "int",
                           p_zero: float | None = None):
    """tail_fcs: [(layer_name, act_key)] in forward order, e.g.
    [("fc2", "fc2_in"), ("fc3", "fc3_in")] — the BP part (C..L)."""
    r_max = lane.int8_r_max
    pz = lane.int8_p_zero if p_zero is None else p_zero

    def step(state: TrainState, batch, probe_mask):
        params = state.params
        zo_part, bp_part = partition_fn(params)
        base = jax.random.wrap_key_data(state.seed)
        seed = prng.seed_from_key(jax.random.fold_in(base, state.step))
        pzero = jnp.float32(pz)

        # functional +/- perturbation (the paper's in-place +1/-2/+1 replay
        # sequence, minus the double-clamp asymmetry; docs/design.md §9)
        zo_p = perturb_int8(zo_part, seed, +1, r_max, pzero)
        logits_p, acts_p = forward({**zo_p, **bp_part}, batch["x"])
        zo_m = perturb_int8(zo_part, seed, -1, r_max, pzero)
        logits_m, _ = forward({**zo_m, **bp_part}, batch["x"])

        if loss_mode == "int":
            g = int_loss_sign(logits_p, logits_m, batch["y"])
        else:
            lf_p = float_loss(logits_p, batch["y"])
            lf_m = float_loss(logits_m, batch["y"])
            g = jnp.sign(lf_p - lf_m).astype(jnp.int32)

        new_zo = zo_update_int8(zo_part, seed, g, r_max, pzero, lane.int8_b_zo)

        # --- BP tail (NITI backward over the last FC layers) ----------- #
        new_bp = dict(bp_part)
        if tail_fcs:
            e = output_error_int8(logits_p, batch["y"])
            for name, act_key in reversed(tail_fcs):
                w = bp_part[name]["w"]
                a_in: QTensor = acts_p[act_key]
                new_w, e = fc_backward_int8(w, a_in, e, lane.int8_b_bp)
                new_bp[name] = {"w": new_w}
                # relu mask for the propagated error (pre-activation of the
                # previous layer is >0 exactly where its output is >0)
                e = e * (a_in.data.astype(jnp.int32) > 0)

        metrics = {
            "loss": float_loss(logits_p, batch["y"]),
            "g": g.astype(jnp.float32),
            "acc": jnp.mean((jnp.argmax(logits_p.data, -1) ==
                             batch["y"]).astype(jnp.float32)),
        }
        return TrainState({**new_zo, **new_bp}, state.step + 1, state.seed), metrics

    return step


def int8_eval(forward: Callable, params, x: QTensor, y) -> jax.Array:
    logits, _ = forward(params, x)
    return jnp.mean((jnp.argmax(logits.data, -1) == y).astype(jnp.float32))
