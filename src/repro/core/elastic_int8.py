"""ElasticZO-INT8 train step (Alg. 2): integer-only hybrid ZO/BP training.

Works on any model exposing ``forward_int8(params, x) -> (logits QTensor,
acts)`` whose BP tail consists of FC layers (the paper's configuration:
ZO-Feat-Cls1/2 put only the last 1-2 FC layers in the BP part).

``loss_mode``:
  "int"   — ternary g = sgn(L+ - L-) from integer logits (INT8*, Eq. 7-12)
  "float" — g = sgn of the fp32 loss difference (the paper's INT8 column)

This module is the int8 *lane definition*; the step is built by the
update engine's int8 numerics plugin (core/engine.py, docs/design.md
§10): per-probe keys ``fold_in(fold_in(base, step), probe_id)`` (the
fleet's global probe schedule), int32 accumulate-then-clamp ZO update,
NITI tail combined as a saturating int8 sum — the identical arithmetic
the fleet's int8 ledger replay applies.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import LaneConfig
from .engine import Int8Engine
from .int8 import QTensor


def make_int8_elastic_step(forward: Callable, partition_fn: Callable,
                           tail_fcs: List[Tuple[str, str]],
                           lane: LaneConfig, loss_mode: str = "int",
                           p_zero: float | None = None):
    """tail_fcs: [(layer_name, act_key)] in forward order, e.g.
    [("fc2", "fc2_in"), ("fc3", "fc3_in")] — the BP part (C..L)."""
    return Int8Engine(lane, partition_fn, tail_fcs=tail_fcs,
                      loss_mode=loss_mode, p_zero=p_zero).make_step(forward)


def int8_eval(forward: Callable, params, x: QTensor, y) -> jax.Array:
    logits, _ = forward(params, x)
    return jnp.mean((jnp.argmax(logits.data, -1) == y).astype(jnp.float32))
