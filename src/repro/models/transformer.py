"""LM stacks: decoder-only (dense/MoE/SSM/hybrid), enc-dec (Whisper), VLM.

Layout: params = {embed, periods (stacked, leading dim = num_periods),
final_norm, unembed [, pos_embed, encoder]}. The layer stack runs as a
``lax.scan`` over periods; a period is one repetition of
``cfg.block_pattern`` (1 layer for uniform archs, 8 for Jamba). Caches ride
the scan as xs/ys. docs/design.md §7 explains the cost-extrapolation contract:
the scan body is identical at any depth, so the dry-run can compile
depth-2/depth-4 variants to recover exact per-layer costs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ATTN, MAMBA, RWKV, ModelConfig
from .layers import (attention, dense_init, init_attention, init_mlp, mlp,
                     rms_norm, subkey)
from .moe import init_moe, moe_ffn
from .ssm import (init_mamba_block, init_mamba_state, init_rwkv_block,
                  init_rwkv_state, mamba_block, rwkv_block)

CE_CHUNKS = 4            # sequence chunks for the cross-entropy epilogue


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def _ffn_is_moe(cfg: ModelConfig, pos_in_period: int) -> bool:
    return cfg.is_moe and (pos_in_period % cfg.moe_every == cfg.moe_offset)


def init_block(key, cfg: ModelConfig, kind: str, pos: int, dtype,
               cross_attn: bool = False):
    d = cfg.d_model
    if kind == RWKV:
        return {"rwkv": init_rwkv_block(subkey(key, "rwkv"), cfg, dtype)}
    p: Dict[str, Any] = {}
    if kind == ATTN:
        p["ln_attn"] = jnp.ones((d,), dtype)
        p["attn"] = init_attention(subkey(key, "attn"), cfg, dtype)
        if cross_attn:
            p["ln_cross"] = jnp.ones((d,), dtype)
            p["cross"] = init_attention(subkey(key, "cross"), cfg, dtype)
    else:  # MAMBA
        p["mamba"] = init_mamba_block(subkey(key, "mamba"), cfg, dtype)
    p["ln_ffn"] = jnp.ones((d,), dtype)
    if _ffn_is_moe(cfg, pos):
        p["moe"] = init_moe(subkey(key, "moe"), cfg, dtype)
    else:
        p["mlp"] = init_mlp(subkey(key, "mlp"), d, cfg.d_ff, dtype)
    return p


def init_period(key, cfg: ModelConfig, dtype, cross_attn=False):
    return {f"blk{i}": init_block(subkey(key, i), cfg, kind, i, dtype, cross_attn)
            for i, kind in enumerate(cfg.pattern)}


def init_lm(key, cfg: ModelConfig, max_seq: int, dtype=None):
    """Full parameter tree. Usable under jax.eval_shape for the dry-run."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    d, Vp = cfg.d_model, cfg.padded_vocab
    periods = jax.vmap(
        lambda k: init_period(k, cfg, dtype, cross_attn=cfg.encoder_layers > 0)
    )(jax.random.split(subkey(key, "periods"), cfg.num_periods))
    params = {
        "embed": dense_init(subkey(key, "embed"), (Vp, d), dtype),
        "periods": periods,
        "final_norm": jnp.ones((d,), dtype),
        "unembed": dense_init(subkey(key, "unembed"), (d, Vp), dtype),
    }
    if cfg.rope_theta <= 0:                      # learned absolute positions
        params["pos_embed"] = dense_init(subkey(key, "pos"), (max_seq, d), dtype)
    if cfg.encoder_layers:
        enc_cfg = dataclasses.replace(cfg, block_pattern=(ATTN,),
                                      num_experts=0, sliding_window=0)
        params["encoder"] = {
            "pos_embed": dense_init(subkey(key, "encpos"),
                                    (cfg.encoder_seq, d), dtype),
            "periods": jax.vmap(
                lambda k: init_period(k, enc_cfg, dtype)
            )(jax.random.split(subkey(key, "enc"), cfg.encoder_layers)),
            "final_norm": jnp.ones((d,), dtype),
        }
    return params


# --------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------- #
def apply_block(p, x, cfg: ModelConfig, kind: str, pos: int, rules, *,
                positions, mode: str, cache=None, cache_len=None,
                enc_out=None, cross_cache=None, causal: bool = True,
                paged=None, full_kv: bool = False):
    """Returns (x, new_cache_entry).

    paged: (page_table, seq_lens) — decode against the paged KV pool
    (serve subsystem); full_kv: prefill returns the un-rolled full-length
    KV even for SWA archs (the paged pool stores absolute positions and
    applies the window as a mask instead of a ring buffer).
    """
    if kind == RWKV:
        state = cache if mode == "decode" else None
        x, st = rwkv_block(p["rwkv"], x, cfg, rules, state)
        return x, (st if mode in ("decode", "prefill") else None)

    new_cache: Dict[str, Any] = {}
    if kind == ATTN:
        h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
        window = cfg.sliding_window
        if mode == "decode":
            y, kv = attention(p["attn"], h, cfg, rules, positions,
                              causal=True, window=window,
                              cache=(cache["k"], cache["v"]),
                              cache_len=cache_len, paged=paged)
            new_cache.update(k=kv[0], v=kv[1])
        else:
            y, kv = attention(p["attn"], h, cfg, rules, positions,
                              causal=causal,
                              window=window, write_cache=(mode == "prefill"))
            if mode == "prefill":
                k, v = kv
                if window and k.shape[1] > window and not full_kv:
                    p0 = k.shape[1] - window         # ring-align SWA cache
                    k = jnp.roll(k[:, -window:], p0 % window, axis=1)
                    v = jnp.roll(v[:, -window:], p0 % window, axis=1)
                new_cache.update(k=k, v=v)
        x = x + y
        if "ln_cross" in p:                          # decoder cross-attention
            h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
            if mode == "decode":
                kv_o = (cross_cache["ck"], cross_cache["cv"])
                new_cache.update(ck=kv_o[0], cv=kv_o[1])
            else:
                kv_o = _cross_kv(p["cross"], enc_out, cfg, rules)
                if mode == "prefill":
                    new_cache.update(ck=kv_o[0], cv=kv_o[1])
            y, _ = attention(p["cross"], h, cfg, rules, positions,
                             causal=False, kv_override=kv_o)
            x = x + y
    else:                                            # MAMBA
        state = cache if mode == "decode" else None
        x, st = mamba_block(p["mamba"], x, cfg, rules, state)
        if mode in ("decode", "prefill"):
            new_cache.update(st)

    h = rms_norm(x, p["ln_ffn"], cfg.norm_eps)
    y = moe_ffn(p["moe"], h, cfg, rules) if "moe" in p else mlp(p["mlp"], h, rules)
    x = rules.act_btd(x + y)
    return x, (new_cache if mode in ("decode", "prefill") else None)


def _cross_kv(p, enc_out, cfg: ModelConfig, rules):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    dup = rules.attn.kv_dup if rules.attn.kind == "tp" else 1
    if dup > 1:
        k = jnp.repeat(k, dup, axis=2)
        v = jnp.repeat(v, dup, axis=2)
    return k, v


# --------------------------------------------------------------------- #
# period stack (scan)
# --------------------------------------------------------------------- #
def run_periods(periods, x, cfg: ModelConfig, rules, *, positions, mode,
                caches=None, cache_len=None, enc_out=None, remat=True,
                pattern=None, unroll=False, paged=None, full_kv=False):
    """Scan the period stack. caches: stacked pytree (leading dim = periods).

    ``unroll=True`` replaces the lax.scan with a python loop over period
    slices — used by the dry-run depth variants so ``cost_analysis`` counts
    every layer (scan bodies are costed once; docs/design.md §7).
    ``paged``/``full_kv`` ride through to apply_block (serve subsystem);
    the page table is shared by every layer, so it is closed over rather
    than scanned.
    """
    pattern = pattern or cfg.pattern

    def body(carry, xs):
        h = carry
        pparams, pcache = xs
        new_caches = []
        for i, kind in enumerate(pattern):
            ci = None if pcache is None else pcache[i]
            h, nc = apply_block(
                pparams[f"blk{i}"], h, cfg, kind, i, rules,
                positions=positions, mode=mode, cache=ci,
                cache_len=cache_len, enc_out=enc_out, cross_cache=ci,
                paged=paged, full_kv=full_kv)
            new_caches.append(nc)
        out_c = tuple(new_caches) if mode in ("decode", "prefill") else None
        return h, out_c

    if remat and mode == "train":
        body = jax.checkpoint(body)

    if unroll:
        n = jax.tree.leaves(periods)[0].shape[0]
        outs = []
        for p_idx in range(n):
            xs_i = (jax.tree.map(lambda a: a[p_idx], periods),
                    None if caches is None
                    else jax.tree.map(lambda a: a[p_idx], caches))
            x, out_c = body(x, xs_i)
            outs.append(out_c)
        if mode in ("decode", "prefill"):
            new_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *outs)
        else:
            new_caches = None
        return x, new_caches

    xs = (periods, caches)
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches


def run_periods_paired(periods, x_pair, cfg: ModelConfig, rules, *,
                       positions, seed, eps, salts, sizes, remat=True,
                       unroll=False, enc_pair=(None, None)):
    """Fused antithetic forward (§Perf iteration): advance the theta+eps*z
    and theta-eps*z probes through the layer stack *together*, so each
    layer's FSDP weight all-gather is paid once for both passes.

    Exactness: the per-slice noise equals the stacked-leaf noise by the
    flat-offset property of core/prng.py, so the losses are bitwise the
    math of the unfused path (up to fp reassociation). Train mode only.
    """
    from ..core import zo as zo_mod
    pattern = cfg.pattern

    def one(h, pparams, enc_out):
        for i, kind in enumerate(pattern):
            h, _ = apply_block(pparams[f"blk{i}"], h, cfg, kind, i, rules,
                               positions=positions, mode="train",
                               enc_out=enc_out)
        return h

    def body(carry, xs):
        hp, hm = carry
        pparams, p_idx = xs
        if rules.strategy == "fsdp" and rules.mesh is not None:
            # gather each layer's weights ONCE (replicated), then derive the
            # +/- perturbed copies locally — this is the whole point of the
            # fused pair: without it GSPMD gathers both perturbed copies.
            pparams = jax.tree.map(
                lambda a: rules.wsc(a, *((None,) * a.ndim)), pparams)
        pp = zo_mod.perturb_slice(pparams, salts, sizes, p_idx, seed, eps)
        hp = one(hp, pp, enc_pair[0])
        pm = zo_mod.perturb_slice(pparams, salts, sizes, p_idx, seed, -eps)
        hm = one(hm, pm, enc_pair[1])
        return (hp, hm), None

    if remat:
        body = jax.checkpoint(body)
    n = jax.tree.leaves(periods)[0].shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    if unroll:
        for i in range(n):
            x_pair, _ = body(x_pair, (jax.tree.map(lambda a: a[i], periods),
                                      jnp.int32(i)))
        return x_pair
    x_pair, _ = jax.lax.scan(body, x_pair, (periods, idx))
    return x_pair


# --------------------------------------------------------------------- #
# embedding / head
# --------------------------------------------------------------------- #
def embed(params, tokens, cfg: ModelConfig, rules, positions,
          img_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    if "pos_embed" in params:
        x = x + jnp.take(params["pos_embed"], positions, axis=0)
    return rules.act_btd(x)


def run_encoder(params, frames, cfg: ModelConfig, rules, unroll=False):
    enc = params["encoder"]
    x = frames + enc["pos_embed"][None, :frames.shape[1]]
    x = rules.act_btd(x.astype(frames.dtype))
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1], dtype=jnp.int32),
                           frames.shape[:2])
    enc_cfg = dataclasses.replace(cfg, block_pattern=(ATTN,), num_experts=0,
                                  sliding_window=0, rope_theta=0.0)

    def body(h, pparams):
        h, _ = apply_block(pparams["blk0"], h, enc_cfg, ATTN, 0, rules,
                           positions=pos, mode="encode", causal=False)
        return h, None

    if unroll:
        n = jax.tree.leaves(enc["periods"])[0].shape[0]
        for i in range(n):
            x, _ = body(x, jax.tree.map(lambda a: a[i], enc["periods"]))
    else:
        x, _ = jax.lax.scan(body, x, enc["periods"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def head_logits(params, x, cfg: ModelConfig, rules):
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["unembed"])
    return rules.logits(logits)


def lm_loss(params, x, labels, mask, cfg: ModelConfig, rules):
    """Chunked CE over the (vocab-sharded) logits. Returns scalar fp32."""
    B, S, _ = x.shape
    Vp = cfg.padded_vocab
    n = CE_CHUNKS if S % CE_CHUNKS == 0 and S >= CE_CHUNKS else 1
    c = S // n
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    tot = jnp.float32(0)
    cnt = jnp.float32(0)
    for i in range(n):
        hc = jax.lax.slice_in_dim(h, i * c, (i + 1) * c, axis=1)
        yc = jax.lax.slice_in_dim(labels, i * c, (i + 1) * c, axis=1)
        mc = jax.lax.slice_in_dim(mask, i * c, (i + 1) * c, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", hc, params["unembed"])
        logits = rules.logits(logits).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(yc, Vp, dtype=logits.dtype)
        ll = jnp.sum(logits * onehot, axis=-1)
        tot = tot + jnp.sum((logz - ll) * mc)
        cnt = cnt + jnp.sum(mc)
    return tot / jnp.maximum(cnt, 1.0)


# --------------------------------------------------------------------- #
# cache construction
# --------------------------------------------------------------------- #
def make_caches(cfg: ModelConfig, B: int, seq_len: int, rules, dtype=None):
    """Zero caches, stacked [periods, ...], matching run_periods xs layout."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    dup = rules.attn.kv_dup if rules.attn.kind == "tp" else 1
    KVd = cfg.num_kv_heads * dup
    T = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    per_period = []
    for i, kind in enumerate(cfg.pattern):
        if kind == ATTN:
            entry = {"k": jnp.zeros((B, T, KVd, cfg.head_dim), dtype),
                     "v": jnp.zeros((B, T, KVd, cfg.head_dim), dtype)}
            if cfg.encoder_layers:
                entry["ck"] = jnp.zeros((B, cfg.encoder_seq, KVd, cfg.head_dim), dtype)
                entry["cv"] = jnp.zeros((B, cfg.encoder_seq, KVd, cfg.head_dim), dtype)
        elif kind == MAMBA:
            entry = init_mamba_state(cfg, B, dtype)
        else:
            entry = init_rwkv_state(cfg, B, dtype)
        per_period.append(entry)
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_periods,) + a.shape).copy(),
        tuple(per_period))
    return stacked


def make_paged_caches(cfg: ModelConfig, slots: int, num_pages: int,
                      page_size: int, rules, dtype=None):
    """Paged serve caches, same pytree structure as ``make_caches``.

    Attention KV lives in a global page pool [periods, num_pages, page_size,
    KVd, Dh] shared by all sequences (page 0 is the reserved null page);
    recurrent (mamba/rwkv) state and cross-attention KV are O(1)-per-token
    or fixed-size, so they stay dense per slot: [periods, slots, ...].
    """
    dtype = dtype or jnp.dtype(cfg.dtype)
    dup = rules.attn.kv_dup if rules.attn.kind == "tp" else 1
    KVd = cfg.num_kv_heads * dup
    per_period = []
    for i, kind in enumerate(cfg.pattern):
        if kind == ATTN:
            entry = {
                "k": jnp.zeros((num_pages, page_size, KVd, cfg.head_dim),
                               dtype),
                "v": jnp.zeros((num_pages, page_size, KVd, cfg.head_dim),
                               dtype)}
            if cfg.encoder_layers:
                entry["ck"] = jnp.zeros((slots, cfg.encoder_seq, KVd,
                                         cfg.head_dim), dtype)
                entry["cv"] = jnp.zeros((slots, cfg.encoder_seq, KVd,
                                         cfg.head_dim), dtype)
        elif kind == MAMBA:
            entry = init_mamba_state(cfg, slots, dtype)
        else:
            entry = init_rwkv_state(cfg, slots, dtype)
        per_period.append(entry)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_periods,) + a.shape).copy(),
        tuple(per_period))
