"""PointNet classifier as the paper uses it (Fig. 1 bottom): five pointwise
FC layers (64,64,64,128,1024) + global max-pool + 3-layer head (512,256,nc).
No T-Nets (the paper's 816k-parameter variant). fp32 and NITI-int8 paths.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..configs.paper_models import PointNetConfig
from ..core.int8 import (QTensor, qdense, qglobal_maxpool, qrelu,
                         quant_from_float)
from .layers import dense_init, subkey

FEAT = ("feat0", "feat1", "feat2", "feat3", "feat4")
HEAD = ("head0", "head1", "cls")
LAYER_NAMES = FEAT + HEAD


def init_pointnet(key, cfg: PointNetConfig = PointNetConfig(),
                  dtype=jnp.float32):
    dims = (3,) + cfg.feat_dims
    p = {}
    for i in range(5):
        p[f"feat{i}"] = {"w": dense_init(subkey(key, f"f{i}"),
                                         (dims[i], dims[i + 1]), dtype),
                         "b": jnp.zeros((dims[i + 1],), dtype)}
    hdims = (cfg.feat_dims[-1],) + cfg.head_dims + (cfg.num_classes,)
    for i, n in enumerate(HEAD):
        p[n] = {"w": dense_init(subkey(key, n), (hdims[i], hdims[i + 1]), dtype),
                "b": jnp.zeros((hdims[i + 1],), dtype)}
    return p


def pointnet_forward(params, pts):
    """pts: [B,N,3] -> (logits [B,nc], acts)."""
    acts = {}
    h = pts
    for n in FEAT:
        h = jax.nn.relu(h @ params[n]["w"] + params[n]["b"])
    h = jnp.max(h, axis=1)                       # global feature [B,1024]
    for n in HEAD[:-1]:
        acts[f"{n}_in"] = h
        h = jax.nn.relu(h @ params[n]["w"] + params[n]["b"])
    acts["cls_in"] = h
    logits = h @ params["cls"]["w"] + params["cls"]["b"]
    return logits, acts


def pointnet_loss(params, batch):
    logits, _ = pointnet_forward(params, batch["x"])
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - ll)


def partition_at(params: Dict, c: int):
    zo = {n: params[n] for n in LAYER_NAMES[:c]}
    bp = {n: params[n] for n in LAYER_NAMES[c:]}
    return zo, bp


# ------------------------------------------------------------------ #
def init_pointnet_int8(key, cfg: PointNetConfig = PointNetConfig()):
    fp = init_pointnet(key, cfg)
    return {n: {"w": quant_from_float(fp[n]["w"], bits=6)}
            for n in LAYER_NAMES}


def pointnet_forward_int8(params, pts: QTensor):
    acts = {}
    h = pts
    for n in FEAT:
        h = qrelu(qdense(h, params[n]["w"]))
    h = qglobal_maxpool(h, axis=1)
    for n in HEAD[:-1]:
        acts[f"{n}_in"] = h
        h = qrelu(qdense(h, params[n]["w"]))
    acts["cls_in"] = h
    logits = qdense(h, params["cls"]["w"])
    return logits, acts
