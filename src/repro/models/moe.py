"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Dispatch is gather/scatter-based (megablocks/MaxText-style), never
materializing a [tokens, E, C] one-hot:

  1. top-k routing -> (expert_id, gate) per slot (k slots per token)
  2. stable argsort slots by expert id; position-in-expert via a
     running-start cummax trick; slots beyond capacity C are dropped
  3. expert buffers [B, E, C, D] built by batched scatter of slot ids,
     then a gather of token vectors
  4. batched expert SwiGLU: einsum('becd,edf->becf') — one MXU call for
     all experts
  5. combine: gather each slot's output row, unsort, weighted sum over k

Sharding plans (rules.moe):
  "ep": expert dim sharded over `model` (GSPMD inserts the all-to-all);
  "tp": d_ff sharded over `model`, experts resident on every chip
        (for E % tp != 0, e.g. mixtral 8e on tp=16).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init, subkey


def init_moe(key, cfg: ModelConfig, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": dense_init(subkey(key, "router"), (d, E), jnp.float32),
        "w_gate": dense_init(subkey(key, "wg"), (E, d, ff), dtype),
        "w_up": dense_init(subkey(key, "wu"), (E, d, ff), dtype),
        "w_down": dense_init(subkey(key, "wd"), (E, ff, d), dtype, fan_in=ff),
    }


def capacity(cfg: ModelConfig, S: int) -> int:
    c = int(math.ceil(S * cfg.experts_per_token * cfg.capacity_factor
                      / cfg.num_experts))
    return max(c, 1)


def moe_ffn(p, x, cfg: ModelConfig, rules):
    """x: [B, S, D] -> [B, S, D]. Group = one sequence (capacity per seq)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = capacity(cfg, S)
    nslot = S * K

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_i = jax.lax.top_k(gates, K)                  # [B,S,K]
    top_g = top_g / jnp.sum(top_g, axis=-1, keepdims=True)

    slot_e = top_i.reshape(B, nslot)                        # expert per slot
    slot_g = top_g.reshape(B, nslot)

    # --- position-in-expert (per group) via stable sort ---------------- #
    sort_idx = jnp.argsort(slot_e, axis=1, stable=True)     # [B, nslot]
    sorted_e = jnp.take_along_axis(slot_e, sort_idx, axis=1)
    ar = jnp.arange(nslot, dtype=jnp.int32)[None, :]
    is_start = jnp.concatenate(
        [jnp.ones((B, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    run_start = jax.lax.cummax(jnp.where(is_start, ar, 0), axis=1)
    pos = ar - run_start                                    # position within expert
    keep = pos < C
    dest = sorted_e * C + jnp.where(keep, pos, 0)           # [B, nslot] in [0, E*C)

    # --- build expert buffers ------------------------------------------ #
    # inverse map: which slot fills buffer cell (e, c)?  sentinel = nslot
    binv = jnp.full((B, E * C), nslot, dtype=jnp.int32)
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, nslot))
    # dropped slots are routed to an out-of-bounds column and discarded
    binv = binv.at[bidx, jnp.where(keep, dest, E * C)].set(
        sort_idx.astype(jnp.int32), mode="drop")
    # token id for each slot (k slots per token, row-major reshape)
    token_of_cell = jnp.minimum(binv // K, S - 1)
    cell_valid = binv < nslot                               # [B, E*C]

    xin = jnp.take_along_axis(x, token_of_cell[..., None], axis=1)   # [B,E*C,D]
    xin = jnp.where(cell_valid[..., None], xin, 0).reshape(B, E, C, D)
    if rules.moe == "ep":
        xin = rules.wsc(xin, rules.batch_nomodel, rules.wmodel, None, None)

    # --- batched expert SwiGLU ----------------------------------------- #
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, p["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", xin, p["w_up"])
    if rules.moe == "tp" and rules.model is not None:
        h = rules.wsc(h, rules.batch, None, None, rules.model)
    out = jnp.einsum("becf,efd->becd", h, p["w_down"]).reshape(B, E * C, D)
    if rules.moe == "ep":
        out = rules.wsc(out.reshape(B, E, C, D),
                        rules.batch_nomodel, rules.wmodel,
                        None, None).reshape(B, E * C, D)

    # --- combine: slot -> token ----------------------------------------- #
    val_sorted = jnp.take_along_axis(out, dest[..., None], axis=1)   # [B,nslot,D]
    val_sorted = jnp.where(keep[..., None], val_sorted, 0)
    unsort = jnp.argsort(sort_idx, axis=1)                  # inverse permutation
    val = jnp.take_along_axis(val_sorted, unsort[..., None], axis=1)
    val = val.reshape(B, S, K, D) * slot_g.reshape(B, S, K)[..., None].astype(val.dtype)
    y = jnp.sum(val, axis=2)
    return rules.act_btd(y.astype(x.dtype))
