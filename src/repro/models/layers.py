"""Core transformer layers: norms, RoPE, GQA attention (TP-planned), SwiGLU.

Attention supports two sharding plans chosen by ``ShardingRules``:

- ``tp``  : heads sharded over ``model``; KV heads physically duplicated
            ``kv_dup``x at compute time (weights stay logical) and Q heads
            activation-padded to a multiple of the TP degree. Zero
            attention-internal collectives (Megatron pattern).
- ``seq`` : weights replicated over ``model``; the sequence dim of the
            attention activations is sharded over ``model`` instead
            (for archs whose head counts don't divide the TP degree).

All score computation is query-chunked (block-causal) so that 32k-token
prefill never materializes an SxS score tensor, and sliding-window archs
only compute the banded blocks. Chunking is a python-level unrolled loop:
no ``lax.scan``, so ``cost_analysis`` sees every FLOP (docs/design.md §7).
"""
from __future__ import annotations

import math
import zlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig

Q_CHUNK = 4096          # query block size for chunked attention


# --------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------- #
def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def subkey(key, *path):
    # crc32, not builtin hash(): str hashes are salted per process, which
    # would make init streams irreproducible across runs (design.md §9)
    for p in path:
        d = p if isinstance(p, int) \
            else zlib.crc32(str(p).encode()) % (2**31)
        key = jax.random.fold_in(key, d)
    return key


# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #
def rms_norm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def group_norm_heads(x, scale, eps=1e-5):
    """Per-head group norm over the last dim; x: [..., H, Dh]."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------- #
def rope(x, positions, theta):
    """x: [B, S, H, Dh], positions: [B, S] (int32)."""
    if theta <= 0:
        return x
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs            # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------- #
def init_attention(key, cfg: ModelConfig, dtype):
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(subkey(key, "wq"), (d, H, Dh), dtype),
        "wk": dense_init(subkey(key, "wk"), (d, KV, Dh), dtype),
        "wv": dense_init(subkey(key, "wv"), (d, KV, Dh), dtype),
        "wo": dense_init(subkey(key, "wo"), (H, Dh, d), dtype, fan_in=H * Dh),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), dtype)
        p["k_norm"] = jnp.ones((Dh,), dtype)
    return p


def _grouped(q, kv_dup, q_pad):
    """[B,S,H,Dh] -> [B,S,KVd,G,Dh] with activation-level Q padding."""
    B, S, H, Dh = q.shape
    if q_pad:
        q = jnp.concatenate(
            [q, jnp.zeros((B, S, q_pad, Dh), q.dtype)], axis=2)
        H += q_pad
    return q, H


def _attend_block(q, k, v, mask, scale):
    """q: [B,Sq,KVd,G,Dh], k/v: [B,T,KVd,Dh], mask: [B or 1, Sq, T]."""
    scores = jnp.einsum("bskgh,btkh->bksgt", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[:, None, :, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bksgt,btkh->bskgh", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def attention(p, x, cfg: ModelConfig, rules, positions,
              *, causal=True, window=0,
              cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              cache_len=None, write_cache=False,
              kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              paged: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None):
    """Returns (y, new_cache_or_None).

    cache: (k_cache, v_cache) each [B, T, KVd, Dh] (already kv-duplicated).
    kv_override: precomputed (k, v) for cross-attention (encoder outputs).
    paged: (page_table [B, P], seq_lens [B]) — decode against a paged KV
      pool; ``cache`` then holds (k_pool, v_pool) [N_pages, ps, KVd, Dh]
      shared by all sequences, and per-row positions come from seq_lens.
    """
    B, S, d = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    plan = rules.attn
    scale = 1.0 / math.sqrt(Dh)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    else:
        k, v = kv_override

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if kv_override is None:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if cfg.rope_theta > 0 and kv_override is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    # duplicate KV heads for the tp plan
    dup = plan.kv_dup if plan.kind == "tp" else 1
    if kv_override is None and dup > 1:
        k = jnp.repeat(k, dup, axis=2)
        v = jnp.repeat(v, dup, axis=2)
    KVd = KV * dup if kv_override is None else k.shape[2]

    q_pad = plan.q_pad if plan.kind == "tp" else 0
    q, Hp = _grouped(q, dup, q_pad)
    G = Hp // KVd
    q = q.reshape(B, S, KVd, G, Dh)
    q = rules.act_heads(q.reshape(B, S, KVd, G * Dh)).reshape(B, S, KVd, G, Dh) \
        if plan.kind == "tp" else q

    new_cache = None
    if cache is not None and paged is not None:
        from ..kernels import ops
        page_table, seq_lens = paged
        k_pool, v_pool = cache
        pos = seq_lens.astype(jnp.int32)                      # [B]
        # the token's K/V write is fused into the megastep (inactive rows
        # — seq_len 0, table all-null — land in the reserved null page,
        # which is never attended), so no pool-wide scatter happens here.
        y, k_pool, v_pool = ops.paged_attention_step(
            q[:, 0], k[:, 0], v[:, 0], k_pool, v_pool, page_table, pos,
            scale=scale, window=window)
        y = y[:, None]
        new_cache = (k_pool, v_pool)
    elif cache is not None:
        k_cache, v_cache = cache
        T = k_cache.shape[1]
        if window > 0:
            pos_w = jnp.mod(cache_len, T)
            k_cache = _ring_write(k_cache, k, pos_w)
            v_cache = _ring_write(v_cache, v, pos_w)
        else:
            k_cache = jax.lax.dynamic_update_slice(
                k_cache, k.astype(k_cache.dtype), (0, cache_len, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                v_cache, v.astype(v_cache.dtype), (0, cache_len, 0, 0))
        new_cache = (k_cache, v_cache)
        k_full, v_full = k_cache, v_cache
        t_pos = jnp.arange(T, dtype=jnp.int32)
        if window > 0:
            # ring buffer: slot t holds absolute position cache_len - ((pos_w - t) mod T)
            rel = jnp.mod(pos_w - t_pos, T)
            abs_pos = cache_len - rel
            valid = (abs_pos >= 0) & (abs_pos <= cache_len) \
                & (abs_pos > cache_len - window)
        else:
            valid = t_pos <= cache_len
        mask = jnp.broadcast_to(valid[None, None, :], (B, S, T))
        y = _attend_block(q, k_full, v_full, mask, scale)
    elif write_cache:
        # prefill: attend over self (chunked) and return the cache
        y = _chunked_self_attention(q, k, v, positions, causal, window, scale, rules)
        new_cache = (k, v)
    else:
        y = _chunked_self_attention(q, k, v, positions, causal, window, scale, rules)

    y = y.reshape(B, S, Hp, Dh)
    if q_pad:
        y = y[:, :, :H, :]
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"])
    return rules.act_btd(out), new_cache


def _ring_write(cache, x, pos):
    """Write x (S=1 decode step) at ring position pos."""
    return jax.lax.dynamic_update_slice(cache, x.astype(cache.dtype),
                                        (0, pos, 0, 0))


def _chunked_self_attention(q, k, v, positions, causal, window, scale, rules):
    """Block-causal (optionally banded/SWA) attention, query-chunked.

    q: [B,S,KVd,G,Dh]; k,v: [B,S,KVd,Dh]. Python-unrolled chunk loop.
    """
    B, S, KVd, G, Dh = q.shape
    nq = max(1, S // Q_CHUNK)
    cq = S // nq
    if rules.attn.kind == "seq" and rules.model is not None:
        # sequence-sharded attention: constrain the seq dim over `model`
        q = rules.wsc(q, rules.batch, rules.model, None, None, None)
    outs = []
    for i in range(nq):
        q_i = jax.lax.slice_in_dim(q, i * cq, (i + 1) * cq, axis=1)
        q_pos = positions[:, i * cq:(i + 1) * cq]
        T = k.shape[1]
        if causal:
            kv_hi = min((i + 1) * cq, T)
            # lowest kv position any query in this chunk can see, chunk-aligned
            kv_lo = max(0, ((i * cq - window + 1) // cq) * cq) if window > 0 else 0
        else:
            kv_lo, kv_hi = 0, T          # cross-attention: full kv length
        k_i = jax.lax.slice_in_dim(k, kv_lo, kv_hi, axis=1)
        v_i = jax.lax.slice_in_dim(v, kv_lo, kv_hi, axis=1)
        if causal:
            t_pos = positions[:, kv_lo:kv_hi]
            mask = t_pos[:, None, :] <= q_pos[:, :, None]
            if window > 0:
                mask &= t_pos[:, None, :] > q_pos[:, :, None] - window
        else:
            mask = jnp.ones((B, cq, kv_hi - kv_lo), bool)
        outs.append(_attend_block(q_i, k_i, v_i, mask, scale))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


# --------------------------------------------------------------------- #
# MLP (SwiGLU)
# --------------------------------------------------------------------- #
def init_mlp(key, d, ff, dtype):
    return {
        "w_gate": dense_init(subkey(key, "wg"), (d, ff), dtype),
        "w_up": dense_init(subkey(key, "wu"), (d, ff), dtype),
        "w_down": dense_init(subkey(key, "wd"), (ff, d), dtype, fan_in=ff),
    }


def mlp(p, x, rules):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if rules.model is not None:
        h = rules.wsc(h, rules.batch, None, rules.model)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return rules.act_btd(out)
