"""RWKV6 (Finch) and Mamba blocks in parallel chunked form.

TPU adaptation (docs/design.md §7): recurrences are evaluated chunk-parallel —
intra-chunk terms as batched matmuls / cumsums, inter-chunk state carried by
``jax.lax.associative_scan`` over chunk boundaries. No ``lax.scan`` over
time: every FLOP is visible to ``cost_analysis`` and the work is MXU/VPU
dense instead of latency-bound sequential steps.

Numerical containment: per-step log-decays are clamped to ``>= -DECAY_CLAMP``
and chunks kept short (``CHUNK``) so the factored intra-chunk rescaling
``exp(lc_i - lc_j)`` stays within fp32 range (bound: e^(CHUNK*DECAY_CLAMP)).
Production kernels (FLA, Mamba CUDA) apply the same style of per-block
rescaling; we document the clamp as a framework constant.

Decode (S=1) uses the exact O(1) recurrence step — no chunking.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init, subkey, rms_norm, group_norm_heads

CHUNK = 16
DECAY_CLAMP = 4.0        # per-step |log decay| bound
SEGMENT = 1024           # unrolled outer segmenting for mamba memory control


def _chunk_scan_combine(a, b):
    """Linear-recurrence combine for associative_scan: s' = a2*s + b2."""
    a1, b1 = a
    a2, b2 = b
    return a1 * a2, b1 * a2 + b2


# ===================================================================== #
# RWKV6 (Finch)
# ===================================================================== #
def init_rwkv_block(key, cfg: ModelConfig, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    H = d // cfg.rwkv_head_dim
    Dh = cfg.rwkv_head_dim
    lora = 32
    p = {
        "ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype),
        # time-mix (ddlerp): base mus + low-rank data-dependent deltas
        "maa_x": jnp.zeros((d,), dtype),
        "maa_base": jnp.zeros((5, d), dtype),               # r,k,v,w,g
        "maa_w1": dense_init(subkey(key, "mw1"), (d, 5 * lora), dtype),
        "maa_w2": dense_init(subkey(key, "mw2"), (5, lora, d), dtype, fan_in=lora),
        "w_r": dense_init(subkey(key, "wr"), (d, d), dtype),
        "w_k": dense_init(subkey(key, "wk"), (d, d), dtype),
        "w_v": dense_init(subkey(key, "wv"), (d, d), dtype),
        "w_g": dense_init(subkey(key, "wg"), (d, d), dtype),
        "w_o": dense_init(subkey(key, "wo"), (d, d), dtype),
        # data-dependent decay: base + low-rank
        "decay_base": jnp.full((d,), -1.0, dtype),
        "decay_w1": dense_init(subkey(key, "dw1"), (d, 64), dtype),
        "decay_w2": dense_init(subkey(key, "dw2"), (64, d), dtype, fan_in=64),
        "bonus": dense_init(subkey(key, "bonus"), (H, Dh), dtype),  # u
        "gn_scale": jnp.ones((H, Dh), dtype),
        # channel-mix
        "cm_mu_k": jnp.zeros((d,), dtype), "cm_mu_r": jnp.zeros((d,), dtype),
        "cm_k": dense_init(subkey(key, "cmk"), (d, ff), dtype),
        "cm_v": dense_init(subkey(key, "cmv"), (ff, d), dtype, fan_in=ff),
        "cm_r": dense_init(subkey(key, "cmr"), (d, d), dtype),
    }
    return p


def _token_shift(x, last: Optional[jnp.ndarray]):
    """Shift sequence right by one; `last` [B,1,D] is the previous token
    (decode carry), zeros at t=0 for training."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def rwkv_time_mix(p, x, cfg: ModelConfig, rules, state):
    """x: [B,S,D]. state: dict(shift [B,1,D], wkv [B,H,Dk,Dv]) or None."""
    B, S, D = x.shape
    Dh = cfg.rwkv_head_dim
    H = D // Dh
    shift_in = state["tm_shift"] if state is not None else None
    xprev = _token_shift(x, shift_in)
    xx = xprev - x
    # ddlerp -- computed per projection to avoid a [B,S,5,D] residency
    xxx = x + xx * p["maa_x"]
    mk = jnp.tanh(jnp.einsum("bsd,dl->bsl", xxx, p["maa_w1"]))
    mk = mk.reshape(B, S, 5, -1)

    def lerped(i):
        mu = p["maa_base"][i] + jnp.einsum("bsl,ld->bsd", mk[:, :, i],
                                           p["maa_w2"][i])
        return x + xx * mu

    xr, xk, xv, xw, xg = (lerped(i) for i in range(5))

    r = jnp.einsum("bsd,de->bse", xr, p["w_r"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"]).reshape(B, S, H, Dh)
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"]).reshape(B, S, H, Dh)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"]))

    decay_logit = p["decay_base"] + jnp.einsum(
        "bsd,de->bse", jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["decay_w1"])),
        p["decay_w2"])
    # log w_t in [-DECAY_CLAMP, -eps] (clamped data-dependent decay)
    logw = -jnp.clip(jnp.exp(decay_logit.astype(jnp.float32)),
                     1e-4, DECAY_CLAMP).reshape(B, S, H, Dh)
    u = p["bonus"].astype(jnp.float32)

    if S == 1 and state is not None:
        # exact decode step
        wkv = state["wkv"]                                   # [B,H,Dk,Dv] fp32
        r1, k1, v1 = (t.reshape(B, H, Dh).astype(jnp.float32) for t in (r, k, v))
        cur = wkv + (u[None] * k1)[..., None] * v1[:, :, None, :]
        o = jnp.einsum("bhk,bhkv->bhv", r1, cur)
        new_wkv = jnp.exp(logw.reshape(B, H, Dh))[..., None] * wkv \
            + k1[..., None] * v1[:, :, None, :]
        out = o.reshape(B, 1, H, Dh)
        new_state = {"tm_shift": x, "wkv": new_wkv}
    else:
        out, last_wkv = _wkv_chunked(
            r, k, v, logw, u,
            init=state["wkv"] if state is not None else None)
        new_state = {"tm_shift": x[:, -1:], "wkv": last_wkv}

    out = group_norm_heads(out.astype(x.dtype), p["gn_scale"], cfg.norm_eps)
    out = out.reshape(B, S, D) * g
    return jnp.einsum("bsd,de->bse", out, p["w_o"]), new_state


def _wkv_chunked(r, k, v, logw, u, init=None):
    """Chunked WKV6: r,k,v [B,S,H,Dh]; logw [B,S,H,Dh] (<=0); u [H,Dh].

    Returns (out [B,S,H,Dh], final_state [B,H,Dk,Dv] fp32).
    """
    B, S, H, Dh = r.shape
    c = min(CHUNK, S)
    S0 = S
    if S % c:
        # pad to a chunk multiple: k=v=0 contributes nothing, logw=0 keeps
        # the state (decay 1) — exact
        pad = c - S % c
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))  # noqa: E731
        r, k, v = zpad(r), zpad(k), zpad(v)
        logw = zpad(logw)
        S = S + pad
    N = S // c
    f32 = jnp.float32
    rc = r.astype(f32).reshape(B, N, c, H, Dh)
    kc = k.astype(f32).reshape(B, N, c, H, Dh)
    vc = v.astype(f32).reshape(B, N, c, H, Dh)
    lw = logw.reshape(B, N, c, H, Dh)

    lc = jnp.cumsum(lw, axis=2)                              # inclusive cumsum
    lc_prev = lc - lw                                        # exclusive
    total = lc[:, :, -1]                                     # [B,N,H,Dh]

    # intra-chunk: scores[i,j] = sum_d r_i k_j exp(lc_prev_i - lc_j)  (j<i)
    q_s = rc * jnp.exp(lc_prev)
    k_s = kc * jnp.exp(-lc)
    scores = jnp.einsum("bnihd,bnjhd->bnhij", q_s, k_s)
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    # bonus diagonal (j == i): r_i (u*k_i) v_i
    diag = jnp.einsum("bnihd,bnihd->bnhi", rc, kc * u[None, None, None])
    out = jnp.einsum("bnhij,bnjhd->bnihd", scores, vc)
    out = out + diag[..., None].transpose(0, 1, 3, 2, 4) * vc

    # chunk states: S_n = exp(total_n) (.) S_{n-1} + sum_j exp(total - lc_j) k_j v_j^T
    contrib = jnp.einsum("bnjhk,bnjhv->bnhkv", kc * jnp.exp(total[:, :, None] - lc), vc)
    decay = jnp.exp(total)[..., None]                        # [B,N,H,Dk,1]
    a_seq = jnp.moveaxis(decay, 1, 0)                        # [N,B,H,Dk,1]
    b_seq = jnp.moveaxis(contrib, 1, 0)                      # [N,B,H,Dk,Dv]
    if init is not None:
        a_seq = jnp.concatenate([jnp.ones_like(a_seq[:1]), a_seq], axis=0)
        b_seq = jnp.concatenate([init[None].astype(f32), b_seq], axis=0)
    acc_a, acc_b = jax.lax.associative_scan(_chunk_scan_combine, (a_seq, b_seq))
    if init is not None:
        states_end = acc_b                                   # [N+1,B,H,Dk,Dv]
        start_states = states_end[:-1]
        final = states_end[-1]
    else:
        states_end = acc_b
        start_states = jnp.concatenate(
            [jnp.zeros_like(acc_b[:1]), acc_b[:-1]], axis=0)
        final = states_end[-1]
    start_states = jnp.moveaxis(start_states, 0, 1)          # [B,N,H,Dk,Dv]

    # inter-chunk: o_i += (r_i * exp(lc_prev_i))^T S_start
    out = out + jnp.einsum("bnihk,bnhkv->bnihv", q_s, start_states)
    return out.reshape(B, S, H, Dh)[:, :S0], final


def rwkv_channel_mix(p, x, rules, state):
    shift_in = state["cm_shift"] if state is not None else None
    xprev = _token_shift(x, shift_in)
    xx = xprev - x
    xk = x + xx * p["cm_mu_k"]
    xr = x + xx * p["cm_mu_r"]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["cm_k"])))
    if rules.model is not None:
        k = rules.wsc(k, rules.batch, None, rules.model)
    v = jnp.einsum("bsf,fd->bsd", k, p["cm_v"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cm_r"]))
    return r * v, {"cm_shift": x[:, -1:]}


def rwkv_block(p, x, cfg: ModelConfig, rules, state):
    """Full RWKV6 block. state: None (train/prefill from zeros) or dict."""
    h, tm_state = rwkv_time_mix(p, rms_norm(x, p["ln1"], cfg.norm_eps),
                                cfg, rules, state)
    x = x + h
    h, cm_state = rwkv_channel_mix(p, rms_norm(x, p["ln2"], cfg.norm_eps),
                                   rules, state)
    x = x + h
    new_state = {**tm_state, **cm_state}
    return rules.act_btd(x), new_state


def init_rwkv_state(cfg: ModelConfig, B: int, dtype):
    d = cfg.d_model
    H = d // cfg.rwkv_head_dim
    Dh = cfg.rwkv_head_dim
    return {
        "tm_shift": jnp.zeros((B, 1, d), dtype),
        "cm_shift": jnp.zeros((B, 1, d), dtype),
        "wkv": jnp.zeros((B, H, Dh, Dh), jnp.float32),
    }


# ===================================================================== #
# Mamba (for Jamba)
# ===================================================================== #
def init_mamba_block(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state_dim
    dt_rank = max(d // 16, 1)
    return {
        "norm": jnp.ones((d,), dtype),
        "in_proj": dense_init(subkey(key, "in"), (d, 2 * di), dtype),
        "conv_w": dense_init(subkey(key, "conv"), (cfg.ssm_conv_width, di), dtype,
                             fan_in=cfg.ssm_conv_width),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(subkey(key, "xp"), (di, dt_rank + 2 * N), dtype),
        "dt_proj": dense_init(subkey(key, "dtp"), (dt_rank, di), dtype, fan_in=dt_rank),
        "dt_bias": jnp.full((di,), -4.6, dtype),             # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N)).copy()).astype(dtype),
        "D_skip": jnp.ones((di,), dtype),
        "out_proj": dense_init(subkey(key, "out"), (di, d), dtype, fan_in=di),
        # Jamba adds RMS norms on dt, B, C
        "dt_norm": jnp.ones((dt_rank,), dtype),
        "B_norm": jnp.ones((N,), dtype),
        "C_norm": jnp.ones((N,), dtype),
    }


def _causal_conv(x, w, b, carry):
    """Depthwise causal conv; x [B,S,di], w [W,di]. carry [B,W-1,di] or None."""
    W = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_carry = xp[:, -(W - 1):] if W > 1 else carry
    return out + b, new_carry


def mamba_block(p, x, cfg: ModelConfig, rules, state):
    """x: [B,S,D]; state: None or dict(conv [B,W-1,di], ssm [B,di,N] fp32)."""
    B, S, D = x.shape
    N = cfg.ssm_state_dim
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    if rules.model is not None:
        xs = rules.wsc(xs, rules.batch, None, rules.model)
    conv_carry = state["conv"] if state is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_carry)
    xs = jax.nn.silu(xs)

    dbc = jnp.einsum("bse,ez->bsz", xs, p["x_proj"])
    dt_rank = p["dt_proj"].shape[0]
    dt_low, Bc, Cc = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt_low = rms_norm(dt_low, p["dt_norm"], cfg.norm_eps)
    Bc = rms_norm(Bc, p["B_norm"], cfg.norm_eps)
    Cc = rms_norm(Cc, p["C_norm"], cfg.norm_eps)
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt_low, p["dt_proj"])
                         + p["dt_bias"].astype(jnp.float32))  # [B,S,di] fp32
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # [di,N]
    xdt = (xs.astype(jnp.float32) * dt)                      # [B,S,di]

    if S == 1 and state is not None:
        ssm = state["ssm"]                                   # [B,di,N] fp32
        la = dt[:, 0, :, None] * A[None]                     # [B,di,N]
        ssm_new = jnp.exp(la) * ssm + xdt[:, 0, :, None] * Bc[:, 0, None, :]
        y = jnp.einsum("bdn,bn->bd", ssm_new, Cc[:, 0].astype(jnp.float32))
        y = y[:, None] + p["D_skip"].astype(jnp.float32) * xs.astype(jnp.float32)
        final_ssm = ssm_new
    else:
        y, final_ssm = _mamba_chunked(
            xdt, dt, A, Bc.astype(jnp.float32), Cc.astype(jnp.float32),
            init=state["ssm"] if state is not None else None)
        y = y + p["D_skip"].astype(jnp.float32) * xs.astype(jnp.float32)

    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_state = {"conv": new_conv, "ssm": final_ssm}
    return rules.act_btd(x + out), new_state


def _mamba_chunked(xdt, dt, A, Bc, Cc, init=None):
    """Chunk-parallel selective-SSM scan.

    xdt, dt: [B,S,di] fp32;  A: [di,N];  Bc, Cc: [B,S,N] fp32.
    Recurrence: h_t = exp(dt_t A) (.) h_{t-1} + xdt_t (x) B_t ;  y_t = h_t . C_t
    Outer unrolled segments of SEGMENT tokens bound the [B,seg,di,N]
    intermediates; inner chunks of CHUNK combine through associative_scan.
    """
    B, S, di = xdt.shape
    N = A.shape[1]
    seg = min(SEGMENT, S)
    carry = init if init is not None else jnp.zeros((B, di, N), jnp.float32)
    ys = []
    for s0 in range(0, S, seg):
        y_seg, carry = _mamba_segment(
            xdt[:, s0:s0 + seg], dt[:, s0:s0 + seg], A,
            Bc[:, s0:s0 + seg], Cc[:, s0:s0 + seg], carry)
        ys.append(y_seg)
    y = jnp.concatenate(ys, axis=1) if len(ys) > 1 else ys[0]
    return y, carry


def _mamba_segment(xdt, dt, A, Bc, Cc, carry):
    B, S, di = xdt.shape
    N = A.shape[1]
    c = min(CHUNK, S)
    S0 = S
    if S % c:
        pad = c - S % c
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))  # noqa: E731
        xdt, dt, Bc, Cc = zp(xdt), zp(dt), zp(Bc), zp(Cc)
        S = S + pad        # dt=0 -> decay exp(0)=1, contribution 0: exact
    NC = S // c
    # per-step log decay, clamped (docs/design.md §7)
    la = jnp.maximum(dt[..., None] * A[None, None], -DECAY_CLAMP)  # [B,S,di,N]
    la = la.reshape(B, NC, c, di, N)
    lc = jnp.cumsum(la, axis=2)                              # inclusive
    total = lc[:, :, -1]                                     # [B,NC,di,N]

    xc = xdt.reshape(B, NC, c, di)
    bc = Bc.reshape(B, NC, c, N)
    cc = Cc.reshape(B, NC, c, N)

    # intra-chunk: Z[l] = cumsum_j<=l  (x_j B_j) * exp(-lc_j)
    contrib = xc[..., None] * bc[:, :, :, None, :] * jnp.exp(-lc)
    Z = jnp.cumsum(contrib, axis=2)                          # [B,NC,c,di,N]
    y_intra = jnp.sum(jnp.exp(lc) * Z * cc[:, :, :, None, :], axis=-1)

    # chunk boundary states
    chunk_contrib = jnp.sum(
        xc[..., None] * bc[:, :, :, None, :] * jnp.exp(total[:, :, None] - lc),
        axis=2)                                              # [B,NC,di,N]
    a_seq = jnp.moveaxis(jnp.exp(total), 1, 0)               # [NC,B,di,N]
    b_seq = jnp.moveaxis(chunk_contrib, 1, 0)
    a_seq = jnp.concatenate([jnp.ones_like(a_seq[:1]), a_seq], axis=0)
    b_seq = jnp.concatenate([carry[None], b_seq], axis=0)
    _, states = jax.lax.associative_scan(_chunk_scan_combine, (a_seq, b_seq))
    start = jnp.moveaxis(states[:-1], 0, 1)                  # [B,NC,di,N]
    final = states[-1]

    # inter-chunk: y_l += C_l . (exp(lc_l) (.) h_start)
    y_inter = jnp.sum(jnp.exp(lc) * start[:, :, None] * cc[:, :, :, None, :],
                      axis=-1)
    y = (y_intra + y_inter).reshape(B, S, di)[:, :S0]
    return y, final


def init_mamba_state(cfg: ModelConfig, B: int, dtype):
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((B, cfg.ssm_conv_width - 1, di), dtype),
        "ssm": jnp.zeros((B, di, cfg.ssm_state_dim), jnp.float32),
    }
