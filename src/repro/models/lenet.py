"""LeNet-5 exactly as the paper uses it (Fig. 1 top): same-padding convs,
2x2 max-pools, 784->120->84->10 FC head. 107,786 fp32 parameters — matching
the paper's ZO/BP split accounting (ZO-Feat-Cls1 trains 106,936, Cls2
96,772). INT8 variant follows NITI (no biases).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..configs.paper_models import LeNet5Config
from ..core.int8 import (QTensor, qconv2d, qdense, qmaxpool2, qrelu,
                         quant_from_float)
from .layers import dense_init, subkey

LAYER_NAMES = ("conv1", "conv2", "fc1", "fc2", "fc3")


def init_lenet5(key, cfg: LeNet5Config = LeNet5Config(), dtype=jnp.float32):
    c1, c2 = cfg.conv_channels
    k = cfg.kernel
    flat = (cfg.in_shape[0] // 4) * (cfg.in_shape[1] // 4) * c2   # 7*7*16
    f1, f2, nc = cfg.fc_dims
    return {
        "conv1": {"w": dense_init(subkey(key, "c1"), (k, k, cfg.in_shape[2], c1),
                                  dtype, fan_in=k * k * cfg.in_shape[2]),
                  "b": jnp.zeros((c1,), dtype)},
        "conv2": {"w": dense_init(subkey(key, "c2"), (k, k, c1, c2), dtype,
                                  fan_in=k * k * c1),
                  "b": jnp.zeros((c2,), dtype)},
        "fc1": {"w": dense_init(subkey(key, "f1"), (flat, f1), dtype),
                "b": jnp.zeros((f1,), dtype)},
        "fc2": {"w": dense_init(subkey(key, "f2"), (f1, f2), dtype),
                "b": jnp.zeros((f2,), dtype)},
        "fc3": {"w": dense_init(subkey(key, "f3"), (f2, nc), dtype),
                "b": jnp.zeros((nc,), dtype)},
    }


def _conv_same(x, w, b):
    k = w.shape[0]
    pad = k // 2
    y = jax.lax.conv_general_dilated(
        x, w, (1, 1), [(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def lenet5_forward(params, x):
    """x: [B,28,28,1] fp32 -> logits [B,10]; returns (logits, acts)."""
    acts = {}
    h = jax.nn.relu(_conv_same(x, params["conv1"]["w"], params["conv1"]["b"]))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                              (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    h = jax.nn.relu(_conv_same(h, params["conv2"]["w"], params["conv2"]["b"]))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                              (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    acts["fc1_in"] = h
    h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    acts["fc2_in"] = h
    h = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
    acts["fc3_in"] = h
    logits = h @ params["fc3"]["w"] + params["fc3"]["b"]
    return logits, acts


def lenet5_loss(params, batch):
    logits, _ = lenet5_forward(params, batch["x"])
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - ll)


def partition_at(params: Dict, c: int):
    """Paper partition point: first c layers ZO, rest BP."""
    zo = {n: params[n] for n in LAYER_NAMES[:c]}
    bp = {n: params[n] for n in LAYER_NAMES[c:]}
    return zo, bp


# ------------------------------------------------------------------ #
# INT8 (NITI) variant — no biases, QTensor weights
# ------------------------------------------------------------------ #
def init_lenet5_int8(key, cfg: LeNet5Config = LeNet5Config()):
    fp = init_lenet5(key, cfg)
    return {n: {"w": quant_from_float(fp[n]["w"], bits=6)} for n in LAYER_NAMES}


def lenet5_forward_int8(params, x: QTensor):
    """x: QTensor [B,28,28,1] -> (logits QTensor [B,10], acts)."""
    acts = {}
    h = qrelu(qconv2d_same(x, params["conv1"]["w"]))
    h = qmaxpool2(h)
    h = qrelu(qconv2d_same(h, params["conv2"]["w"]))
    h = qmaxpool2(h)
    h = QTensor(h.data.reshape(h.data.shape[0], -1), h.exp)
    acts["fc1_in"] = h
    h = qrelu(qdense(h, params["fc1"]["w"]))
    acts["fc2_in"] = h
    h = qrelu(qdense(h, params["fc2"]["w"]))
    acts["fc3_in"] = h
    logits = qdense(h, params["fc3"]["w"])
    return logits, acts


def qconv2d_same(x: QTensor, w: QTensor):
    k = w.data.shape[0]
    pad = k // 2
    xd = jnp.pad(x.data, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    return qconv2d(QTensor(xd, x.exp), w)
