"""Gradient compression for the BP tail (beyond-paper, docs/design.md §8).

ElasticZO already reduces the ZO part's gradient traffic to one scalar per
probe; the only tensor collective left in training is the BP-tail gradient
all-reduce. ``int8_compress``/``int8_decompress`` implement per-tensor
scaled int8 quantization with error feedback — the residual is carried in
the caller's state so the quantization error is re-injected next step
(Seide et al. / 1-bit SGD style convergence behaviour).

Under GSPMD the all-reduce itself is implicit; production multi-host use
wraps the tail-grad reduction in shard_map with these around a psum. The
unit tests validate the error-feedback contraction property.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def int8_compress(g: jax.Array, residual: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(g + residual) -> (q int8, scale fp32, new_residual)."""
    x = g.astype(jnp.float32) + residual
    # initial=0 keeps zero-size leaves legal (empty pytree groups)
    scale = jnp.maximum(jnp.max(jnp.abs(x), initial=0.0), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_residual = x - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residuals):
    """Tree-wise error-feedback int8 compression."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    qs, scales, new_rs = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = int8_compress(g, r)
        qs.append(q)
        scales.append(s)
        new_rs.append(nr)
    return (jax.tree_util.tree_unflatten(tdef, qs),
            jax.tree_util.tree_unflatten(tdef, scales),
            jax.tree_util.tree_unflatten(tdef, new_rs))


def decompress_tree(qs, scales):
    return jax.tree.map(int8_decompress, qs, scales)


def compressed_psum(grads, residuals, axis_name: str):
    """shard_map-side helper: quantize -> psum(int32) -> dequantize.

    Protocol: (1) pmax of the local maxima fixes a *shared* scale per
    tensor (one scalar all-reduce), (2) every shard quantizes against it,
    (3) int8 payloads are psum'd in int32 (exact), (4) dequantize + error
    feedback. Wire format ~1 byte/element.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        x = g.astype(jnp.float32) + r
        scale = jax.lax.pmax(
            jnp.maximum(jnp.max(jnp.abs(x), initial=0.0), 1e-30),
            axis_name) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_r = x - q.astype(jnp.float32) * scale
        avg = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32) \
            * scale / n
        return avg, new_r

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    avg = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_res = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return avg, new_res
