"""Sharded, async, elastic-restorable checkpointing.

Layout:  <dir>/step_<N>/
           manifest.json   - step, flat param keys, shapes/dtypes, mesh info
           arrays.npz      - one entry per flattened leaf (host-gathered)
           COMMIT          - written last; a checkpoint without COMMIT is
                             ignored (atomic-commit protocol)

Restore never requires the saving mesh: arrays are saved unsharded
(host-gathered per leaf) and re-sharded on load via ``jax.device_put`` with
the *current* mesh's shardings — this is what makes elastic up/down-scaling
work (tests/test_checkpoint.py saves on a (2,2) mesh and restores on (4,1)).
For multi-host production this maps to per-host shard files + a gather-free
restore path; on this single-host harness the gather is a no-op.

Async: ``save_async`` snapshots to host RAM synchronously (cheap, device ->
pinned host), then writes files on a background thread so the train loop
never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import ml_dtypes
import numpy as np

import jax

# npz cannot store bfloat16: persist as a uint16 view, restore from the
# manifest's logical dtype.
_BF16 = np.dtype(ml_dtypes.bfloat16)


def _to_savable(a: np.ndarray) -> np.ndarray:
    return a.view(np.uint16) if a.dtype == _BF16 else a


def _from_saved(a: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str == "bfloat16":
        return a.view(_BF16)
    return a


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = leaf
    return flat


def save(ckpt_dir: str | Path, step: int, params, extra: Optional[Dict] = None):
    """Synchronous sharded-save with atomic commit."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    tmp = d.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(params)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(tmp / "arrays.npz",
             **{str(i): _to_savable(a) for i, a in enumerate(arrays.values())})
    manifest = {
        "step": int(step),
        "time": time.time(),
        "keys": list(arrays.keys()),
        "shapes": [list(a.shape) for a in arrays.values()],
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMIT").write_text("ok")
    if d.exists():
        shutil.rmtree(d)
    os.rename(tmp, d)
    return d


class AsyncCheckpointer:
    """Snapshot-on-call, write-on-thread. One in-flight save at a time."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, params, extra=None):
        self.wait()
        flat = _flatten(params)
        snapshot = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def _write():
            d = self.dir / f"step_{step:08d}"
            tmp = d.with_suffix(".tmp")
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz",
                     **{str(i): _to_savable(a)
                        for i, a in enumerate(snapshot.values())})
            manifest = {"step": int(step), "time": time.time(),
                        "keys": list(snapshot.keys()),
                        "shapes": [list(a.shape) for a in snapshot.values()],
                        "dtypes": [str(a.dtype) for a in snapshot.values()],
                        "extra": extra or {}}
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            (tmp / "COMMIT").write_text("ok")
            if d.exists():
                shutil.rmtree(d)
            os.rename(tmp, d)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.dir.glob("step_*"))
        for old in steps[:-self.keep]:
            if (old / "COMMIT").exists():
                shutil.rmtree(old, ignore_errors=True)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")
             if (p / "COMMIT").exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, template, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int]:
    """Restore into `template`'s pytree structure; reshard onto `shardings`
    (same structure) if given — the saving mesh is irrelevant."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / "arrays.npz") as z:
        arrays = {k: _from_saved(z[str(i)], manifest["dtypes"][i])
                  for i, k in enumerate(manifest["keys"])}
    flat_template = _flatten(template)
    missing = set(flat_template) - set(arrays)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    flat_shard = _flatten(shardings) if shardings is not None else {}
    leaves = []
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(template)[0]]
    for k in paths:
        a = arrays[k]
        sh = flat_shard.get(k)
        leaves.append(jax.device_put(a, sh) if sh is not None else jax.numpy.asarray(a))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return tree, int(manifest["step"])
