"""Sharded, async, elastic-restorable checkpointing.

Layout:  <dir>/step_<N>/
           manifest.json   - step, flat param keys, shapes/dtypes, mesh info
           arrays.npz      - one entry per flattened leaf (host-gathered)
           COMMIT          - written last; a checkpoint without COMMIT is
                             ignored (atomic-commit protocol)

Delta mode (repro.fleet): ``save_delta`` writes ``ledger.bin`` — a seed-
ledger slice — plus a manifest with ``mode: "delta"`` and ``base_step``
instead of arrays.npz. Restoring a delta checkpoint loads the full
checkpoint at ``base_step`` from the same directory and replays the
slice through a caller-supplied ``replay_fn`` (fleet/replay.make_replay_fn);
for ElasticZO that is KBs of (seed, scalar) records standing in for a
full parameter image.

Restore never requires the saving mesh: arrays are saved unsharded
(host-gathered per leaf) and re-sharded on load via ``jax.device_put`` with
the *current* mesh's shardings — this is what makes elastic up/down-scaling
work (tests/test_checkpoint.py saves on a (2,2) mesh and restores on (4,1)).
For multi-host production this maps to per-host shard files + a gather-free
restore path; on this single-host harness the gather is a no-op.

Async: ``save_async`` snapshots to host RAM synchronously (cheap, device ->
pinned host), then writes files on a background thread so the train loop
never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import ml_dtypes
import numpy as np

import jax

from .. import obs

# npz cannot store bfloat16: persist as a uint16 view, restore from the
# manifest's logical dtype.
_BF16 = np.dtype(ml_dtypes.bfloat16)


def _to_savable(a: np.ndarray) -> np.ndarray:
    return a.view(np.uint16) if a.dtype == _BF16 else a


def _from_saved(a: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str == "bfloat16":
        return a.view(_BF16)
    return a


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = leaf
    return flat


def _atomic_commit(ckpt_dir: str | Path, step: int, manifest: Dict,
                   write_payload) -> Path:
    """The one copy of the tmp-dir / manifest / COMMIT / rename dance.

    write_payload(tmp_path) writes the checkpoint's files; the COMMIT
    marker and the rename to the final name happen last, so readers only
    ever see complete checkpoints (a leftover ``*.tmp`` dir — even one
    containing COMMIT — is ignored by latest_step/_gc)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    tmp = d.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    write_payload(tmp)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMIT").write_text("ok")
    if d.exists():
        shutil.rmtree(d)
    os.rename(tmp, d)
    return d


def _array_manifest(step: int, arrays: Dict[str, np.ndarray],
                    extra: Optional[Dict]) -> Dict:
    return {
        "step": int(step),
        "mode": "full",
        # wall-clock metadata stamp: time.time() is right here (and only
        # here) — durations elsewhere use obs.monotonic
        # reprolint: allow(monotonic-clock) -- wall-clock manifest stamp
        "time": time.time(),
        "keys": list(arrays.keys()),
        "shapes": [list(a.shape) for a in arrays.values()],
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "extra": extra or {},
    }


def _write_arrays(tmp: Path, arrays: Dict[str, np.ndarray]):
    np.savez(tmp / "arrays.npz",
             **{str(i): _to_savable(a) for i, a in enumerate(arrays.values())})


def save(ckpt_dir: str | Path, step: int, params, extra: Optional[Dict] = None):
    """Synchronous sharded-save with atomic commit."""
    flat = _flatten(params)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    return _atomic_commit(ckpt_dir, step, _array_manifest(step, arrays, extra),
                          lambda tmp: _write_arrays(tmp, arrays))


def save_delta(ckpt_dir: str | Path, step: int, base_step: int,
               ledger_bytes: bytes, extra: Optional[Dict] = None):
    """Checkpoint step `step` as (base_step, ledger slice) — no arrays.

    The slice must cover commits [base_step, step) and a committed *full*
    checkpoint must exist at base_step in the same directory (restore
    chains through it; delta-of-delta is deliberately not supported).
    """
    manifest = {"step": int(step), "mode": "delta",
                # reprolint: allow(monotonic-clock) -- wall-clock manifest stamp
                "base_step": int(base_step), "time": time.time(),
                "extra": extra or {}}
    led = obs.get().memory
    if led.armed:
        # cumulative delta write volume — the paper's "a ledger slice IS
        # a checkpoint" claim, in bytes
        led.alloc("ckpt.delta", len(ledger_bytes))
    return _atomic_commit(ckpt_dir, step, manifest,
                          lambda tmp: (tmp / "ledger.bin")
                          .write_bytes(ledger_bytes))


class AsyncCheckpointer:
    """Snapshot-on-call, write-on-thread. One in-flight save at a time."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, params, extra=None):
        self.wait()
        flat = _flatten(params)
        snapshot = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        led = obs.get().memory
        key = ("ckpt.pending", id(self), step)
        if led.armed:
            # the host snapshot is live until the writer thread is done
            led.alloc("ckpt.pending",
                      sum(a.nbytes for a in snapshot.values()), key=key)

        def _write():
            try:
                _atomic_commit(self.dir, step,
                               _array_manifest(step, snapshot, extra),
                               lambda tmp: _write_arrays(tmp, snapshot))
                self._gc()
            finally:
                if led.armed:
                    led.free("ckpt.pending", key=key)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(p for p in self.dir.glob("step_*")
                       if not p.name.endswith(".tmp"))
        for old in steps[:-self.keep]:
            if (old / "COMMIT").exists():
                shutil.rmtree(old, ignore_errors=True)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    # a crash between COMMIT and the rename can leave step_<N>.tmp with a
    # COMMIT marker inside — only renamed (complete) dirs count
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")
             if (p / "COMMIT").exists() and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, template, step: Optional[int] = None,
            shardings=None, replay_fn=None) -> Tuple[Any, int]:
    """Restore into `template`'s pytree structure; reshard onto `shardings`
    (same structure) if given — the saving mesh is irrelevant.

    Delta checkpoints additionally need ``replay_fn(params, ledger_bytes,
    base_step, step) -> params`` (fleet/replay.make_replay_fn): the base
    full checkpoint is restored (and resharded) first, then the ledger
    slice is replayed on top.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    if manifest.get("mode", "full") == "delta":
        if replay_fn is None:
            raise ValueError(
                f"checkpoint at step {step} is a ledger delta (base "
                f"{manifest['base_step']}); pass replay_fn to restore it")
        base_step = int(manifest["base_step"])
        params, _ = restore(ckpt_dir, template, step=base_step,
                            shardings=shardings)
        params = replay_fn(params, (d / "ledger.bin").read_bytes(),
                           base_step, step)
        return params, int(manifest["step"])
    with np.load(d / "arrays.npz") as z:
        arrays = {k: _from_saved(z[str(i)], manifest["dtypes"][i])
                  for i, k in enumerate(manifest["keys"])}
    flat_template = _flatten(template)
    missing = set(flat_template) - set(arrays)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    flat_shard = _flatten(shardings) if shardings is not None else {}
    leaves = []
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(template)[0]]
    for k in paths:
        a = arrays[k]
        sh = flat_shard.get(k)
        leaves.append(jax.device_put(a, sh) if sh is not None else jax.numpy.asarray(a))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return tree, int(manifest["step"])
