"""Elastic scaling runtime: survive mesh-size changes mid-training.

The contract (docs/design.md §8):
  1. training state = (params checkpoint, step);  data state = step;
  2. ZO noise is a pure function of (seed, step, global flat index)
     (core/prng.py), so it is *identical on any mesh*;
  3. checkpoints restore onto whatever mesh currently exists
     (train/checkpoint.py re-shards on load).

``resume_on_mesh`` packages this: given a checkpoint dir and a (possibly
different) mesh, it rebuilds rules/shardings/step-fn and returns a state
that continues bit-exact. The straggler path is orthogonal: probes are
masked per-step (core/elastic.py), no remesh needed for a slow host.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import LaneConfig, ModelConfig, ShapeConfig
from ..core import api
from ..core.elastic import TrainState
from ..sharding.params import param_shardings
from ..sharding.rules import ShardingRules
from . import checkpoint as ckpt


def build_for_mesh(cfg: ModelConfig, shape: ShapeConfig, lane: LaneConfig,
                   mesh, strategy: str = "tp"):
    """(model, param_shardings, jitted step) for the given mesh."""
    rules = ShardingRules(mesh, cfg, shape, strategy=strategy)
    model = api.build(cfg, shape, lane, rules)
    pshard = param_shardings(model.abstract_params(), rules)
    step = jax.jit(model.train_step, donate_argnums=(0,))
    return model, pshard, step


def resume_on_mesh(ckpt_dir, cfg: ModelConfig, shape: ShapeConfig,
                   lane: LaneConfig, mesh, seed: int = 0,
                   strategy: str = "tp") -> Tuple[TrainState, object, object]:
    """Restore the latest checkpoint onto `mesh` (any size/shape).

    Returns (state, model, jitted_step). If no checkpoint exists, fresh
    init on the mesh.
    """
    model, pshard, step = build_for_mesh(cfg, shape, lane, mesh, strategy)
    template = model.abstract_params()
    last = ckpt.latest_step(ckpt_dir) if ckpt_dir else None
    if last is None:
        params = model.init(jax.random.key(seed))
        if mesh is not None:
            params = jax.tree.map(jax.device_put, params, pshard)
        state = TrainState(params, jnp.int32(0),
                           jax.random.key_data(jax.random.key(seed)))
    else:
        params, at_step = ckpt.restore(ckpt_dir, template, shardings=pshard)
        state = TrainState(params, jnp.int32(at_step),
                           jax.random.key_data(jax.random.key(seed)))
    return state, model, step
