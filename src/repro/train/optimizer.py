"""Optimizers + schedules, from scratch (no optax offline).

The paper uses vanilla SGD (no momentum/weight decay) with a 0.8x/10-epoch
decay for FP32 training, Adam for fine-tuning pre-training. All are
provided for the BP-tail/full-BP lanes; ZO updates live in core/zo.py.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, opt_state, step) -> (updates, opt_state); caller applies
    # params - lr(step) * updates? No: lr folded in here. updates are deltas.


def _cast_like(x, ref):
    return x.astype(ref.dtype) if hasattr(ref, "dtype") else x


def sgd(lr: Callable[[jax.Array], jax.Array] | float,
        momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, step):
        eta = lr_fn(step)
        if momentum == 0.0:
            return jax.tree.map(lambda g: eta * g.astype(jnp.float32), grads), ()
        new_m = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            upd = jax.tree.map(
                lambda m, g: eta * (momentum * m + g.astype(jnp.float32)),
                new_m, grads)
        else:
            upd = jax.tree.map(lambda m: eta * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


def adam(lr: Callable[[jax.Array], jax.Array] | float, b1=0.9, b2=0.999,
         eps=1e-8) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, step):
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), v)
        upd = jax.tree.map(
            lambda m_, v_: lr_fn(step) * m_ / (jnp.sqrt(v_) + eps), mh, vh)
        return upd, {"m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) - u).astype(p.dtype),
        params, updates)


# ------------------------------ schedules ---------------------------- #
def step_decay(base: float, factor: float = 0.8, every: int = 10_000):
    """Paper schedule: decay by `factor` every `every` steps (10 epochs)."""
    def f(step):
        k = jnp.floor(step.astype(jnp.float32) / every)
        return jnp.float32(base) * jnp.power(jnp.float32(factor), k)
    return f


def cosine(base: float, total: int, warmup: int = 0, floor: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.float32(base) * jnp.where(warmup > 0, warm, 1.0) * cos
    return f
