"""Training driver: metrics, checkpoint cadence, crash recovery, stragglers.

The loop is deliberately dumb about data: batches are pure functions of the
step index (data/synthetic.py), so the *entire* restart state is the
checkpointed (params, step) — after a crash or an elastic re-mesh, training
resumes bit-exactly (ZO noise included, because core/prng.py noise is
mesh-independent).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from .. import obs
from ..core.elastic import TrainState
from . import checkpoint as ckpt


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    keep: int = 3
    seed: int = 0
    # straggler simulation/mitigation: probability a probe is dropped and
    # masked out instead of waited for (docs/design.md §8)
    probe_drop_rate: float = 0.0
    n_probes: int = 1
    # explicit per-step probe masks (fp32[n_probes]), e.g. the realized
    # commit masks of a fleet run (repro.fleet) replayed through the
    # single-process reference; overrides the rng drop stream.
    mask_fn: Optional[Callable[[int], Any]] = None
    # jit=False runs step_fn as-is: required for host-side composite steps
    # (fleet/reference.py) whose sub-programs are jitted individually and
    # must not be re-fused into one program (FMA contraction would shift
    # the stream by ~1 ulp vs the fleet's update path).
    jit: bool = True

    @classmethod
    def for_lane(cls, lane, **kwargs) -> "LoopConfig":
        """Derive the probe count from the lane instead of hand-syncing.

        The engine-built step asserts its probe_mask shape against the
        lane, so a mismatched manual ``n_probes`` fails loudly at trace
        time; this constructor makes it impossible to mismatch.
        """
        if "n_probes" in kwargs:
            raise ValueError("n_probes is derived from lane.zo_num_probes")
        return cls(n_probes=lane.zo_num_probes, **kwargs)


def init_state(params, seed: int) -> TrainState:
    return TrainState(params, jnp.int32(0),
                      jax.random.key_data(jax.random.key(seed)))


@dataclass
class RunResult:
    """Terminal state of a training run plus the logged (step, loss) curve.

    Unpacks as ``state, history = run(...)`` — ``run`` used to smuggle the
    curve out via a ``run.history`` function attribute, which was both
    thread-hostile and invisible to callers.
    """
    state: TrainState
    history: list

    def __iter__(self):
        return iter((self.state, self.history))


def run(step_fn: Callable, state: TrainState,
        batch_fn: Callable[[int], Dict[str, Any]],
        cfg: LoopConfig,
        param_shardings=None) -> "RunResult":
    """batch_fn(step) -> device-ready batch dict."""
    saver = ckpt.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep) if cfg.ckpt_dir else None
    jstep = jax.jit(step_fn, donate_argnums=(0,)) \
        if cfg.jit and not isinstance(step_fn, jax.stages.Wrapped) else step_fn

    # resume if a committed checkpoint exists
    start = int(state.step)
    if cfg.ckpt_dir:
        last = ckpt.latest_step(cfg.ckpt_dir)
        if last is not None and last > start:
            params, last = ckpt.restore(cfg.ckpt_dir, state.params,
                                        shardings=param_shardings)
            state = TrainState(params, jnp.int32(last), state.seed)
            start = last
            obs.log("train", f"resumed from step {last}", step=last)

    rec = obs.get()
    mem = rec.memory
    if rec.enabled:
        # params are rebound (donation replaces them in place each step,
        # sizes constant); the batch is tracked per step below
        mem.rebind("train.params", obs.memory.tree_nbytes(state.params),
                   key=("train.params", id(cfg)))
    rng = np.random.default_rng(cfg.seed + 17)
    t0 = obs.monotonic()
    history = []
    for step in range(start, cfg.total_steps):
        batch = batch_fn(step)
        if rec.enabled:
            batch_nbytes = mem.alloc("train.batch",
                                     obs.memory.tree_nbytes(batch))
        if cfg.mask_fn is not None:
            mask = np.asarray(cfg.mask_fn(step), np.float32)
        else:
            mask = (rng.uniform(size=cfg.n_probes) >=
                    cfg.probe_drop_rate).astype(np.float32)
            if mask.sum() == 0:
                mask[0] = 1.0      # never drop every probe
        with rec.span("train/step", track="train", step=step) as sp:
            state, metrics = jstep(state, batch, jnp.asarray(mask))
            if rec.enabled:
                jax.block_until_ready(metrics)
        if rec.enabled:
            mem.free("train.batch", batch_nbytes)
            rec.histogram("train.step_ms").observe(sp.dur_ns / 1e6)
            toks = batch.get("tokens")      # absent for vision batches
            ntok = int(np.prod(toks.shape)) if hasattr(toks, "shape") else 0
            if ntok and sp.dur_ns:
                rec.counter("train.tokens").inc(ntok)
                rec.gauge("train.tokens_per_s").set(ntok / (sp.dur_ns / 1e9))
            rec.gauge("train.loss").set(float(metrics["loss"]))
        if cfg.log_every and (step % cfg.log_every == 0
                              or step == cfg.total_steps - 1):
            if rec.enabled:
                obs.memory.sample()   # reconcile tagged vs jax.live_arrays
            loss = float(metrics["loss"])
            history.append((step, loss))
            dt = obs.monotonic() - t0
            obs.log("train",
                    f"step {step:6d} loss {loss:.4f} "
                    f"({dt / max(step - start + 1, 1):.3f}s/step)",
                    step=step, loss=loss)
        if saver and step > start and step % cfg.ckpt_every == 0:
            saver.save(step, state.params, extra={"loss": float(metrics['loss'])})
    if saver:
        saver.save(cfg.total_steps, state.params)
        saver.wait()
    return RunResult(state, history)
